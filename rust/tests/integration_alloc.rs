//! Integration: the static scratchpad planner end to end — every model
//! in the zoo compiles to a `MemoryPlan` that round-trips through the
//! simulator's planned mode with zero capacity/overlap/residency
//! violations, and the planned program still passes IR verification.

use polymem::accel::{simulate, simulate_planned, AccelConfig};
use polymem::ir::verify::{verify_graph, verify_program};
use polymem::ir::Graph;
use polymem::passes::manager::{AllocStage, PassManager};

fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", polymem::models::mlp(8, 784, 256, 10, 3)),
        ("transformer", polymem::models::transformer_block(64, 128, 4, 256)),
        ("resnet18", polymem::models::resnet18(1)),
        ("resnet50", polymem::models::resnet50(1)),
        ("wavenet", polymem::models::parallel_wavenet()),
    ]
}

fn planned_manager(cfg: &AccelConfig) -> PassManager {
    PassManager {
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    }
}

#[test]
fn plans_round_trip_over_zoo() {
    let cfg = AccelConfig::inferentia_like();
    for (name, g) in zoo() {
        let rep = planned_manager(&cfg)
            .run(g)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        verify_graph(&rep.program.graph).unwrap_or_else(|e| panic!("{name}: {e}"));
        verify_program(&rep.program).unwrap_or_else(|e| panic!("{name}: {e}"));
        let plan = rep.plan.as_ref().expect("alloc stage ran");
        polymem::alloc::verify_plan(&rep.program, plan, &cfg)
            .unwrap_or_else(|e| panic!("{name}: plan violation: {e}"));
        let sim = simulate_planned(&rep.program, plan, &cfg, None)
            .unwrap_or_else(|e| panic!("{name}: planned replay rejected: {e}"));
        assert!(sim.seconds > 0.0, "{name}: zero latency");
        assert!(sim.offchip_total() > 0, "{name}: no compulsory traffic");
        assert!(
            sim.peak_scratchpad <= cfg.scratchpad_bytes(),
            "{name}: plan exceeds SRAM: {} > {}",
            sim.peak_scratchpad,
            cfg.scratchpad_bytes()
        );
    }
}

#[test]
fn planned_never_worse_than_dynamic_offchip() {
    // the acceptance relation of the planner, on the two paper models
    let cfg = AccelConfig::inferentia_like();
    for (name, g) in [
        ("resnet50", polymem::models::resnet50(1)),
        ("wavenet", polymem::models::parallel_wavenet()),
    ] {
        let base = PassManager::default().run(g.clone()).unwrap();
        let dynamic = simulate(&base.program, &cfg, None);
        let rep = planned_manager(&cfg).run(g).unwrap();
        let plan = rep.plan.as_ref().unwrap();
        let planned = simulate_planned(&rep.program, plan, &cfg, None).unwrap();
        assert!(
            planned.offchip_total() <= dynamic.offchip_total(),
            "{name}: planned {} > dynamic {}",
            planned.offchip_total(),
            dynamic.offchip_total()
        );
    }
}

#[test]
fn constrained_capacity_still_round_trips() {
    // shrink the banks until spilling is forced; the plan must still
    // verify and replay
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 8; // 1 MiB total
    let rep = planned_manager(&cfg)
        .run(polymem::models::resnet18(1))
        .unwrap();
    verify_program(&rep.program).unwrap();
    let plan = rep.plan.as_ref().unwrap();
    polymem::alloc::verify_plan(&rep.program, plan, &cfg).unwrap();
    let sim = simulate_planned(&rep.program, plan, &cfg, None).unwrap();
    assert!(sim.peak_scratchpad <= cfg.scratchpad_bytes());
}

#[test]
fn scheduling_never_raises_peak_footprint() {
    let cfg = AccelConfig::inferentia_like();
    for (name, g) in zoo() {
        let rep = planned_manager(&cfg).run(g).unwrap();
        let s = rep.plan.as_ref().unwrap().stats;
        assert!(
            s.peak_live_after <= s.peak_live_before,
            "{name}: scheduler raised the peak: {:?}",
            s
        );
    }
}
