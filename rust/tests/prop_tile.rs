//! Property tests for the tiling subsystem.
//!
//! The two invariants everything downstream leans on:
//! 1. **Exact cover** — the tiles of a strip-mined nest partition its
//!    original domain: every point covered exactly once, including
//!    boundary tiles of non-divisible extents (no overlap, no gap).
//! 2. **Budget** — every tile nest the stage emits has a working set
//!    within the double-buffer budget it was sized for.
//!
//! Plus the end-to-end teeth: a deliberately prime-sized conv (nothing
//! divides evenly, every grid edge is a boundary tile) must stay
//! bit-identical through the tiled pipeline.

use polymem::accel::AccelConfig;
use polymem::interp::diff::diff_pipeline;
use polymem::ir::{GraphBuilder, Program};
use polymem::passes::manager::{AllocStage, PassManager, TileStage};
use polymem::tile::{footprint, run_tiling, TileOpts};
use polymem::util::fuzzgraph;
use polymem::util::rng::SplitMix64;

/// Exact cover over random fuzzed graphs: strip-mine every program
/// with a tiny budget, then check per original tensor element that the
/// tile store-images tile the original store-image multiset exactly.
#[test]
fn tiles_cover_every_store_exactly_once() {
    for seed in 0..40u64 {
        let g = fuzzgraph::fuzz_graph(seed.wrapping_mul(0x9e37_79b9).wrapping_add(11));
        let baseline = Program::lower(g.clone());
        let mut tiled = Program::lower(g);
        let cfg = AccelConfig::tiny(1024); // aggressive: tile everything possible
        run_tiling(&mut tiled, &cfg, &TileOpts::default());

        // per tensor, count store writes per linearized element
        let count_writes = |prog: &Program| {
            use std::collections::BTreeMap;
            let mut m: BTreeMap<(u32, i64), usize> = BTreeMap::new();
            for nest in &prog.nests {
                let shape = &prog.graph.tensor(nest.store.tensor).shape;
                let dom = polymem::poly::IterDomain::new(shape);
                for p in nest.domain.points() {
                    let idx = nest.store.map.apply(&p);
                    assert!(
                        dom.contains(&idx),
                        "seed {seed}: store escapes box in {}",
                        nest.name
                    );
                    *m.entry((nest.store.tensor.0, dom.linearize(&idx))).or_insert(0) += 1;
                }
            }
            m
        };
        let want = count_writes(&baseline);
        let got = count_writes(&tiled);
        assert_eq!(want, got, "seed {seed}: store cover changed under tiling");
    }
}

/// Budget: every tile nest emitted under a given chip fits the
/// double-buffer budget (half the scratchpad by default).
#[test]
fn tile_working_sets_fit_the_budget() {
    let mut r = SplitMix64::new(0xB07);
    for _ in 0..30 {
        let seed = r.next_u64();
        let g = fuzzgraph::fuzz_graph_with(seed, &fuzzgraph::FuzzOpts::oversized());
        let mut prog = Program::lower(g);
        let cfg = AccelConfig::tiny(4 * 1024);
        let stats = run_tiling(&mut prog, &cfg, &TileOpts::default());
        let budget = cfg.scratchpad_bytes() / 2;
        for nest in prog.nests.iter().filter(|n| n.tile.is_some()) {
            let ws = footprint::nest_working_set(&prog.graph, nest);
            assert!(
                ws <= budget,
                "seed {seed}: tile nest '{}' working set {ws} > budget {budget} ({stats:?})",
                nest.name
            );
        }
    }
}

/// Non-divisible extents: a conv whose every spatial and channel
/// extent is prime, tiled on a chip that forces small tiles — boundary
/// tiles on every grid edge — must compute bit-identical outputs
/// through the full tiled pipeline (lower → dme → tile → bank → plan).
#[test]
fn prime_sized_conv_is_bit_identical_through_tiled_pipeline() {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[1, 3, 17, 13]);
    let w = b.weight("w", &[7, 3, 3, 3]);
    let c = b.conv2d("c", x, w, 1, 1);
    let n = b.batchnorm("bn", c);
    let r = b.relu("r", n);
    b.mark_output(r);
    let g = b.finish();
    let _ = x;

    let cfg = AccelConfig::tiny(2 * 1024);
    let pm = PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    };
    let rep = diff_pipeline(g, &pm, 0x0917_1e5d).unwrap();
    assert!(rep.stages.iter().any(|s| s == "tile"), "{:?}", rep.stages);
}

/// The grid never leaves a remainder: for random grids and sizes, the
/// per-tile extents sum to the full domain in every dim.
#[test]
fn boundary_extents_sum_to_full_extent() {
    let mut r = SplitMix64::new(42);
    for _ in 0..200 {
        let extent = r.range_i64(1, 50);
        let tile = r.range_i64(1, 50);
        let mut covered = 0i64;
        let mut o = 0i64;
        while o < extent {
            let e = tile.min(extent - o);
            assert!(e >= 1);
            covered += e;
            o += tile;
        }
        assert_eq!(covered, extent, "extent {extent} tile {tile}");
    }
}
