//! Property tests over the coordinator: response integrity, batching
//! accounting, and policy invariants under randomized load patterns.

use polymem::coordinator::batcher::{BatchPolicy, Batcher, Flush};
use polymem::coordinator::{EchoBackend, Server, ServerConfig};
use polymem::util::prop::Prop;
use std::time::{Duration, Instant};

#[test]
fn every_request_answered_correctly() {
    Prop::new("all responses correct under random load", 12).check(|g| {
        let len = g.usize_in(1, 16);
        let max_batch = g.usize_in(1, 16);
        let n = g.usize_in(1, 200);
        let mut be = EchoBackend::new(len, max_batch);
        be.delay = Duration::from_micros(g.u64() % 500);
        let cfg = ServerConfig {
            max_batch,
            max_wait: Duration::from_micros(100 + g.u64() % 2000),
            queue_cap: 1 << 14,
            ..Default::default()
        };
        let srv = Server::start(be, cfg);
        let handles: Vec<_> = (0..n)
            .map(|k| {
                let val = k as f32;
                srv.submit(vec![val; len]).unwrap()
            })
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            let out = h.wait().unwrap();
            assert_eq!(out, vec![2.0 * k as f32; len], "request {k} corrupted");
        }
        let snap = srv.metrics().snapshot();
        assert_eq!(snap.requests as usize, n);
        assert_eq!(snap.errors, 0);
        // batch accounting: batches × max_batch >= requests
        assert!(snap.batches as usize * max_batch >= n);
    });
}

#[test]
fn batcher_never_exceeds_max_batch() {
    Prop::new("batcher take() <= max_batch, conserves requests", 200).check(|g| {
        let max_batch = g.usize_in(1, 32);
        let policy = BatchPolicy::new(max_batch, Duration::from_millis(g.u64() % 50));
        let mut b = Batcher::new(policy);
        let t0 = Instant::now();
        let mut pushed = 0u64;
        let mut taken: Vec<u64> = Vec::new();
        for _ in 0..g.usize_in(1, 100) {
            if g.bool() {
                b.push(t0, pushed);
                pushed += 1;
            } else {
                let ids = b.take(max_batch);
                assert!(ids.len() <= max_batch);
                taken.extend(ids);
            }
            assert_eq!(b.pending() as u64, pushed - taken.len() as u64, "accounting broken");
        }
        // drain
        loop {
            let ids = b.take(max_batch);
            if ids.is_empty() {
                break;
            }
            taken.extend(ids);
        }
        // conservation with identity: every pushed span id comes back
        // exactly once, in FIFO order
        assert_eq!(taken, (0..pushed).collect::<Vec<u64>>(), "ids lost, invented or reordered");
    });
}

#[test]
fn batcher_poll_consistent() {
    Prop::new("poll(): Empty iff pending==0; Now when full", 200).check(|g| {
        let max_batch = g.usize_in(1, 16);
        let wait = Duration::from_millis(1 + g.u64() % 100);
        let mut b = Batcher::new(BatchPolicy::new(max_batch, wait));
        let t0 = Instant::now();
        assert_eq!(b.poll(t0), Flush::Empty);
        let n = g.usize_in(1, 40);
        for k in 0..n {
            b.push(t0, k as u64);
        }
        match b.poll(t0) {
            Flush::Now => assert!(n >= max_batch),
            Flush::Wait(d) => {
                assert!(n < max_batch);
                assert!(d <= wait);
            }
            Flush::Empty => panic!("pending but Empty"),
        }
        // past the deadline it must flush regardless of batch size
        assert_eq!(b.poll(t0 + wait + Duration::from_millis(1)), Flush::Now);
    });
}

#[test]
fn metrics_percentiles_ordered() {
    Prop::new("latency percentiles are monotone", 100).check(|g| {
        let m = polymem::coordinator::Metrics::new();
        for _ in 0..g.usize_in(1, 50) {
            let n = g.usize_in(1, 8);
            let lats: Vec<Duration> = (0..n)
                .map(|_| Duration::from_micros(g.u64() % 10_000))
                .collect();
            m.record_batch(n, &lats);
        }
        let s = m.snapshot();
        assert!(s.p50_latency <= s.p99_latency);
        assert!(s.mean_batch >= 1.0);
        assert!(s.requests >= s.batches);
    });
}
