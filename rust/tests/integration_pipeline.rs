//! Integration: the full pipeline (lower → DME → bank map → splice →
//! simulate) over every model in the zoo, with verification at every
//! boundary and cross-mode sanity relations.

use polymem::accel::{simulate, AccelConfig};
use polymem::ir::verify::{verify_graph, verify_program};
use polymem::ir::Graph;
use polymem::passes::manager::{BankMode, PassManager};

fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", polymem::models::mlp(8, 784, 256, 10, 3)),
        ("transformer", polymem::models::transformer_block(64, 128, 4, 256)),
        ("resnet18", polymem::models::resnet18(1)),
        ("resnet50", polymem::models::resnet50(1)),
        ("wavenet", polymem::models::parallel_wavenet()),
    ]
}

#[test]
fn full_pipeline_over_zoo() {
    let cfg = AccelConfig::inferentia_like();
    for (name, g) in zoo() {
        verify_graph(&g).unwrap();
        let pm = PassManager::default();
        let rep = pm.run(g).unwrap_or_else(|e| panic!("{name}: {e}"));
        verify_program(&rep.program).unwrap();
        let sim = simulate(&rep.program, &cfg, None);
        assert!(sim.seconds > 0.0, "{name}: zero latency");
        assert!(sim.offchip_total() > 0, "{name}: no compulsory traffic?");
        assert!(
            sim.peak_scratchpad <= cfg.scratchpad_bytes(),
            "{name}: scratchpad overflow"
        );
    }
}

#[test]
fn optimization_never_hurts_traffic() {
    // (DME on, global) must beat (DME off, local) on movement for every
    // model with anything to optimize
    let cfg = AccelConfig::inferentia_like();
    for (name, g) in zoo() {
        let best = PassManager::default().run(g.clone()).unwrap();
        let worst = PassManager {
            enable_dme: false,
            bank_mode: BankMode::Local,
            ..Default::default()
        }
        .run(g)
        .unwrap();
        let best_sim = simulate(&best.program, &cfg, None);
        let worst_sim = simulate(&worst.program, &cfg, None);
        assert!(
            best_sim.onchip_movement_total() <= worst_sim.onchip_movement_total(),
            "{name}: optimized on-chip movement worse"
        );
        assert!(
            best_sim.offchip_total() <= worst_sim.offchip_total(),
            "{name}: optimized off-chip worse"
        );
        assert!(
            best_sim.seconds <= worst_sim.seconds * 1.001,
            "{name}: optimized latency worse"
        );
    }
}

#[test]
fn dme_and_bank_compose() {
    // pipeline order matters: DME first shrinks what bank mapping sees.
    // On WaveNet, DME removes the transposes whose placements the bank
    // pass would otherwise have to track.
    let pm = PassManager::default();
    let rep = pm.run(polymem::models::parallel_wavenet()).unwrap();
    let dme = rep.dme.as_ref().unwrap();
    assert_eq!(dme.pairs_eliminated, 123);
    let bank = rep.bank.as_ref().unwrap();
    // conv1d chain is uniform channel-major: global mapping needs no copies
    assert_eq!(bank.stats.copies_inserted, 0, "{:?}", bank.stats);
}

#[test]
fn batch_scales_traffic_monotonically() {
    let cfg = AccelConfig::inferentia_like();
    let mut last = 0;
    for batch in [1i64, 2, 4] {
        let rep = PassManager::default()
            .run(polymem::models::resnet50(batch))
            .unwrap();
        let sim = simulate(&rep.program, &cfg, None);
        assert!(
            sim.offchip_total() > last,
            "off-chip traffic must grow with batch"
        );
        last = sim.offchip_total();
    }
}

#[test]
fn verify_catches_pipeline_corruption() {
    // sanity that verification is actually wired into the pipeline:
    // a corrupted graph must be rejected, not silently compiled.
    let mut g = polymem::models::mlp(2, 8, 8, 2, 1);
    let out = g.outputs()[0];
    g.tensor_mut(out).shape = vec![2, 3]; // corrupt
    assert!(PassManager::default().run(g).is_err());
}
