//! Integration: the batching server end to end, over the echo backend
//! (always) and the PJRT artifact backend (when built).

use polymem::coordinator::{EchoBackend, PjrtBackend, Server, ServerConfig};
use polymem::runtime::RuntimeClient;
use std::path::Path;
use std::time::Duration;

#[test]
fn concurrent_submitters() {
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        queue_cap: 1 << 14,
        ..Default::default()
    };
    let srv = std::sync::Arc::new(Server::start(EchoBackend::new(4, 8), cfg));
    let mut joins = vec![];
    for t in 0..8u32 {
        let srv = srv.clone();
        joins.push(std::thread::spawn(move || {
            for k in 0..100u32 {
                let v = (t * 1000 + k) as f32;
                let h = srv.submit(vec![v, v, v, v]).unwrap();
                let out = h.wait().unwrap();
                assert_eq!(out, vec![2.0 * v; 4]);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let snap = srv.metrics().snapshot();
    assert_eq!(snap.requests, 800);
    assert_eq!(snap.errors, 0);
}

#[test]
fn shutdown_drains_inflight() {
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        queue_cap: 1024,
        ..Default::default()
    };
    let mut be = EchoBackend::new(2, 4);
    be.delay = Duration::from_millis(1);
    let srv = Server::start(be, cfg);
    let handles: Vec<_> = (0..64)
        .map(|k| srv.submit(vec![k as f32, 1.0]).unwrap())
        .collect();
    // shutdown is graceful only after responses; wait first
    for h in handles {
        assert!(h.wait().is_ok());
    }
    srv.shutdown();
}

#[test]
fn pjrt_backend_end_to_end() {
    let artifact = Path::new("artifacts/model.hlo.txt");
    if !artifact.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_cap: 2048,
        ..Default::default()
    };
    let srv = Server::start_with(
        move || {
            let rt = RuntimeClient::cpu()?;
            let model = rt.load_hlo_text(Path::new("artifacts/model.hlo.txt"))?;
            Ok(PjrtBackend::new(model, 8, &[3, 32, 32], 10))
        },
        cfg,
    )
    .unwrap();
    // identical inputs → identical logits, across different batches
    let img = vec![0.25f32; 3 * 32 * 32];
    let h1 = srv.submit(img.clone()).unwrap();
    let first = h1.wait().unwrap();
    let handles: Vec<_> = (0..32).map(|_| srv.submit(img.clone()).unwrap()).collect();
    for h in handles {
        let out = h.wait().unwrap();
        assert_eq!(out.len(), 10);
        for k in 0..10 {
            assert!(
                (out[k] - first[k]).abs() < 1e-4,
                "batching changed numerics at {k}"
            );
        }
    }
    let snap = srv.metrics().snapshot();
    assert_eq!(snap.errors, 0);
    assert!(snap.mean_batch > 1.0, "batching never engaged: {snap:?}");
    srv.shutdown();
}

/// Failure injection: a backend that errors on every Nth batch. The
/// server must fail exactly the requests of failing batches, keep
/// serving afterwards, and account errors in metrics.
struct FlakyBackend {
    inner: EchoBackend,
    calls: usize,
    fail_every: usize,
}

impl polymem::coordinator::Backend for FlakyBackend {
    fn input_len(&self) -> usize {
        self.inner.len
    }
    fn output_len(&self) -> usize {
        self.inner.len
    }
    fn max_batch(&self) -> usize {
        self.inner.max_batch
    }
    fn infer(&mut self, batch: &[f32], n: usize) -> polymem::util::error::Result<Vec<f32>> {
        self.calls += 1;
        if self.calls % self.fail_every == 0 {
            polymem::bail!("injected failure on call {}", self.calls);
        }
        polymem::coordinator::Backend::infer(&mut self.inner, batch, n)
    }
}

#[test]
fn injected_failures_are_isolated() {
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap: 4096,
        ..Default::default()
    };
    let be = FlakyBackend { inner: EchoBackend::new(2, 4), calls: 0, fail_every: 3 };
    let srv = Server::start(be, cfg);
    let handles: Vec<_> = (0..120)
        .map(|k| srv.submit(vec![k as f32, 0.0]).unwrap())
        .collect();
    let mut ok = 0usize;
    let mut failed = 0usize;
    for (k, h) in handles.into_iter().enumerate() {
        match h.wait() {
            Ok(out) => {
                assert_eq!(out, vec![2.0 * k as f32, 0.0], "survivor corrupted");
                ok += 1;
            }
            Err(e) => {
                assert!(e.to_string().contains("injected failure"), "{e}");
                failed += 1;
            }
        }
    }
    assert!(failed > 0, "no batch ever failed");
    assert!(ok > 0, "no batch ever succeeded");
    assert_eq!(ok + failed, 120);
    let snap = srv.metrics().snapshot();
    assert_eq!(snap.errors as usize, failed);
    assert_eq!(snap.requests as usize, ok);
    srv.shutdown();
}

/// Conservation under multi-threaded load against a slow backend and a
/// small queue: every submit either resolves (correctly) or is rejected
/// at the door, accepted + rejected == attempted, and the queue is
/// empty once the server drains.
#[test]
fn stress_conserves_every_request() {
    const THREADS: u32 = 8;
    const PER_THREAD: u32 = 150; // 1200 total
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 64, // small: backpressure must engage
        ..Default::default()
    };
    let mut be = EchoBackend::new(4, 8);
    be.delay = Duration::from_micros(300); // slow enough to fill the queue
    let srv = std::sync::Arc::new(Server::start(be, cfg));
    let mut joins = vec![];
    for t in 0..THREADS {
        let srv = srv.clone();
        joins.push(std::thread::spawn(move || {
            let mut oks = 0u64;
            let mut rejects = 0u64;
            for k in 0..PER_THREAD {
                let v = (t * 10_000 + k) as f32;
                match srv.submit(vec![v; 4]) {
                    Ok(h) => {
                        let out = h.wait().expect("accepted request must resolve");
                        assert_eq!(out, vec![2.0 * v; 4], "response corrupted");
                        oks += 1;
                    }
                    Err(e) => {
                        assert!(e.to_string().contains("queue full"), "{e}");
                        rejects += 1;
                    }
                }
            }
            (oks, rejects)
        }));
    }
    let mut oks = 0u64;
    let mut rejects = 0u64;
    for j in joins {
        let (o, r) = j.join().unwrap();
        oks += o;
        rejects += r;
    }
    assert_eq!(
        oks + rejects,
        u64::from(THREADS * PER_THREAD),
        "requests lost or invented"
    );
    assert!(oks > 0, "nothing was ever served");
    srv.shutdown();
    assert_eq!(srv.queued(), 0, "queue slots leaked");
    let snap = srv.metrics().snapshot();
    assert_eq!(snap.requests, oks, "served != accepted");
    assert_eq!(snap.errors, 0);
    // span conservation: every accepted request left exactly one
    // complete six-phase chain behind — no orphans, no duplicates —
    // and rejected submits left none (span ids are allocated after
    // the backpressure gate)
    assert_eq!(srv.recorder().spans_started(), oks, "span ids != accepted requests");
    assert_eq!(srv.recorder().overwritten(), 0, "default ring too small for this load");
    let chains = srv.recorder().chains();
    assert_eq!(chains.len() as u64, oks, "orphan or missing span chains");
    for (span, c) in &chains {
        assert!(c.is_complete(), "span {span} has a broken chain: {c:?}");
    }
    // and the Chrome export of those chains stays B/E balanced
    let j = polymem::util::json::parse(&srv.trace_chrome_json()).unwrap();
    let mut depth = 0i64;
    for e in j.get("traceEvents").unwrap().as_arr().unwrap() {
        match e.get("ph").unwrap().as_str().unwrap() {
            "B" => depth += 1,
            "E" => {
                depth -= 1;
                assert!(depth >= 0, "E before matching B");
            }
            _ => {}
        }
    }
    assert_eq!(depth, 0, "unbalanced trace events");
}

/// The same threaded load against a deliberately tiny flight recorder:
/// overwriting must stay invisible to callers — every accepted request
/// still resolves correctly, and the ring stays at its bound.
#[test]
fn stress_bounded_recorder_never_perturbs_responses() {
    const THREADS: u32 = 8;
    const PER_THREAD: u32 = 150;
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        span_cap: 64, // far below 1200 requests × 6 events
    };
    let mut be = EchoBackend::new(4, 8);
    be.delay = Duration::from_micros(300);
    let srv = std::sync::Arc::new(Server::start(be, cfg));
    let mut joins = vec![];
    for t in 0..THREADS {
        let srv = srv.clone();
        joins.push(std::thread::spawn(move || {
            let mut oks = 0u64;
            for k in 0..PER_THREAD {
                let v = (t * 10_000 + k) as f32;
                if let Ok(h) = srv.submit(vec![v; 4]) {
                    assert_eq!(
                        h.wait().expect("accepted request must resolve"),
                        vec![2.0 * v; 4],
                        "response corrupted under a wrapping recorder"
                    );
                    oks += 1;
                }
            }
            oks
        }));
    }
    let oks: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    srv.shutdown();
    assert!(oks > 0);
    assert!(srv.recorder().len() <= 64, "ring exceeded its bound");
    assert!(srv.recorder().overwritten() > 0, "tiny ring never wrapped");
    assert_eq!(srv.metrics().snapshot().requests, oks);
}

#[test]
fn startup_failure_reported() {
    let cfg = ServerConfig::default();
    let r = Server::start_with::<EchoBackend, _>(
        || Err(polymem::format_err!("deliberate startup failure")),
        cfg,
    );
    assert!(r.is_err());
}
