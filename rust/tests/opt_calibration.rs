//! Incremental-vs-full calibration of the joint search.
//!
//! `opt::search` memoizes realization (one bank mapping per search,
//! one tiled+spliced program per tile survivor) and scores candidates
//! on the shared artifacts. This suite holds that incremental path to
//! the pre-memoization bar: for **every** candidate the search
//! realized — recorded in `OptOutcome::audit` in realization order —
//! a from-scratch `opt::realize_full` (clone → tile → bank → splice →
//! plan → `cost::evaluate`, sharing nothing) must produce the same
//! `CostBreakdown` byte-exactly, seconds compared on raw f64 bits.
//!
//! Reproduce a fuzz failure: `FUZZ_SEED=<seed> FUZZ_CASES=1 cargo test
//! --test opt_calibration fuzzed`.

use polymem::accel::AccelConfig;
use polymem::alloc::AllocOpts;
use polymem::ir::loopnest::Program;
use polymem::ir::Graph;
use polymem::models::{self, WaveNetConfig};
use polymem::opt::{realize_full, search, OptOpts};
use polymem::passes::dme::run_dme;
use polymem::passes::manager::BankMode;
use polymem::passes::BankConfig;
use polymem::tile::TileOpts;
use polymem::util::fuzzgraph;

fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", models::mlp(2, 12, 8, 4, 2)),
        ("transformer", models::transformer_block(8, 16, 2, 32)),
        ("resnet18", models::resnet18_scaled(1, 16, 8, 10)),
        ("resnet50", models::resnet50_scaled(1, 16, 8, 10)),
        ("mobilenet", models::mobilenet_v1_scaled(1, 16, 8, 10)),
        ("inception", models::inception_stack_scaled(1, 2, 8, 4)),
        (
            "wavenet",
            models::parallel_wavenet_with(WaveNetConfig {
                flows: 2,
                layers_per_flow: 3,
                channels: 4,
                time: 40,
                kernel: 2,
                dilation_cycle: 10,
            }),
        ),
    ]
}

fn post_dme(g: Graph) -> Program {
    let mut p = Program::lower(g);
    run_dme(&mut p);
    p
}

/// Search, then replay every audited candidate through the unshared
/// reference path and demand bit-exact agreement.
fn assert_calibrated(name: &str, prog: &Program, cfg: &AccelConfig, bank_mode: BankMode) {
    let out = match search(
        prog,
        bank_mode,
        &BankConfig::default(),
        cfg,
        &TileOpts::default(),
        &AllocOpts::default(),
        &OptOpts::default(),
    ) {
        Ok(out) => out,
        // a graph whose seed cannot plan has nothing to calibrate
        Err(_) => return,
    };
    assert!(!out.audit.is_empty(), "{name}: empty audit trail");
    assert_eq!(
        out.audit.len(),
        out.stats.candidates,
        "{name}: audit must cover every realized candidate"
    );
    let mut best_seen = i64::MAX;
    for (i, (dv, cost)) in out.audit.iter().enumerate() {
        let full = realize_full(
            prog,
            *dv,
            bank_mode,
            &BankConfig::default(),
            cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
        )
        .unwrap_or_else(|e| {
            panic!("{name}: audited candidate {} failed the reference path: {e}", dv.describe())
        });
        assert!(
            full.bits_eq(cost),
            "{name}: candidate {} (index {i}) diverged from the reference realization:\n\
             memoized: {:?}\nfull:     {:?}",
            dv.describe(),
            cost,
            full
        );
        best_seen = best_seen.min(cost.offchip_total());
        assert_eq!(
            out.stats.trajectory[i], best_seen,
            "{name}: trajectory entry {i} disagrees with the audited scores"
        );
    }
    // the winner's score is the audit's running minimum
    assert_eq!(out.stats.best_offchip, best_seen, "{name}: winner not the audited minimum");
}

#[test]
fn zoo_search_scores_match_full_realization() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        let prog = post_dme(g);
        assert_calibrated(name, &prog, &cfg, BankMode::Global);
    }
}

#[test]
fn zoo_search_scores_match_full_realization_under_local_banking() {
    // local mode splices the most remap copies, so the memoized
    // spliced program carries the most shared structure to get wrong
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo().into_iter().take(3) {
        let prog = post_dme(g);
        assert_calibrated(name, &prog, &cfg, BankMode::Local);
    }
}

#[test]
fn unbanked_search_scores_match_full_realization() {
    // BankMode::None: no tier-0 memo at all — the staged artifact is
    // the tiled program itself and the calibration must still hold
    let cfg = AccelConfig::tiny(8 * 1024);
    let (name, g) = ("resnet18", models::resnet18_scaled(1, 16, 8, 10));
    let prog = post_dme(g);
    assert_calibrated(name, &prog, &cfg, BankMode::None);
}

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => {
            let parsed = s
                .strip_prefix("0x")
                .or_else(|| s.strip_prefix("0X"))
                .map(|h| u64::from_str_radix(h, 16))
                .unwrap_or_else(|| s.parse());
            parsed.unwrap_or_else(|_| panic!("{name}={s}: not a u64 (decimal or 0x-hex)"))
        }
    }
}

#[test]
fn fuzzed_search_scores_match_full_realization() {
    // seeded random DAGs on a cramped 4 KiB scratchpad, alternating
    // bank modes — the property must hold off the curated zoo too
    let base = env_u64("FUZZ_SEED", 0xCA11_B8A7E);
    let cases = env_u64("FUZZ_CASES", 25);
    let cfg = AccelConfig::tiny(4 * 1024);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e3779b97f4a7c15));
        let g = fuzzgraph::fuzz_graph(seed);
        let prog = post_dme(g);
        let bank_mode = if seed % 2 == 0 { BankMode::Global } else { BankMode::Local };
        assert_calibrated(&format!("FUZZ_SEED={seed}"), &prog, &cfg, bank_mode);
    }
}
