//! Integration: bank mapping across models — the paper's E2 shape must
//! hold (global wins, by roughly the paper's factor), and the
//! assignments must be structurally sound.

use polymem::accel::{simulate, AccelConfig};
use polymem::ir::verify::verify_graph;
use polymem::passes::bank::{input_requirement, is_weight_operand, BankConfig};
use polymem::passes::manager::{BankMode, PassManager};
use polymem::report::pct_reduction;

fn run(mode: BankMode, batch: i64) -> (polymem::passes::bank::BankAssignment, polymem::accel::SimReport) {
    let pm = PassManager { bank_mode: mode, ..Default::default() };
    let rep = pm.run(polymem::models::resnet50(batch)).unwrap();
    let sim = simulate(&rep.program, &AccelConfig::inferentia_like(), None);
    (rep.bank.unwrap(), sim)
}

#[test]
fn e2_headline_shape() {
    let (local, local_sim) = run(BankMode::Local, 1);
    let (global, global_sim) = run(BankMode::Global, 1);
    // global strictly wins on remap copies and bytes
    assert!(global.stats.copies_inserted < local.stats.copies_inserted);
    assert!(global.stats.copy_bytes < local.stats.copy_bytes);
    // paper ballpark: ~76% on-chip copy reduction
    let red = pct_reduction(local_sim.onchip_copy_total(), global_sim.onchip_copy_total());
    assert!((60.0..90.0).contains(&red), "on-chip reduction {red:.1}%");
    // off-chip copies do not get worse
    assert!(global_sim.offchip_copy_total() <= local_sim.offchip_copy_total());
}

#[test]
fn assignments_cover_all_activations() {
    let (asg, _) = run(BankMode::Global, 1);
    for node in asg.graph.nodes() {
        // every activation tensor an operator stages must have a placement
        assert!(
            asg.placements.contains_key(&node.output),
            "missing placement for output of {}",
            node.name
        );
    }
}

#[test]
fn hard_requirements_satisfied_post_pass() {
    // after conflict materialization, every MXU/pool activation edge
    // must see its required placement
    for mode in [BankMode::Local, BankMode::Global] {
        let (asg, _) = run(mode, 1);
        verify_graph(&asg.graph).unwrap();
        for node in asg.graph.nodes() {
            for (pos, &inp) in node.inputs.iter().enumerate() {
                if is_weight_operand(&asg.graph, node, pos) {
                    continue;
                }
                if asg.graph.tensor(inp).kind == polymem::ir::TensorKind::Input {
                    continue;
                }
                if let Some(req) = input_requirement(node, pos) {
                    assert_eq!(
                        asg.placements.get(&inp),
                        Some(&req),
                        "{mode:?}: node {} input {pos} violates its requirement",
                        node.name
                    );
                }
            }
        }
    }
}

#[test]
fn memcopy_nodes_match_stats() {
    for mode in [BankMode::Local, BankMode::Global] {
        let (asg, _) = run(mode, 1);
        let n = asg
            .graph
            .count_nodes(|nd| matches!(nd.kind, polymem::ir::OpKind::MemCopy));
        assert_eq!(n, asg.stats.copies_inserted, "{mode:?}");
    }
}

#[test]
fn global_wins_on_resnet18_and_transformer_too() {
    for g in [
        polymem::models::resnet18(1),
        polymem::models::transformer_block(128, 256, 8, 1024),
    ] {
        let mut bytes = vec![];
        for mode in [BankMode::Local, BankMode::Global] {
            let pm = PassManager { bank_mode: mode, ..Default::default() };
            let rep = pm.run(g.clone()).unwrap();
            bytes.push(rep.bank.unwrap().stats.copy_bytes);
        }
        assert!(bytes[1] <= bytes[0], "global {} > local {}", bytes[1], bytes[0]);
    }
}

#[test]
fn bank_count_does_not_flip_winner() {
    for banks in [4usize, 8, 32] {
        let mut bytes = vec![];
        for mode in [BankMode::Local, BankMode::Global] {
            let pm = PassManager {
                bank_mode: mode,
                bank_cfg: BankConfig { banks, ..Default::default() },
                ..Default::default()
            };
            let rep = pm.run(polymem::models::resnet50(1)).unwrap();
            bytes.push(rep.bank.unwrap().stats.copy_bytes);
        }
        assert!(bytes[1] < bytes[0], "banks={banks}");
    }
}
