//! Cost-model calibration: the invariant `cost/` stands on.
//!
//! The joint optimizer trusts `cost::evaluate` as a stand-in for the
//! simulator — "fewer predicted bytes" must *be* "fewer simulated
//! bytes". This suite holds the model to that bar **exactly**: for
//! every pipeline that produces a plan, the predicted traffic equals
//! `simulate_planned`'s accounting byte-for-byte per traffic class,
//! and the predicted latencies equal `simulate_planned` /
//! `simulate_pipelined` seconds bit-for-bit — over all 7 model
//! builders and ≥ 200 fuzzed graphs (`FUZZ_SEED` / `FUZZ_CASES`
//! override for replay, as in `tests/diff_pipeline.rs`).

use polymem::accel::{simulate_pipelined, simulate_planned, AccelConfig};
use polymem::cost;
use polymem::ir::Graph;
use polymem::models::{self, WaveNetConfig};
use polymem::passes::manager::{AllocStage, OptStage, PassManager, TileStage};
use polymem::util::fuzzgraph;

/// Same interpreter-sized zoo as the differential suite.
fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", models::mlp(2, 12, 8, 4, 2)),
        ("transformer", models::transformer_block(8, 16, 2, 32)),
        ("resnet18", models::resnet18_scaled(1, 16, 8, 10)),
        ("resnet50", models::resnet50_scaled(1, 16, 8, 10)),
        ("mobilenet", models::mobilenet_v1_scaled(1, 16, 8, 10)),
        ("inception", models::inception_stack_scaled(1, 2, 8, 4)),
        (
            "wavenet",
            models::parallel_wavenet_with(WaveNetConfig {
                flows: 2,
                layers_per_flow: 3,
                channels: 4,
                time: 40,
                kernel: 2,
                dilation_cycle: 10,
            }),
        ),
    ]
}

fn planned(cfg: AccelConfig) -> PassManager {
    PassManager {
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

fn tiled(cfg: AccelConfig) -> PassManager {
    PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

fn opted(cfg: AccelConfig) -> PassManager {
    PassManager {
        opt: Some(OptStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

/// Assert the calibration invariant for one compiled program+plan.
fn assert_calibrated(name: &str, pm: &PassManager, g: Graph, cfg: &AccelConfig) {
    let rep = pm.run(g).unwrap_or_else(|e| panic!("{name}: pipeline failed: {e}"));
    let plan = rep.plan.as_ref().expect("alloc stage configured");
    let predicted = cost::evaluate(&rep.program, plan, cfg);
    let sim = simulate_planned(&rep.program, plan, cfg, None)
        .unwrap_or_else(|e| panic!("{name}: plan rejected: {e}"));
    assert_eq!(
        predicted.traffic, sim.traffic,
        "{name}: predicted traffic diverges from the planned replay"
    );
    assert_eq!(
        predicted.offchip_total(),
        sim.offchip_total(),
        "{name}: off-chip bytes diverge"
    );
    assert_eq!(
        predicted.staging_deposit_bytes, sim.staging_deposit_bytes,
        "{name}: staging deposits diverge"
    );
    assert_eq!(
        predicted.onchip_movement_total(),
        sim.onchip_movement_total(),
        "{name}: on-chip movement diverges"
    );
    assert_eq!(
        predicted.peak_scratchpad, sim.peak_scratchpad,
        "{name}: peak scratchpad diverges"
    );
    assert_eq!(
        predicted.serial_seconds.to_bits(),
        sim.seconds.to_bits(),
        "{name}: serial seconds diverge ({} vs {})",
        predicted.serial_seconds,
        sim.seconds
    );
    let pipe = simulate_pipelined(&rep.program, plan, cfg, None).unwrap();
    assert_eq!(
        predicted.pipelined_seconds.to_bits(),
        pipe.seconds.to_bits(),
        "{name}: pipelined seconds diverge ({} vs {})",
        predicted.pipelined_seconds,
        pipe.seconds
    );
    // and no plan beats the compulsory floor
    assert!(predicted.offchip_total() >= cost::compulsory_offchip(&rep.program), "{name}");
}

#[test]
fn zoo_calibrated_through_planned_pipeline() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        assert_calibrated(name, &planned(cfg.clone()), g, &cfg);
    }
}

#[test]
fn zoo_calibrated_through_tiled_pipeline() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        assert_calibrated(name, &tiled(cfg.clone()), g, &cfg);
    }
}

#[test]
fn zoo_calibrated_through_opt_pipeline() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        assert_calibrated(name, &opted(cfg.clone()), g, &cfg);
    }
}

/// Read a u64 override (decimal or 0x-hex), aborting on unparseable
/// values (same contract as the differential suite).
fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => {
            let parsed = s
                .strip_prefix("0x")
                .or_else(|| s.strip_prefix("0X"))
                .map(|h| u64::from_str_radix(h, 16))
                .unwrap_or_else(|| s.parse());
            parsed.unwrap_or_else(|_| panic!("{name}={s}: not a u64 (decimal or 0x-hex)"))
        }
    }
}

#[test]
fn fuzzed_graphs_calibrated() {
    // ≥ 200 seeded random DAGs through the plan-producing pipeline
    // configurations, mirroring the differential suite's rotation:
    // planned / tiled alternate, and every 4th oversized seed
    // (seed ≡ 3 mod 16) runs the joint-optimizer configuration
    let base = env_u64("FUZZ_SEED", 0xF0_2255ED);
    let cases = env_u64("FUZZ_CASES", 200);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e3779b97f4a7c15));
        let g = fuzzgraph::fuzz_graph(seed);
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = if seed % 16 == 3 {
            opted(cfg.clone())
        } else if seed % 2 == 0 {
            planned(cfg.clone())
        } else {
            tiled(cfg.clone())
        };
        assert_calibrated(
            &format!("FUZZ_SEED={seed}"),
            &pm,
            g,
            &cfg,
        );
    }
}
