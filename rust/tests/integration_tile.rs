//! Integration: the tiling subsystem end to end on ResNet-50.
//!
//! The acceptance scenario of the tile/ subsystem: on a chip whose
//! scratchpad is **smaller than ResNet-50's largest intermediate**
//! (conv1's 3.2 MB feature map against a 2 MiB scratchpad), the tiled
//! pipeline must report strictly fewer off-chip bytes than the untiled
//! planned path — because chain intermediates that streaming round-
//! trips through DRAM are now produced and consumed inside
//! double-buffered staging regions.

use polymem::accel::{simulate_pipelined, simulate_planned, AccelConfig};
use polymem::ir::verify::{verify_graph, verify_program};
use polymem::passes::manager::{AllocStage, PassManager, TileStage};

/// Inferentia-like chip shrunk to a 2 MiB scratchpad (16 banks × 64
/// KiB × 2 groups) — smaller than conv1's 1×64×112×112 output.
fn cramped() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4;
    cfg
}

#[test]
fn resnet50_tiled_beats_untiled_planned_offchip() {
    let cfg = cramped();
    let largest = polymem::models::resnet50(1)
        .tensors()
        .map(|t| t.size_bytes())
        .max()
        .unwrap();
    assert!(
        largest > cfg.scratchpad_bytes(),
        "scenario requires a tensor ({largest} B) larger than the scratchpad ({} B)",
        cfg.scratchpad_bytes()
    );

    // untiled planned path
    let untiled_pm = PassManager {
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let urep = untiled_pm.run(polymem::models::resnet50(1)).unwrap();
    let uplan = urep.plan.as_ref().expect("alloc stage ran");
    let usim = simulate_planned(&urep.program, uplan, &cfg, None)
        .expect("untiled plan verifies");

    // tiled pipeline
    let tiled_pm = PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let trep = tiled_pm.run(polymem::models::resnet50(1)).unwrap();
    verify_graph(&trep.program.graph).unwrap();
    verify_program(&trep.program).unwrap();
    let tstats = trep.tile.expect("tile stage ran");
    assert!(tstats.groups > 0, "nothing tiled: {tstats:?}");
    assert!(tstats.fused_chains > 0, "conv→bn→relu chains must fuse: {tstats:?}");
    let tplan = trep.plan.as_ref().expect("alloc stage ran");
    assert!(
        tplan.stats.tile_staged > 0,
        "no chain intermediate staged: {:?}",
        tplan.stats
    );
    let tsim = simulate_pipelined(&trep.program, tplan, &cfg, None)
        .expect("tiled plan verifies");

    assert!(
        tsim.offchip_total() < usim.offchip_total(),
        "tiled off-chip {} B must be strictly below untiled planned {} B",
        tsim.offchip_total(),
        usim.offchip_total()
    );
    assert!(tsim.peak_scratchpad <= cfg.scratchpad_bytes());
    assert!(usim.peak_scratchpad <= cfg.scratchpad_bytes());
}

#[test]
fn tiled_plan_round_trips_on_wavenet() {
    // the DME workload has long elementwise flows and dilated Conv1d —
    // different chain shapes than ResNet; the tiled plan must still
    // verify and replay. Scaled so each [1, C, T] tensor (8 KiB) busts
    // the 4 KiB scratchpad without exploding the debug-mode schedule.
    use polymem::models::WaveNetConfig;
    let g = polymem::models::parallel_wavenet_with(WaveNetConfig {
        flows: 2,
        layers_per_flow: 3,
        channels: 8,
        time: 256,
        kernel: 2,
        dilation_cycle: 10,
    });
    let cfg = AccelConfig::tiny(4 * 1024);
    let pm = PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let rep = pm.run(g).unwrap();
    verify_program(&rep.program).unwrap();
    let tstats = rep.tile.expect("tile stage ran");
    assert!(tstats.groups > 0, "{tstats:?}");
    let plan = rep.plan.as_ref().unwrap();
    let sim = simulate_pipelined(&rep.program, plan, &cfg, None).unwrap();
    assert!(sim.offchip_total() > 0);
    assert!(sim.peak_scratchpad <= cfg.scratchpad_bytes());
}
