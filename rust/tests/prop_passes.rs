//! Property tests over the passes: DME must preserve copy-plumbing
//! semantics on randomly generated memory-op graphs, and global bank
//! mapping must never lose to the local baseline.

use polymem::ir::loopnest::Program;
use polymem::ir::verify::{verify_graph, verify_program};
use polymem::ir::{Graph, GraphBuilder, TensorKind};
use polymem::passes::dme::run_dme;
use polymem::passes::manager::{BankMode, PassManager};
use polymem::util::prop::{Gen, Prop};

/// Random chain/DAG of memory-bound ops over small tensors.
fn random_memory_graph(g: &mut Gen) -> Graph {
    let mut b = GraphBuilder::new();
    let ndim = g.usize_in(1, 4);
    let shape = g.shape(ndim, 5);
    let mut frontier = vec![b.input("x", &shape)];
    let ops = g.usize_in(1, 10);
    for k in 0..ops {
        let src = *g.choose(&frontier);
        let cur_shape = b.graph().tensor(src).shape.to_vec();
        let nd = cur_shape.len();
        let out = match g.usize_in(0, 7) {
            0 => b.transpose(&format!("t{k}"), src, &g.permutation(nd)),
            1 => {
                // reshape to a random factorization of numel
                let numel: i64 = cur_shape.iter().product();
                let mut dims = vec![];
                let mut rest = numel;
                while rest > 1 && dims.len() < 3 {
                    let mut d = g.i64_in(1, rest + 1);
                    while rest % d != 0 {
                        d -= 1;
                    }
                    dims.push(d);
                    rest /= d;
                }
                if rest > 1 || dims.is_empty() {
                    dims.push(rest.max(1));
                }
                b.reshape(&format!("r{k}"), src, &dims)
            }
            2 => {
                let reps: Vec<i64> = (0..nd).map(|_| g.i64_in(1, 3)).collect();
                b.tile(&format!("tile{k}"), src, &reps)
            }
            3 => {
                let axis = g.usize_in(0, nd);
                b.repeat(&format!("rep{k}"), src, axis, g.i64_in(1, 3))
            }
            4 => {
                let begin: Vec<i64> =
                    cur_shape.iter().map(|&e| g.i64_in(0, e)).collect();
                let end: Vec<i64> = cur_shape
                    .iter()
                    .zip(&begin)
                    .map(|(&e, &s)| g.i64_in(s + 1, e + 1))
                    .collect();
                let stride: Vec<i64> = (0..nd).map(|_| g.i64_in(1, 3)).collect();
                b.slice(&format!("s{k}"), src, &begin, &end, &stride)
            }
            5 => {
                let lo: Vec<i64> = (0..nd).map(|_| g.i64_in(0, 3)).collect();
                let hi: Vec<i64> = (0..nd).map(|_| g.i64_in(0, 3)).collect();
                b.pad(&format!("p{k}"), src, &lo, &hi)
            }
            _ => b.identity(&format!("id{k}"), src),
        };
        frontier.push(out);
    }
    // concat two compatible frontier tensors when possible, else chain
    let last = *frontier.last().unwrap();
    let out = b.identity("out", last);
    b.mark_output(out);
    // some graphs leave dead intermediates (frontier branches never
    // consumed); tie them off as outputs so verification passes
    let dead: Vec<_> = frontier
        .iter()
        .copied()
        .filter(|t| {
            b.graph().consumers(*t).is_empty()
                && b.graph().tensor(*t).kind == TensorKind::Intermediate
        })
        .collect();
    for t in dead {
        b.mark_output(t);
    }
    b.finish()
}

#[test]
fn dme_preserves_random_memory_graphs() {
    use polymem::interp::diff::assert_equivalent;
    Prop::new("DME preserves semantics on random memory graphs", 60).check(|g| {
        let graph = random_memory_graph(g);
        verify_graph(&graph).unwrap();
        let mut prog = Program::lower(graph);
        verify_program(&prog).unwrap();
        let before = prog.clone();
        let _stats = run_dme(&mut prog);
        verify_program(&prog).expect("DME broke program invariants");
        assert_equivalent(&before, &prog, 0x5EED);
    });
}

#[test]
fn dme_only_removes_never_adds() {
    Prop::new("DME monotonically shrinks the program", 40).check(|g| {
        let graph = random_memory_graph(g);
        let before = Program::lower(graph.clone());
        let mut prog = Program::lower(graph);
        let stats = run_dme(&mut prog);
        assert!(prog.nests.len() <= before.nests.len());
        assert_eq!(
            before.nests.len() - prog.nests.len(),
            stats.pairs_eliminated,
            "nest count delta must equal eliminated pairs"
        );
        assert!(prog.graph.tensors().count() <= before.graph.tensors().count());
    });
}

/// Random conv/vector/transpose graphs for the bank-mapping relation.
fn random_conv_graph(g: &mut Gen) -> Graph {
    let mut b = GraphBuilder::new();
    let c0 = *g.choose(&[4i64, 8, 16]);
    let mut cur = b.input("x", &[1, c0, 8, 8]);
    let mut c = c0;
    for k in 0..g.usize_in(2, 9) {
        cur = match g.usize_in(0, 6) {
            0 | 1 => {
                let cout = *g.choose(&[8i64, 16, 600, 1024]);
                let w = b.weight(&format!("w{k}"), &[cout, c, 1, 1]);
                c = cout;
                b.conv2d(&format!("c{k}"), cur, w, 1, 0)
            }
            2 => b.relu(&format!("r{k}"), cur),
            3 => b.batchnorm(&format!("bn{k}"), cur),
            4 => b.transpose(&format!("t{k}"), cur, &[0, 2, 3, 1]),
            _ => {
                // transpose back if channels not in dim 1, else pool
                let shape = b.graph().tensor(cur).shape.to_vec();
                if shape[1] == 8 && shape[3] == c {
                    b.transpose(&format!("tb{k}"), cur, &[0, 3, 1, 2])
                } else {
                    b.maxpool(&format!("p{k}"), cur, 1, 1)
                }
            }
        };
        // keep NCHW for conv legality: if channels moved, move them back
        let shape = b.graph().tensor(cur).shape.to_vec();
        if shape[1] != c {
            cur = b.transpose(&format!("fix{k}"), cur, &[0, 3, 1, 2]);
        }
    }
    b.mark_output(cur);
    b.finish()
}

#[test]
fn global_never_loses_to_local() {
    Prop::new("global bank mapping <= local on copy bytes", 40).check(|g| {
        let graph = random_conv_graph(g);
        verify_graph(&graph).unwrap();
        let mut bytes = vec![];
        for mode in [BankMode::Local, BankMode::Global] {
            let pm = PassManager { bank_mode: mode, ..Default::default() };
            let rep = pm.run(graph.clone()).expect("pipeline");
            bytes.push(rep.bank.unwrap().stats.copy_bytes);
        }
        assert!(
            bytes[1] <= bytes[0],
            "global {} > local {} on a random conv graph",
            bytes[1],
            bytes[0]
        );
    });
}

#[test]
fn simulator_invariants_on_random_graphs() {
    use polymem::accel::{simulate, AccelConfig, TrafficClass};
    Prop::new("sim: determinism, conservation, capacity", 30).check(|g| {
        let graph = if g.bool() {
            random_memory_graph(g)
        } else {
            random_conv_graph(g)
        };
        let rep = PassManager::default().run(graph).expect("pipeline");
        let cfg = if g.bool() {
            AccelConfig::inferentia_like()
        } else {
            AccelConfig::tiny(8 * 1024)
        };
        let s1 = simulate(&rep.program, &cfg, None);
        let s2 = simulate(&rep.program, &cfg, None);
        // determinism
        assert_eq!(s1.traffic, s2.traffic);
        assert_eq!(s1.peak_scratchpad, s2.peak_scratchpad);
        // capacity respected
        assert!(s1.peak_scratchpad <= cfg.scratchpad_bytes());
        // every input/weight must be staged at least once
        let compulsory: i64 = rep
            .program
            .graph
            .tensors()
            .filter(|t| {
                matches!(
                    t.kind,
                    polymem::ir::TensorKind::Input | polymem::ir::TensorKind::Weight
                )
            })
            .map(|t| t.size_bytes())
            .sum();
        assert!(
            s1.traffic.get(TrafficClass::InputLoad)
                + s1.traffic.get(TrafficClass::WeightLoad)
                >= compulsory.min(1),
            "compulsory staging missing"
        );
        // outputs written back exactly once
        let out_bytes: i64 = rep
            .program
            .graph
            .outputs()
            .iter()
            .map(|t| rep.program.graph.tensor(*t).size_bytes())
            .sum();
        assert_eq!(s1.traffic.get(TrafficClass::OutputStore), out_bytes);
        // latency positive and monotone in traffic
        assert!(s1.seconds > 0.0);
        // spills imply a smaller-than-peak-liveness scratchpad; a
        // resident-friendly config must not spill when tiny one didn't
        let big = AccelConfig::inferentia_like();
        let s_big = simulate(&rep.program, &big, None);
        assert!(
            s_big.traffic.get(TrafficClass::Spill)
                <= s1.traffic.get(TrafficClass::Spill).max(0)
                || cfg.scratchpad_bytes() >= big.scratchpad_bytes(),
            "bigger scratchpad spilled more"
        );
    });
}

#[test]
fn dme_never_increases_simulated_traffic() {
    use polymem::accel::{simulate, AccelConfig};
    use polymem::ir::loopnest::Program as P;
    Prop::new("DME reduces (or keeps) on-chip movement", 25).check(|g| {
        let graph = random_memory_graph(g);
        let cfg = AccelConfig::inferentia_like();
        let before = simulate(&P::lower(graph.clone()), &cfg, None);
        let mut prog = P::lower(graph);
        run_dme(&mut prog);
        let after = simulate(&prog, &cfg, None);
        assert!(
            after.onchip_movement_total() <= before.onchip_movement_total(),
            "DME increased on-chip movement: {} -> {}",
            before.onchip_movement_total(),
            after.onchip_movement_total()
        );
        assert!(after.offchip_total() <= before.offchip_total());
    });
}

#[test]
fn pipeline_verifies_on_random_graphs() {
    Prop::new("full pipeline keeps invariants on random graphs", 30).check(|g| {
        let graph = if g.bool() {
            random_memory_graph(g)
        } else {
            random_conv_graph(g)
        };
        let rep = PassManager::default().run(graph).expect("pipeline");
        verify_program(&rep.program).expect("invariants broken");
    });
}
