//! Property tests over the polyhedral substrate: the algebraic laws
//! the DME pass relies on must hold for arbitrary maps, not just the
//! ones operators emit.

use polymem::poly::expr::Expr;
use polymem::poly::matrix::IMat;
use polymem::poly::smith::{left_inverse, smith_normal_form};
use polymem::poly::{AccessMap, IterDomain};
use polymem::util::prop::{Gen, Prop};

fn random_matrix(g: &mut Gen, rows: usize, cols: usize, lo: i64, hi: i64) -> IMat {
    let mut m = IMat::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            m[(i, j)] = g.i64_in(lo, hi);
        }
    }
    m
}

/// Random unimodular matrix: product of elementary row operations on I.
fn random_unimodular(g: &mut Gen, n: usize) -> IMat {
    let mut m = IMat::identity(n);
    if n < 2 {
        return m; // no off-diagonal elementary ops exist
    }
    for _ in 0..g.usize_in(1, 8) {
        let a = g.usize_in(0, n);
        let mut b = g.usize_in(0, n);
        if a == b {
            b = (b + 1) % n;
        }
        let f = g.i64_in(-3, 4);
        // row_a += f * row_b
        for j in 0..n {
            let v = m[(b, j)];
            m[(a, j)] += f * v;
        }
    }
    m
}

fn random_quasi_expr(g: &mut Gen, dims: usize, depth: usize) -> Expr {
    if depth == 0 || g.chance(0.4) {
        return if g.bool() {
            Expr::dim(g.usize_in(0, dims))
        } else {
            Expr::cst(g.i64_in(-5, 6))
        };
    }
    match g.usize_in(0, 4) {
        0 => random_quasi_expr(g, dims, depth - 1).add(random_quasi_expr(g, dims, depth - 1)),
        1 => random_quasi_expr(g, dims, depth - 1).scale(g.i64_in(-4, 5)),
        2 => random_quasi_expr(g, dims, depth - 1).floordiv(g.i64_in(1, 7)),
        _ => random_quasi_expr(g, dims, depth - 1).modulo(g.i64_in(1, 7)),
    }
}

#[test]
fn smith_decomposition_laws() {
    Prop::new("U·A·V = D, U,V unimodular, D diagonal divisibility", 150).check(|g| {
        let rows = g.usize_in(1, 5);
        let cols = g.usize_in(1, 5);
        let a = random_matrix(g, rows, cols, -6, 7);
        let s = smith_normal_form(&a);
        assert_eq!(s.u.mul(&a).mul(&s.v), s.d);
        assert_eq!(s.u.det().abs(), 1);
        assert_eq!(s.v.det().abs(), 1);
        for i in 0..rows {
            for j in 0..cols {
                if i != j {
                    assert_eq!(s.d[(i, j)], 0);
                }
            }
        }
        let r = rows.min(cols);
        for k in 0..r.saturating_sub(1) {
            let (x, y) = (s.d[(k, k)], s.d[(k + 1, k + 1)]);
            assert!(x >= 0 && y >= 0);
            if x != 0 && y != 0 {
                assert_eq!(y % x, 0);
            }
        }
    });
}

#[test]
fn left_inverse_is_inverse() {
    Prop::new("L·A = I for unimodular-extended maps", 100).check(|g| {
        let n = g.usize_in(1, 4);
        let u = random_unimodular(g, n);
        if let Some(l) = left_inverse(&u) {
            assert_eq!(l.mul(&u), IMat::identity(n));
        } else {
            panic!("unimodular matrix must have a left inverse: {u:?}");
        }
    });
}

#[test]
fn reverse_roundtrip_on_domain() {
    Prop::new("f'(f(i)) = i for invertible affine maps", 100).check(|g| {
        let n = g.usize_in(1, 4);
        let u = random_unimodular(g, n);
        let b: Vec<i64> = (0..n).map(|_| g.i64_in(-10, 11)).collect();
        let f = AccessMap::affine(&u, &b);
        let rev = f.reverse().expect("unimodular affine map must reverse");
        let dom = IterDomain::new(&g.shape(n, 5));
        for p in dom.sample(32, g.u64()) {
            assert_eq!(rev.apply(&f.apply(&p)), p);
        }
    });
}

#[test]
fn compose_matches_pointwise_application() {
    Prop::new("(f∘g)(i) = f(g(i)) incl. quasi-affine", 150).check(|g| {
        let inner_dims = g.usize_in(1, 3);
        let mid_dims = g.usize_in(1, 3);
        let out_dims = g.usize_in(1, 3);
        let inner = AccessMap::new(
            inner_dims,
            (0..mid_dims).map(|_| random_quasi_expr(g, inner_dims, 2)).collect(),
        );
        let outer = AccessMap::new(
            mid_dims,
            (0..out_dims).map(|_| random_quasi_expr(g, mid_dims, 2)).collect(),
        );
        let composed = outer.compose(&inner);
        let dom = IterDomain::new(&g.shape(inner_dims, 6));
        for p in dom.sample(24, g.u64()) {
            assert_eq!(
                composed.apply(&p),
                outer.apply(&inner.apply(&p)),
                "composition law broken for {outer:?} ∘ {inner:?} at {p:?}"
            );
        }
    });
}

#[test]
fn simplification_preserves_semantics() {
    Prop::new("simplified_in(e) ≡ e on the domain", 200).check(|g| {
        let dims = g.usize_in(1, 3);
        let shape = g.shape(dims, 8);
        let e = random_quasi_expr(g, dims, 3);
        let s = e.clone().simplified_in(&shape);
        let dom = IterDomain::new(&shape);
        for p in dom.sample(24, g.u64()) {
            assert_eq!(e.eval(&p), s.eval(&p), "simplify changed {e:?} -> {s:?} at {p:?}");
        }
    });
}

#[test]
fn reverse_rejects_noninjective() {
    Prop::new("rank-deficient maps have no reverse", 60).check(|g| {
        let n = g.usize_in(2, 4);
        // build a rank-deficient matrix: duplicate a row
        let mut m = random_matrix(g, n, n, -4, 5);
        let src = g.usize_in(0, n);
        let mut dst = g.usize_in(0, n);
        if dst == src {
            dst = (dst + 1) % n;
        }
        for j in 0..n {
            let v = m[(src, j)];
            m[(dst, j)] = v;
        }
        let f = AccessMap::affine(&m, &vec![0; n]);
        assert!(f.reverse().is_none(), "degenerate map reversed: {m:?}");
    });
}

#[test]
fn reverse_roundtrip_on_random_injective_affine_maps() {
    // Beyond square unimodular maps: stack a unimodular core with
    // redundant rows (integer combinations of the core's rows) and
    // shuffle the row order. Invariant factors stay 1, so an exact
    // affine reverse must exist and `f' ∘ f = id` must hold on the
    // whole domain.
    Prop::new("f'∘f = id on injective affine maps (redundant rows)", 100).check(|g| {
        let n = g.usize_in(1, 4);
        let extra = g.usize_in(0, 3);
        let u = random_unimodular(g, n);
        let m = n + extra;
        let mut rows: Vec<Vec<i64>> =
            (0..n).map(|i| (0..n).map(|j| u[(i, j)]).collect()).collect();
        for _ in 0..extra {
            let mut combo = vec![0i64; n];
            for i in 0..n {
                let c = g.i64_in(-2, 3);
                for (j, cell) in combo.iter_mut().enumerate() {
                    *cell += c * u[(i, j)];
                }
            }
            rows.push(combo);
        }
        for i in (1..rows.len()).rev() {
            let j = g.usize_in(0, i + 1);
            rows.swap(i, j);
        }
        let mut c = IMat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                c[(i, j)] = rows[i][j];
            }
        }
        let b: Vec<i64> = (0..m).map(|_| g.i64_in(-10, 11)).collect();
        let f = AccessMap::affine(&c, &b);
        let rev = f
            .reverse()
            .expect("unimodular-extended injective map must have an affine reverse");
        let dom = IterDomain::new(&g.shape(n, 6));
        for p in dom.sample(24, g.u64()) {
            assert_eq!(rev.apply(&f.apply(&p)), p, "roundtrip failed for {f:?} at {p:?}");
        }
    });
}

#[test]
fn piecewise_pieces_stay_disjoint_and_covering_under_composition() {
    use polymem::poly::piecewise::{Guard, Piece, PiecewiseMap};
    // A concat-style 1-D partition of [0, L) into k segments, composed
    // with an affine inner map, must remain a partition of the inner
    // domain (exactly-one piece per point) and agree pointwise with
    // apply-then-apply.
    Prop::new("piecewise ∘ affine stays a partition", 120).check(|g| {
        let k = g.usize_in(2, 5);
        let lens: Vec<i64> = (0..k).map(|_| g.i64_in(1, 5)).collect();
        let total: i64 = lens.iter().sum();
        let mut pieces = Vec::new();
        let mut off = 0i64;
        for len in &lens {
            pieces.push(Piece {
                guards: vec![Guard { dim: 0, lo: off, hi: off + len }],
                map: AccessMap::new(1, vec![Expr::dim(0).add(Expr::cst(-off))]),
            });
            off += len;
        }
        let m = PiecewiseMap::new(1, pieces);
        let full = IterDomain::new(&[total]);
        assert!(m.is_total_on(&full), "generator built a non-partition");

        // inner map: either a shift i ↦ i + c (guards translate through
        // unit coefficients) or a dim-remap from a 2-D space
        let (inner, inner_dom) = if g.bool() {
            let c = g.i64_in(0, total);
            (
                AccessMap::new(1, vec![Expr::dim(0).add(Expr::cst(c))]),
                IterDomain::new(&[total - c.min(total - 1)]),
            )
        } else {
            let other = g.i64_in(1, 5);
            (
                AccessMap::new(2, vec![Expr::dim(1)]),
                IterDomain::new(&[other, total]),
            )
        };
        let composed = m
            .compose_inner(&inner)
            .expect("unit-coefficient inner maps must compose");
        assert!(
            composed.is_total_on(&inner_dom),
            "composition broke the partition: {composed:?} on {inner_dom:?}"
        );
        for p in inner_dom.sample(24, g.u64()) {
            assert_eq!(
                composed.apply(&p),
                m.apply(&inner.apply(&p)),
                "composition law broken at {p:?}"
            );
        }
    });
}

#[test]
fn linearize_delinearize_roundtrip() {
    Prop::new("linearize ∘ delinearize = id", 120).check(|g| {
        let dims = g.usize_in(1, 4);
        let dom = IterDomain::new(&g.shape(dims, 9));
        for p in dom.sample(16, g.u64()) {
            let off = dom.linearize(&p);
            assert_eq!(dom.delinearize(off), p);
            assert!(off >= 0 && off < dom.cardinality());
        }
    });
}
