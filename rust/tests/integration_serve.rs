//! Integration: the production serving path — plan cache, planned
//! backend, cost-aware bucketized batching, load simulation — on a
//! small model so the whole path runs in tier-1 time.

use polymem::accel::AccelConfig;
use polymem::coordinator::{Backend, BucketCost, Server, ServerConfig};
use polymem::serve::{run_load, Arrivals, LoadSimConfig, PlanCache, PlanCacheConfig, PlannedBackend};
use std::time::Duration;

fn tiny() -> AccelConfig {
    AccelConfig::tiny(64 * 1024)
}

fn mlp_cache() -> PlanCache {
    PlanCache::new(
        "mlp",
        PlanCacheConfig { accel: tiny(), joint: false, verify: true, max_entries: 0 },
    )
}

#[test]
fn plan_cache_memoizes_and_buckets_scale() {
    let mut cache = mlp_cache();
    let arts = cache.compile_buckets(&[1, 2, 4]).unwrap();
    assert_eq!(cache.misses(), 3);
    assert_eq!(cache.hits(), 0);
    assert_eq!(cache.len(), 3);

    let again = cache.get_or_compile(2).unwrap();
    assert_eq!(cache.hits(), 1, "second lookup must be a cache hit");
    assert_eq!(again.batch, 2);

    for a in &arts {
        // per-request shapes agree across buckets (mlp: 784 -> 10)
        assert_eq!(a.in_len, 784);
        assert_eq!(a.out_len, 10);
        assert!(a.service_seconds > 0.0, "b{}: zero service time", a.batch);
        assert!(a.cost.offchip_total() > 0);
        assert!(a.compile_seconds > 0.0);
    }
    // off-chip bytes grow with batch (activations scale) …
    let o: Vec<i64> = arts.iter().map(|a| a.cost.offchip_total()).collect();
    assert!(o[0] < o[1] && o[1] < o[2], "off-chip not increasing: {o:?}");
    // … but sublinearly per request (weights amortize): b4 beats 4×b1
    assert!(
        o[2] < 4 * o[0],
        "no amortization: batch-4 {} vs 4 × batch-1 {}",
        o[2],
        4 * o[0]
    );
}

#[test]
fn planned_backend_routes_to_smallest_fitting_bucket() {
    let mut cache = mlp_cache();
    let arts = cache.compile_buckets(&[4, 1, 2]).unwrap(); // any order in
    let be = PlannedBackend::new(arts).unwrap();
    assert_eq!(be.max_batch(), 4);
    assert_eq!(be.bucket_for(1).batch, 1);
    assert_eq!(be.bucket_for(2).batch, 2);
    assert_eq!(be.bucket_for(3).batch, 4); // padded onto the 4-bucket
    assert_eq!(be.bucket_for(4).batch, 4);
    let costs = be.bucket_costs().expect("planned backends publish costs");
    assert_eq!(costs.len(), 3);
    assert!(costs.windows(2).all(|w| w[0].batch < w[1].batch));
}

#[test]
fn planned_backend_serves_through_server() {
    let mut cache = mlp_cache();
    let arts = cache.compile_buckets(&[1, 2, 4]).unwrap();
    let in_len = arts[0].in_len;
    let out_len = arts[0].out_len;
    // time_scale 0: model the bytes, skip the sleeps (test speed)
    let be = PlannedBackend::new(arts).unwrap().with_time_scale(0.0);
    let srv = Server::start(
        be,
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            ..Default::default()
        },
    );
    let handles: Vec<_> = (0..32)
        .map(|k| srv.submit(vec![k as f32; in_len]).unwrap())
        .collect();
    for (k, h) in handles.into_iter().enumerate() {
        let out = h.wait().unwrap();
        assert_eq!(out, vec![2.0 * k as f32; out_len], "request {k} misrouted");
    }
    let snap = srv.metrics().snapshot();
    assert_eq!(snap.requests, 32);
    assert_eq!(snap.errors, 0);
    // the cost-aware flush path charged predicted traffic
    assert!(snap.predicted_offchip_bytes > 0, "bucket accounting never engaged");
    assert!(srv
        .metrics_text()
        .contains("polymem_predicted_offchip_bytes_total"));
    srv.shutdown();
    assert_eq!(srv.queued(), 0);
}

#[test]
fn bucketized_serving_saves_bytes_on_planned_artifacts() {
    // the acceptance shape on a tier-1-sized model: real compiled
    // artifacts, equal offered load, strictly fewer predicted off-chip
    // bytes per request than the fixed max-batch baseline
    let mut cache = mlp_cache();
    let arts = cache.compile_buckets(&[1, 2, 4]).unwrap();
    let costs: Vec<BucketCost> = arts
        .iter()
        .map(|a| BucketCost {
            batch: a.batch as usize,
            offchip_bytes: a.cost.offchip_total(),
            service_seconds: a.service_seconds,
        })
        .collect();
    let fixed = vec![*costs.last().unwrap()];
    let svc_max = fixed[0].service_seconds;
    let low_rate = 0.25 * 4.0 / svc_max;
    let cfg = LoadSimConfig {
        arrivals: Arrivals::Poisson { rate_qps: low_rate, requests: 1500, seed: 5 },
        max_wait: Duration::from_secs_f64(svc_max * 2.0),
        queue_cap: 64,
        slo: None,
    };
    let bucketized = run_load(&costs, &cfg, "bucketized");
    let baseline = run_load(&fixed, &cfg, "fixed");
    assert_eq!(bucketized.submitted, baseline.submitted);
    assert!(
        bucketized.bytes_per_request < baseline.bytes_per_request,
        "bucketized {} >= fixed {}",
        bucketized.bytes_per_request,
        baseline.bytes_per_request
    );
    // conservation in both runs
    for r in [&bucketized, &baseline] {
        assert_eq!(r.completed + r.rejected, r.submitted, "{}: lost requests", r.label);
    }
}
