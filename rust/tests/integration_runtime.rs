//! Integration: the PJRT runtime against both hand-written HLO and the
//! real AOT artifacts (when `make artifacts` has run).
//!
//! The whole file requires the real PJRT client, so it only compiles
//! with `--features pjrt` (default builds use the stub runtime).
#![cfg(feature = "pjrt")]

use polymem::runtime::RuntimeClient;
use std::path::Path;

const MATMUL_HLO: &str = r#"
HloModule mm

ENTRY main {
  x = f32[4,3]{1,0} parameter(0)
  w = f32[3,2]{1,0} parameter(1)
  ROOT mm = f32[4,2]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;

#[test]
fn matmul_numerics() {
    let rt = RuntimeClient::cpu().unwrap();
    let m = rt.load_hlo_str("mm", MATMUL_HLO).unwrap();
    let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
    let w = vec![1f32, 0.0, 0.0, 1.0, 1.0, 1.0];
    let out = m.run_f32(&[(&x, &[4, 3]), (&w, &[3, 2])]).unwrap();
    // row0 = [0,1,2] -> [0*1+1*0+2*1, 0*0+1*1+2*1] = [2, 3]
    assert_eq!(out[0..2], [2.0, 3.0]);
    assert_eq!(out.len(), 8);
}

#[test]
fn repeated_execution_stable() {
    let rt = RuntimeClient::cpu().unwrap();
    let m = rt.load_hlo_str("mm2", MATMUL_HLO).unwrap();
    let x: Vec<f32> = (0..12).map(|v| (v as f32) * 0.5).collect();
    let w: Vec<f32> = (0..6).map(|v| (v as f32) - 2.0).collect();
    let first = m.run_f32(&[(&x, &[4, 3]), (&w, &[3, 2])]).unwrap();
    for _ in 0..10 {
        let again = m.run_f32(&[(&x, &[4, 3]), (&w, &[3, 2])]).unwrap();
        assert_eq!(first, again);
    }
}

fn artifact() -> Option<std::path::PathBuf> {
    let p = Path::new("artifacts/model.hlo.txt");
    p.exists().then(|| p.to_path_buf())
}

#[test]
fn aot_artifact_loads_and_runs() {
    let Some(path) = artifact() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = RuntimeClient::cpu().unwrap();
    let m = rt.load_hlo_text(&path).unwrap();
    let input = vec![0.1f32; 8 * 3 * 32 * 32];
    let out = m.run_f32(&[(&input, &[8, 3, 32, 32])]).unwrap();
    assert_eq!(out.len(), 8 * 10);
    assert!(out.iter().all(|v| v.is_finite()));
    // constant input → identical logits per batch row
    for row in 1..8 {
        assert_eq!(out[row * 10..row * 10 + 10], out[0..10]);
    }
}

#[test]
fn aot_artifact_deterministic_across_loads() {
    let Some(path) = artifact() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = RuntimeClient::cpu().unwrap();
    let m1 = rt.load_hlo_text(&path).unwrap();
    let m2 = rt.load_hlo_text(&path).unwrap();
    let mut input = vec![0f32; 8 * 3 * 32 * 32];
    for (k, v) in input.iter_mut().enumerate() {
        *v = ((k % 97) as f32) / 97.0 - 0.5;
    }
    let a = m1.run_f32(&[(&input, &[8, 3, 32, 32])]).unwrap();
    let b = m2.run_f32(&[(&input, &[8, 3, 32, 32])]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn batch1_artifact_agrees_with_batch8() {
    let Some(path8) = artifact() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let path1 = Path::new("artifacts/model.b1.hlo.txt");
    if !path1.exists() {
        eprintln!("skipping: batch-1 artifact missing");
        return;
    }
    let rt = RuntimeClient::cpu().unwrap();
    let m8 = rt.load_hlo_text(&path8).unwrap();
    let m1 = rt.load_hlo_text(path1).unwrap();
    let mut img = vec![0f32; 3 * 32 * 32];
    for (k, v) in img.iter_mut().enumerate() {
        *v = ((k % 31) as f32) / 31.0;
    }
    // batch-8 input with the test image in row 0
    let mut batch = vec![0f32; 8 * 3 * 32 * 32];
    batch[..img.len()].copy_from_slice(&img);
    let out8 = m8.run_f32(&[(&batch, &[8, 3, 32, 32])]).unwrap();
    let out1 = m1.run_f32(&[(&img, &[1, 3, 32, 32])]).unwrap();
    for k in 0..10 {
        assert!(
            (out8[k] - out1[k]).abs() < 1e-4,
            "batch variants disagree at {k}: {} vs {}",
            out8[k],
            out1[k]
        );
    }
}
