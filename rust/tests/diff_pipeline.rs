//! Differential equivalence across the whole pass pipeline.
//!
//! The acceptance bar for every transformation in this repo: the
//! program after lower → DME → bank map (+ copy splice) → static plan
//! computes **bit-identical** outputs to the freshly lowered program,
//! for all 7 model builders (at interpreter-sized configurations with
//! the full-model topology) and for ≥ 200 seeded random graphs from
//! `util::fuzzgraph`. A final meta-test injects a known-bad mutation
//! and proves the oracle catches it.
//!
//! Reproduce a fuzz failure: the panic message prints the case seed —
//! re-run with `FUZZ_SEED=<seed> FUZZ_CASES=1 cargo test --test
//! diff_pipeline fuzzed` (see README.md §Differential fuzzing).

use polymem::accel::AccelConfig;
use polymem::interp::diff::{diff_pipeline, first_mismatch, stage_outputs};
use polymem::interp::{interpret, Buffers};
use polymem::ir::loopnest::{Body, Program};
use polymem::ir::verify::verify_graph;
use polymem::ir::{Graph, GraphBuilder};
use polymem::models::{self, WaveNetConfig};
use polymem::passes::dme::run_dme;
use polymem::passes::manager::{AllocStage, BankMode, OptStage, PassManager, TileStage};
use polymem::poly::AccessMap;
use polymem::shard::{interpret_sharded, search_sharded, ShardOpts};
use polymem::util::fuzzgraph;

const SEED: u64 = 0xD1FF_5EED;

/// All 7 model builders at interpreter-sized configurations. The
/// scaled variants keep the full models' topology and operator mix
/// (same conv/concat/attention plumbing) with widths and resolutions
/// the exhaustive interpreter can execute in milliseconds.
fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", models::mlp(2, 12, 8, 4, 2)),
        ("transformer", models::transformer_block(8, 16, 2, 32)),
        ("resnet18", models::resnet18_scaled(1, 16, 8, 10)),
        ("resnet50", models::resnet50_scaled(1, 16, 8, 10)),
        ("mobilenet", models::mobilenet_v1_scaled(1, 16, 8, 10)),
        ("inception", models::inception_stack_scaled(1, 2, 8, 4)),
        (
            "wavenet",
            models::parallel_wavenet_with(WaveNetConfig {
                flows: 2,
                layers_per_flow: 3,
                channels: 4,
                time: 40,
                kernel: 2,
                dilation_cycle: 10,
            }),
        ),
    ]
}

fn planned(cfg: AccelConfig) -> PassManager {
    PassManager {
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

fn tiled(cfg: AccelConfig) -> PassManager {
    PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

fn opted(cfg: AccelConfig) -> PassManager {
    PassManager {
        opt: Some(OptStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

#[test]
fn zoo_equivalent_through_global_planned_pipeline() {
    // a cramped scratchpad so the plan stage actually splits windows /
    // spills on the larger zoo members — the spliced spill/reload nests
    // must replay to identical bits
    let pm = planned(AccelConfig::tiny(8 * 1024));
    for (name, g) in zoo() {
        let rep = diff_pipeline(g, &pm, SEED).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(rep.stages.first().map(|s| s.as_str()), Some("lower"), "{name}");
        assert_eq!(rep.stages.last().map(|s| s.as_str()), Some("plan"), "{name}");
        assert!(rep.elements > 0, "{name}: nothing compared");
    }
}

#[test]
fn zoo_equivalent_through_tiled_planned_pipeline() {
    // a scratchpad smaller than the zoo's feature maps, so the tile
    // stage strip-mines real chains and the planner stages their
    // intermediates — the full lower → dme → tile → bank → plan ladder
    // must stay bit-identical
    let pm = tiled(AccelConfig::tiny(8 * 1024));
    for (name, g) in zoo() {
        let rep = diff_pipeline(g, &pm, SEED).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            rep.stages.iter().any(|s| s == "tile"),
            "{name}: tile stage not observed in {:?}",
            rep.stages
        );
        assert_eq!(rep.stages.last().map(|s| s.as_str()), Some("plan"), "{name}");
    }
}

#[test]
fn zoo_equivalent_through_opt_pipeline() {
    // the joint optimizer may pick widened fusion (multi-consumer,
    // conv-chain halo recompute), a different tile budget, a group
    // reschedule and a different spill flavor — whatever it picks, the
    // full lower → dme → opt → bank → plan ladder must stay
    // bit-identical
    let pm = opted(AccelConfig::tiny(8 * 1024));
    for (name, g) in zoo() {
        let rep = diff_pipeline(g, &pm, SEED).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            rep.stages.iter().any(|s| s == "opt"),
            "{name}: opt stage not observed in {:?}",
            rep.stages
        );
        assert_eq!(rep.stages.last().map(|s| s.as_str()), Some("plan"), "{name}");
    }
}

#[test]
fn zoo_equivalent_through_local_bank_pipeline() {
    // local mode maximizes inserted MemCopy nodes — the splice path
    let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
    for (name, g) in zoo() {
        diff_pipeline(g, &pm, SEED).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

/// Read a u64 override (decimal or 0x-hex). An env var that is *set
/// but unparseable* aborts loudly — silently falling back to the
/// default would turn a replay attempt into a meaningless green run.
fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => {
            let parsed = s
                .strip_prefix("0x")
                .or_else(|| s.strip_prefix("0X"))
                .map(|h| u64::from_str_radix(h, 16))
                .unwrap_or_else(|| s.parse());
            parsed.unwrap_or_else(|_| panic!("{name}={s}: not a u64 (decimal or 0x-hex)"))
        }
    }
}

#[test]
fn fuzzed_graphs_equivalent_across_all_stages() {
    // ≥ 200 seeded random DAGs; FUZZ_SEED / FUZZ_CASES override for
    // replay (ci.sh passes them through)
    let base = env_u64("FUZZ_SEED", 0xF0_2255ED);
    let cases = env_u64("FUZZ_CASES", 200);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e3779b97f4a7c15));
        let g = fuzzgraph::fuzz_graph(seed);
        verify_graph(&g)
            .unwrap_or_else(|e| panic!("FUZZ_SEED={seed}: generator built invalid graph: {e}"));
        // rotate pipeline configurations so every stage combination is
        // fuzzed: global / local / global + static planning / tiling +
        // planning. Derived from the seed (not the loop index) so
        // FUZZ_SEED=<s> FUZZ_CASES=1 replays the exact failing case,
        // config included. Seeds ≡ 3 (mod 4) are exactly the ones the
        // generator hands oversized tensors (`FuzzOpts::oversized`), so
        // the tiled config always sees scratchpad-busting graphs — and
        // every 4th such oversized seed (≡ 3 mod 16) runs the joint-
        // optimizer configuration instead, so widened fusion, halo
        // recompute and spill-flavor choices are fuzzed too — and every
        // 8th oversized seed (≡ 7 mod 32, disjoint from the joint slot)
        // compiles sharded at num_cores = 2, holding the composed
        // lower → dme → opt(shard) → bank → plan stages to bit-identical
        // outputs across the cut.
        if seed % 32 == 7 {
            let cfg = AccelConfig::tiny(4 * 1024).with_cores(2);
            let opts =
                ShardOpts { joint: true, verify: true, max_cut_points: 4, ..ShardOpts::default() };
            let outcome = search_sharded(&g, &cfg, &opts).unwrap_or_else(|e| {
                panic!("shard search failed (replay with FUZZ_SEED={seed} FUZZ_CASES=1): {e}")
            });
            let outputs = g.outputs();
            let reference = stage_outputs(&Program::lower(g), &outputs, seed, "reference")
                .unwrap_or_else(|e| panic!("FUZZ_SEED={seed}: reference interpretation: {e}"));
            let sharded = interpret_sharded(&outcome.stages, &outputs, seed)
                .unwrap_or_else(|e| panic!("FUZZ_SEED={seed}: sharded interpretation: {e}"));
            assert!(
                first_mismatch(&reference, &sharded).is_none(),
                "sharded outputs diverged (replay with FUZZ_SEED={seed} FUZZ_CASES=1, \
                 cuts {:?})",
                outcome.cuts
            );
            continue;
        }
        let pm = match seed % 4 {
            0 => PassManager::default(),
            1 => PassManager { bank_mode: BankMode::Local, ..Default::default() },
            2 => planned(AccelConfig::tiny(4 * 1024)),
            _ if seed % 16 == 3 => opted(AccelConfig::tiny(4 * 1024)),
            _ => tiled(AccelConfig::tiny(4 * 1024)),
        };
        diff_pipeline(g, &pm, seed).unwrap_or_else(|e| {
            panic!("differential mismatch (replay with FUZZ_SEED={seed} FUZZ_CASES=1): {e}")
        });
    }
}

#[test]
fn oracle_detects_injected_miscompile() {
    // slice folds into the output copy as `out[i] = x[i + 1]`; the
    // injected mutation drops the offset. Inputs are pinned to
    // 0,1,2,…  so the divergence is certain, not probabilistic.
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[8]);
    let s = b.slice("s", x, &[1], &[8], &[1]);
    let y = b.identity("out", s);
    b.mark_output(y);
    let g = b.finish();
    let out = g.outputs()[0];

    let mut prog = Program::lower(g);
    let run = |prog: &Program| -> Vec<f64> {
        let mut bufs = Buffers::seeded(&prog.graph, 0);
        bufs.set_tensor(x, (0..8).map(|v| v as f64).collect());
        interpret(prog, &mut bufs).unwrap();
        bufs.tensor(out).to_vec()
    };
    let baseline = run(&prog);
    assert_eq!(baseline, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);

    let stats = run_dme(&mut prog);
    assert!(stats.pairs_eliminated >= 1);
    assert_eq!(run(&prog), baseline, "unmutated post-DME program must match");

    // inject the miscompile: surviving copy now reads x[i] instead of
    // x[i + 1]
    let nest = prog
        .nests
        .iter_mut()
        .find(|n| n.body.is_copy())
        .expect("output copy survives DME");
    let Body::Copy { load } = &mut nest.body else { unreachable!() };
    load.pieces[0].map = AccessMap::identity(1);

    let mutated = run(&prog);
    assert_eq!(mutated, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    assert_ne!(mutated, baseline, "oracle lost its teeth");
}

#[test]
fn seeded_harness_detects_injected_miscompile() {
    // same canary through the public stage_outputs/first_mismatch API
    // the differential suite uses (seeded inputs this time)
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[3, 5]);
    let t = b.transpose("t", x, &[1, 0]);
    let y = b.identity("out", t);
    b.mark_output(y);
    let g = b.finish();
    let outputs = g.outputs();

    let mut prog = Program::lower(g);
    let base = stage_outputs(&prog, &outputs, SEED, "lower").unwrap();
    run_dme(&mut prog);
    let post = stage_outputs(&prog, &outputs, SEED, "dme").unwrap();
    assert!(first_mismatch(&base, &post).is_none(), "DME broke the transpose");

    // out is [5,3]; the folded (correct) read map is (i0,i1) -> [i1,i0].
    // Corrupt it to (i0,i1) -> [i1, (i0+1) mod 5]: still in-bounds, but
    // every output column shifted by one source row — a routing bug of
    // exactly the kind a wrong guard translation would produce.
    let nest = prog.nests.iter_mut().find(|n| n.body.is_copy()).unwrap();
    let Body::Copy { load } = &mut nest.body else { unreachable!() };
    use polymem::poly::Expr;
    load.pieces[0].map = AccessMap::new(
        2,
        vec![Expr::dim(1), Expr::dim(0).add(Expr::cst(1)).modulo(5)],
    );
    let bad = stage_outputs(&prog, &outputs, SEED, "mutated").unwrap();
    assert!(
        first_mismatch(&base, &bad).is_some(),
        "seeded oracle must flag the corrupted permutation"
    );
}
