//! Integration: request-scoped observability across the serving stack —
//! span conservation in traced load simulations (synthetic zoo tables
//! and real compiled artifacts), the cost-drift auditor on a live
//! planned-backend server, and the Chrome export of virtual-time spans.

use polymem::accel::AccelConfig;
use polymem::coordinator::{BucketCost, Server, ServerConfig};
use polymem::obs::FlightRecorder;
use polymem::serve::{
    run_load_traced, Arrivals, LoadSimConfig, PlanCache, PlanCacheConfig, PlannedBackend,
};
use std::time::Duration;

/// Synthetic bucket table: off-chip bytes = weights + batch ×
/// activations (the shape the plan cache produces for real models).
fn table(weights: i64, act: i64, buckets: &[usize]) -> Vec<BucketCost> {
    buckets
        .iter()
        .map(|&b| {
            let bytes = weights + act * b as i64;
            BucketCost { batch: b, offchip_bytes: bytes, service_seconds: bytes as f64 / 50e9 }
        })
        .collect()
}

fn sim_cfg(arrivals: Arrivals, queue_cap: usize) -> LoadSimConfig {
    LoadSimConfig {
        arrivals,
        max_wait: Duration::from_micros(500),
        queue_cap,
        slo: None,
    }
}

/// Every admitted request in a traced load sim must leave exactly one
/// complete six-phase chain; rejected arrivals must leave none — across
/// a zoo of cost-table shapes and arrival processes, including runs
/// where backpressure sheds load.
#[test]
fn zoo_load_sims_conserve_spans() {
    let zoo: Vec<(&str, Vec<BucketCost>)> = vec![
        ("weights-heavy", table(8_000_000, 500_000, &[1, 2, 4, 8])),
        ("activation-heavy", table(200_000, 4_000_000, &[1, 2, 4, 8])),
        ("single-bucket", table(8_000_000, 500_000, &[8])),
        ("sparse-buckets", table(2_000_000, 1_000_000, &[1, 16])),
    ];
    let loads: Vec<(&str, Arrivals, usize)> = vec![
        ("closed", Arrivals::Closed { clients: 12, requests: 600 }, 64),
        (
            "poisson-low",
            Arrivals::Poisson { rate_qps: 3_000.0, requests: 600, seed: 42 },
            64,
        ),
        // far over capacity with a tight queue: rejects must happen
        (
            "poisson-shed",
            Arrivals::Poisson { rate_qps: 60_000.0, requests: 600, seed: 7 },
            8,
        ),
    ];
    let mut shed_seen = false;
    for (model, costs) in &zoo {
        for (load, arrivals, queue_cap) in &loads {
            let r = FlightRecorder::new(600 * 8);
            let rep = run_load_traced(
                costs,
                &sim_cfg(*arrivals, *queue_cap),
                &format!("{model}/{load}"),
                Some(&r),
            );
            assert_eq!(
                rep.completed + rep.rejected,
                rep.submitted,
                "{model}/{load}: requests lost"
            );
            // spans allocated only for admitted requests
            assert_eq!(
                r.spans_started(),
                rep.completed,
                "{model}/{load}: span ids != admitted requests"
            );
            let chains = r.chains();
            assert_eq!(
                chains.len() as u64,
                rep.completed,
                "{model}/{load}: orphan or missing chains"
            );
            for (span, c) in &chains {
                assert!(c.is_complete(), "{model}/{load}: span {span} broken: {c:?}");
            }
            shed_seen |= rep.rejected > 0;
        }
    }
    assert!(shed_seen, "no run ever shed load — the reject path went untested");
}

/// The same conservation over *real* compiled artifacts: plan-cache
/// buckets for the mlp on the tiny 64 KiB accelerator, and the Chrome
/// export of the resulting virtual-time spans stays B/E balanced.
#[test]
fn traced_load_sim_over_compiled_artifacts_exports_chrome() {
    let mut cache = PlanCache::new(
        "mlp",
        PlanCacheConfig { accel: AccelConfig::tiny(64 * 1024), joint: false, verify: true, max_entries: 0 },
    );
    let arts = cache.compile_buckets(&[1, 2, 4]).unwrap();
    let costs: Vec<BucketCost> = arts
        .iter()
        .map(|a| BucketCost {
            batch: a.batch as usize,
            offchip_bytes: a.cost.offchip_total(),
            service_seconds: a.service_seconds,
        })
        .collect();
    let svc_max = costs.iter().map(|c| c.service_seconds).fold(0.0f64, f64::max);
    let r = FlightRecorder::new(500 * 8);
    let rep = run_load_traced(
        &costs,
        &LoadSimConfig {
            arrivals: Arrivals::Closed { clients: 6, requests: 500 },
            max_wait: Duration::from_secs_f64(svc_max * 2.0),
            queue_cap: 64,
            slo: None,
        },
        "mlp/traced",
        Some(&r),
    );
    assert_eq!(rep.completed, 500);
    let chains = r.chains();
    assert_eq!(chains.len(), 500);
    assert!(chains.values().all(|c| c.is_complete()));
    // flush accounting is consistent with the chains
    let flushes: u64 = rep.flushes_by_bucket.values().sum();
    assert_eq!(flushes, rep.batches);
    // the chrome export parses, balances, and carries the bucket
    // counter track of flush decisions
    let j = polymem::util::json::parse(&r.to_chrome().to_json().to_string_compact()).unwrap();
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty());
    let mut depth = 0i64;
    let mut counters = 0usize;
    for e in evs {
        match e.get("ph").unwrap().as_str().unwrap() {
            "B" => depth += 1,
            "E" => {
                depth -= 1;
                assert!(depth >= 0, "E before matching B");
            }
            "C" => counters += 1,
            _ => {}
        }
    }
    assert_eq!(depth, 0, "unbalanced trace");
    assert!(counters > 0, "no bucket counter events exported");
}

/// The drift auditor's contract on a live server: a `PlannedBackend`
/// replays exactly the plan-cache numbers it published, so per-bucket
/// drift is byte-exact zero (bytes) and bit-exact zero (seconds).
#[test]
fn planned_backend_cost_drift_is_exactly_zero() {
    let mut cache = PlanCache::new(
        "mlp",
        PlanCacheConfig { accel: AccelConfig::tiny(64 * 1024), joint: false, verify: true, max_entries: 0 },
    );
    let arts = cache.compile_buckets(&[1, 2, 4]).unwrap();
    let in_len = arts[0].in_len;
    let be = PlannedBackend::new(arts).unwrap().with_time_scale(0.0);
    let srv = Server::start(
        be,
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            ..Default::default()
        },
    );
    let handles: Vec<_> = (0..48)
        .map(|k| srv.submit(vec![k as f32; in_len]).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let snap = srv.metrics().snapshot();
    assert_eq!(snap.requests, 48);
    assert!(!snap.drift.is_empty(), "drift auditor never engaged");
    let mut audited = 0u64;
    for (bucket, d) in &snap.drift {
        audited += d.batches;
        assert_eq!(d.bytes_drift(), 0, "bucket {bucket}: off-chip bytes drifted");
        assert_eq!(d.seconds_drift(), 0.0, "bucket {bucket}: service seconds drifted");
    }
    assert_eq!(audited, snap.batches, "some batches escaped the audit");
    let text = srv.metrics_text();
    assert!(text.contains("polymem_cost_drift_bytes"), "{text}");
    assert!(text.contains("polymem_cost_drift_seconds"), "{text}");
    srv.shutdown();
}
