//! Telemetry invariants: attribution conservation and trace export.
//!
//! The attribution table is only trustworthy if it is an *accounting
//! identity*, not a sampling estimate. This suite pins that: for every
//! replay mode (dynamic, planned, pipelined), the per-node × per-class
//! byte cells sum **bit-exactly** per traffic class to the replay's
//! own `TrafficCounters` — and, through the calibration invariant, to
//! `cost::evaluate`'s predicted traffic — over all 7 model builders
//! and ≥ 200 fuzzed graphs (`FUZZ_SEED` / `FUZZ_CASES` override for
//! replay, as in `tests/diff_pipeline.rs`).
//!
//! The Chrome-trace golden test pins the export format promises:
//! timestamps sorted nondecreasing, `B`/`E` balanced per thread, and
//! the occupancy counter track present.

use polymem::accel::{
    simulate, simulate_pipelined, simulate_planned, AccelConfig, Trace, TrafficClass,
};
use polymem::cost;
use polymem::ir::Graph;
use polymem::models::{self, WaveNetConfig};
use polymem::passes::manager::{AllocStage, OptStage, PassManager, TileStage};
use polymem::util::fuzzgraph;
use polymem::util::json;

/// Same interpreter-sized zoo as the differential and calibration
/// suites.
fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", models::mlp(2, 12, 8, 4, 2)),
        ("transformer", models::transformer_block(8, 16, 2, 32)),
        ("resnet18", models::resnet18_scaled(1, 16, 8, 10)),
        ("resnet50", models::resnet50_scaled(1, 16, 8, 10)),
        ("mobilenet", models::mobilenet_v1_scaled(1, 16, 8, 10)),
        ("inception", models::inception_stack_scaled(1, 2, 8, 4)),
        (
            "wavenet",
            models::parallel_wavenet_with(WaveNetConfig {
                flows: 2,
                layers_per_flow: 3,
                channels: 4,
                time: 40,
                kernel: 2,
                dilation_cycle: 10,
            }),
        ),
    ]
}

fn planned(cfg: AccelConfig) -> PassManager {
    PassManager {
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

fn tiled(cfg: AccelConfig) -> PassManager {
    PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

fn opted(cfg: AccelConfig) -> PassManager {
    PassManager {
        opt: Some(OptStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg)),
        ..Default::default()
    }
}

/// Per-class bit-exact comparison of an attribution's totals against a
/// replay's counters (`TrafficCounters` equality would also pass, but
/// per-class failure messages name the leaking class).
fn assert_totals_match(
    name: &str,
    mode: &str,
    attr: &polymem::accel::Attribution,
    traffic: &polymem::accel::TrafficCounters,
) {
    let totals = attr.totals();
    for c in TrafficClass::ALL {
        assert_eq!(
            totals.get(c),
            traffic.get(c),
            "{name}/{mode}: attribution does not conserve {}",
            c.label()
        );
    }
}

/// Conservation for one compiled program+plan, across both planned
/// replay modes and against the cost model's prediction.
fn assert_conserved(name: &str, pm: &PassManager, g: Graph, cfg: &AccelConfig) {
    let rep = pm.run(g).unwrap_or_else(|e| panic!("{name}: pipeline failed: {e}"));
    let plan = rep.plan.as_ref().expect("alloc stage configured");

    let mut tr = Trace::new(0); // attribution is independent of the event cap
    let sim = simulate_planned(&rep.program, plan, cfg, Some(&mut tr))
        .unwrap_or_else(|e| panic!("{name}: plan rejected: {e}"));
    assert_totals_match(name, "planned", tr.attr(), &sim.traffic);

    // ... and therefore to the cost model's prediction (calibration)
    let predicted = cost::evaluate(&rep.program, plan, cfg);
    assert_totals_match(name, "predicted", tr.attr(), &predicted.traffic);

    // the pipelined replay reorders time, not bytes
    let mut trp = Trace::new(0);
    let pipe = simulate_pipelined(&rep.program, plan, cfg, Some(&mut trp)).unwrap();
    assert_totals_match(name, "pipelined", trp.attr(), &pipe.traffic);
    assert_totals_match(name, "pipelined-vs-planned", trp.attr(), &sim.traffic);
}

#[test]
fn zoo_conserved_through_planned_pipeline() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        assert_conserved(name, &planned(cfg.clone()), g, &cfg);
    }
}

#[test]
fn zoo_conserved_through_tiled_pipeline() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        assert_conserved(name, &tiled(cfg.clone()), g, &cfg);
    }
}

#[test]
fn zoo_conserved_through_opt_pipeline() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        assert_conserved(name, &opted(cfg.clone()), g, &cfg);
    }
}

#[test]
fn zoo_conserved_through_dynamic_simulate() {
    // the dynamic (furthest-next-use) replay shares the same pairing
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        let rep = PassManager::default()
            .run(g)
            .unwrap_or_else(|e| panic!("{name}: pipeline failed: {e}"));
        let mut tr = Trace::new(0);
        let sim = simulate(&rep.program, &cfg, Some(&mut tr));
        assert_totals_match(name, "dynamic", tr.attr(), &sim.traffic);
    }
}

/// Read a u64 override (decimal or 0x-hex), aborting on unparseable
/// values (same contract as the differential suite).
fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => {
            let parsed = s
                .strip_prefix("0x")
                .or_else(|| s.strip_prefix("0X"))
                .map(|h| u64::from_str_radix(h, 16))
                .unwrap_or_else(|| s.parse());
            parsed.unwrap_or_else(|_| panic!("{name}={s}: not a u64 (decimal or 0x-hex)"))
        }
    }
}

#[test]
fn fuzzed_graphs_conserved() {
    // ≥ 200 seeded random DAGs, same pipeline rotation as the
    // calibration suite: planned / tiled alternate, every seed
    // ≡ 3 mod 16 runs the joint-optimizer configuration
    let base = env_u64("FUZZ_SEED", 0xF0_2255ED);
    let cases = env_u64("FUZZ_CASES", 200);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e3779b97f4a7c15));
        let g = fuzzgraph::fuzz_graph(seed);
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = if seed % 16 == 3 {
            opted(cfg.clone())
        } else if seed % 2 == 0 {
            planned(cfg.clone())
        } else {
            tiled(cfg.clone())
        };
        assert_conserved(&format!("FUZZ_SEED={seed}"), &pm, g, &cfg);
    }
}

/// The 2 MiB cramped configuration (inferentia-like geometry, banks
/// shrunk — same as `tests/integration_tile.rs`).
fn cramped() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4; // 8 MiB -> 2 MiB
    cfg.name = "inferentia-like/4".into();
    cfg
}

#[test]
fn resnet50_conv1_is_an_offchip_hotspot_at_2mib() {
    // the acceptance scenario: full ResNet-50 under a cramped 2 MiB
    // scratchpad — the stem conv (largest feature map) must surface
    // near the top of the per-layer off-chip ranking
    let cfg = cramped();
    let rep = tiled(cfg.clone()).run(models::resnet50(1)).unwrap();
    let plan = rep.plan.as_ref().unwrap();
    let mut tr = Trace::new(0);
    let sim = simulate_planned(&rep.program, plan, &cfg, Some(&mut tr)).unwrap();
    assert_totals_match("resnet50@2MiB", "planned", tr.attr(), &sim.traffic);

    let conv1 = rep
        .program
        .graph
        .nodes()
        .iter()
        .find(|n| n.name == "conv1")
        .expect("resnet50 stem conv present")
        .id;
    let ranked = tr.attr().per_node_offchip();
    let rank = ranked.iter().position(|&(n, _)| n == conv1);
    assert!(
        matches!(rank, Some(r) if r < 3),
        "conv1 not in the top-3 off-chip layers: rank {rank:?} of {}",
        ranked.len()
    );

    // and the rendered table names it
    let table = polymem::report::attribution_table(&rep.program.graph, tr.attr(), 8);
    assert!(table.contains("conv1"), "table missing conv1:\n{table}");
    assert!(table.contains("TOTAL"), "table missing TOTAL row:\n{table}");
}

#[test]
fn chrome_trace_export_is_well_formed() {
    // golden structural properties of the exported JSON: sorted
    // timestamps, balanced B/E nesting per thread, named threads, and
    // the scratchpad counter track — through a serialize/parse
    // round-trip, exactly what `--trace-out` writes
    let cfg = AccelConfig::tiny(8 * 1024);
    let rep = tiled(cfg.clone()).run(models::resnet18_scaled(1, 16, 8, 10)).unwrap();
    let plan = rep.plan.as_ref().unwrap();
    let mut tr = Trace::new(10_000);
    simulate_pipelined(&rep.program, plan, &cfg, Some(&mut tr)).unwrap();
    assert!(!tr.spans().is_empty());
    assert!(!tr.occupancy().is_empty());

    let text = tr.to_chrome_json().to_string_compact();
    let j = json::parse(&text).expect("exported trace must be valid JSON");
    assert_eq!(j.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    let evs = j.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
    assert!(!evs.is_empty());

    let mut last_ts = f64::NEG_INFINITY;
    let mut depth: std::collections::BTreeMap<i64, i64> = Default::default();
    let (mut names, mut counters) = (0usize, 0usize);
    for e in evs {
        let ts = e.get("ts").and_then(|v| v.as_f64()).expect("ts");
        assert!(ts >= last_ts, "timestamps must be nondecreasing");
        last_ts = ts;
        assert_eq!(e.get("pid").and_then(|v| v.as_i64()), Some(1));
        let tid = e.get("tid").and_then(|v| v.as_i64()).expect("tid");
        match e.get("ph").and_then(|v| v.as_str()).expect("ph") {
            "B" => *depth.entry(tid).or_insert(0) += 1,
            "E" => {
                let d = depth.entry(tid).or_insert(0);
                *d -= 1;
                assert!(*d >= 0, "E before matching B on tid {tid}");
            }
            "M" => names += 1,
            "C" => counters += 1,
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(depth.values().all(|&d| d == 0), "unbalanced spans: {depth:?}");
    assert_eq!(names, 2, "compute + dma thread names");
    assert!(counters > 0, "scratchpad occupancy counter track missing");
}

#[test]
fn event_log_bounded_but_attribution_complete() {
    // a tiny event cap must not perturb the byte accounting
    let cfg = AccelConfig::tiny(8 * 1024);
    let rep = tiled(cfg.clone()).run(models::resnet50_scaled(1, 16, 8, 10)).unwrap();
    let plan = rep.plan.as_ref().unwrap();

    let mut capped = Trace::new(4);
    let sim = simulate_planned(&rep.program, plan, &cfg, Some(&mut capped)).unwrap();
    assert!(capped.events().len() <= 4);
    assert!(capped.dropped() > 0, "scaled resnet50 must overflow a 4-event cap");
    assert_totals_match("resnet50-capped", "planned", capped.attr(), &sim.traffic);

    // identical attribution with an uncapped log
    let mut full = Trace::new(usize::MAX);
    simulate_planned(&rep.program, plan, &cfg, Some(&mut full)).unwrap();
    assert_eq!(capped.attr(), full.attr());
}
