//! Property tests for the static scratchpad planner (via
//! `util/prop.rs`): over random operator graphs and random capacities,
//!
//! 1. no two simultaneously-live tensors receive overlapping
//!    `(bank group, offset)` regions — checked here independently of
//!    `alloc::verify_plan`, straight from liveness;
//! 2. every planned program (including inserted spill/reload nests)
//!    passes `ir/verify.rs`;
//! 3. the plan replays through the simulator's planned mode with zero
//!    violations.

use polymem::accel::{simulate_planned, AccelConfig};
use polymem::alloc::{Home, PlanWindow};
use polymem::ir::verify::{verify_graph, verify_program};
use polymem::ir::{Graph, GraphBuilder, Program, TensorId};
use polymem::passes::manager::{AllocStage, PassManager};
use polymem::util::prop::{Gen, Prop};

/// A random DAG of the ops the planner has to cope with: convs (bank
/// requirements), elementwise joins (multi-use tensors), transposes
/// and slices (copy nests), concat (multi-nest nodes).
fn random_graph(g: &mut Gen) -> Graph {
    let mut b = GraphBuilder::new();
    let side = 4 + 4 * g.i64_in(1, 4); // 8..16
    let c = 8i64;
    let x = b.input("x", &[1, c, side, side]);
    let mut frontier = vec![x];
    let n_ops = g.usize_in(3, 10);
    for k in 0..n_ops {
        let cur = *g.choose(&frontier);
        let out = match g.usize_in(0, 6) {
            0 => {
                // conv needs NCHW with the expected channel count
                let shape = b.graph().tensor(cur).shape.clone();
                if shape.len() == 4 && shape[1] == c {
                    let w = b.weight(&format!("w{k}"), &[c, c, 1, 1]);
                    b.conv2d(&format!("conv{k}"), cur, w, 1, 0)
                } else {
                    b.relu(&format!("relu{k}"), cur)
                }
            }
            1 => b.relu(&format!("relu{k}"), cur),
            2 => b.transpose(&format!("tr{k}"), cur, &[0, 2, 3, 1]),
            3 => {
                // join two frontier tensors when shapes agree
                let other = *g.choose(&frontier);
                if b.graph().tensor(other).shape == b.graph().tensor(cur).shape
                    && other != cur
                {
                    b.add(&format!("add{k}"), cur, other)
                } else {
                    b.relu(&format!("relu{k}"), cur)
                }
            }
            4 => {
                let shape = b.graph().tensor(cur).shape.clone();
                if shape.len() == 4 {
                    b.maxpool(&format!("pool{k}"), cur, 1, 1)
                } else {
                    b.identity(&format!("id{k}"), cur)
                }
            }
            _ => b.identity(&format!("id{k}"), cur),
        };
        frontier.push(out);
    }
    // join all frontier leaves (tensors nothing read) so the graph has
    // no dead intermediates, then mark one output
    let leaves: Vec<TensorId> = frontier
        .iter()
        .copied()
        .filter(|t| b.graph().consumers(*t).is_empty())
        .collect();
    let mut acc = leaves[0];
    for (j, &l) in leaves.iter().enumerate().skip(1) {
        let a_shape = b.graph().tensor(acc).shape.clone();
        let l_shape = b.graph().tensor(l).shape.clone();
        acc = if a_shape == l_shape {
            b.add(&format!("join{j}"), acc, l)
        } else {
            let numel: i64 = l_shape.iter().product();
            let flat = b.reshape(&format!("flat{j}"), l, &[1, numel]);
            let a_numel: i64 = a_shape.iter().product();
            let a_flat = b.reshape(&format!("aflat{j}"), acc, &[1, a_numel]);
            b.concat(&format!("cat{j}"), &[a_flat, flat], 1)
        };
    }
    b.mark_output(acc);
    b.finish()
}

fn random_cfg(g: &mut Gen) -> AccelConfig {
    // between "everything fits" and "almost nothing fits"
    let mut cfg = AccelConfig::tiny(1 << g.usize_in(12, 22));
    cfg.bank_bytes = cfg.bank_bytes.max(polymem::alloc::ALLOC_ALIGN);
    cfg
}

#[test]
fn planned_regions_never_overlap_and_ir_verifies() {
    Prop::new("alloc: disjoint regions + valid IR", 40).check(|g| {
        let graph = random_graph(g);
        verify_graph(&graph).expect("generator built a valid graph");
        let cfg = random_cfg(g);
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(graph).expect("pipeline");
        let plan = rep.plan.as_ref().expect("alloc ran");
        let prog: &Program = &rep.program;

        // (2) planned program passes ir/verify.rs
        verify_graph(&prog.graph).expect("planned graph verifies");
        verify_program(prog).expect("planned program verifies");

        // (1) independent overlap check: windows that share a live
        // position must have disjoint regions per group
        let flat: Vec<(TensorId, PlanWindow)> = plan
            .tensors
            .iter()
            .flat_map(|(t, tp)| tp.windows.iter().map(|w| (*t, *w)))
            .collect();
        for (i, (ta, wa)) in flat.iter().enumerate() {
            let Home::Scratch(ra) = wa.home else { continue };
            for (tb, wb) in flat.iter().skip(i + 1) {
                let Home::Scratch(rb) = wb.home else { continue };
                if ra.group != rb.group || ta == tb {
                    continue;
                }
                // strictly-shared live position (beyond the
                // operand->result handoff point)
                let s = wa.start.max(wb.start);
                let e = wa.end.min(wb.end);
                if s >= e {
                    continue;
                }
                let addr_disjoint = ra.end() <= rb.offset || rb.end() <= ra.offset;
                assert!(
                    addr_disjoint,
                    "{ta:?}@{ra:?} and {tb:?}@{rb:?} overlap while both live \
                     (windows {wa:?} / {wb:?})"
                );
            }
        }

        // (3) zero-violation replay
        let sim = simulate_planned(prog, plan, &cfg, None).expect("planned replay");
        assert!(sim.peak_scratchpad <= cfg.scratchpad_bytes());
    });
}

#[test]
fn plan_windows_cover_every_touch() {
    Prop::new("alloc: residency covers schedule", 25).check(|g| {
        let graph = random_graph(g);
        let cfg = random_cfg(g);
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(graph).expect("pipeline");
        let plan = rep.plan.as_ref().unwrap();
        for (pos, nest) in rep.program.nests.iter().enumerate() {
            for load in nest.body.loads() {
                for piece in &load.pieces {
                    if let Some(t) = piece.tensor {
                        assert!(
                            plan.window_at(t, pos).is_some(),
                            "{t:?} untracked at {pos}"
                        );
                    }
                }
            }
            assert!(plan.window_at(nest.store.tensor, pos).is_some());
        }
    });
}
