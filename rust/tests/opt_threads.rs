//! Thread-count invariance of the joint search.
//!
//! The determinism contract of `opt::search`'s parallel candidate
//! realization: the worker pool affects wall time only. For the full
//! 7-builder zoo and ≥ 50 fuzzed graphs, running the search with 1, 2
//! and 8 threads must produce the identical winning decision string,
//! `best_offchip`, best-cost `trajectory`, `GenerationStats` rows, and
//! a bit-exact audit trail — which is what lets the differential
//! oracle hold the opt pipeline to bit-identity at any thread count.
//!
//! Reproduce a fuzz failure: `FUZZ_SEED=<seed> FUZZ_CASES=1 cargo test
//! --test opt_threads fuzzed`.

use polymem::accel::AccelConfig;
use polymem::alloc::AllocOpts;
use polymem::ir::loopnest::Program;
use polymem::ir::Graph;
use polymem::models::{self, WaveNetConfig};
use polymem::opt::{search, OptOpts, OptOutcome};
use polymem::passes::dme::run_dme;
use polymem::passes::manager::BankMode;
use polymem::passes::BankConfig;
use polymem::tile::TileOpts;
use polymem::util::fuzzgraph;

/// The same 7 interpreter-sized builders the differential suite uses.
fn zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("mlp", models::mlp(2, 12, 8, 4, 2)),
        ("transformer", models::transformer_block(8, 16, 2, 32)),
        ("resnet18", models::resnet18_scaled(1, 16, 8, 10)),
        ("resnet50", models::resnet50_scaled(1, 16, 8, 10)),
        ("mobilenet", models::mobilenet_v1_scaled(1, 16, 8, 10)),
        ("inception", models::inception_stack_scaled(1, 2, 8, 4)),
        (
            "wavenet",
            models::parallel_wavenet_with(WaveNetConfig {
                flows: 2,
                layers_per_flow: 3,
                channels: 4,
                time: 40,
                kernel: 2,
                dilation_cycle: 10,
            }),
        ),
    ]
}

/// What the manager's opt stage sees: the post-DME snapshot.
fn post_dme(g: Graph) -> Program {
    let mut p = Program::lower(g);
    run_dme(&mut p);
    p
}

fn run(
    prog: &Program,
    cfg: &AccelConfig,
    bank_mode: BankMode,
    threads: usize,
) -> Result<OptOutcome, polymem::alloc::PlanError> {
    search(
        prog,
        bank_mode,
        &BankConfig::default(),
        cfg,
        &TileOpts::default(),
        &AllocOpts::default(),
        &OptOpts { threads, ..OptOpts::default() },
    )
}

/// Assert 2- and 8-thread searches land exactly where 1 thread does.
fn assert_invariant(name: &str, prog: &Program, cfg: &AccelConfig, bank_mode: BankMode) {
    let base = run(prog, cfg, bank_mode, 1);
    for threads in [2usize, 8] {
        let alt = run(prog, cfg, bank_mode, threads);
        match (&base, &alt) {
            (Ok(b), Ok(a)) => {
                let (bs, als) = (&b.stats, &a.stats);
                assert_eq!(bs.decision, als.decision, "{name} t={threads}: decision");
                assert_eq!(bs.best_offchip, als.best_offchip, "{name} t={threads}: best_offchip");
                assert_eq!(
                    bs.best_pipelined_seconds.to_bits(),
                    als.best_pipelined_seconds.to_bits(),
                    "{name} t={threads}: best_pipelined_seconds"
                );
                assert_eq!(
                    bs.baseline_offchip, als.baseline_offchip,
                    "{name} t={threads}: baseline_offchip"
                );
                assert_eq!(bs.candidates, als.candidates, "{name} t={threads}: candidates");
                assert_eq!(bs.pruned, als.pruned, "{name} t={threads}: pruned");
                assert_eq!(bs.trajectory, als.trajectory, "{name} t={threads}: trajectory");
                assert_eq!(bs.generations, als.generations, "{name} t={threads}: generations");
                // the winning artifact itself, not just its score
                assert_eq!(
                    b.alloc_opts.lookahead, a.alloc_opts.lookahead,
                    "{name} t={threads}: winner lookahead"
                );
                assert_eq!(
                    b.program.nests.len(),
                    a.program.nests.len(),
                    "{name} t={threads}: winner program shape"
                );
                // audit trail: same candidates in the same order with
                // bit-exact scores
                assert_eq!(b.audit.len(), a.audit.len(), "{name} t={threads}: audit length");
                for ((d1, c1), (d2, c2)) in b.audit.iter().zip(&a.audit) {
                    assert_eq!(d1.describe(), d2.describe(), "{name} t={threads}: audit order");
                    assert!(
                        c1.bits_eq(c2),
                        "{name} t={threads}: audit score diverged for {}",
                        d1.describe()
                    );
                }
            }
            (Err(be), Err(ae)) => {
                // a seed that cannot plan must fail identically at any
                // thread count
                assert_eq!(
                    be.to_string(),
                    ae.to_string(),
                    "{name} t={threads}: error diverged"
                );
            }
            (Ok(_), Err(e)) => panic!("{name} t={threads}: parallel search failed: {e}"),
            (Err(e), Ok(_)) => panic!("{name} t={threads}: only serial search failed: {e}"),
        }
    }
}

#[test]
fn zoo_search_is_thread_count_invariant() {
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo() {
        let prog = post_dme(g);
        assert_invariant(name, &prog, &cfg, BankMode::Global);
    }
}

#[test]
fn zoo_search_is_thread_count_invariant_under_local_banking() {
    // local mode maximizes spliced MemCopy nodes, so the shared
    // tier-1 staged artifact carries the most extra structure here
    let cfg = AccelConfig::tiny(8 * 1024);
    for (name, g) in zoo().into_iter().take(3) {
        let prog = post_dme(g);
        assert_invariant(name, &prog, &cfg, BankMode::Local);
    }
}

#[test]
fn shard_search_is_thread_count_invariant_over_the_cut_axis() {
    // the cut-point axis rides on the same worker pool: the sharded
    // winner (cuts, per-stage decisions, combined cost, search shape)
    // must be identical at any thread count
    use polymem::shard::{search_sharded, ShardOpts};
    let cfg = AccelConfig::tiny(8 * 1024).with_cores(2);
    for (name, g) in zoo().into_iter().take(3) {
        let at = |threads: usize| {
            search_sharded(&g, &cfg, &ShardOpts { joint: true, threads, ..ShardOpts::default() })
                .unwrap_or_else(|e| panic!("{name} t={threads}: {e}"))
        };
        let base = at(1);
        for threads in [2usize, 8] {
            let alt = at(threads);
            assert_eq!(base.cuts, alt.cuts, "{name} t={threads}: cuts");
            assert_eq!(base.describe(), alt.describe(), "{name} t={threads}: decision");
            assert!(base.cost.bits_eq(&alt.cost), "{name} t={threads}: combined cost");
            let (b, a) = (&base.stats, &alt.stats);
            assert_eq!(
                (b.candidates, b.evaluated, b.pruned, b.infeasible),
                (a.candidates, a.evaluated, a.pruned, a.infeasible),
                "{name} t={threads}: search shape"
            );
            assert_eq!(
                (b.stage_compiles, b.memo_hits),
                (a.stage_compiles, a.memo_hits),
                "{name} t={threads}: memo shape"
            );
        }
    }
}

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Err(_) => default,
        Ok(s) => {
            let parsed = s
                .strip_prefix("0x")
                .or_else(|| s.strip_prefix("0X"))
                .map(|h| u64::from_str_radix(h, 16))
                .unwrap_or_else(|| s.parse());
            parsed.unwrap_or_else(|_| panic!("{name}={s}: not a u64 (decimal or 0x-hex)"))
        }
    }
}

#[test]
fn fuzzed_search_is_thread_count_invariant() {
    // ≥ 50 seeded random DAGs on a cramped 4 KiB scratchpad so tiling,
    // staging and spill decisions all engage; FUZZ_SEED / FUZZ_CASES
    // override for replay, same scheme as the differential suite
    let base = env_u64("FUZZ_SEED", 0x0077_11EA0);
    let cases = env_u64("FUZZ_CASES", 50);
    let cfg = AccelConfig::tiny(4 * 1024);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e3779b97f4a7c15));
        let g = fuzzgraph::fuzz_graph(seed);
        let prog = post_dme(g);
        let bank_mode = if seed % 2 == 0 { BankMode::Global } else { BankMode::Local };
        assert_invariant(&format!("FUZZ_SEED={seed}"), &prog, &cfg, bank_mode);
    }
}
