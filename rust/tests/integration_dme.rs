//! Integration: DME across models — semantics preservation checked by
//! an element-fingerprint interpreter over the copy plumbing, and the
//! paper's E1 invariants on the WaveNet workload.

use polymem::ir::loopnest::{Body, Program};
use polymem::ir::verify::verify_program;
use polymem::ir::{Graph, TensorKind};
use polymem::passes::dme::run_dme;
use std::collections::BTreeMap;

/// Interpret all copy nests: every input/weight element gets a unique
/// fingerprint; outputs collect whatever the copy plumbing routes to
/// them. Compute nests are opaque (not interpreted), so only graphs
/// whose outputs are copy-reachable give full coverage — but partial
/// coverage still validates every rewritten load on the way.
fn fingerprint_outputs(prog: &Program) -> BTreeMap<(u32, i64), i64> {
    let g = &prog.graph;
    let mut mem: BTreeMap<(u32, i64), i64> = BTreeMap::new();
    for t in g.tensors() {
        if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
            for k in 0..t.numel() {
                mem.insert((t.id.0, k), ((t.id.0 as i64) << 40) | k);
            }
        }
    }
    for nest in &prog.nests {
        let out = nest.store.tensor;
        let out_dom = polymem::poly::IterDomain::new(&g.tensor(out).shape);
        if let Body::Copy { load } = &nest.body {
            for p in nest.domain.points() {
                let (src_t, src_idx) = load.at(&p).expect("uncovered point");
                let v = match src_t {
                    Some(s) => {
                        let s_dom = polymem::poly::IterDomain::new(&g.tensor(s).shape);
                        let key = (s.0, s_dom.linearize(&src_idx));
                        // compute outputs are never interpreted: give each
                        // element a deterministic fingerprint instead, so
                        // reads through rewritten maps stay comparable
                        mem.get(&key)
                            .copied()
                            .unwrap_or(((key.0 as i64) << 40) | key.1 | (1 << 62))
                    }
                    None => 0,
                };
                mem.insert((out.0, out_dom.linearize(&nest.store.map.apply(&p))), v);
            }
        }
    }
    let outs: std::collections::HashSet<u32> = g.outputs().iter().map(|t| t.0).collect();
    mem.into_iter().filter(|((t, _), _)| outs.contains(t)).collect()
}

fn assert_dme_preserves(graph: Graph) -> polymem::passes::dme::DmeStats {
    let before_prog = Program::lower(graph.clone());
    verify_program(&before_prog).unwrap();
    let before = fingerprint_outputs(&before_prog);
    let mut prog = Program::lower(graph);
    let stats = run_dme(&mut prog);
    verify_program(&prog).unwrap();
    let after = fingerprint_outputs(&prog);
    assert_eq!(before, after, "DME changed copy-plumbing semantics");
    stats
}

#[test]
fn wavenet_small_preserved() {
    use polymem::models::wavenet::{parallel_wavenet_with, WaveNetConfig};
    let cfg = WaveNetConfig {
        flows: 2,
        layers_per_flow: 2,
        channels: 4,
        time: 24,
        kernel: 2,
        dilation_cycle: 2,
    };
    let stats = assert_dme_preserves(parallel_wavenet_with(cfg));
    assert!(stats.pairs_eliminated > 0);
}

#[test]
fn wavenet_full_headline() {
    // the paper's E1 headline on the full-size graph (no interpreter —
    // too many points — but full verification)
    let mut prog = Program::lower(polymem::models::parallel_wavenet());
    let stats = run_dme(&mut prog);
    verify_program(&prog).unwrap();
    assert_eq!(stats.pairs_before, 124);
    assert_eq!(stats.pairs_eliminated, 123);
    let mb = stats.bytes_before as f64 / 1e6;
    assert!((140.0..152.0).contains(&mb), "{mb:.1} MB");
    // post-DME program has exactly one copy nest left (the output
    // layout transpose) and it writes the model output
    let survivors: Vec<_> = prog.copy_nests().collect();
    assert_eq!(survivors.len(), 1);
    assert_eq!(
        prog.graph.tensor(survivors[0].store.tensor).kind,
        TensorKind::Output
    );
}

#[test]
fn transformer_preserved() {
    let g = polymem::models::transformer_block(8, 16, 2, 32);
    let stats = assert_dme_preserves(g);
    assert!(stats.pairs_eliminated > 0);
}

#[test]
fn resnet_flatten_eliminated() {
    // ResNet-50's only copy nest is the GAP→FC flatten; it reads a
    // compute output and is absorbed into the matmul's access map.
    let mut prog = Program::lower(polymem::models::resnet18(1));
    let stats = run_dme(&mut prog);
    verify_program(&prog).unwrap();
    assert_eq!(stats.pairs_before, 1);
    assert_eq!(stats.pairs_eliminated, 1);
    assert_eq!(prog.load_store_pairs(), 0);
}

#[test]
fn dme_idempotent() {
    let g = polymem::models::transformer_block(16, 32, 2, 64);
    let mut prog = Program::lower(g);
    let s1 = run_dme(&mut prog);
    let s2 = run_dme(&mut prog);
    assert!(s1.pairs_eliminated > 0);
    assert_eq!(s2.pairs_eliminated, 0, "second run must be a no-op");
    verify_program(&prog).unwrap();
}

#[test]
fn dme_respects_outputs_everywhere() {
    // mark EVERY memory-op output as a graph output: nothing eliminable
    use polymem::ir::GraphBuilder;
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[4, 6]);
    let t = b.transpose("t", x, &[1, 0]);
    let r = b.reshape("r", t, &[3, 8]);
    b.mark_output(t);
    b.mark_output(r);
    let mut prog = Program::lower(b.finish());
    let stats = run_dme(&mut prog);
    assert_eq!(stats.pairs_eliminated, 0);
    verify_program(&prog).unwrap();
}
