//! Integration: DME across models — semantics preservation checked by
//! the shared reference interpreter (`polymem::interp`, which executes
//! compute nests too, unlike the copy-only fingerprint walker this
//! file used to carry), and the paper's E1 invariants on the WaveNet
//! workload.

use polymem::interp::diff::assert_equivalent;
use polymem::ir::loopnest::Program;
use polymem::ir::verify::verify_program;
use polymem::ir::{Graph, TensorKind};
use polymem::passes::dme::run_dme;

fn assert_dme_preserves(graph: Graph) -> polymem::passes::dme::DmeStats {
    let mut prog = Program::lower(graph);
    verify_program(&prog).unwrap();
    let before = prog.clone();
    let stats = run_dme(&mut prog);
    verify_program(&prog).unwrap();
    assert_equivalent(&before, &prog, 0xA11);
    stats
}

#[test]
fn wavenet_small_preserved() {
    use polymem::models::wavenet::{parallel_wavenet_with, WaveNetConfig};
    let cfg = WaveNetConfig {
        flows: 2,
        layers_per_flow: 2,
        channels: 4,
        time: 24,
        kernel: 2,
        dilation_cycle: 2,
    };
    let stats = assert_dme_preserves(parallel_wavenet_with(cfg));
    assert!(stats.pairs_eliminated > 0);
}

#[test]
fn wavenet_full_headline() {
    // the paper's E1 headline on the full-size graph (no interpreter —
    // too many points — but full verification)
    let mut prog = Program::lower(polymem::models::parallel_wavenet());
    let stats = run_dme(&mut prog);
    verify_program(&prog).unwrap();
    assert_eq!(stats.pairs_before, 124);
    assert_eq!(stats.pairs_eliminated, 123);
    let mb = stats.bytes_before as f64 / 1e6;
    assert!((140.0..152.0).contains(&mb), "{mb:.1} MB");
    // post-DME program has exactly one copy nest left (the output
    // layout transpose) and it writes the model output
    let survivors: Vec<_> = prog.copy_nests().collect();
    assert_eq!(survivors.len(), 1);
    assert_eq!(
        prog.graph.tensor(survivors[0].store.tensor).kind,
        TensorKind::Output
    );
}

#[test]
fn transformer_preserved() {
    let g = polymem::models::transformer_block(8, 16, 2, 32);
    let stats = assert_dme_preserves(g);
    assert!(stats.pairs_eliminated > 0);
}

#[test]
fn resnet_flatten_eliminated() {
    // ResNet-50's only copy nest is the GAP→FC flatten; it reads a
    // compute output and is absorbed into the matmul's access map.
    let mut prog = Program::lower(polymem::models::resnet18(1));
    let stats = run_dme(&mut prog);
    verify_program(&prog).unwrap();
    assert_eq!(stats.pairs_before, 1);
    assert_eq!(stats.pairs_eliminated, 1);
    assert_eq!(prog.load_store_pairs(), 0);
}

#[test]
fn dme_idempotent() {
    let g = polymem::models::transformer_block(16, 32, 2, 64);
    let mut prog = Program::lower(g);
    let s1 = run_dme(&mut prog);
    let s2 = run_dme(&mut prog);
    assert!(s1.pairs_eliminated > 0);
    assert_eq!(s2.pairs_eliminated, 0, "second run must be a no-op");
    verify_program(&prog).unwrap();
}

#[test]
fn dme_respects_outputs_everywhere() {
    // mark EVERY memory-op output as a graph output: nothing eliminable
    use polymem::ir::GraphBuilder;
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[4, 6]);
    let t = b.transpose("t", x, &[1, 0]);
    let r = b.reshape("r", t, &[3, 8]);
    b.mark_output(t);
    b.mark_output(r);
    let mut prog = Program::lower(b.finish());
    let stats = run_dme(&mut prog);
    assert_eq!(stats.pairs_eliminated, 0);
    verify_program(&prog).unwrap();
}
