//! Shared bank-mapping vocabulary (§2.2).
//!
//! ## The placement model
//!
//! The accelerator's scratchpad is organized as `B` banks with disjoint
//! address spaces; each bank feeds one partition of the compute fabric.
//! A tensor staged on chip is *spread* across banks along one of its
//! dimensions — [`Placement::dim`] — and sits in one of two physical
//! bank groups:
//!
//! * [`Align::Row`] — the banks wired to the systolic array's **row**
//!   inputs. Operand tensors of matmul/conv **must** be Row-aligned on
//!   their contraction/channel dimension (the paper: "data from
//!   different channels of the feature map and weights must be mapped
//!   to different memory banks").
//! * [`Align::Col`] — the banks fed by the array's **column** outputs
//!   (PSUM eviction side). Conv/matmul results arrive here, spread
//!   along the output-channel dimension ("the result of the Conv2D
//!   needs to be spread across several banks, guided by the different
//!   output channels").
//!
//! Moving a tensor between placements is an inter-bank copy, which on
//! this architecture transits the memory system (the paper: "data
//! movement between different banks is very slow through the main
//! memory").
//!
//! ## The compiler degree of freedom
//!
//! The eviction DMA can deposit a result into **either** group at equal
//! cost — *if the destination is known when the operator is scheduled*.
//! That is precisely what global propagation (§2.2) provides and local
//! mapping lacks. The one hardware restriction we model: results wider
//! than [`BankConfig::col_flex_limit`] output channels are streamed
//! through more PSUM column groups than the crossbar can redirect, so
//! their eviction is pinned to [`Align::Col`] — these are the residual
//! copies that survive global mapping (the paper reports 24% of
//! on-chip copy bytes remaining on ResNet-50).

use crate::ir::graph::{Graph, Node};
use crate::ir::op::OpKind;
use crate::ir::tensor::TensorId;
use std::collections::BTreeMap;

/// Physical bank group.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Align {
    Row,
    Col,
}

/// How a tensor is spread across scratchpad banks.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Placement {
    /// Tensor dimension distributed across banks.
    pub dim: usize,
    /// Bank group the tensor occupies.
    pub align: Align,
}

impl Placement {
    pub fn row(dim: usize) -> Placement {
        Placement { dim, align: Align::Row }
    }

    pub fn col(dim: usize) -> Placement {
        Placement { dim, align: Align::Col }
    }
}

/// Bank-mapping configuration (chip parameters relevant to the passes).
#[derive(Clone, Copy, Debug)]
pub struct BankConfig {
    /// Number of scratchpad banks per group.
    pub banks: usize,
    /// Above this output-channel count a conv/matmul result cannot be
    /// redirected at eviction time and is pinned to `Col`.
    pub col_flex_limit: i64,
}

impl Default for BankConfig {
    fn default() -> Self {
        // 16 banks per group; the eviction crossbar covers 4 column
        // groups of 128 PEs → 512 output channels.
        BankConfig { banks: 16, col_flex_limit: 512 }
    }
}

/// The result of a bank-mapping pass: a placement per staged tensor and
/// a graph extended with the `MemCopy` nodes realizing the remaining
/// inter-bank moves. Both the local baseline and global mapping produce
/// this, so the traffic simulator treats them identically.
#[derive(Clone, Debug)]
pub struct BankAssignment {
    pub graph: Graph,
    pub placements: BTreeMap<TensorId, Placement>,
    pub stats: BankStats,
}

/// Pass statistics — inputs to the paper's E2 table.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BankStats {
    /// Inter-bank remap copies inserted.
    pub copies_inserted: usize,
    /// Total bytes moved by those copies.
    pub copy_bytes: i64,
    /// Def-use edges whose placements agree (no copy).
    pub edges_matched: usize,
    /// Fixed-point iterations (global mapping only).
    pub iterations: usize,
}

/// The hard placement requirement an operator imposes on one of its
/// *activation* inputs (weights are staged by the DMA directly into the
/// required arrangement and never pay a remap).
pub fn input_requirement(node: &Node, input_pos: usize) -> Option<Placement> {
    match &node.kind {
        // MXU operators: activation operand must be Row-aligned on the
        // contraction/channel dim.
        OpKind::Conv2d { .. } | OpKind::DepthwiseConv2d { .. } => {
            (input_pos == 0).then_some(Placement::row(1))
        }
        OpKind::Conv1d { .. } => (input_pos == 0).then_some(Placement::row(1)),
        OpKind::MatMul => match input_pos {
            0 => Some(Placement::row(1)), // [M, K] spread by K
            _ => None,                    // weight operand
        },
        // Pooling engine reads channel-parallel, Row side.
        OpKind::Pool { .. } | OpKind::GlobalAvgPool => Some(Placement::row(1)),
        _ => None,
    }
}

/// True when `input_pos` of this node is a weight-like operand
/// (excluded from remap-copy accounting).
pub fn is_weight_operand(g: &Graph, node: &Node, input_pos: usize) -> bool {
    matches!(
        g.tensor(node.inputs[input_pos]).kind,
        crate::ir::tensor::TensorKind::Weight
    )
}

/// The output-channel dimension of an MXU/pool operator, if any.
pub fn out_channel_dim(kind: &OpKind) -> Option<usize> {
    match kind {
        OpKind::Conv2d { .. }
        | OpKind::DepthwiseConv2d { .. }
        | OpKind::Conv1d { .. }
        | OpKind::Pool { .. }
        | OpKind::GlobalAvgPool => Some(1),
        OpKind::MatMul => Some(1),
        _ => None,
    }
}

/// Whether this node's result eviction is pinned to `Col`
/// (output-channel count beyond the crossbar's flexibility).
pub fn forced_col(g: &Graph, node: &Node, cfg: &BankConfig) -> bool {
    match out_channel_dim(&node.kind) {
        Some(d) if is_mxu(&node.kind) => {
            g.tensor(node.output).shape[d] > cfg.col_flex_limit
        }
        _ => false,
    }
}

/// MXU (systolic array) operators.
pub fn is_mxu(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Conv2d { .. }
            | OpKind::DepthwiseConv2d { .. }
            | OpKind::Conv1d { .. }
            | OpKind::MatMul
    )
}

/// Vector-engine operators: placement-transparent, but all activation
/// operands and the result must share one placement.
pub fn is_vector(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Unary(_)
            | OpKind::Binary(_)
            | OpKind::BatchNorm
            | OpKind::BiasAdd
            | OpKind::Softmax
    )
}

/// Transfer a placement **forward** through a memory-bound operator:
/// given the placement of the input, the placement of the output that
/// requires no inter-bank movement. `None` = the op inherently reshuffles
/// the banked dim (a copy is unavoidable on this edge).
pub fn transfer_forward(kind: &OpKind, in_shape: &[i64], p: Placement) -> Option<Placement> {
    match kind {
        OpKind::Identity | OpKind::MemCopy => Some(p),
        OpKind::Transpose { perm } => {
            // output dim d' reads input dim perm[d']; banked input dim p.dim
            // appears at output position d' with perm[d'] == p.dim
            let d2 = perm.iter().position(|&q| q == p.dim)?;
            Some(Placement { dim: d2, align: p.align })
        }
        OpKind::Reshape { shape } => {
            let d2 = reshape_dim_map(in_shape, shape, p.dim)?;
            Some(Placement { dim: d2, align: p.align })
        }
        OpKind::Tile { reps } => {
            // tiling along the banked dim replicates across banks → reshuffle
            (reps[p.dim] == 1).then_some(p)
        }
        OpKind::Repeat { axis, .. } => (*axis != p.dim).then_some(p),
        OpKind::StridedSlice { begin, stride, .. } => {
            // slicing the banked dim keeps bank alignment only for a
            // stride-1 prefix starting at a bank boundary (begin 0)
            if begin[p.dim] == 0 && stride[p.dim] == 1 {
                Some(p)
            } else {
                None
            }
        }
        OpKind::Concat { axis } => (*axis != p.dim).then_some(p),
        OpKind::Pad { lo, .. } => (lo[p.dim] == 0).then_some(p),
        _ => None, // not a memory-bound op
    }
}

/// Transfer a placement **backward** through a memory-bound operator:
/// the input placement that produces the given output placement with no
/// inter-bank movement.
pub fn transfer_backward(kind: &OpKind, in_shape: &[i64], out_shape: &[i64], p: Placement) -> Option<Placement> {
    match kind {
        OpKind::Identity | OpKind::MemCopy => Some(p),
        OpKind::Transpose { perm } => Some(Placement { dim: perm[p.dim], align: p.align }),
        OpKind::Reshape { .. } => {
            let d2 = reshape_dim_map(out_shape, in_shape, p.dim)?;
            Some(Placement { dim: d2, align: p.align })
        }
        OpKind::Tile { reps } => (reps[p.dim] == 1).then_some(p),
        OpKind::Repeat { axis, .. } => (*axis != p.dim).then_some(p),
        OpKind::StridedSlice { begin, stride, .. } => {
            if begin[p.dim] == 0 && stride[p.dim] == 1 {
                Some(p)
            } else {
                None
            }
        }
        OpKind::Concat { axis } => (*axis != p.dim).then_some(p),
        OpKind::Pad { lo, .. } => (lo[p.dim] == 0).then_some(p),
        _ => None,
    }
}

/// Map a dimension through a reshape: dim `d` of `from` corresponds to a
/// dim of `to` iff the row-major prefix products up to `d` and the
/// extents match (the dimension survives as a whole unit).
fn reshape_dim_map(from: &[i64], to: &[i64], d: usize) -> Option<usize> {
    let prefix: i64 = from[..d].iter().product();
    let mut acc = 1i64;
    for (k, &e) in to.iter().enumerate() {
        if acc == prefix && e == from[d] {
            return Some(k);
        }
        acc *= e;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;

    #[test]
    fn requirements_for_conv_and_matmul() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 8, 8]);
        let w = b.weight("w", &[16, 8, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let _ = c;
        let g = b.finish();
        let node = &g.nodes()[0];
        assert_eq!(input_requirement(node, 0), Some(Placement::row(1)));
        assert_eq!(input_requirement(node, 1), None);
        assert!(is_weight_operand(&g, node, 1));
        assert!(!is_weight_operand(&g, node, 0));
        assert!(is_mxu(&node.kind));
    }

    #[test]
    fn forced_col_thresholds() {
        let cfg = BankConfig::default();
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 64, 8, 8]);
        let w1 = b.weight("w1", &[256, 64, 1, 1]);
        let c1 = b.conv2d("narrow", x, w1, 1, 0);
        let w2 = b.weight("w2", &[1024, 256, 1, 1]);
        let _c2 = b.conv2d("wide", c1, w2, 1, 0);
        let g = b.finish();
        let narrow = g.nodes().iter().find(|n| n.name == "narrow").unwrap();
        let wide = g.nodes().iter().find(|n| n.name == "wide").unwrap();
        assert!(!forced_col(&g, narrow, &cfg));
        assert!(forced_col(&g, wide, &cfg));
    }

    #[test]
    fn transpose_transfer_roundtrip() {
        let kind = OpKind::Transpose { perm: vec![0, 2, 3, 1] };
        let in_shape = [1, 64, 8, 8];
        let out_shape = [1, 8, 8, 64];
        let p = Placement::row(1);
        let fwd = transfer_forward(&kind, &in_shape, p).unwrap();
        assert_eq!(fwd.dim, 3); // channel dim moved to position 3
        let back = transfer_backward(&kind, &in_shape, &out_shape, fwd).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn reshape_transfer() {
        // [N, C, H, W] -> [N, C, H*W]: C survives
        let kind = OpKind::Reshape { shape: vec![1, 64, 64] };
        let p = transfer_forward(&kind, &[1, 64, 8, 8], Placement::row(1)).unwrap();
        assert_eq!(p.dim, 1);
        // [N, C, H, W] -> [N, C*H*W]: C destroyed
        let kind2 = OpKind::Reshape { shape: vec![1, 4096] };
        assert!(transfer_forward(&kind2, &[1, 64, 8, 8], Placement::row(1)).is_none());
        // flatten [N, C, 1, 1] -> [N, C] keeps C
        let kind3 = OpKind::Reshape { shape: vec![1, 2048] };
        let p3 = transfer_forward(&kind3, &[1, 2048, 1, 1], Placement::row(1)).unwrap();
        assert_eq!(p3.dim, 1);
    }

    #[test]
    fn slice_tile_pad_transfers() {
        let ss = OpKind::StridedSlice {
            begin: vec![0, 0],
            end: vec![2, 8],
            stride: vec![1, 1],
        };
        assert!(transfer_forward(&ss, &[4, 8], Placement::row(0)).is_some());
        let ss2 = OpKind::StridedSlice {
            begin: vec![2, 0],
            end: vec![4, 8],
            stride: vec![1, 1],
        };
        assert!(transfer_forward(&ss2, &[4, 8], Placement::row(0)).is_none());
        assert!(transfer_forward(&ss2, &[4, 8], Placement::row(1)).is_some());

        let tile = OpKind::Tile { reps: vec![2, 1] };
        assert!(transfer_forward(&tile, &[4, 8], Placement::row(0)).is_none());
        assert!(transfer_forward(&tile, &[4, 8], Placement::row(1)).is_some());

        let pad = OpKind::Pad { lo: vec![0, 2], hi: vec![0, 2] };
        assert!(transfer_forward(&pad, &[4, 8], Placement::row(1)).is_none());
        assert!(transfer_forward(&pad, &[4, 8], Placement::row(0)).is_some());
    }

    #[test]
    fn vector_classification() {
        assert!(is_vector(&OpKind::BatchNorm));
        assert!(is_vector(&OpKind::Binary(crate::ir::op::BinaryFn::Add)));
        assert!(!is_vector(&OpKind::MatMul));
        assert!(!is_vector(&OpKind::Identity));
    }
}
