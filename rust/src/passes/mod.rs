//! Compiler passes — the paper's contribution.
//!
//! * [`dme`] — §2.1 data-movement elimination: load/store pair removal
//!   by affine reverse + composition, iterated to a fixed point.
//! * [`bank`] — shared bank-mapping vocabulary (placements, per-op
//!   requirements, transfer functions through memory-bound operators).
//! * [`bank_local`] — the paper's evaluation baseline: per-operator
//!   local mapping, no propagation; every mismatched def-use edge pays
//!   an inter-bank remap copy.
//! * [`bank_global`] — §2.2 global mapping: fixed-point propagation of
//!   bank mappings across the operator graph; residual conflicts
//!   materialize explicit `MemCopy` nodes.
//! * [`liveness`] — tensor live ranges over the nest schedule, used by
//!   the accelerator simulator's scratchpad allocator and the static
//!   planner's residency windows.
//! * [`manager`] — ordered pass driver with per-pass statistics and
//!   inter-pass verification; optionally runs the static scratchpad
//!   planner ([`crate::alloc`]) as a final stage after bank mapping.

pub mod bank;
pub mod bank_global;
pub mod bank_local;
pub mod dme;
pub mod liveness;
pub mod manager;

pub use bank::{Align, BankAssignment, BankConfig, Placement};
pub use dme::{run_dme, DmeStats};
pub use manager::{AllocStage, OptStage, PassManager, PassReport, TileStage};
