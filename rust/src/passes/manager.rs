//! Pass manager: ordered pipeline with per-pass statistics and
//! inter-pass verification — the driver `polymem compile` runs.

use super::bank::{BankAssignment, BankConfig};
use super::dme::{run_dme, DmeStats};
use crate::accel::config::AccelConfig;
use crate::alloc::{plan_memory, AllocOpts, MemoryPlan};
use crate::ir::loopnest::Program;
use crate::ir::verify::{verify_graph, verify_program, VerifyError};
use crate::tile::{run_tiling, TileOpts, TileStats};
use std::time::{Duration, Instant};

/// Which bank-mapping algorithm to run (the paper's E2 comparison).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BankMode {
    /// No bank mapping at all (for ablations).
    None,
    /// Per-operator local mapping (baseline).
    Local,
    /// §2.2 global fixed-point mapping.
    Global,
}

impl BankMode {
    pub fn parse(s: &str) -> Option<BankMode> {
        match s {
            "none" => Some(BankMode::None),
            "local" => Some(BankMode::Local),
            "global" => Some(BankMode::Global),
            _ => None,
        }
    }
}

/// The static-planner stage configuration (`alloc` subsystem), run
/// after bank mapping when enabled.
#[derive(Clone, Debug)]
pub struct AllocStage {
    /// Chip whose scratchpad geometry the plan targets.
    pub accel: AccelConfig,
    pub opts: AllocOpts,
}

impl AllocStage {
    pub fn for_accel(accel: AccelConfig) -> AllocStage {
        AllocStage { accel, opts: AllocOpts::default() }
    }
}

/// The tiling stage configuration (`tile` subsystem), run between DME
/// and bank mapping when enabled.
#[derive(Clone, Debug)]
pub struct TileStage {
    /// Chip whose scratchpad the tile working sets are sized for.
    pub accel: AccelConfig,
    pub opts: TileOpts,
}

impl TileStage {
    pub fn for_accel(accel: AccelConfig) -> TileStage {
        TileStage { accel, opts: TileOpts::default() }
    }
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PassManager {
    pub enable_dme: bool,
    pub bank_mode: BankMode,
    pub bank_cfg: BankConfig,
    /// Polyhedral tiling (strip-mining + chain fusion), run between
    /// DME and bank mapping. `None` (the default) keeps whole-tensor
    /// nests; `Some` strip-mines oversized nests so the planner can
    /// stage tensors larger than the scratchpad tile by tile.
    pub tile: Option<TileStage>,
    /// Static scratchpad planning (scheduling + offsets + spills).
    /// `None` (the default) leaves residency to the simulator's
    /// dynamic baseline; `Some` produces a [`MemoryPlan`] the planned
    /// simulator mode replays verbatim.
    pub alloc: Option<AllocStage>,
    /// Verify IR between passes (on by default; benches may disable).
    pub verify: bool,
}

impl Default for PassManager {
    fn default() -> Self {
        PassManager {
            enable_dme: true,
            bank_mode: BankMode::Global,
            bank_cfg: BankConfig::default(),
            tile: None,
            alloc: None,
            verify: true,
        }
    }
}

/// Everything the pipeline produced, for reporting and simulation.
#[derive(Clone, Debug)]
pub struct PassReport {
    /// The optimized program (nests post-DME; graph post-bank-mapping,
    /// including inserted `MemCopy` nodes; rescheduled and
    /// spill-extended when the alloc stage ran).
    pub program: Program,
    pub dme: Option<DmeStats>,
    /// Tiling statistics (tile stage enabled only).
    pub tile: Option<TileStats>,
    pub bank: Option<BankAssignment>,
    /// The static memory plan (alloc stage enabled only).
    pub plan: Option<MemoryPlan>,
    pub dme_time: Duration,
    pub tile_time: Duration,
    pub bank_time: Duration,
    pub alloc_time: Duration,
}

impl PassManager {
    /// Run the full pipeline on a graph.
    pub fn run(&self, graph: crate::ir::Graph) -> Result<PassReport, VerifyError> {
        self.run_observed(graph, |_, _| {})
    }

    /// Run the pipeline, calling `observe(stage, program)` with the
    /// program state after each executed stage: `"lower"` (always),
    /// `"dme"`, `"tile"`, `"bank"` (after bank mapping **and** copy
    /// splicing, so the observed program is executable) and `"plan"`.
    /// The differential equivalence harness ([`crate::interp::diff`])
    /// snapshots these to prove every stage preserves semantics.
    pub fn run_observed(
        &self,
        graph: crate::ir::Graph,
        mut observe: impl FnMut(&str, &Program),
    ) -> Result<PassReport, VerifyError> {
        if self.verify {
            verify_graph(&graph)?;
        }
        let mut program = Program::lower(graph);
        if self.verify {
            verify_program(&program)?;
        }
        observe("lower", &program);

        let mut dme_stats = None;
        let t0 = Instant::now();
        if self.enable_dme {
            dme_stats = Some(run_dme(&mut program));
            if self.verify {
                verify_program(&program)?;
            }
            observe("dme", &program);
        }
        let dme_time = t0.elapsed();

        // Tiling: strip-mine oversized nests (and fuse elementwise
        // consumers onto their producer's grid) so residency can be
        // planned tile by tile. Runs before bank mapping: the bank
        // passes work on the graph, and copy splicing handles multi-
        // nest consumers already (concat), so tile nests need nothing
        // special downstream.
        let tt = Instant::now();
        let mut tile_stats = None;
        if let Some(stage) = &self.tile {
            let stats = run_tiling(&mut program, &stage.accel, &stage.opts);
            if self.verify {
                verify_program(&program)?;
            }
            observe("tile", &program);
            tile_stats = Some(stats);
        }
        let tile_time = tt.elapsed();

        let t1 = Instant::now();
        let bank = match self.bank_mode {
            BankMode::None => None,
            BankMode::Local => Some(super::bank_local::run_local(&program.graph, &self.bank_cfg)),
            BankMode::Global => {
                Some(super::bank_global::run_global(&program.graph, &self.bank_cfg))
            }
        };
        let bank_time = t1.elapsed();
        if let (Some(b), true) = (&bank, self.verify) {
            verify_graph(&b.graph)?;
        }

        // Patch the inserted MemCopy nodes into the (DME-optimized)
        // program: one identity copy nest per MemCopy, inserted before
        // its consumer's nests, with the consumer's loads re-pointed at
        // the remapped tensor. Re-lowering the whole graph would lose
        // the DME-composed access maps, so we splice instead.
        let program = if let Some(b) = &bank {
            let mut p2 = program;
            splice_memcopies(&mut p2, &b.graph);
            if self.verify {
                verify_program(&p2)?;
            }
            observe("bank", &p2);
            p2
        } else {
            program
        };

        // Static scratchpad planning: reschedule for footprint, assign
        // concrete regions, make spills explicit IR.
        let t2 = Instant::now();
        let mut plan = None;
        let program = if let Some(stage) = &self.alloc {
            let res = plan_memory(program, bank.as_ref(), &stage.accel, &stage.opts)
                .map_err(|e| VerifyError(format!("alloc: {e}")))?;
            if self.verify {
                verify_graph(&res.program.graph)?;
                verify_program(&res.program)?;
            }
            observe("plan", &res.program);
            plan = Some(res.plan);
            res.program
        } else {
            program
        };
        let alloc_time = t2.elapsed();

        Ok(PassReport {
            program,
            dme: dme_stats,
            tile: tile_stats,
            bank,
            plan,
            dme_time,
            tile_time,
            bank_time,
            alloc_time,
        })
    }
}

/// Splice the bank pass's `MemCopy` nodes into a lowered program:
/// adopt the bank graph (which is the program's graph plus MemCopy
/// nodes), add one identity copy nest per MemCopy before its consumer's
/// first nest, and re-point that consumer's loads at the remapped
/// tensor.
///
/// When the remapped edge belongs to a fused tile chain — the
/// consumer's tile nests interleave with the producer's, so there is
/// no position where the source is fully written *and* unread — the
/// copy is spliced tile-wise instead: one copy nest per producer tile,
/// covering exactly that tile's store image, inserted right after the
/// producing tile so the consumer's same-index tile reads a complete
/// copy. The tile copies inherit the producer's `TileTag` and so stay
/// inside its pipeline group.
fn splice_memcopies(prog: &mut Program, bank_graph: &crate::ir::Graph) {
    use crate::ir::loopnest::{Body, LoadStmt, LoopNest, StoreStmt};
    use crate::ir::op::OpKind;
    use crate::poly::{AccessMap, Expr, IterDomain};

    let memcopies: Vec<_> = bank_graph
        .nodes()
        .iter()
        .filter(|n| matches!(n.kind, OpKind::MemCopy))
        .cloned()
        .collect();
    prog.graph = bank_graph.clone();
    for mc in memcopies {
        let src = mc.inputs[0];
        let dst = mc.output;
        let consumers = prog.graph.consumers(dst);
        assert_eq!(consumers.len(), 1, "memcopy feeds exactly one consumer");
        let consumer = consumers[0].id;
        let shape = prog.graph.tensor(src).shape.clone();
        let nd = shape.len();
        let consumer_first = prog
            .nests
            .iter()
            .position(|n| n.node == consumer)
            .expect("consumer nest not found");
        let writer_positions = prog.writers(src);
        let last_writer = writer_positions.iter().copied().max().unwrap_or(0);

        // re-point the consumer's loads from src to dst
        for n in prog.nests.iter_mut().filter(|n| n.node == consumer) {
            for load in n.body.loads_mut() {
                for piece in &mut load.pieces {
                    if piece.tensor == Some(src) {
                        piece.tensor = Some(dst);
                    }
                }
            }
        }

        if consumer_first > last_writer {
            // ordinary schedule: src is complete before the consumer
            let nest = LoopNest {
                node: mc.id,
                tile: None,
                name: mc.name.clone(),
                domain: IterDomain::new(&shape),
                store: StoreStmt { tensor: dst, map: AccessMap::identity(nd) },
                body: Body::Copy { load: LoadStmt::total(src, AccessMap::identity(nd)) },
            };
            prog.nests.insert(consumer_first, nest);
        } else {
            // interleaved tile chain: copy tile-by-tile. Highest
            // position first so earlier indices stay valid.
            for &wpos in writer_positions.iter().rev() {
                let wnest = &prog.nests[wpos];
                let tag = wnest
                    .tile
                    .expect("interleaved writer must be a tile nest");
                let ext = wnest.domain.extents().to_vec();
                // tile nests have unit-dim stores: the image is a box
                let bbox: Vec<(i64, i64)> = wnest
                    .store
                    .map
                    .exprs()
                    .iter()
                    .map(|e| e.range(&ext).expect("store arity"))
                    .collect();
                let exts: Vec<i64> = bbox.iter().map(|&(lo, hi)| hi - lo + 1).collect();
                let map = AccessMap::new(
                    nd,
                    bbox.iter()
                        .enumerate()
                        .map(|(d, &(lo, _))| Expr::dim(d).add(Expr::cst(lo)))
                        .collect(),
                );
                let nest = LoopNest {
                    node: mc.id,
                    tile: Some(tag),
                    name: format!("{}@t{}", mc.name, tag.index),
                    domain: IterDomain::new(&exts),
                    store: StoreStmt { tensor: dst, map: map.clone() },
                    body: Body::Copy { load: LoadStmt::total(src, map) },
                };
                prog.nests.insert(wpos + 1, nest);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;

    fn sample() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16, 8, 8]);
        let t1 = b.transpose("t1", x, &[0, 2, 3, 1]);
        let t2 = b.transpose("t2", t1, &[0, 3, 1, 2]);
        let w = b.weight("w", &[16, 16, 3, 3]);
        let c = b.conv2d("c", t2, w, 1, 1);
        let r = b.relu("r", c);
        let w2 = b.weight("w2", &[16, 16, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        b.finish()
    }

    #[test]
    fn full_pipeline_runs() {
        let report = PassManager::default().run(sample()).unwrap();
        let dme = report.dme.unwrap();
        assert_eq!(dme.tensors_eliminated, 2); // both transposes fold away
        let bank = report.bank.as_ref().unwrap();
        assert_eq!(bank.stats.copies_inserted, 0); // global mapping clean
        // program reflects the bank graph
        assert_eq!(
            report.program.graph.nodes().len(),
            bank.graph.nodes().len()
        );
    }

    #[test]
    fn local_mode_inserts_copies() {
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        let report = pm.run(sample()).unwrap();
        let bank = report.bank.as_ref().unwrap();
        assert!(bank.stats.copies_inserted >= 1);
        // the memcopy nests survive the re-lowering + protected DME
        let memcopies = report
            .program
            .graph
            .count_nodes(|n| matches!(n.kind, crate::ir::OpKind::MemCopy));
        assert_eq!(memcopies, bank.stats.copies_inserted);
    }

    #[test]
    fn bank_none_skips() {
        let pm = PassManager { bank_mode: BankMode::None, ..Default::default() };
        let report = pm.run(sample()).unwrap();
        assert!(report.bank.is_none());
    }

    #[test]
    fn dme_disabled_keeps_pairs() {
        let pm = PassManager { enable_dme: false, ..Default::default() };
        let report = pm.run(sample()).unwrap();
        assert!(report.dme.is_none());
        assert!(report.program.load_store_pairs() >= 2);
    }

    #[test]
    fn alloc_stage_produces_plan() {
        use crate::accel::config::AccelConfig;
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(AccelConfig::inferentia_like())),
            ..Default::default()
        };
        let report = pm.run(sample()).unwrap();
        let plan = report.plan.expect("alloc stage ran");
        assert_eq!(plan.n_positions, report.program.nests.len());
        crate::alloc::verify_plan(
            &report.program,
            &plan,
            &AccelConfig::inferentia_like(),
        )
        .unwrap();
    }

    #[test]
    fn alloc_stage_off_by_default() {
        let report = PassManager::default().run(sample()).unwrap();
        assert!(report.plan.is_none());
    }

    #[test]
    fn observer_sees_stages_in_order() {
        use crate::accel::config::AccelConfig;
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(AccelConfig::inferentia_like())),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        pm.run_observed(sample(), |s, p| {
            assert!(!p.nests.is_empty());
            stages.push(s.to_string());
        })
        .unwrap();
        assert_eq!(stages, vec!["lower", "dme", "bank", "plan"]);
    }

    #[test]
    fn tile_stage_observed_between_dme_and_bank() {
        use crate::accel::config::AccelConfig;
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg)),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        let report = pm
            .run_observed(sample(), |s, _| stages.push(s.to_string()))
            .unwrap();
        assert_eq!(stages, vec!["lower", "dme", "tile", "bank", "plan"]);
        let tile = report.tile.expect("tile stage ran");
        assert!(tile.groups >= 1, "4 KiB chip must force tiling: {tile:?}");
        assert!(report.program.nests.iter().any(|n| n.tile.is_some()));
    }

    #[test]
    fn tile_stage_off_by_default() {
        let report = PassManager::default().run(sample()).unwrap();
        assert!(report.tile.is_none());
        assert!(report.program.nests.iter().all(|n| n.tile.is_none()));
    }

    #[test]
    fn observer_skips_disabled_stages() {
        let pm = PassManager {
            enable_dme: false,
            bank_mode: BankMode::None,
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        pm.run_observed(sample(), |s, _| stages.push(s.to_string())).unwrap();
        assert_eq!(stages, vec!["lower"]);
    }

    #[test]
    fn bank_mode_parsing() {
        assert_eq!(BankMode::parse("local"), Some(BankMode::Local));
        assert_eq!(BankMode::parse("global"), Some(BankMode::Global));
        assert_eq!(BankMode::parse("none"), Some(BankMode::None));
        assert_eq!(BankMode::parse("x"), None);
    }
}
