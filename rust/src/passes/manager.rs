//! Pass manager: ordered pipeline with per-pass statistics and
//! inter-pass verification — the driver `polymem compile` runs.

use super::bank::{BankAssignment, BankConfig};
use super::dme::{run_dme, DmeStats};
use crate::accel::config::AccelConfig;
use crate::alloc::{plan_memory, AllocOpts, MemoryPlan};
use crate::ir::loopnest::Program;
use crate::ir::verify::{verify_graph, verify_program, VerifyError};
use crate::opt::{OptOpts, OptStats};
use crate::tile::{run_tiling, TileOpts, TileStats};
use std::time::{Duration, Instant};

/// Which bank-mapping algorithm to run (the paper's E2 comparison).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BankMode {
    /// No bank mapping at all (for ablations).
    None,
    /// Per-operator local mapping (baseline).
    Local,
    /// §2.2 global fixed-point mapping.
    Global,
}

impl BankMode {
    pub fn parse(s: &str) -> Option<BankMode> {
        match s {
            "none" => Some(BankMode::None),
            "local" => Some(BankMode::Local),
            "global" => Some(BankMode::Global),
            _ => None,
        }
    }
}

/// The static-planner stage configuration (`alloc` subsystem), run
/// after bank mapping when enabled.
#[derive(Clone, Debug)]
pub struct AllocStage {
    /// Chip whose scratchpad geometry the plan targets.
    pub accel: AccelConfig,
    pub opts: AllocOpts,
}

impl AllocStage {
    pub fn for_accel(accel: AccelConfig) -> AllocStage {
        AllocStage { accel, opts: AllocOpts::default() }
    }
}

/// The tiling stage configuration (`tile` subsystem), run between DME
/// and bank mapping when enabled.
#[derive(Clone, Debug)]
pub struct TileStage {
    /// Chip whose scratchpad the tile working sets are sized for.
    pub accel: AccelConfig,
    pub opts: TileOpts,
}

impl TileStage {
    pub fn for_accel(accel: AccelConfig) -> TileStage {
        TileStage { accel, opts: TileOpts::default() }
    }
}

/// The joint-optimizer stage configuration (`opt` subsystem), run
/// between DME and bank mapping when enabled — in place of the fixed
/// `tile` stage, whose staged-greedy configuration is the search's
/// seed candidate.
///
/// Candidate realization inside the search is memoized (the bank
/// mapping once per search, the tiled+spliced program once per tile
/// survivor) and fans out over `opts.threads` workers; both are
/// outcome-invariant, so the stage's downstream replay — and the
/// differential oracle's opt-stage snapshot — stay bit-identical at
/// any thread count.
#[derive(Clone, Debug)]
pub struct OptStage {
    /// Chip the candidate plans are realized and scored against.
    pub accel: AccelConfig,
    pub opts: OptOpts,
}

impl OptStage {
    pub fn for_accel(accel: AccelConfig) -> OptStage {
        OptStage { accel, opts: OptOpts::default() }
    }

    /// Same stage with an explicit worker count (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> OptStage {
        self.opts.threads = threads;
        self
    }
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PassManager {
    pub enable_dme: bool,
    pub bank_mode: BankMode,
    pub bank_cfg: BankConfig,
    /// Polyhedral tiling (strip-mining + chain fusion), run between
    /// DME and bank mapping. `None` (the default) keeps whole-tensor
    /// nests; `Some` strip-mines oversized nests so the planner can
    /// stage tensors larger than the scratchpad tile by tile.
    pub tile: Option<TileStage>,
    /// Whole-model joint optimization (`crate::opt`): a beam search
    /// over fusion/tiling/scheduling/spill decision vectors, each
    /// realized through tile → bank → plan and scored by the unified
    /// cost model. Runs between DME and bank mapping *in place of* the
    /// fixed `tile` stage (which it supersedes when both are set); the
    /// winning vector's tiled program continues down the pipeline and
    /// its planner configuration overrides the `alloc` stage's, so the
    /// downstream replay reproduces the winning plan exactly.
    pub opt: Option<OptStage>,
    /// Static scratchpad planning (scheduling + offsets + spills).
    /// `None` (the default) leaves residency to the simulator's
    /// dynamic baseline; `Some` produces a [`MemoryPlan`] the planned
    /// simulator mode replays verbatim.
    pub alloc: Option<AllocStage>,
    /// Verify IR between passes (on by default; benches may disable).
    pub verify: bool,
}

impl Default for PassManager {
    fn default() -> Self {
        PassManager {
            enable_dme: true,
            bank_mode: BankMode::Global,
            bank_cfg: BankConfig::default(),
            tile: None,
            opt: None,
            alloc: None,
            verify: true,
        }
    }
}

/// Everything the pipeline produced, for reporting and simulation.
#[derive(Clone, Debug)]
pub struct PassReport {
    /// The optimized program (nests post-DME; graph post-bank-mapping,
    /// including inserted `MemCopy` nodes; rescheduled and
    /// spill-extended when the alloc stage ran).
    pub program: Program,
    pub dme: Option<DmeStats>,
    /// Tiling statistics (tile or opt stage enabled only; under `opt`
    /// these describe the winning candidate's tiling).
    pub tile: Option<TileStats>,
    /// Joint-search statistics (opt stage enabled only).
    pub opt: Option<OptStats>,
    pub bank: Option<BankAssignment>,
    /// The static memory plan (alloc stage enabled only).
    pub plan: Option<MemoryPlan>,
    pub dme_time: Duration,
    pub tile_time: Duration,
    pub bank_time: Duration,
    pub alloc_time: Duration,
    /// Wall time of every *executed* stage, in execution order, with
    /// the same names [`PassManager::run_observed`] reports. Each entry
    /// is mirrored to the global telemetry collector (as
    /// `passes.<name>`) when [`crate::obs::enabled`].
    pub phases: Vec<crate::obs::PhaseSample>,
}

/// Record one executed stage's wall time: into the report's phase list
/// and (gated) the global collector.
fn record_phase(phases: &mut Vec<crate::obs::PhaseSample>, name: &str, d: Duration) {
    let secs = d.as_secs_f64();
    crate::obs::phase(&format!("passes.{name}"), secs);
    phases.push(crate::obs::PhaseSample::new(name, secs));
}

impl PassManager {
    /// Run the full pipeline on a graph.
    pub fn run(&self, graph: crate::ir::Graph) -> Result<PassReport, VerifyError> {
        self.run_observed(graph, |_, _| {})
    }

    /// Run the pipeline, calling `observe(stage, program)` with the
    /// program state after each executed stage: `"lower"` (always),
    /// `"dme"`, `"tile"`, `"bank"` (after bank mapping **and** copy
    /// splicing, so the observed program is executable) and `"plan"`.
    /// The differential equivalence harness ([`crate::interp::diff`])
    /// snapshots these to prove every stage preserves semantics.
    pub fn run_observed(
        &self,
        graph: crate::ir::Graph,
        mut observe: impl FnMut(&str, &Program),
    ) -> Result<PassReport, VerifyError> {
        let mut phases: Vec<crate::obs::PhaseSample> = Vec::new();
        if self.verify {
            verify_graph(&graph)?;
        }
        let tl = Instant::now();
        let mut program = Program::lower(graph);
        if self.verify {
            verify_program(&program)?;
        }
        record_phase(&mut phases, "lower", tl.elapsed());
        observe("lower", &program);

        let mut dme_stats = None;
        let t0 = Instant::now();
        if self.enable_dme {
            dme_stats = Some(run_dme(&mut program));
            if self.verify {
                verify_program(&program)?;
            }
            record_phase(&mut phases, "dme", t0.elapsed());
            observe("dme", &program);
        }
        let dme_time = t0.elapsed();

        // Tiling / joint optimization, between DME and bank mapping.
        // `opt` supersedes `tile`: the search explores tiling decisions
        // (the fixed tile stage's configuration is its seed candidate)
        // and hands back the winning candidate's tiled program plus the
        // planner configuration that reproduces its plan downstream.
        let tt = Instant::now();
        let mut tile_stats = None;
        let mut opt_stats = None;
        let mut opt_alloc: Option<AllocOpts> = None;
        if let Some(stage) = &self.opt {
            // the search scores *static plans*; without an alloc stage
            // it would report costs for plans the pipeline never
            // produces — refuse the shape instead
            let Some(alloc_stage) = &self.alloc else {
                return Err(VerifyError(
                    "opt: the opt stage requires the alloc stage (the joint search \
                     scores static memory plans; configure `alloc` with the same \
                     accelerator)"
                        .to_string(),
                ));
            };
            // the "downstream replays the winner exactly" contract
            // needs both stages to target one chip: refuse a
            // misconfigured pipeline instead of silently scoring plans
            // (bytes via the bank geometry, latency via the engine
            // parameters) for different hardware than the alloc stage
            // realizes. `name` is a label and may differ.
            {
                let (x, y) = (&stage.accel, &alloc_stage.accel);
                let mismatch = x.banks != y.banks
                    || x.bank_bytes != y.bank_bytes
                    || x.pe_rows != y.pe_rows
                    || x.pe_cols != y.pe_cols
                    || x.vector_lanes != y.vector_lanes
                    || x.clock_hz != y.clock_hz
                    || x.dram_bps != y.dram_bps
                    || x.onchip_copy_bps != y.onchip_copy_bps;
                if mismatch {
                    return Err(VerifyError(format!(
                        "opt: OptStage accel ({} banks × {} B/bank, {} B/s DRAM) != \
                         AllocStage accel ({} banks × {} B/bank, {} B/s DRAM); the \
                         joint search must score plans for the chip the alloc stage \
                         plans",
                        x.banks, x.bank_bytes, x.dram_bps, y.banks, y.bank_bytes, y.dram_bps
                    )));
                }
            }
            // the caller's configured stage options seed every
            // candidate: the search varies only its own axes on top
            let base_tile = self.tile.as_ref().map(|t| t.opts).unwrap_or_default();
            let base_alloc = alloc_stage.opts;
            let outcome = crate::opt::search(
                &program,
                self.bank_mode,
                &self.bank_cfg,
                &stage.accel,
                &base_tile,
                &base_alloc,
                &stage.opts,
            )
            .map_err(|e| VerifyError(format!("opt: {e}")))?;
            program = outcome.program;
            if self.verify {
                verify_program(&program)?;
            }
            record_phase(&mut phases, "opt", tt.elapsed());
            observe("opt", &program);
            tile_stats = outcome.tile_stats;
            opt_stats = Some(outcome.stats);
            opt_alloc = Some(outcome.alloc_opts);
        } else if let Some(stage) = &self.tile {
            // strip-mine oversized nests (and fuse consumers onto their
            // producer's grid) so residency can be planned tile by
            // tile. The bank passes work on the graph, and copy
            // splicing handles multi-nest consumers already (concat),
            // so tile nests need nothing special downstream.
            let stats = run_tiling(&mut program, &stage.accel, &stage.opts);
            if self.verify {
                verify_program(&program)?;
            }
            record_phase(&mut phases, "tile", tt.elapsed());
            observe("tile", &program);
            tile_stats = Some(stats);
        }
        let tile_time = tt.elapsed();

        let t1 = Instant::now();
        let bank = match self.bank_mode {
            BankMode::None => None,
            BankMode::Local => Some(super::bank_local::run_local(&program.graph, &self.bank_cfg)),
            BankMode::Global => {
                Some(super::bank_global::run_global(&program.graph, &self.bank_cfg))
            }
        };
        let bank_time = t1.elapsed();
        if let (Some(b), true) = (&bank, self.verify) {
            verify_graph(&b.graph)?;
        }

        // Patch the inserted MemCopy nodes into the (DME-optimized)
        // program: one identity copy nest per MemCopy, inserted before
        // its consumer's nests, with the consumer's loads re-pointed at
        // the remapped tensor. Re-lowering the whole graph would lose
        // the DME-composed access maps, so we splice instead.
        let program = if let Some(b) = &bank {
            let mut p2 = program;
            splice_memcopies(&mut p2, &b.graph);
            if self.verify {
                verify_program(&p2)?;
            }
            // mapping + splicing: the whole executable bank stage
            record_phase(&mut phases, "bank", t1.elapsed());
            observe("bank", &p2);
            p2
        } else {
            program
        };

        // Static scratchpad planning: reschedule for footprint, assign
        // concrete regions, make spills explicit IR.
        let t2 = Instant::now();
        let mut plan = None;
        let program = if let Some(stage) = &self.alloc {
            // the joint optimizer's winning planner configuration
            // overrides the stage default, so the plan produced here is
            // exactly the one the search scored
            let alloc_opts = opt_alloc.unwrap_or(stage.opts);
            let res = plan_memory(program, bank.as_ref(), &stage.accel, &alloc_opts)
                .map_err(|e| VerifyError(format!("alloc: {e}")))?;
            if self.verify {
                verify_graph(&res.program.graph)?;
                verify_program(&res.program)?;
            }
            record_phase(&mut phases, "plan", t2.elapsed());
            observe("plan", &res.program);
            plan = Some(res.plan);
            res.program
        } else {
            program
        };
        let alloc_time = t2.elapsed();

        Ok(PassReport {
            program,
            dme: dme_stats,
            tile: tile_stats,
            opt: opt_stats,
            bank,
            plan,
            dme_time,
            tile_time,
            bank_time,
            alloc_time,
            phases,
        })
    }
}

/// Splice the bank pass's `MemCopy` nodes into a lowered program
/// (`pub(crate)`: the joint optimizer realizes its candidates through
/// the same bank → splice → plan path this manager runs):
/// adopt the bank graph (which is the program's graph plus MemCopy
/// nodes), add one identity copy nest per MemCopy before its consumer's
/// first nest, and re-point that consumer's loads at the remapped
/// tensor.
///
/// When the remapped edge belongs to a fused tile chain — the
/// consumer's tile nests interleave with the producer's, so there is
/// no position where the source is fully written *and* unread — the
/// copy is spliced tile-wise instead: one copy nest per producer tile,
/// covering exactly that tile's store image, inserted right after the
/// producing tile so the consumer's same-index tile reads a complete
/// copy. The tile copies inherit the producer's `TileTag` and so stay
/// inside its pipeline group.
pub(crate) fn splice_memcopies(prog: &mut Program, bank_graph: &crate::ir::Graph) {
    use crate::ir::loopnest::{Body, LoadStmt, LoopNest, StoreStmt};
    use crate::ir::op::OpKind;
    use crate::poly::{AccessMap, Expr, IterDomain};

    let memcopies: Vec<_> = bank_graph
        .nodes()
        .iter()
        .filter(|n| matches!(n.kind, OpKind::MemCopy))
        .cloned()
        .collect();
    prog.graph = bank_graph.clone();
    for mc in memcopies {
        let src = mc.inputs[0];
        let dst = mc.output;
        let consumers = prog.graph.consumers(dst);
        assert_eq!(consumers.len(), 1, "memcopy feeds exactly one consumer");
        let consumer = consumers[0].id;
        let shape = prog.graph.tensor(src).shape.clone();
        let nd = shape.len();
        let consumer_first = prog
            .nests
            .iter()
            .position(|n| n.node == consumer)
            .expect("consumer nest not found");
        let writer_positions = prog.writers(src);
        let last_writer = writer_positions.iter().copied().max().unwrap_or(0);

        // re-point the consumer's loads from src to dst
        for n in prog.nests.iter_mut().filter(|n| n.node == consumer) {
            for load in n.body.loads_mut() {
                for piece in &mut load.pieces {
                    if piece.tensor == Some(src) {
                        piece.tensor = Some(dst);
                    }
                }
            }
        }

        if consumer_first > last_writer {
            // ordinary schedule: src is complete before the consumer
            let nest = LoopNest {
                node: mc.id,
                tile: None,
                name: mc.name.clone(),
                domain: IterDomain::new(&shape),
                store: StoreStmt { tensor: dst, map: AccessMap::identity(nd) },
                body: Body::Copy { load: LoadStmt::total(src, AccessMap::identity(nd)) },
            };
            prog.nests.insert(consumer_first, nest);
        } else {
            // interleaved tile chain: copy tile-by-tile. Highest
            // position first so earlier indices stay valid.
            for &wpos in writer_positions.iter().rev() {
                let wnest = &prog.nests[wpos];
                let tag = wnest
                    .tile
                    .expect("interleaved writer must be a tile nest");
                let ext = wnest.domain.extents().to_vec();
                // tile nests have unit-dim stores: the image is a box
                let bbox: Vec<(i64, i64)> = wnest
                    .store
                    .map
                    .exprs()
                    .iter()
                    .map(|e| e.range(&ext).expect("store arity"))
                    .collect();
                let exts: Vec<i64> = bbox.iter().map(|&(lo, hi)| hi - lo + 1).collect();
                let map = AccessMap::new(
                    nd,
                    bbox.iter()
                        .enumerate()
                        .map(|(d, &(lo, _))| Expr::dim(d).add(Expr::cst(lo)))
                        .collect(),
                );
                let nest = LoopNest {
                    node: mc.id,
                    tile: Some(tag),
                    name: format!("{}@t{}", mc.name, tag.index),
                    domain: IterDomain::new(&exts),
                    store: StoreStmt { tensor: dst, map: map.clone() },
                    body: Body::Copy { load: LoadStmt::total(src, map) },
                };
                prog.nests.insert(wpos + 1, nest);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;

    fn sample() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16, 8, 8]);
        let t1 = b.transpose("t1", x, &[0, 2, 3, 1]);
        let t2 = b.transpose("t2", t1, &[0, 3, 1, 2]);
        let w = b.weight("w", &[16, 16, 3, 3]);
        let c = b.conv2d("c", t2, w, 1, 1);
        let r = b.relu("r", c);
        let w2 = b.weight("w2", &[16, 16, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        b.finish()
    }

    #[test]
    fn full_pipeline_runs() {
        let report = PassManager::default().run(sample()).unwrap();
        let dme = report.dme.unwrap();
        assert_eq!(dme.tensors_eliminated, 2); // both transposes fold away
        let bank = report.bank.as_ref().unwrap();
        assert_eq!(bank.stats.copies_inserted, 0); // global mapping clean
        // program reflects the bank graph
        assert_eq!(
            report.program.graph.nodes().len(),
            bank.graph.nodes().len()
        );
    }

    #[test]
    fn local_mode_inserts_copies() {
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        let report = pm.run(sample()).unwrap();
        let bank = report.bank.as_ref().unwrap();
        assert!(bank.stats.copies_inserted >= 1);
        // the memcopy nests survive the re-lowering + protected DME
        let memcopies = report
            .program
            .graph
            .count_nodes(|n| matches!(n.kind, crate::ir::OpKind::MemCopy));
        assert_eq!(memcopies, bank.stats.copies_inserted);
    }

    #[test]
    fn bank_none_skips() {
        let pm = PassManager { bank_mode: BankMode::None, ..Default::default() };
        let report = pm.run(sample()).unwrap();
        assert!(report.bank.is_none());
    }

    #[test]
    fn dme_disabled_keeps_pairs() {
        let pm = PassManager { enable_dme: false, ..Default::default() };
        let report = pm.run(sample()).unwrap();
        assert!(report.dme.is_none());
        assert!(report.program.load_store_pairs() >= 2);
    }

    #[test]
    fn alloc_stage_produces_plan() {
        use crate::accel::config::AccelConfig;
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(AccelConfig::inferentia_like())),
            ..Default::default()
        };
        let report = pm.run(sample()).unwrap();
        let plan = report.plan.expect("alloc stage ran");
        assert_eq!(plan.n_positions, report.program.nests.len());
        crate::alloc::verify_plan(
            &report.program,
            &plan,
            &AccelConfig::inferentia_like(),
        )
        .unwrap();
    }

    #[test]
    fn alloc_stage_off_by_default() {
        let report = PassManager::default().run(sample()).unwrap();
        assert!(report.plan.is_none());
    }

    #[test]
    fn observer_sees_stages_in_order() {
        use crate::accel::config::AccelConfig;
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(AccelConfig::inferentia_like())),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        pm.run_observed(sample(), |s, p| {
            assert!(!p.nests.is_empty());
            stages.push(s.to_string());
        })
        .unwrap();
        assert_eq!(stages, vec!["lower", "dme", "bank", "plan"]);
    }

    #[test]
    fn tile_stage_observed_between_dme_and_bank() {
        use crate::accel::config::AccelConfig;
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg)),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        let report = pm
            .run_observed(sample(), |s, _| stages.push(s.to_string()))
            .unwrap();
        assert_eq!(stages, vec!["lower", "dme", "tile", "bank", "plan"]);
        let tile = report.tile.expect("tile stage ran");
        assert!(tile.groups >= 1, "4 KiB chip must force tiling: {tile:?}");
        assert!(report.program.nests.iter().any(|n| n.tile.is_some()));
    }

    #[test]
    fn opt_stage_observed_between_dme_and_bank() {
        use crate::accel::config::AccelConfig;
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            opt: Some(OptStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg)),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        let report = pm
            .run_observed(sample(), |s, _| stages.push(s.to_string()))
            .unwrap();
        assert_eq!(stages, vec!["lower", "dme", "opt", "bank", "plan"]);
        let stats = report.opt.expect("opt stage ran");
        assert!(stats.candidates >= 1, "{stats:?}");
        assert!(stats.best_offchip <= stats.baseline_offchip, "{stats:?}");
        assert!(report.plan.is_some());
    }

    #[test]
    fn opt_requires_alloc_stage() {
        use crate::accel::config::AccelConfig;
        let pm = PassManager {
            opt: Some(OptStage::for_accel(AccelConfig::tiny(4 * 1024))),
            ..Default::default()
        };
        let err = pm.run(sample()).unwrap_err();
        assert!(err.0.contains("requires the alloc stage"), "{err}");
    }

    #[test]
    fn opt_rejects_mismatched_alloc_accel() {
        use crate::accel::config::AccelConfig;
        let pm = PassManager {
            opt: Some(OptStage::for_accel(AccelConfig::tiny(4 * 1024))),
            alloc: Some(AllocStage::for_accel(AccelConfig::tiny(8 * 1024))),
            ..Default::default()
        };
        let err = pm.run(sample()).unwrap_err();
        assert!(err.0.contains("OptStage accel"), "{err}");
    }

    #[test]
    fn opt_supersedes_tile_stage() {
        use crate::accel::config::AccelConfig;
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            opt: Some(OptStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg)),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        pm.run_observed(sample(), |s, _| stages.push(s.to_string())).unwrap();
        assert!(stages.iter().any(|s| s == "opt"));
        assert!(!stages.iter().any(|s| s == "tile"));
    }

    #[test]
    fn phases_cover_executed_stages_in_order() {
        use crate::accel::config::AccelConfig;
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg)),
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        let report = pm
            .run_observed(sample(), |s, _| stages.push(s.to_string()))
            .unwrap();
        let names: Vec<&str> = report.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, stages, "phase names mirror observed stages");
        assert!(report.phases.iter().all(|p| p.seconds >= 0.0));
        // disabled stages leave no phase behind
        let pm = PassManager {
            enable_dme: false,
            bank_mode: BankMode::None,
            ..Default::default()
        };
        let report = pm.run(sample()).unwrap();
        let names: Vec<&str> = report.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["lower"]);
    }

    #[test]
    fn tile_stage_off_by_default() {
        let report = PassManager::default().run(sample()).unwrap();
        assert!(report.tile.is_none());
        assert!(report.program.nests.iter().all(|n| n.tile.is_none()));
    }

    #[test]
    fn observer_skips_disabled_stages() {
        let pm = PassManager {
            enable_dme: false,
            bank_mode: BankMode::None,
            ..Default::default()
        };
        let mut stages: Vec<String> = Vec::new();
        pm.run_observed(sample(), |s, _| stages.push(s.to_string())).unwrap();
        assert_eq!(stages, vec!["lower"]);
    }

    #[test]
    fn bank_mode_parsing() {
        assert_eq!(BankMode::parse("local"), Some(BankMode::Local));
        assert_eq!(BankMode::parse("global"), Some(BankMode::Global));
        assert_eq!(BankMode::parse("none"), Some(BankMode::None));
        assert_eq!(BankMode::parse("x"), None);
    }
}
