//! Tensor liveness over the nest schedule.
//!
//! The accelerator simulator's scratchpad allocator needs, for every
//! schedule point, which tensors are live (produced, with a future
//! read). Live ranges follow the linear nest order (the schedule the
//! coordinator executes).

use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorKind};
use std::collections::BTreeMap;

/// Live range of one tensor in schedule positions (nest indexes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LiveRange {
    /// First schedule position that writes the tensor (usize::MAX for
    /// inputs/weights: live from the start).
    pub def: usize,
    /// Last schedule position that reads the tensor (inclusive);
    /// `usize::MAX` for graph outputs (live to the end).
    pub last_use: usize,
}

/// Liveness result: ranges plus helpers for the allocator.
#[derive(Clone, Debug)]
pub struct Liveness {
    pub ranges: BTreeMap<TensorId, LiveRange>,
    /// Sorted read positions per tensor (§Perf: makes `next_use_after`
    /// a binary search instead of a schedule scan — the simulator calls
    /// it for every resident tensor at every step).
    uses: BTreeMap<TensorId, Vec<usize>>,
    n_points: usize,
}

impl Liveness {
    /// Compute live ranges of every tensor over the program schedule.
    pub fn analyze(prog: &Program) -> Liveness {
        let mut ranges: BTreeMap<TensorId, LiveRange> = BTreeMap::new();
        let mut uses: BTreeMap<TensorId, Vec<usize>> = BTreeMap::new();
        for t in prog.graph.tensors() {
            match t.kind {
                TensorKind::Input | TensorKind::Weight => {
                    ranges.insert(t.id, LiveRange { def: 0, last_use: 0 });
                }
                _ => {}
            }
        }
        for (pos, nest) in prog.nests.iter().enumerate() {
            for load in nest.body.loads() {
                for piece in &load.pieces {
                    if let Some(t) = piece.tensor {
                        let r = ranges
                            .entry(t)
                            .or_insert(LiveRange { def: pos, last_use: pos });
                        r.last_use = r.last_use.max(pos);
                        let u = uses.entry(t).or_default();
                        if u.last() != Some(&pos) {
                            u.push(pos);
                        }
                    }
                }
            }
            let out = nest.store.tensor;
            let r = ranges
                .entry(out)
                .or_insert(LiveRange { def: pos, last_use: pos });
            r.def = r.def.min(pos);
        }
        // outputs stay live to the end
        for out in prog.graph.outputs() {
            if let Some(r) = ranges.get_mut(&out) {
                r.last_use = usize::MAX;
            }
        }
        Liveness { ranges, uses, n_points: prog.nests.len() }
    }

    /// Is `t` live at schedule position `pos` (after its def, before or
    /// at its last use)?
    pub fn live_at(&self, t: TensorId, pos: usize) -> bool {
        self.ranges
            .get(&t)
            .map(|r| r.def <= pos && pos <= r.last_use)
            .unwrap_or(false)
    }

    /// Tensors live at a schedule position.
    pub fn live_set(&self, pos: usize) -> Vec<TensorId> {
        self.ranges
            .iter()
            .filter(|(_, r)| r.def <= pos && pos <= r.last_use)
            .map(|(t, _)| *t)
            .collect()
    }

    /// Peak sum of live intermediate bytes across the schedule — the
    /// scratchpad footprint DME shrinks.
    pub fn peak_live_bytes(&self, prog: &Program) -> i64 {
        (0..self.n_points.max(1))
            .map(|pos| {
                self.live_set(pos)
                    .iter()
                    .filter(|t| {
                        matches!(
                            prog.graph.tensor(**t).kind,
                            TensorKind::Intermediate | TensorKind::Output
                        )
                    })
                    .map(|t| prog.graph.tensor(*t).size_bytes())
                    .sum::<i64>()
            })
            .max()
            .unwrap_or(0)
    }

    /// Sorted schedule positions at which `t` is read (empty for
    /// tensors never loaded). Used by the static allocator
    /// (`crate::alloc`) to build residency windows and handoff checks.
    pub fn use_positions(&self, t: TensorId) -> &[usize] {
        self.uses.get(&t).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Is `t` read exactly at `pos`?
    pub fn read_at(&self, t: TensorId, pos: usize) -> bool {
        self.use_positions(t).binary_search(&pos).is_ok()
    }

    /// Next read of `t` strictly after `pos`; `None` if dead after.
    pub fn next_use_after(&self, _prog: &Program, t: TensorId, pos: usize) -> Option<usize> {
        let r = self.ranges.get(&t)?;
        if r.last_use == usize::MAX {
            return Some(usize::MAX);
        }
        let u = self.uses.get(&t)?;
        let k = u.partition_point(|&p| p <= pos);
        u.get(k).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;

    #[test]
    fn straight_chain_ranges() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 4]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let y = b.identity("y", t2);
        b.mark_output(y);
        let prog = Program::lower(b.finish());
        let lv = Liveness::analyze(&prog);
        assert_eq!(lv.ranges[&t1], LiveRange { def: 0, last_use: 1 });
        assert_eq!(lv.ranges[&t2], LiveRange { def: 1, last_use: 2 });
        assert_eq!(lv.ranges[&y].last_use, usize::MAX);
        assert!(lv.live_at(t1, 0));
        assert!(lv.live_at(t1, 1));
        assert!(!lv.live_at(t1, 2));
    }

    #[test]
    fn fanout_extends_range() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 4]);
        let t1 = b.transpose("t1", x, &[1, 0]); // pos 0
        let a = b.identity("a", t1); // pos 1
        let bb = b.identity("b", t1); // pos 2
        let c = b.concat("c", &[a, bb], 0); // pos 3,4
        b.mark_output(c);
        let prog = Program::lower(b.finish());
        let lv = Liveness::analyze(&prog);
        assert_eq!(lv.ranges[&t1], LiveRange { def: 0, last_use: 2 });
        assert_eq!(lv.next_use_after(&prog, t1, 0), Some(1));
        assert_eq!(lv.next_use_after(&prog, t1, 1), Some(2));
        assert_eq!(lv.next_use_after(&prog, t1, 2), None);
    }

    #[test]
    fn peak_bytes_reflects_overlap() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]); // 16 KiB
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let y = b.identity("y", t2);
        b.mark_output(y);
        let prog = Program::lower(b.finish());
        let lv = Liveness::analyze(&prog);
        // at pos 1 both t1 and t2 are live: 32 KiB
        assert_eq!(lv.peak_live_bytes(&prog), 2 * 64 * 64 * 4);
    }
}
