//! §2.2 Global memory-bank mapping.
//!
//! "We first derive bank mappings for the operators with bank-mapping
//! restrictions, e.g., conv2D, matmul, pooling, etc., then propagate
//! these mappings across the network based on the data dependencies
//! between operators. We perform a fixed-point iteration to propagate
//! the mappings to cover all operators in the neural network and make
//! sure that the output of an operator maps to the memory banks
//! required by the next operator."
//!
//! Implementation:
//! 1. **Pin** hardware-fixed placements: results of MXU operators wider
//!    than the eviction crossbar ([`super::bank::forced_col`]) are
//!    pinned to `Col`.
//! 2. **Seed** every MXU/pool activation-input edge with its hard `Row`
//!    requirement (unless the tensor is pinned).
//! 3. **Propagate** placements to a fixed point across def-use edges,
//!    backward and forward, through placement-transparent operators
//!    (vector engine ops) and memory-bound index transforms
//!    ([`super::bank::transfer_forward`] / `transfer_backward`).
//!    First-writer-wins: a tensor that already has a placement is never
//!    overwritten — remaining disagreements become explicit copies.
//! 4. **Default** anything still unplaced using the same per-operator
//!    defaults as the local baseline.
//! 5. **Materialize** a `MemCopy` per def-use edge whose placement
//!    still violates the consumer's requirement (the paper's `t → t'`
//!    conflict resolution).

use super::bank::{
    forced_col, input_requirement, is_vector, is_weight_operand, out_channel_dim,
    transfer_backward, transfer_forward, BankAssignment, BankConfig, Placement,
};
use super::bank_local::{default_output_placement, materialize_copies};
use crate::ir::graph::Graph;
use crate::ir::tensor::{TensorId, TensorKind};
use std::collections::BTreeMap;

/// Run global bank mapping over a graph (typically post-DME).
pub fn run_global(graph: &Graph, cfg: &BankConfig) -> BankAssignment {
    let mut placements: BTreeMap<TensorId, Placement> = BTreeMap::new();
    let mut pinned: std::collections::BTreeSet<TensorId> = Default::default();

    // 1. pins
    for node in graph.nodes() {
        if forced_col(graph, node, cfg) {
            placements.insert(
                node.output,
                Placement::col(out_channel_dim(&node.kind).unwrap()),
            );
            pinned.insert(node.output);
        }
    }

    // 2. seeds: hard consumer requirements
    for node in graph.nodes() {
        for (pos, &inp) in node.inputs.iter().enumerate() {
            if is_weight_operand(graph, node, pos) {
                continue;
            }
            if let Some(req) = input_requirement(node, pos) {
                if !pinned.contains(&inp) {
                    placements.entry(inp).or_insert(req);
                }
            }
        }
    }

    // 3. fixed-point propagation
    let mut iterations = 0;
    loop {
        iterations += 1;
        let mut changed = false;
        for node in graph.nodes() {
            let kind = &node.kind;
            let out = node.output;
            // activation inputs only
            let act_inputs: Vec<(usize, TensorId)> = node
                .inputs
                .iter()
                .copied()
                .enumerate()
                .filter(|(pos, t)| {
                    !is_weight_operand(graph, node, *pos)
                        && graph.tensor(*t).kind != TensorKind::Input
                })
                .collect();

            if is_vector(kind) {
                // transparent: unify output and all activation inputs
                let known = placements
                    .get(&out)
                    .copied()
                    .or_else(|| act_inputs.iter().find_map(|(_, t)| placements.get(t).copied()));
                if let Some(p) = known {
                    if !placements.contains_key(&out) {
                        placements.insert(out, p);
                        changed = true;
                    }
                    for (_, t) in &act_inputs {
                        if !placements.contains_key(t) {
                            placements.insert(*t, p);
                            changed = true;
                        }
                    }
                }
            } else if kind.is_memory_bound() && !node.rewritten {
                if let Some((_, inp)) = act_inputs.first().copied() {
                    let in_shape = graph.tensor(inp).shape.clone();
                    let out_shape = graph.tensor(out).shape.clone();
                    // forward
                    if let (Some(p), false) =
                        (placements.get(&inp).copied(), placements.contains_key(&out))
                    {
                        if let Some(q) = transfer_forward(kind, &in_shape, p) {
                            placements.insert(out, q);
                            changed = true;
                        }
                    }
                    // backward
                    if let (Some(p), false) =
                        (placements.get(&out).copied(), placements.contains_key(&inp))
                    {
                        if let Some(q) = transfer_backward(kind, &in_shape, &out_shape, p) {
                            placements.insert(inp, q);
                            changed = true;
                        }
                    }
                    // concat: unify all inputs with the output placement
                    if act_inputs.len() > 1 {
                        if let Some(p) = placements.get(&out).copied() {
                            for (_, t) in &act_inputs {
                                if !placements.contains_key(t) {
                                    if let Some(q) =
                                        transfer_backward(kind, &graph.tensor(*t).shape, &out_shape, p)
                                    {
                                        placements.insert(*t, q);
                                        changed = true;
                                    }
                                }
                            }
                        }
                    }
                }
            } else {
                // MXU / pool: output is flexible unless pinned. Adopt the
                // (propagated) requirement of a downstream consumer if one
                // reached this tensor; otherwise leave for defaulting.
                // Nothing to do here: consumers seed/propagate into
                // `placements[out]` directly.
                let _ = out;
            }
        }
        if !changed {
            break;
        }
    }

    // 4. defaults for stragglers (same rules as the local baseline)
    for node in graph.nodes() {
        if !placements.contains_key(&node.output) {
            let p = default_output_placement(graph, node, &placements, cfg);
            placements.insert(node.output, p);
        }
    }

    // 5. conflict materialization (shared with local)
    materialize_copies(graph.clone(), placements, cfg, iterations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::verify_graph;
    use crate::passes::bank_local::run_local;

    /// conv → bn → relu → conv: global mapping propagates the second
    /// conv's Row requirement backward through the vector chain into
    /// the first conv's eviction → zero copies (vs 1 for local).
    #[test]
    fn conv_chain_zero_copies() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16, 16, 16]);
        let w1 = b.weight("w1", &[32, 16, 3, 3]);
        let c1 = b.conv2d("c1", x, w1, 1, 1);
        let bn = b.batchnorm("bn", c1);
        let r = b.relu("r", bn);
        let w2 = b.weight("w2", &[32, 32, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        let g = b.finish();
        let local = run_local(&g, &BankConfig::default());
        let global = run_global(&g, &BankConfig::default());
        verify_graph(&global.graph).unwrap();
        assert_eq!(local.stats.copies_inserted, 1);
        assert_eq!(global.stats.copies_inserted, 0);
        // c1's eviction was redirected to Row@1
        assert_eq!(global.placements[&c1], Placement::row(1));
    }

    /// A wide conv (Cout > col_flex_limit) cannot redirect its eviction:
    /// the copy survives even under global mapping.
    #[test]
    fn forced_col_copy_survives() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 64, 8, 8]);
        let w1 = b.weight("w1", &[1024, 64, 1, 1]);
        let c1 = b.conv2d("wide", x, w1, 1, 0);
        let r = b.relu("r", c1);
        let w2 = b.weight("w2", &[64, 1024, 1, 1]);
        let c2 = b.conv2d("c2", r, w2, 1, 0);
        b.mark_output(c2);
        let g = b.finish();
        let global = run_global(&g, &BankConfig::default());
        assert_eq!(global.stats.copies_inserted, 1);
        assert_eq!(global.placements[&c1], Placement::col(1));
        // raising the limit removes the copy
        let cfg2 = BankConfig { banks: 16, col_flex_limit: 4096 };
        let global2 = run_global(&g, &cfg2);
        assert_eq!(global2.stats.copies_inserted, 0);
    }

    /// Residual block: the shortcut tensor feeds both the add and the
    /// next stage; propagation unifies everything on Row@1 → no copies.
    #[test]
    fn residual_block_unifies() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 64, 8, 8]);
        let w0 = b.weight("w0", &[64, 64, 1, 1]);
        let pre = b.conv2d("pre", x, w0, 1, 0);
        let w1 = b.weight("w1", &[64, 64, 3, 3]);
        let c1 = b.conv2d("c1", pre, w1, 1, 1);
        let r1 = b.relu("r1", c1);
        let w2 = b.weight("w2", &[64, 64, 3, 3]);
        let c2 = b.conv2d("c2", r1, w2, 1, 1);
        let a = b.add("a", c2, pre); // shortcut
        let r2 = b.relu("r2", a);
        let w3 = b.weight("w3", &[64, 64, 1, 1]);
        let c3 = b.conv2d("c3", r2, w3, 1, 0);
        b.mark_output(c3);
        let g = b.finish();
        let local = run_local(&g, &BankConfig::default());
        let global = run_global(&g, &BankConfig::default());
        assert!(local.stats.copies_inserted >= 2, "local: {:?}", local.stats);
        assert_eq!(global.stats.copies_inserted, 0, "global: {:?}", global.stats);
    }

    /// Propagation crosses transposes: conv → NHWC transpose → NCHW
    /// transpose → conv needs no copy globally.
    #[test]
    fn propagates_through_transposes() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 8, 8]);
        let w1 = b.weight("w1", &[8, 8, 1, 1]);
        let c1 = b.conv2d("c1", x, w1, 1, 0);
        let t1 = b.transpose("t1", c1, &[0, 2, 3, 1]);
        let t2 = b.transpose("t2", t1, &[0, 3, 1, 2]);
        let w2 = b.weight("w2", &[8, 8, 1, 1]);
        let c2 = b.conv2d("c2", t2, w2, 1, 0);
        b.mark_output(c2);
        let g = b.finish();
        let global = run_global(&g, &BankConfig::default());
        assert_eq!(global.stats.copies_inserted, 0);
        // t1's output carries Row on the moved channel dim (3)
        assert_eq!(global.placements[&t1], Placement::row(3));
    }

    /// A genuinely conflicting tensor under global mapping: a pinned
    /// wide conv result (Col) meets a flexible narrow conv result (Row)
    /// at a lane-locked vector add — exactly one copy, on the pinned
    /// operand.
    #[test]
    fn genuine_conflict_single_copy() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 64, 8, 8]);
        let ww = b.weight("ww", &[1024, 64, 1, 1]);
        let wide = b.conv2d("wide", x, ww, 1, 0); // pinned Col@1
        let wn = b.weight("wn", &[1024, 64, 1, 1]);
        let narrow0 = b.conv2d("narrow0", x, wn, 1, 0);
        let r = b.relu("r", narrow0);
        let s = b.add("s", wide, r);
        let wf = b.weight("wf", &[64, 1024, 1, 1]);
        let c3 = b.conv2d("c3", s, wf, 1, 0); // seeds s = Row@1
        b.mark_output(c3);
        let g = b.finish();
        verify_graph(&g).unwrap();
        let global = run_global(&g, &BankConfig::default());
        verify_graph(&global.graph).unwrap();
        // narrow0 is also wide (1024) here → pinned too; both operands
        // of the add are Col while the add's result must be Row → two
        // remaps. Shrink one conv to be flexible and re-check.
        assert!(global.stats.copies_inserted >= 1);
        assert!(global.stats.copies_inserted <= 2, "{:?}", global.stats);

        // flexible variant: narrow conv (Cout=64) can evict Row directly
        let mut b2 = GraphBuilder::new();
        let x2 = b2.input("x", &[1, 64, 8, 8]);
        let ww2 = b2.weight("ww", &[1024, 64, 1, 1]);
        let wide2 = b2.conv2d("wide", x2, ww2, 1, 0);
        let sq = b2.weight("sq", &[64, 1024, 1, 1]);
        let shrink = b2.conv2d("shrink", wide2, sq, 1, 0); // Row-capable
        let t = b2.transpose("keep", shrink, &[0, 1, 2, 3]);
        let w3 = b2.weight("w3", &[64, 64, 1, 1]);
        let c4 = b2.conv2d("c4", t, w3, 1, 0);
        b2.mark_output(c4);
        let g2 = b2.finish();
        let global2 = run_global(&g2, &BankConfig::default());
        // only the wide→shrink edge pays (pinned Col vs Row requirement)
        assert_eq!(global2.stats.copies_inserted, 1, "{:?}", global2.stats);
    }

    #[test]
    fn global_never_worse_than_local() {
        // randomized small graphs: global copy bytes <= local copy bytes
        use crate::util::prop::Prop;
        Prop::new("global <= local", 25).check(|gen| {
            let mut b = GraphBuilder::new();
            let mut cur = b.input("x", &[1, 8, 8, 8]);
            let n_ops = gen.usize_in(2, 8);
            for k in 0..n_ops {
                cur = match gen.usize_in(0, 5) {
                    0 => {
                        let w = b.weight(&format!("w{k}"), &[8, 8, 1, 1]);
                        b.conv2d(&format!("c{k}"), cur, w, 1, 0)
                    }
                    1 => b.relu(&format!("r{k}"), cur),
                    2 => b.transpose(&format!("t{k}"), cur, &[0, 2, 3, 1]),
                    3 => b.transpose(&format!("u{k}"), cur, &[0, 3, 1, 2]),
                    _ => b.maxpool(&format!("p{k}"), cur, 1, 1),
                };
            }
            b.mark_output(cur);
            let g = b.finish();
            let local = run_local(&g, &BankConfig::default());
            let global = run_global(&g, &BankConfig::default());
            assert!(
                global.stats.copy_bytes <= local.stats.copy_bytes,
                "global {} > local {}",
                global.stats.copy_bytes,
                local.stats.copy_bytes
            );
        });
    }
}
