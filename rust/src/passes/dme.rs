//! §2.1 Data-movement elimination.
//!
//! A *copy pair* is a loop nest whose body is exactly
//! `v = t_l[f_l(i)]; t_s[f_s(i)] = v`. For each intermediate tensor
//! `t_s` defined entirely by copy nests, the pass:
//!
//! 1. reverses each writer's store function `f_s` to
//!    `f_s' : idx_{t_s} ↦ i` ([`AccessMap::reverse`]; exact, Smith
//!    normal form — fails on strided/non-injective stores);
//! 2. builds `g_ls = f_l ∘ f_s'` (paper eq. 1) per writer, guarded by
//!    the writer's store image box (writers of `concat` cover disjoint
//!    regions of `t_s`);
//! 3. rewrites every load piece reading `t_s` with
//!    `g' = g_ls ∘ f_l'` (paper eq. 2), translating the region guards
//!    through `f_l'`;
//! 4. deletes the writer nests and `t_s` itself, and repeats to a
//!    fixed point (an eliminated copy can expose another: e.g.
//!    `transpose ∘ transpose` chains collapse step by step).
//!
//! Legality (conservative, in line with the paper's restriction to
//! memory-bound operators):
//! * `t_s` must be an [`TensorKind::Intermediate`] (never a model
//!   output) and all its writers must be copy nests;
//! * every writer store must have an exact affine reverse and its
//!   image box must tile `t_s` exactly (disjoint, full coverage);
//! * every reader guard must be translatable through the reader's
//!   access map (single-dim affine components); otherwise the tensor
//!   is skipped;
//! * readers with implicit-padding semantics (`oob_zero`) are skipped
//!   unless the rewrite provably preserves out-of-bounds points.

use crate::ir::loopnest::{Access, Body, LoadStmt, Program};
use crate::ir::tensor::{TensorId, TensorKind};
use crate::poly::expr::Expr;
use crate::poly::piecewise::Guard;
use crate::poly::AccessMap;
use std::collections::{HashMap, HashSet};

/// Statistics reported by the pass — the quantities the paper's E1
/// experiment tabulates.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DmeStats {
    /// Copy nests present before the pass (the paper's "load-store pairs").
    pub pairs_before: usize,
    /// Copy nests eliminated.
    pub pairs_eliminated: usize,
    /// Intermediate tensors removed.
    pub tensors_eliminated: usize,
    /// Bytes of intermediate storage removed.
    pub bytes_eliminated: i64,
    /// Bytes of intermediate storage before the pass (copy-defined only).
    pub bytes_before: i64,
    /// Fixed-point iterations executed.
    pub iterations: usize,
}

/// A reconstructed definition of a copy-defined tensor: pieces
/// `(guards on idx_{t_s}, source)` whose guards tile the tensor box.
struct CopyDef {
    pieces: Vec<DefPiece>,
}

struct DefPiece {
    guards: Vec<Guard>,
    /// `None` = constant zero (pad border).
    source: Option<(TensorId, AccessMap)>, // map: idx_{t_s} -> idx_source
}

/// Run DME to a fixed point on a lowered program.
pub fn run_dme(prog: &mut Program) -> DmeStats {
    let mut stats = DmeStats {
        pairs_before: prog.load_store_pairs(),
        ..Default::default()
    };
    // bytes of copy-defined tensors before (including externally
    // visible ones — the paper's 146 MB denominator counts the
    // non-eliminable output copy too)
    {
        let mut writers_all: HashMap<TensorId, bool> = HashMap::new();
        for nest in &prog.nests {
            let e = writers_all.entry(nest.store.tensor).or_insert(true);
            *e &= nest.body.is_copy();
        }
        stats.bytes_before = writers_all
            .iter()
            .filter(|(_, &all_copy)| all_copy)
            .map(|(t, _)| prog.graph.tensor(*t).size_bytes())
            .sum();
    }

    loop {
        stats.iterations += 1;
        // Per-iteration def/use indexes over nest positions (§Perf:
        // replaces O(candidates × nests) rescans with O(nests) builds
        // plus incremental updates; eliminated nests are tombstoned in
        // `dead` and swept once at the end of the iteration).
        let mut writers: HashMap<TensorId, Vec<usize>> = HashMap::new();
        let mut readers: HashMap<TensorId, Vec<usize>> = HashMap::new();
        for (i, nest) in prog.nests.iter().enumerate() {
            writers.entry(nest.store.tensor).or_default().push(i);
            for load in nest.body.loads() {
                for piece in &load.pieces {
                    if let Some(t) = piece.tensor {
                        readers.entry(t).or_default().push(i);
                    }
                }
            }
        }
        let mut dead: HashSet<usize> = HashSet::new();

        // candidates in schedule order: intermediates defined only by
        // copy nests
        let mut seen = HashSet::new();
        let mut candidates = Vec::new();
        for nest in &prog.nests {
            let t = nest.store.tensor;
            if !seen.insert(t) || prog.graph.tensor(t).kind != TensorKind::Intermediate {
                continue;
            }
            if writers[&t].iter().all(|&w| prog.nests[w].body.is_copy()) {
                candidates.push(t);
            }
        }

        let mut progress = false;
        for t in candidates {
            if try_eliminate(prog, t, &mut stats, &writers, &mut readers, &mut dead) {
                progress = true;
            }
        }
        if !progress {
            break;
        }
        // sweep tombstoned nests
        let mut idx = 0usize;
        prog.nests.retain(|_| {
            let keep = !dead.contains(&idx);
            idx += 1;
            keep
        });
    }
    stats
}

/// Attempt to eliminate one tensor; returns true on success.
fn try_eliminate(
    prog: &mut Program,
    t: TensorId,
    stats: &mut DmeStats,
    writers: &HashMap<TensorId, Vec<usize>>,
    readers: &mut HashMap<TensorId, Vec<usize>>,
    dead: &mut HashSet<usize>,
) -> bool {
    let writer_idxs: Vec<usize> = writers
        .get(&t)
        .map(|v| v.iter().copied().filter(|i| !dead.contains(i)).collect())
        .unwrap_or_default();
    let Some(def) = build_copy_def(prog, t, &writer_idxs) else { return false };
    let t_bytes = prog.graph.tensor(t).size_bytes();

    // Pre-compute rewrites for every reader; abort without mutating if
    // any reader cannot be rewritten. Reader index entries can be stale
    // (a nest rewritten earlier may no longer read `t`) — the piece
    // check below filters them.
    let reader_idxs: Vec<usize> = {
        let mut v: Vec<usize> = readers
            .get(&t)
            .map(|v| v.iter().copied().filter(|i| !dead.contains(i)).collect())
            .unwrap_or_default();
        v.sort_unstable();
        v.dedup();
        v
    };
    let mut rewrites: Vec<(usize, usize, LoadStmt)> = Vec::new(); // (nest, load idx, new load)
    for &ridx in &reader_idxs {
        let nest = &prog.nests[ridx];
        for (lidx, load) in nest.body.loads().iter().enumerate() {
            if !load.pieces.iter().any(|p| p.tensor == Some(t)) {
                continue;
            }
            let Some(new_load) = rewrite_load(load, t, &def, nest.domain.extents()) else {
                return false;
            };
            rewrites.push((ridx, lidx, new_load));
        }
    }

    // The sources read by the rewritten loads must already be written
    // before each reader nest — guaranteed by SSA + schedule order
    // (sources were written before the copy nest, which precedes all
    // readers). Commit the rewrites and update the reader index with
    // the new sources.
    for (ridx, lidx, new_load) in rewrites {
        for piece in &new_load.pieces {
            if let Some(src) = piece.tensor {
                readers.entry(src).or_default().push(ridx);
            }
        }
        let nest = &mut prog.nests[ridx];
        nest.body.loads_mut()[lidx] = new_load;
    }

    // Tombstone the writer nests (swept at iteration end).
    let writer_count = writer_idxs.len();
    dead.extend(writer_idxs);

    // Fix the graph: rewire consumer node inputs from t to the source
    // tensors, then drop the producing node and the tensor record.
    let src_tensors: Vec<TensorId> = {
        let mut s: Vec<TensorId> = def
            .pieces
            .iter()
            .filter_map(|p| p.source.as_ref().map(|(t, _)| *t))
            .collect();
        s.sort();
        s.dedup();
        s
    };
    let producer = prog.graph.producer(t).map(|n| n.id);
    let consumer_ids: Vec<_> = prog.graph.consumers(t).iter().map(|n| n.id).collect();
    for cid in consumer_ids {
        let node = prog.graph.node_mut(cid);
        let mut new_inputs = Vec::with_capacity(node.inputs.len());
        for &inp in &node.inputs {
            if inp == t {
                for &s in &src_tensors {
                    if !new_inputs.contains(&s) {
                        new_inputs.push(s);
                    }
                }
            } else {
                new_inputs.push(inp);
            }
        }
        node.inputs = new_inputs;
        // the node's OpKind no longer describes its access pattern —
        // the true (composed) maps live in its loop nests
        node.rewritten = true;
    }
    if let Some(pid) = producer {
        prog.graph.remove_node(pid);
    }

    stats.pairs_eliminated += writer_count;
    stats.tensors_eliminated += 1;
    stats.bytes_eliminated += t_bytes;
    true
}

/// Build the piecewise definition of `t` from its writer copy nests.
fn build_copy_def(prog: &Program, t: TensorId, writers: &[usize]) -> Option<CopyDef> {
    let t_shape = prog.graph.tensor(t).shape.clone();
    if writers.is_empty() {
        return None;
    }
    let mut pieces = Vec::new();
    let mut covered: i64 = 0;
    let mut boxes: Vec<Vec<(i64, i64)>> = Vec::new();
    for &w in writers {
        let nest = &prog.nests[w];
        let Body::Copy { load } = &nest.body else { return None };
        // store must be exactly reversible on its image
        let f_s = &nest.store.map;
        let rev = f_s.reverse()?;
        if !f_s.is_injective_on(&nest.domain) {
            return None;
        }
        let bounds = f_s.image_bounds(&nest.domain)?;
        // the image bounding box must be exactly the image (card match)
        let box_card: i64 = bounds.iter().map(|(lo, hi)| hi - lo + 1).product();
        if box_card != nest.domain.cardinality() {
            return None;
        }
        // disjointness against previously collected boxes
        for prev in &boxes {
            if boxes_overlap(prev, &bounds) {
                return None;
            }
        }
        boxes.push(bounds.clone());
        covered += box_card;
        let region_guards: Vec<Guard> = bounds
            .iter()
            .enumerate()
            .filter(|(d, &(lo, hi))| !(lo == 0 && hi == t_shape[*d] - 1))
            .map(|(d, &(lo, hi))| Guard { dim: d, lo, hi: hi + 1 })
            .collect();
        // each load piece becomes a def piece: guards on i translated
        // through f_s' into guards on idx
        for acc in &load.pieces {
            if acc.oob_zero {
                return None; // copy with implicit-pad read: not expected
            }
            let mut guards = region_guards.clone();
            for g in &acc.guards {
                // guard on loop dim g.dim; translate through rev:
                // i = rev(idx); component g.dim of rev is affine in idx
                let comp = &rev.exprs()[g.dim];
                let translated = guard_through_expr(comp, g, rev.in_dims())?;
                match translated {
                    Translated::Always => {}
                    Translated::Never => {
                        guards.clear();
                        guards.push(Guard { dim: 0, lo: 1, hi: 1 }); // unsat — skip push below
                        break;
                    }
                    Translated::Guards(gs) => guards.extend(gs),
                }
            }
            if guards.iter().any(|g| g.lo >= g.hi) {
                continue; // unsatisfiable piece
            }
            let guards = normalize_guards(guards)?;
            let source = match acc.tensor {
                Some(src) => Some((src, acc.map.compose(&rev))),
                None => None,
            };
            pieces.push(DefPiece { guards, source });
        }
    }
    // full coverage of the tensor box
    let total: i64 = t_shape.iter().product();
    if covered != total {
        return None;
    }
    Some(CopyDef { pieces })
}

fn boxes_overlap(a: &[(i64, i64)], b: &[(i64, i64)]) -> bool {
    a.iter().zip(b).all(|(&(alo, ahi), &(blo, bhi))| alo <= bhi && blo <= ahi)
}

/// Merge duplicate-dim guards (intersection); `None` if contradictory.
fn normalize_guards(gs: Vec<Guard>) -> Option<Vec<Guard>> {
    let mut by_dim: std::collections::BTreeMap<usize, (i64, i64)> = Default::default();
    for g in gs {
        let e = by_dim.entry(g.dim).or_insert((g.lo, g.hi));
        e.0 = e.0.max(g.lo);
        e.1 = e.1.min(g.hi);
        if e.0 >= e.1 {
            return None;
        }
    }
    Some(
        by_dim
            .into_iter()
            .map(|(dim, (lo, hi))| Guard { dim, lo, hi })
            .collect(),
    )
}

enum Translated {
    Always,
    Never,
    Guards(Vec<Guard>),
}

/// Translate a guard `lo <= e(i) < hi` into box guards on `i`, when `e`
/// is a constant or a single-dim affine `c·i_k + b`.
fn guard_through_expr(e: &Expr, g: &Guard, in_dims: usize) -> Option<Translated> {
    let (coeffs, b) = e.as_affine(in_dims)?;
    let nz: Vec<usize> = coeffs
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != 0)
        .map(|(k, _)| k)
        .collect();
    match nz.as_slice() {
        [] => {
            if b >= g.lo && b < g.hi {
                Some(Translated::Always)
            } else {
                Some(Translated::Never)
            }
        }
        [k] => {
            let c = coeffs[*k];
            let (lo, hi) = if c > 0 {
                // lo <= c*i + b < hi  →  ceil((lo-b)/c) <= i < ceil((hi-b)/c)
                (ceil_div(g.lo - b, c), ceil_div(g.hi - b, c))
            } else {
                // c < 0: lo <= c*i + b  →  i <= (b - lo)/c ... flip:
                // i >= ceil((b - hi + 1) / -c), i < floor((b - lo) / -c) + 1
                let m = -c;
                (ceil_div(b - g.hi + 1, m), (b - g.lo).div_euclid(m) + 1)
            };
            if lo >= hi {
                Some(Translated::Never)
            } else {
                Some(Translated::Guards(vec![Guard { dim: *k, lo, hi }]))
            }
        }
        _ => None,
    }
}

fn ceil_div(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    (a + b - 1).div_euclid(b)
}

/// Rewrite one load statement replacing pieces that read `t` via the
/// copy definition. Returns `None` when any guard cannot be translated.
fn rewrite_load(
    load: &LoadStmt,
    t: TensorId,
    def: &CopyDef,
    dom_extents: &[i64],
) -> Option<LoadStmt> {
    let mut pieces = Vec::new();
    for acc in &load.pieces {
        if acc.tensor != Some(t) {
            pieces.push(acc.clone());
            continue;
        }
        if acc.oob_zero {
            // Implicit-pad read (conv with virtual padding): the rewrite
            // is sound only when out-of-bounds points stay out of bounds
            // under the composed map — true exactly when the definition
            // is one total piece whose map is a pure permutation
            // (transpose). Anything else (offsets, strides, div/mod)
            // could alias padding onto real data — bail.
            match &def.pieces[..] {
                [DefPiece { guards, source: Some((src, q)) }]
                    if guards.is_empty() && q.is_permutation() =>
                {
                    pieces.push(Access {
                        guards: acc.guards.clone(),
                        tensor: Some(*src),
                        map: q.compose(&acc.map),
                        oob_zero: true,
                    });
                    continue;
                }
                _ => return None,
            }
        }
        // reader reads t via m = acc.map (loop i' -> idx_t), under acc.guards
        for dp in &def.pieces {
            // translate dp.guards (on idx_t) through m into guards on i'
            let mut new_guards = acc.guards.clone();
            let mut unsat = false;
            for g in &dp.guards {
                let comp = &acc.map.exprs()[g.dim];
                match guard_through_expr(comp, g, acc.map.in_dims()) {
                    Some(Translated::Always) => {}
                    Some(Translated::Never) => {
                        unsat = true;
                        break;
                    }
                    Some(Translated::Guards(gs)) => new_guards.extend(gs),
                    None => {
                        // component not single-dim affine (e.g. reader is
                        // a reshape with div/mod): cannot translate — the
                        // whole elimination is abandoned.
                        return None;
                    }
                }
            }
            if unsat {
                continue;
            }
            let Some(new_guards) = normalize_guards(new_guards) else { continue };
            // drop guards that are implied by the domain box
            let new_guards: Vec<Guard> = new_guards
                .into_iter()
                .filter(|g| !(g.lo <= 0 && g.hi >= dom_extents[g.dim]))
                .collect();
            match &dp.source {
                Some((src, q)) => {
                    pieces.push(Access {
                        guards: new_guards,
                        tensor: Some(*src),
                        map: q.compose(&acc.map).simplified_in(
                            &crate::poly::IterDomain::new(dom_extents),
                        ),
                        oob_zero: false,
                    });
                }
                None => {
                    pieces.push(Access {
                        guards: new_guards,
                        tensor: None,
                        map: AccessMap::identity(acc.map.in_dims()),
                        oob_zero: false,
                    });
                }
            }
        }
    }
    if pieces.is_empty() {
        return None;
    }
    Some(LoadStmt { pieces })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;
    use crate::ir::verify::{verify_graph, verify_program};

    /// DME must preserve the program as a function of its inputs. The
    /// shared reference interpreter ([`crate::interp`]) is the oracle:
    /// unlike the copy-only fingerprint walker these tests used to
    /// carry, it executes `Body::Compute` nests too, so graphs whose
    /// outputs pass through matmuls/convs are fully checked — no
    /// "not interpreted" blind spot.
    fn check_dme_preserves(graph: crate::ir::Graph) -> (DmeStats, Program) {
        verify_graph(&graph).unwrap();
        let mut prog = Program::lower(graph);
        verify_program(&prog).unwrap();
        let before = prog.clone();
        let stats = run_dme(&mut prog);
        verify_program(&prog).unwrap();
        crate::interp::diff::assert_equivalent(&before, &prog, 0xD31);
        (stats, prog)
    }

    #[test]
    fn eliminates_transpose_chain() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 4, 5]);
        let t1 = b.transpose("t1", x, &[1, 2, 0]);
        let t2 = b.transpose("t2", t1, &[2, 0, 1]);
        let y = b.identity("out", t2);
        b.mark_output(y);
        let (stats, prog) = check_dme_preserves(b.finish());
        // t1 and t2 feed copies all the way; the final identity writes
        // the output tensor and must remain; t1, t2 eliminated.
        assert_eq!(stats.tensors_eliminated, 2);
        assert_eq!(stats.pairs_eliminated, 2);
        assert_eq!(prog.load_store_pairs(), 1);
        // final load must read x directly with the composed (identity) map
        let last = prog.copy_nests().next().unwrap();
        let Body::Copy { load } = &last.body else { panic!() };
        let (src, map) = load.single().unwrap();
        assert_eq!(src, x);
        assert!(map.is_identity(), "t2∘t1 should compose to identity, got {map:?}");
    }

    #[test]
    fn eliminates_slice_of_concat() {
        let mut b = GraphBuilder::new();
        let a = b.input("a", &[2, 3]);
        let c = b.input("c", &[2, 5]);
        let cat = b.concat("cat", &[a, c], 1);
        // slice crossing both concat regions
        let s = b.slice("s", cat, &[0, 1], &[2, 7], &[1, 1]);
        let y = b.identity("out", s);
        b.mark_output(y);
        let (stats, prog) = check_dme_preserves(b.finish());
        assert_eq!(stats.tensors_eliminated, 2); // cat_out and s_out
        // the surviving output copy is piecewise over two sources
        let last = prog.copy_nests().next().unwrap();
        let Body::Copy { load } = &last.body else { panic!() };
        assert_eq!(load.tensors().len(), 2);
    }

    #[test]
    fn eliminates_tile_repeat_reads() {
        // tile/repeat loads are quasi-affine but their stores are
        // identity — they are eliminable as long as the *readers* have
        // translatable guards (none here).
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4]);
        let t = b.tile("t", x, &[3]);
        let r = b.repeat("r", t, 0, 2);
        let y = b.identity("out", r);
        b.mark_output(y);
        let (stats, prog) = check_dme_preserves(b.finish());
        assert_eq!(stats.tensors_eliminated, 2);
        let last = prog.copy_nests().next().unwrap();
        let Body::Copy { load } = &last.body else { panic!() };
        let (src, _) = load.single().unwrap();
        assert_eq!(src, x);
    }

    #[test]
    fn keeps_output_tensors() {
        // a transpose producing a *graph output* must not be eliminated
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 5]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let (stats, prog) = check_dme_preserves(b.finish());
        assert_eq!(stats.tensors_eliminated, 0);
        assert_eq!(prog.load_store_pairs(), 1);
    }

    #[test]
    fn pad_then_slice_resolves_pieces() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4]);
        let p = b.pad("p", x, &[2], &[2]);
        // slice the left border + part of the interior
        let s = b.slice("s", p, &[1], &[5], &[1]);
        let y = b.identity("out", s);
        b.mark_output(y);
        let (stats, prog) = check_dme_preserves(b.finish());
        assert!(stats.tensors_eliminated >= 1);
        let last = prog.copy_nests().next().unwrap();
        let Body::Copy { load } = &last.body else { panic!() };
        // must read x on one region and zero on the other
        assert!(load.pieces.iter().any(|a| a.tensor.is_none()));
        assert!(load.pieces.iter().any(|a| a.tensor == Some(x)));
    }

    #[test]
    fn rewrites_compute_consumer_loads() {
        // transpose feeding a matmul: the transpose dies, the matmul's
        // load map absorbs the permutation. The oracle interprets the
        // matmul itself (the old fingerprint walker could not).
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 4]);
        let t = b.transpose("t", x, &[1, 0]); // [4, 8]
        let w = b.weight("w", &[8, 6]);
        let m = b.matmul("mm", t, w);
        b.mark_output(m);
        let (stats, prog) = check_dme_preserves(b.finish());
        assert_eq!(stats.tensors_eliminated, 1);
        assert_eq!(prog.load_store_pairs(), 0);
        // matmul now reads x with transposed access
        let mm = prog.nests.iter().find(|n| n.name == "mm").unwrap();
        let Body::Compute { loads, .. } = &mm.body else { panic!() };
        let (src, map) = loads[0].single().unwrap();
        assert_eq!(src, x);
        // loop (m, n, k): t[m, k] = x[k, m]
        assert_eq!(map.apply(&[2, 0, 3]), vec![3, 2]);
        // graph was rewired: matmul inputs now [x, w]
        let node = prog.graph.nodes().iter().find(|n| n.name == "mm").unwrap();
        assert_eq!(node.inputs, vec![x, w]);
    }

    #[test]
    fn fixed_point_iterates() {
        // a chain long enough that one sweep in a bad order would miss:
        // each elimination enables the next only in reverse order.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[2, 3, 4]);
        let mut cur = x;
        for k in 0..6 {
            cur = b.transpose(&format!("t{k}"), cur, &[2, 0, 1]);
        }
        let y = b.identity("out", cur);
        b.mark_output(y);
        let (stats, prog) = check_dme_preserves(b.finish());
        assert_eq!(stats.tensors_eliminated, 6);
        assert_eq!(prog.load_store_pairs(), 1);
        let last = prog.copy_nests().next().unwrap();
        let Body::Copy { load } = &last.body else { panic!() };
        let (src, map) = load.single().unwrap();
        assert_eq!(src, x);
        assert!(map.is_identity()); // 6 rotations of a 3-cycle = id
    }

    #[test]
    fn reshape_between_copies_eliminated() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[6, 4]);
        let r = b.reshape("r", x, &[3, 8]);
        let t = b.transpose("t", r, &[1, 0]);
        let y = b.identity("out", t);
        b.mark_output(y);
        let (stats, _) = check_dme_preserves(b.finish());
        // reshape's reader (transpose) has permutation guards only —
        // both eliminable.
        assert_eq!(stats.tensors_eliminated, 2);
    }

    #[test]
    fn stats_track_bytes() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]); // 16 KiB
        let t = b.transpose("t", x, &[1, 0]);
        let y = b.identity("out", t);
        b.mark_output(y);
        let g = b.finish();
        let mut prog = Program::lower(g);
        let stats = run_dme(&mut prog);
        assert_eq!(stats.tensors_eliminated, 1);
        assert_eq!(stats.bytes_eliminated, 64 * 64 * 4);
        assert!(stats.bytes_before >= stats.bytes_eliminated);
    }
}
