//! Local bank mapping — the paper's evaluation baseline.
//!
//! "Local mapping … generates mappings within each operator, without
//! propagation, but keeps the output of an operator in on-chip memory
//! if it will be directly used as the input of the next operator."
//!
//! Every operator picks its hardware-default placement in isolation:
//! MXU results land Col-aligned on the output-channel dim (that is
//! where the systolic array evicts), vector/pool results inherit their
//! first operand's placement, memory-bound ops carry placements through
//! their index transform. At every def-use edge whose placement differs
//! from the consumer's requirement, an inter-bank `MemCopy` is
//! materialized.

use super::bank::{
    input_requirement, is_mxu, is_vector, is_weight_operand, out_channel_dim,
    transfer_forward, BankAssignment, BankConfig, BankStats, Placement,
};
use crate::ir::graph::Graph;
use crate::ir::op::OpKind;
use crate::ir::tensor::{TensorId, TensorKind};
use std::collections::BTreeMap;

/// Run local mapping over a graph (typically post-DME).
pub fn run_local(graph: &Graph, cfg: &BankConfig) -> BankAssignment {
    let mut placements: BTreeMap<TensorId, Placement> = BTreeMap::new();

    // 1. per-operator defaults, in topo order, no lookahead
    for node in graph.nodes() {
        let out = node.output;
        let p = default_output_placement(graph, node, &placements, cfg);
        placements.insert(out, p);
    }

    materialize_copies(graph.clone(), placements, cfg, 0)
}

/// The operator's default output placement given only its own inputs
/// (no consumer knowledge — the essence of the local baseline).
pub(crate) fn default_output_placement(
    g: &Graph,
    node: &crate::ir::graph::Node,
    placements: &BTreeMap<TensorId, Placement>,
    _cfg: &BankConfig,
) -> Placement {
    let kind = &node.kind;
    if is_mxu(kind) {
        // systolic eviction default: Col on the output-channel dim
        return Placement::col(out_channel_dim(kind).unwrap());
    }
    if matches!(kind, OpKind::Pool { .. } | OpKind::GlobalAvgPool) {
        return Placement::row(1);
    }
    if is_vector(kind) {
        // vector lanes write back alongside their first staged operand
        for &inp in &node.inputs {
            if let Some(p) = placements.get(&inp) {
                return *p;
            }
        }
        return Placement::row(default_dim(g, node.output));
    }
    // memory-bound: carry the input placement through the transform
    // (unless DME rewrote the node — its true access is opaque here)
    if !node.rewritten {
        if let Some(&inp) = node.inputs.first() {
            if let Some(p) = placements.get(&inp) {
                let in_shape = &g.tensor(inp).shape;
                if let Some(q) = transfer_forward(kind, in_shape, *p) {
                    return q;
                }
            }
        }
    }
    Placement::row(default_dim(g, node.output))
}

fn default_dim(g: &Graph, t: TensorId) -> usize {
    // spread along the outermost non-unit dim (sequential inner access)
    let shape = &g.tensor(t).shape;
    shape
        .iter()
        .position(|&e| e > 1)
        .unwrap_or(0)
        .min(shape.len().saturating_sub(1))
}

/// Shared final sweep: given per-tensor placements, walk every def-use
/// edge, compare against the consumer's requirement, and insert a
/// `MemCopy` node per mismatch. Used by both local and global passes so
/// the simulator sees a uniform graph.
pub(crate) fn materialize_copies(
    mut graph: Graph,
    mut placements: BTreeMap<TensorId, Placement>,
    _cfg: &BankConfig,
    iterations: usize,
) -> BankAssignment {
    let mut stats = BankStats { iterations, ..Default::default() };
    // Collect (consumer node, input position, required placement) first;
    // mutating while scanning would invalidate the iteration.
    let mut fixes: Vec<(crate::ir::graph::NodeId, usize, Placement)> = Vec::new();
    for node in graph.nodes() {
        // vector match rule: the engine's lanes are hard-wired bank-to-
        // bank, so every staged activation input must sit in the same
        // placement the result is written to.
        let vector_anchor: Option<Placement> = if is_vector(&node.kind) {
            placements.get(&node.output).copied()
        } else {
            None
        };
        for (pos, &inp) in node.inputs.iter().enumerate() {
            if is_weight_operand(&graph, node, pos) {
                continue; // weights are staged directly into position
            }
            if graph.tensor(inp).kind == TensorKind::Input {
                continue; // host DMA deposits model inputs as required
            }
            let req = input_requirement(node, pos).or({
                if is_vector(&node.kind) {
                    // non-anchor operands must match the anchor
                    match vector_anchor {
                        Some(a) if placements.get(&inp) != Some(&a) => Some(a),
                        _ => None,
                    }
                } else {
                    None
                }
            });
            let Some(req) = req else { continue };
            match placements.get(&inp) {
                Some(p) if *p == req => {
                    stats.edges_matched += 1;
                }
                Some(_) => {
                    fixes.push((node.id, pos, req));
                }
                None => {
                    // unstaged (shouldn't happen post-assignment); treat as match
                    stats.edges_matched += 1;
                }
            }
        }
    }

    for (consumer, pos, req) in fixes {
        let inp = graph.node(consumer).inputs[pos];
        let info = graph.tensor(inp).clone();
        let remapped = graph.add_tensor(
            format!("{}~remap", info.name),
            &info.shape,
            info.dtype,
            TensorKind::Intermediate,
        );
        graph.insert_node_before(
            consumer,
            format!("memcopy_{}", stats.copies_inserted),
            OpKind::MemCopy,
            vec![inp],
            remapped,
        );
        graph.node_mut(consumer).inputs[pos] = remapped;
        placements.insert(remapped, req);
        stats.copies_inserted += 1;
        stats.copy_bytes += info.size_bytes();
    }

    BankAssignment { graph, placements, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::verify_graph;

    /// conv → bn → relu → conv: local mapping must pay exactly one
    /// remap at the second conv's input.
    #[test]
    fn conv_chain_pays_one_copy() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16, 16, 16]);
        let w1 = b.weight("w1", &[32, 16, 3, 3]);
        let c1 = b.conv2d("c1", x, w1, 1, 1);
        let bn = b.batchnorm("bn", c1);
        let r = b.relu("r", bn);
        let w2 = b.weight("w2", &[32, 32, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        let g = b.finish();
        let asg = run_local(&g, &BankConfig::default());
        verify_graph(&asg.graph).unwrap();
        assert_eq!(asg.stats.copies_inserted, 1);
        assert_eq!(asg.stats.copy_bytes, 32 * 16 * 16 * 4);
        // the memcopy feeds c2
        let c2n = asg.graph.nodes().iter().find(|n| n.name == "c2").unwrap();
        let producer = asg.graph.producer(c2n.inputs[0]).unwrap();
        assert_eq!(producer.kind, OpKind::MemCopy);
    }

    #[test]
    fn vector_mismatch_pays_copy() {
        // add(conv_out /*Col*/, pool_out /*Row*/): operands differ
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 8, 8]);
        let w = b.weight("w", &[8, 8, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let p = b.maxpool("p", x, 1, 1);
        let a = b.add("a", c, p);
        b.mark_output(a);
        let g = b.finish();
        let asg = run_local(&g, &BankConfig::default());
        verify_graph(&asg.graph).unwrap();
        // pool needs Row on x: x is a model input (free); add: anchor = c
        // (Col@1), p is Row@1 → one copy
        assert_eq!(asg.stats.copies_inserted, 1);
    }

    #[test]
    fn transpose_carries_placement() {
        // conv → transpose(NCHW→NHWC) → transpose back → conv:
        // placement rides through both transposes; single remap at conv2.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 8, 8]);
        let w1 = b.weight("w1", &[8, 8, 1, 1]);
        let c1 = b.conv2d("c1", x, w1, 1, 0);
        let t1 = b.transpose("t1", c1, &[0, 2, 3, 1]);
        let t2 = b.transpose("t2", t1, &[0, 3, 1, 2]);
        let w2 = b.weight("w2", &[8, 8, 1, 1]);
        let c2 = b.conv2d("c2", t2, w2, 1, 0);
        b.mark_output(c2);
        let g = b.finish();
        let asg = run_local(&g, &BankConfig::default());
        assert_eq!(asg.stats.copies_inserted, 1);
        // t2's output placement must be Col@1 again (rode through)
        let t2_out = g.nodes().iter().find(|n| n.name == "t2").unwrap().output;
        assert_eq!(asg.placements[&t2_out], Placement::col(1));
    }

    #[test]
    fn matched_edges_counted() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 4, 4]);
        let p1 = b.maxpool("p1", x, 2, 2);
        let p2 = b.maxpool("p2", p1, 2, 2);
        b.mark_output(p2);
        let g = b.finish();
        let asg = run_local(&g, &BankConfig::default());
        // pool writes Row@1; next pool requires Row@1 → matched
        assert_eq!(asg.stats.copies_inserted, 0);
        assert_eq!(asg.stats.edges_matched, 1);
    }
}
