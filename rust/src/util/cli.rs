//! Small declarative CLI parser (clap is not in the offline crate
//! cache). Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options with defaults, and positional arguments, plus generated help.

use std::collections::BTreeMap;

/// An option/flag specification.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A declarative command: name, help, options, positional names.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: vec![], positionals: vec![] }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Parse this command's arguments (already stripped of the command
    /// name itself).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = vec![];
        let mut pos: Vec<String> = vec![];
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key} for '{}'", self.name))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                pos.push(a.clone());
            }
            i += 1;
        }
        // fill defaults / check required
        for o in &self.opts {
            if o.is_flag {
                continue;
            }
            if !values.contains_key(o.name) {
                match o.default {
                    Some(d) => {
                        values.insert(o.name.to_string(), d.to_string());
                    }
                    None => return Err(format!("missing required option --{}", o.name)),
                }
            }
        }
        if pos.len() > self.positionals.len() {
            return Err(format!(
                "too many positional arguments for '{}' (expected {})",
                self.name,
                self.positionals.len()
            ));
        }
        Ok(Parsed { values, flags, positionals: pos })
    }

    pub fn usage(&self) -> String {
        let mut s = format!("  {} — {}\n", self.name, self.about);
        for (p, h) in &self.positionals {
            s.push_str(&format!("      <{p}>  {h}\n"));
        }
        for o in &self.opts {
            if o.is_flag {
                s.push_str(&format!("      --{}  {}\n", o.name, o.help));
            } else {
                match o.default {
                    Some(d) => s.push_str(&format!(
                        "      --{} <v>  {} (default: {})\n",
                        o.name, o.help, d
                    )),
                    None => s.push_str(&format!("      --{} <v>  {} (required)\n", o.name, o.help)),
                }
            }
        }
        s
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected unsigned integer, got '{}'", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected u64, got '{}'", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected float, got '{}'", self.get(name)))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

/// A multi-command application.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nCOMMANDS:\n", self.name, self.about);
        for c in &self.commands {
            s.push_str(&c.usage());
        }
        s
    }

    /// Dispatch: returns (command name, parsed args) or a help/error string.
    pub fn dispatch(&self, argv: &[String]) -> Result<(&Command, Parsed), String> {
        let Some(cmd_name) = argv.first() else {
            return Err(self.usage());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;
        let parsed = cmd.parse(&argv[1..])?;
        Ok((cmd, parsed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("compile", "compile a model")
            .opt("model", "resnet50", "model name")
            .opt("banks", "16", "bank count")
            .req("out", "output path")
            .flag("verbose", "chatty")
            .positional("input", "input file")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let p = cmd().parse(&s(&["--out", "/tmp/x"])).unwrap();
        assert_eq!(p.get("model"), "resnet50");
        assert_eq!(p.get_usize("banks").unwrap(), 16);
        assert_eq!(p.get("out"), "/tmp/x");
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&s(&[])).unwrap_err().contains("--out"));
    }

    #[test]
    fn equals_form_and_flags() {
        let p = cmd()
            .parse(&s(&["--out=/o", "--banks=8", "--verbose", "file.json"]))
            .unwrap();
        assert_eq!(p.get_usize("banks").unwrap(), 8);
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional(0), Some("file.json"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&s(&["--nope", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&s(&["--verbose=1", "--out", "x"])).is_err());
    }

    #[test]
    fn app_dispatch() {
        let app = App { name: "polymem", about: "test", commands: vec![cmd()] };
        let (c, p) = app.dispatch(&s(&["compile", "--out", "x"])).unwrap();
        assert_eq!(c.name, "compile");
        assert_eq!(p.get("out"), "x");
        assert!(app.dispatch(&s(&["bogus"])).is_err());
        assert!(app.dispatch(&s(&["--help"])).is_err());
    }
}
