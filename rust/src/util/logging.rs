//! Leveled stderr logging, controlled by `POLYMEM_LOG`.
//!
//! The spec is a comma-separated list: a bare level
//! (`error|warn|info|debug|trace`) sets the default, and
//! `module::path=level` entries override it per module subtree —
//! longest matching prefix wins, e.g.
//! `POLYMEM_LOG=warn,polymem::opt=trace` silences everything below
//! warn except the joint optimizer. Default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static MODS: Mutex<Option<Vec<(String, Level)>>> = Mutex::new(None);

fn parse_level(s: &str) -> Option<Level> {
    match s {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Parse a spec: bare levels set the default, `module=level` entries
/// accumulate. Unparsable entries are ignored.
fn parse_spec(spec: &str) -> (Option<Level>, Vec<(String, Level)>) {
    let mut def = None;
    let mut mods = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((m, l)) = part.split_once('=') {
            if let Some(lv) = parse_level(l.trim()) {
                mods.push((m.trim().to_string(), lv));
            }
        } else if let Some(lv) = parse_level(part) {
            def = Some(lv);
        }
    }
    (def, mods)
}

fn init_from_env() -> u8 {
    let spec = std::env::var("POLYMEM_LOG").unwrap_or_default();
    let (def, mods) = parse_spec(&spec);
    *MODS.lock().unwrap() = if mods.is_empty() { None } else { Some(mods) };
    let lvl = def.unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current default level (lazily initialized from the environment).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the default level programmatically (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Apply a full `POLYMEM_LOG`-style spec programmatically, replacing
/// any per-module overrides currently in effect.
pub fn set_module_spec(spec: &str) {
    let (def, mods) = parse_spec(spec);
    *MODS.lock().unwrap() = if mods.is_empty() { None } else { Some(mods) };
    if let Some(d) = def {
        set_level(d);
    }
}

/// Is `l` enabled at the default level (no module filtering)?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Is `l` enabled for `module`? Per-module overrides apply to the
/// named module and its submodules; the longest matching prefix wins,
/// and modules with no override use the default level.
pub fn enabled_for(l: Level, module: &str) -> bool {
    let def = level(); // also forces env initialization of MODS
    if let Some(mods) = MODS.lock().unwrap().as_ref() {
        let mut best: Option<(usize, Level)> = None;
        for (m, lv) in mods {
            let subtree = module.len() > m.len()
                && module.starts_with(m.as_str())
                && module[m.len()..].starts_with("::");
            if (module == m || subtree) && best.map(|(n, _)| m.len() > n).unwrap_or(true) {
                best = Some((m.len(), *lv));
            }
        }
        if let Some((_, lv)) = best {
            return l <= lv;
        }
    }
    l <= def
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled_for(l, module) {
        eprintln!("[{:5}] {}: {}", format!("{l:?}").to_uppercase(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests mutating the global level/spec (the harness
    /// runs same-binary tests concurrently).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        let _g = TEST_LOCK.lock().unwrap();
        set_module_spec(""); // clear any module overrides
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn module_spec_filters() {
        let _g = TEST_LOCK.lock().unwrap();
        set_module_spec("warn,polymem::opt=trace");
        set_level(Level::Warn); // bare level in the spec also sets this
        assert!(enabled_for(Level::Trace, "polymem::opt"));
        assert!(enabled_for(Level::Trace, "polymem::opt::search"));
        // `optx` is not in the `opt` subtree
        assert!(!enabled_for(Level::Trace, "polymem::optx"));
        assert!(!enabled_for(Level::Info, "polymem::tile"));
        assert!(enabled_for(Level::Warn, "polymem::tile"));
        // longest matching prefix wins
        set_module_spec("info,polymem=error,polymem::opt=debug");
        assert!(enabled_for(Level::Debug, "polymem::opt"));
        assert!(!enabled_for(Level::Warn, "polymem::tile"));
        assert!(enabled_for(Level::Info, "other::crate"));
        // restore defaults for concurrent tests
        set_module_spec("info");
        set_level(Level::Info);
    }
}
