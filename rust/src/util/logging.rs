//! Leveled stderr logging, controlled by `POLYMEM_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("POLYMEM_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level (lazily initialized from the environment).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {}: {}", format!("{l:?}").to_uppercase(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
