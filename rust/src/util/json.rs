//! Minimal JSON value, writer and recursive-descent parser (serde is
//! not in the offline crate cache). Used for accelerator configs,
//! experiment reports, and the coordinator's wire format.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 plus an i64 fast path.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            Json::Num(v) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(v) => Some(*v as f64),
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document (full input must be consumed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> ParseError {
        ParseError { offset: self.pos, message: m.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| self.err("bad number"))
        } else {
            text.parse::<i64>()
                .map(Json::Int)
                .map_err(|_| self.err("bad number"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", Json::Str("polymem".into())),
            ("banks", Json::Int(16)),
            ("ratio", Json::Num(0.76)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("dims", Json::Arr(vec![Json::Int(1), Json::Int(2)])),
        ]);
        let s = v.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(back, v);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": [true, null, -3.5e2]}], "c": ""}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_arr()
                .unwrap()[2]
                .as_f64()
                .unwrap(),
            -350.0
        );
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "");
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
        let u = parse(r#""Aé""#).unwrap();
        assert_eq!(u.as_str().unwrap(), "Aé");
    }

    #[test]
    fn errors_have_offsets() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        let e = parse("nul").unwrap_err();
        assert_eq!(e.offset, 0);
    }

    #[test]
    fn int_vs_float() {
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("42.0").unwrap(), Json::Num(42.0));
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("42.0").unwrap().as_i64(), Some(42));
        assert_eq!(parse("42.5").unwrap().as_i64(), None);
    }
}
