//! Infrastructure substrate.
//!
//! The build image is offline with a minimal crate cache (no clap /
//! serde / criterion / proptest / rand), so the small generic pieces a
//! production repo would pull from crates.io are implemented here:
//!
//! * [`error`] — string-backed error + context helpers (replaces
//!   `anyhow` for the runtime/serving layers).
//! * [`rng`] — SplitMix64 PRNG (replaces `rand`).
//! * [`prop`] — a seeded, shrinking property-test driver (replaces
//!   `proptest` for the invariants this repo checks).
//! * [`bench`] — a criterion-style measurement harness (warmup, sample
//!   statistics, throughput) used by `cargo bench` targets.
//! * [`json`] — a minimal JSON writer/parser for configs and reports.
//! * [`cli`] — a small declarative argument parser for the `polymem`
//!   binary and examples.
//! * [`regress`] — tolerance-based benchmark regression comparator
//!   (the `bench-regress` CI gate).
//! * [`logging`] — leveled stderr logging.
//! * [`fuzzgraph`] — seeded random operator-DAG generator for the
//!   differential equivalence fuzzer.

pub mod bench;
pub mod cli;
pub mod error;
pub mod fuzzgraph;
pub mod json;
pub mod logging;
pub mod prop;
pub mod regress;
pub mod rng;
