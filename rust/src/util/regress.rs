//! Benchmark regression gate: tolerance-based comparison of two
//! benchmark JSON records (a committed baseline vs the current run).
//!
//! The comparator flattens both documents to dot-path → numeric-leaf
//! maps (`loads.poisson-low (0.25x cap) / bucketized.qps`), classifies
//! each metric's improvement direction from its leaf name (latency and
//! byte counts should fall, qps and attainment should rise), and flags
//! a regression when the current value moves past the baseline in the
//! *bad* direction by more than the relative tolerance. Metrics whose
//! direction is unknown are recorded but never gated, and noisy
//! wall-clock paths (e.g. `compile_seconds`, the live-server section)
//! are excluded via substring skip patterns so the gate only binds on
//! the deterministic virtual-time numbers.
//!
//! Array elements are keyed by their `"label"` field when present, so
//! reordering load-sim rows does not shuffle the comparison.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Which way a metric is supposed to move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    LowerIsBetter,
    HigherIsBetter,
    /// Direction unknown: compared and reported, never gated.
    Informational,
}

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::LowerIsBetter => "lower-better",
            Direction::HigherIsBetter => "higher-better",
            Direction::Informational => "info",
        }
    }
}

/// Classify a metric path by its leaf name. Rate and ratio names are
/// checked first: a throughput leaf like `candidates_per_second`
/// contains the substring `seconds`, so testing the lower-is-better
/// set first would gate it backwards.
pub fn direction_for(path: &str) -> Direction {
    let leaf = path.rsplit('.').next().unwrap_or(path);
    const HIGHER: &[&str] = &[
        "qps", "attainment", "met", "completed", "hits", "throughput", "per_second", "speedup",
    ];
    const LOWER: &[&str] = &[
        "latency", "bytes", "seconds", "missed", "rejected", "burn", "overwritten", "spill",
        "offchip",
    ];
    if HIGHER.iter().any(|k| leaf.contains(k)) {
        Direction::HigherIsBetter
    } else if LOWER.iter().any(|k| leaf.contains(k)) {
        Direction::LowerIsBetter
    } else {
        Direction::Informational
    }
}

/// Comparison options.
#[derive(Clone, Debug)]
pub struct RegressOptions {
    /// Allowed relative movement in the bad direction (0.15 = 15%).
    pub rel_tol: f64,
    /// Path substrings excluded from gating entirely.
    pub skip: Vec<String>,
}

impl Default for RegressOptions {
    fn default() -> RegressOptions {
        RegressOptions { rel_tol: 0.15, skip: Vec::new() }
    }
}

/// One compared metric.
#[derive(Clone, Debug)]
pub struct MetricCheck {
    pub path: String,
    pub direction: Direction,
    pub baseline: f64,
    pub current: f64,
    pub regressed: bool,
}

impl MetricCheck {
    /// Signed relative movement vs baseline (positive = value rose).
    pub fn rel_change(&self) -> f64 {
        (self.current - self.baseline) / self.baseline.abs().max(1e-12)
    }
}

/// Full comparison outcome.
#[derive(Clone, Debug, Default)]
pub struct RegressReport {
    pub checks: Vec<MetricCheck>,
    /// Gated metrics present in the baseline but absent from the
    /// current run — losing a metric is itself a regression.
    pub missing: Vec<String>,
    /// Metrics present only in the current run (never a failure).
    pub added: Vec<String>,
    /// Metrics excluded by skip patterns.
    pub skipped: usize,
}

impl RegressReport {
    pub fn regressions(&self) -> Vec<&MetricCheck> {
        self.checks.iter().filter(|c| c.regressed).collect()
    }

    pub fn passed(&self) -> bool {
        self.missing.is_empty() && self.checks.iter().all(|c| !c.regressed)
    }

    /// Human-readable verdict table (regressions first).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in self.regressions() {
            let _ = writeln!(
                out,
                "REGRESSED  {:<60} {:>14.4} -> {:>14.4} ({:+.1}%, {})",
                c.path,
                c.baseline,
                c.current,
                100.0 * c.rel_change(),
                c.direction.name()
            );
        }
        for m in &self.missing {
            let _ = writeln!(out, "MISSING    {m} (present in baseline, absent now)");
        }
        let gated = self
            .checks
            .iter()
            .filter(|c| c.direction != Direction::Informational)
            .count();
        let _ = writeln!(
            out,
            "{}: {} metrics compared ({} gated, {} informational, {} skipped), \
             {} regressed, {} missing, {} new",
            if self.passed() { "PASS" } else { "FAIL" },
            self.checks.len(),
            gated,
            self.checks.len() - gated,
            self.skipped,
            self.regressions().len(),
            self.missing.len(),
            self.added.len()
        );
        out
    }
}

/// Flatten numeric leaves to `path -> value`. Object keys join with
/// `.`; array elements use their `"label"` field when present, else
/// the index.
pub fn flatten(j: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    walk(j, String::new(), &mut out);
    out
}

fn join(prefix: &str, seg: &str) -> String {
    if prefix.is_empty() {
        seg.to_string()
    } else {
        format!("{prefix}.{seg}")
    }
}

fn walk(j: &Json, prefix: String, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Int(v) => {
            out.insert(prefix, *v as f64);
        }
        Json::Num(v) => {
            if v.is_finite() {
                out.insert(prefix, *v);
            }
        }
        Json::Obj(pairs) => {
            for (k, v) in pairs {
                walk(v, join(&prefix, k), out);
            }
        }
        Json::Arr(items) => {
            for (i, v) in items.iter().enumerate() {
                let seg = v
                    .get("label")
                    .and_then(|l| l.as_str())
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| i.to_string());
                walk(v, join(&prefix, &seg), out);
            }
        }
        _ => {}
    }
}

/// Compare a current benchmark record against a baseline.
pub fn compare(baseline: &Json, current: &Json, opts: &RegressOptions) -> RegressReport {
    let base = flatten(baseline);
    let cur = flatten(current);
    let skip = |path: &str| opts.skip.iter().any(|s| !s.is_empty() && path.contains(s));
    let mut rep = RegressReport::default();
    for (path, &b) in &base {
        if skip(path) {
            rep.skipped += 1;
            continue;
        }
        let dir = direction_for(path);
        let Some(&c) = cur.get(path) else {
            if dir != Direction::Informational {
                rep.missing.push(path.clone());
            }
            continue;
        };
        // movement past the baseline in the bad direction, beyond the
        // tolerance band scaled by the baseline's magnitude
        let band = opts.rel_tol * b.abs().max(1e-12);
        let regressed = match dir {
            Direction::LowerIsBetter => c - b > band,
            Direction::HigherIsBetter => b - c > band,
            Direction::Informational => false,
        };
        rep.checks.push(MetricCheck { path: path.clone(), direction: dir, baseline: b, current: c, regressed });
    }
    for path in cur.keys() {
        if !base.contains_key(path) && !skip(path) {
            rep.added.push(path.clone());
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(qps: f64, p99: i64, bpr: f64) -> Json {
        Json::obj(vec![
            ("model", Json::Str("m".into())),
            (
                "loads",
                Json::Arr(vec![Json::obj(vec![
                    ("label", Json::Str("low".into())),
                    ("qps", Json::Num(qps)),
                    ("p99_latency_us", Json::Int(p99)),
                    ("bytes_per_request", Json::Num(bpr)),
                    ("mean_batch", Json::Num(3.0)),
                ])]),
            ),
        ])
    }

    #[test]
    fn identical_runs_pass() {
        let a = doc(1000.0, 500, 4096.0);
        let rep = compare(&a, &a, &RegressOptions::default());
        assert!(rep.passed(), "{}", rep.render());
        assert!(rep.regressions().is_empty());
        assert!(rep.missing.is_empty() && rep.added.is_empty());
    }

    #[test]
    fn directional_gating() {
        let base = doc(1000.0, 500, 4096.0);
        // qps fell 50%, latency doubled, bytes doubled: three regressions
        let bad = compare(&base, &doc(500.0, 1000, 8192.0), &RegressOptions::default());
        assert_eq!(bad.regressions().len(), 3, "{}", bad.render());
        assert!(!bad.passed());
        // everything *improved* by the same magnitudes: no regression
        let good = compare(&base, &doc(2000.0, 250, 2048.0), &RegressOptions::default());
        assert!(good.passed(), "{}", good.render());
        // within tolerance: 10% worse everywhere passes at 15%
        let ok = compare(&base, &doc(900.0, 550, 4505.0), &RegressOptions::default());
        assert!(ok.passed(), "{}", ok.render());
    }

    #[test]
    fn informational_metrics_never_gate() {
        let base = doc(1000.0, 500, 4096.0);
        let mut cur = doc(1000.0, 500, 4096.0);
        if let Json::Obj(pairs) = &mut cur {
            if let Some(Json::Arr(items)) = pairs.get_mut("loads") {
                if let Json::Obj(row) = &mut items[0] {
                    // wildly different, but direction unknown: not gated
                    row.insert("mean_batch".to_string(), Json::Num(8.0));
                }
            }
        }
        let rep = compare(&base, &cur, &RegressOptions::default());
        assert!(rep.passed(), "{}", rep.render());
    }

    #[test]
    fn missing_gated_metric_fails_and_skip_excuses_it() {
        let base = doc(1000.0, 500, 4096.0);
        let cur = Json::obj(vec![("model", Json::Str("m".into()))]);
        let rep = compare(&base, &cur, &RegressOptions::default());
        assert!(!rep.passed());
        assert!(!rep.missing.is_empty());
        let skipped = compare(
            &base,
            &cur,
            &RegressOptions { rel_tol: 0.15, skip: vec!["loads".into()] },
        );
        assert!(skipped.passed(), "{}", skipped.render());
        assert!(skipped.skipped > 0);
    }

    #[test]
    fn labels_key_array_rows() {
        let flat = flatten(&doc(1.0, 2, 3.0));
        assert!(flat.contains_key("loads.low.qps"), "{flat:?}");
        assert_eq!(direction_for("loads.low.qps"), Direction::HigherIsBetter);
        assert_eq!(direction_for("loads.low.p99_latency_us"), Direction::LowerIsBetter);
        assert_eq!(direction_for("loads.low.mean_batch"), Direction::Informational);
    }

    #[test]
    fn rates_beat_their_unit_suffix() {
        // throughput leaves whose names embed a time unit must still
        // gate higher-is-better — the compile-phases record depends on
        // this for candidates/second and the memoization speedup
        assert_eq!(
            direction_for("beam_sweep.beam8.candidates_per_second"),
            Direction::HigherIsBetter
        );
        assert_eq!(direction_for("beam_sweep.beam8.speedup_vs_full_serial"), Direction::HigherIsBetter);
        // plain wall-time leaves still fall the right way
        assert_eq!(direction_for("opt_profile.opt_stats.search_seconds"), Direction::LowerIsBetter);
        assert_eq!(direction_for("beam_sweep.beam8.best_offchip"), Direction::LowerIsBetter);
        // unchanged serving leaves keep their classification
        assert_eq!(direction_for("loads.low.bytes_per_request"), Direction::LowerIsBetter);
        assert_eq!(direction_for("loads.low.deadline_met"), Direction::HigherIsBetter);
    }
}
