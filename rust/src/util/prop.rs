//! A small seeded property-test driver (offline stand-in for proptest).
//!
//! Usage (`no_run`: doctest binaries don't inherit the rpath to
//! libxla_extension's bundled libstdc++ in this offline image):
//! ```no_run
//! use polymem::util::prop::{Prop, Gen};
//! Prop::new("addition commutes", 200).check(|g: &mut Gen| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case runs with an independent, reportable seed; on panic the
//! driver re-raises with the failing case index and seed so the exact
//! case can be replayed with `PROP_SEED`.

use super::rng::SplitMix64;

/// Value generator handed to each property case.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: SplitMix64::new(seed) }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below((hi - lo) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Random shape with `ndim` dims, each extent in `[1, max_extent]`.
    pub fn shape(&mut self, ndim: usize, max_extent: i64) -> Vec<i64> {
        (0..ndim).map(|_| self.i64_in(1, max_extent + 1)).collect()
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut p);
        p
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// A named property with a case budget.
pub struct Prop {
    name: &'static str,
    cases: usize,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str, cases: usize) -> Self {
        // Honor PROP_SEED for replaying a specific failure.
        let base_seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| fnv1a(name.as_bytes()));
        Prop { name, cases, base_seed }
    }

    /// Run the property over `cases` generated cases. Panics with case
    /// seed information on the first failure.
    pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(&self, f: F) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed);
                f(&mut g);
            });
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{}' failed at case {}/{} (replay: PROP_SEED={}):\n  {}",
                    self.name, case, self.cases, seed, msg
                );
            }
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        Prop::new("abs is nonneg for > i64::MIN", 100).check(|g| {
            let v = g.i64_in(-1_000_000, 1_000_000);
            assert!(v.abs() >= 0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        Prop::new("always fails", 10).check(|_g| {
            panic!("boom");
        });
    }

    #[test]
    fn generator_helpers_in_bounds() {
        Prop::new("gen helpers", 50).check(|g| {
            let s = g.shape(3, 8);
            assert_eq!(s.len(), 3);
            assert!(s.iter().all(|&e| (1..=8).contains(&e)));
            let p = g.permutation(5);
            let mut q = p.clone();
            q.sort();
            assert_eq!(q, vec![0, 1, 2, 3, 4]);
            let u = g.usize_in(2, 10);
            assert!((2..10).contains(&u));
        });
    }
}
