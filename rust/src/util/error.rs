//! Minimal error type + context helpers (anyhow is not in the offline
//! crate cache). API mirrors the small subset of `anyhow` this repo
//! uses — a string-backed error, `Result` alias, a `Context` extension
//! trait and the `format_err!` / `bail!` / `ensure!` macros — so the
//! runtime/coordinator code reads the same as it would with anyhow.

use std::fmt;

/// A string-backed error with an optional cause chain (flattened into
/// the message, which is all the serving layer ever reports).
#[derive(Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(m: String) -> Error {
        Error::msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Error {
        Error::msg(m)
    }
}

/// Crate-wide result alias (the `anyhow::Result` stand-in).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on results and options.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*))
    };
}

/// Assert-or-early-return with a formatted [`Error`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7);
    }

    fn guarded(v: i64) -> Result<i64> {
        ensure!(v > 0, "need positive, got {v}");
        Ok(v)
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
        assert!(guarded(3).is_ok());
        assert_eq!(
            guarded(-1).unwrap_err().to_string(),
            "need positive, got -1"
        );
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<i32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let s: Option<i32> = Some(4);
        assert_eq!(s.with_context(|| "x".into()).unwrap(), 4);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
