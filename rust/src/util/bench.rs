//! Criterion-style measurement harness (criterion is not in the offline
//! crate cache). Provides warmup, timed sampling, and summary statistics
//! (mean / p50 / p95 / p99 / min), plus a tiny suite runner used by the
//! `cargo bench` targets (which are built with `harness = false`).

use std::time::{Duration, Instant};

/// Summary statistics for one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Stats {
    /// items/second derived from mean latency, if items_per_iter set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.mean.as_secs_f64())
    }

    pub fn print(&self) {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        println!(
            "{:<44} mean {:>12?}  p50 {:>12?}  p99 {:>12?}  min {:>12?}{}",
            self.name, self.mean, self.p50, self.p99, self.min, tp
        );
    }
}

/// One benchmark: measures `f` repeatedly; `f` returns a value that is
/// black-boxed to stop the optimizer from deleting the work.
pub struct Bench {
    name: String,
    warmup: Duration,
    samples: usize,
    items_per_iter: Option<f64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(200),
            samples: 30,
            items_per_iter: None,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        assert!(n >= 1);
        self.samples = n;
        self
    }

    /// Declare the number of logical items processed per iteration so
    /// the report can show throughput.
    pub fn throughput_items(mut self, n: f64) -> Self {
        self.items_per_iter = Some(n);
        self
    }

    /// Run the benchmark and return statistics.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> Stats {
        // Warmup until the budget is consumed (at least one call).
        let wstart = Instant::now();
        loop {
            black_box(f());
            if wstart.elapsed() >= self.warmup {
                break;
            }
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
        Stats {
            name: self.name,
            samples: self.samples,
            mean: total / self.samples as u32,
            min: times[0],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            items_per_iter: self.items_per_iter,
        }
    }
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write a machine-readable benchmark record to
/// `$BENCH_JSON_DIR/<name>` (default: `target/`, which is gitignored —
/// ad-hoc `cargo bench` runs must not litter the working tree). The
/// bench mains call this so `ci.sh` can collect per-run JSON artifacts
/// (`BENCH_plan.json`, `BENCH_tile.json`) for the perf trajectory.
pub fn write_json_record(name: &str, json: &crate::util::json::Json) {
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| "target".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = std::path::Path::new(&dir).join(name);
    match std::fs::write(&path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
}

/// A collection of benchmarks printed as a table, used by bench mains.
pub struct Suite {
    title: String,
    results: Vec<Stats>,
}

impl Suite {
    pub fn new(title: impl Into<String>) -> Self {
        let title = title.into();
        println!("\n=== {title} ===");
        Suite { title, results: vec![] }
    }

    pub fn add(&mut self, stats: Stats) {
        stats.print();
        self.results.push(stats);
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    pub fn finish(self) -> Vec<Stats> {
        println!("=== {} done ({} benchmarks) ===", self.title, self.results.len());
        self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let stats = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .samples(5)
            .run(|| 1 + 1);
        assert_eq!(stats.samples, 5);
        assert!(stats.min <= stats.p50 && stats.p50 <= stats.p99);
    }

    #[test]
    fn throughput_reported() {
        let stats = Bench::new("tp")
            .warmup(Duration::from_millis(1))
            .samples(3)
            .throughput_items(1000.0)
            .run(|| std::thread::sleep(Duration::from_micros(100)));
        let tp = stats.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1000.0 / 100e-6 * 1.1);
    }

    #[test]
    fn ordering_of_percentiles() {
        let mut i = 0u64;
        let stats = Bench::new("var")
            .warmup(Duration::from_millis(1))
            .samples(20)
            .run(|| {
                i += 1;
                // variable work
                (0..(i % 5) * 1000).sum::<u64>()
            });
        assert!(stats.min <= stats.mean * 2);
        assert!(stats.p50 <= stats.p95);
        assert!(stats.p95 <= stats.p99);
    }
}
