//! Seeded random operator-DAG generator for differential fuzzing.
//!
//! Builds small, shape-checked graphs mixing the memory-bound ops DME
//! attacks (transpose / reshape / tile / repeat / slice / pad / concat
//! / split / identity) with compute ops (matmul, padded conv2d,
//! pooling, softmax, elementwise) so random chains hit DME fixed-point
//! interactions, piecewise-load rewrites and `oob_zero` legality
//! checks the hand-written model builders never exercise.
//!
//! Every generated graph:
//! * passes [`crate::ir::verify::verify_graph`] by construction (ops
//!   are only emitted when their preconditions hold — the generator
//!   retries rather than building invalid nodes);
//! * is tiny (tensor element counts capped by [`FuzzOpts::max_elems`])
//!   so exhaustive execution on the reference interpreter stays cheap;
//! * is a pure function of the seed — a failing seed printed by the
//!   differential suite reproduces the exact graph (see README.md).

use crate::ir::builder::GraphBuilder;
use crate::ir::op::{OpKind, PoolKind};
use crate::ir::tensor::{TensorId, TensorKind};
use crate::ir::Graph;
use crate::util::rng::SplitMix64;

/// Generator limits.
#[derive(Clone, Copy, Debug)]
pub struct FuzzOpts {
    /// Target operator count (the generator may fall slightly short if
    /// repeated proposals fail their preconditions).
    pub ops: usize,
    /// Cap on any tensor's element count.
    pub max_elems: i64,
    /// Force the first input to have at least this many elements
    /// (0 = no floor). Set above a test chip's scratchpad capacity so
    /// fuzzed graphs exercise the tiling and streaming-fallback paths
    /// instead of always fitting on chip.
    pub min_first_input_elems: i64,
}

impl Default for FuzzOpts {
    fn default() -> Self {
        FuzzOpts { ops: 12, max_elems: 192, min_first_input_elems: 0 }
    }
}

impl FuzzOpts {
    /// Oversized-tensor variant: the first input alone (≥ 1280 f32
    /// elements = 5 KiB) exceeds the 4 KiB scratchpad of
    /// `AccelConfig::tiny(4096)`, so planner streaming and the tiling
    /// stage both trigger. Fewer ops keep exhaustive interpretation
    /// cheap despite the bigger tensors.
    pub fn oversized() -> Self {
        FuzzOpts { ops: 8, max_elems: 2560, min_first_input_elems: 1280 }
    }
}

/// Generate a graph from a seed with default limits — except that
/// every fourth seed uses [`FuzzOpts::oversized`], so the corpus mixes
/// chip-sized and scratchpad-busting tensors deterministically.
pub fn fuzz_graph(seed: u64) -> Graph {
    let opts = if seed % 4 == 3 { FuzzOpts::oversized() } else { FuzzOpts::default() };
    fuzz_graph_with(seed, &opts)
}

/// Generate a graph from a seed.
pub fn fuzz_graph_with(seed: u64, opts: &FuzzOpts) -> Graph {
    let mut r = SplitMix64::new(seed);
    let mut b = GraphBuilder::new();
    let mut pool: Vec<TensorId> = Vec::new();
    let n_inputs = 1 + r.below(2) as usize;
    for i in 0..n_inputs {
        let shape = if i == 0 && opts.min_first_input_elems > 0 {
            random_big_shape(&mut r, opts.min_first_input_elems, opts.max_elems)
        } else {
            random_shape(&mut r, opts.max_elems)
        };
        pool.push(b.input(&format!("in{i}"), &shape));
    }
    let mut made = 0usize;
    let mut attempts = 0usize;
    while made < opts.ops && attempts < opts.ops * 16 {
        attempts += 1;
        if let Some(new) = random_op(&mut b, &mut r, &pool, made, opts) {
            pool.extend(new);
            made += 1;
        }
    }
    // Unconsumed intermediates become graph outputs: verify_graph
    // forbids dead intermediates, DME must preserve outputs, and the
    // oracle compares exactly these tensors.
    let sinks: Vec<TensorId> = pool
        .iter()
        .copied()
        .filter(|&t| {
            b.graph().consumers(t).is_empty()
                && b.graph().tensor(t).kind == TensorKind::Intermediate
        })
        .collect();
    for t in sinks {
        b.mark_output(t);
    }
    if b.graph().outputs().is_empty() {
        let y = b.identity("out", pool[0]);
        b.mark_output(y);
    }
    b.finish()
}

fn random_shape(r: &mut SplitMix64, max_elems: i64) -> Vec<i64> {
    let rank = 1 + r.below(4) as usize; // 1..=4
    loop {
        let hi = if rank >= 4 { 4 } else { 6 };
        let dims: Vec<i64> = (0..rank).map(|_| r.range_i64(1, hi)).collect();
        if dims.iter().product::<i64>() <= max_elems {
            return dims;
        }
    }
}

/// A rank-2 shape with `min_elems ≤ numel ≤ max_elems` — big enough to
/// bust a test scratchpad, rank-2 so matmul/elementwise chains apply.
fn random_big_shape(r: &mut SplitMix64, min_elems: i64, max_elems: i64) -> Vec<i64> {
    let rows = r.range_i64(2, 9); // 2..=8
    let lo = (min_elems + rows - 1) / rows;
    let hi = (max_elems / rows).max(lo);
    vec![rows, r.range_i64(lo, hi + 1)]
}

/// Random factorization of `numel` into 1–3 dims.
fn random_factorization(r: &mut SplitMix64, numel: i64) -> Vec<i64> {
    let mut dims = Vec::new();
    let mut rest = numel;
    while rest > 1 && dims.len() < 2 {
        // random divisor of `rest`
        let mut d = r.range_i64(1, rest + 1);
        while rest % d != 0 {
            d -= 1;
        }
        dims.push(d);
        rest /= d;
    }
    dims.push(rest);
    dims
}

/// Propose one operator over the pool. Returns the produced tensors,
/// or `None` when the proposal's preconditions fail (caller retries).
fn random_op(
    b: &mut GraphBuilder,
    r: &mut SplitMix64,
    pool: &[TensorId],
    k: usize,
    opts: &FuzzOpts,
) -> Option<Vec<TensorId>> {
    let cur = *r.choose(pool);
    let shape = b.graph().tensor(cur).shape.clone();
    let nd = shape.len();
    let numel: i64 = shape.iter().product();
    match r.below(14) {
        0 => {
            let mut perm: Vec<usize> = (0..nd).collect();
            r.shuffle(&mut perm);
            Some(vec![b.transpose(&format!("tr{k}"), cur, &perm)])
        }
        1 => {
            let new_shape = random_factorization(r, numel);
            Some(vec![b.reshape(&format!("rs{k}"), cur, &new_shape)])
        }
        2 => {
            if numel * 2 > opts.max_elems {
                return None;
            }
            let axis = r.below(nd as u64) as usize;
            let mut reps = vec![1i64; nd];
            reps[axis] = 2;
            Some(vec![b.tile(&format!("tile{k}"), cur, &reps)])
        }
        3 => {
            if numel * 2 > opts.max_elems {
                return None;
            }
            let axis = r.below(nd as u64) as usize;
            Some(vec![b.repeat(&format!("rep{k}"), cur, axis, 2)])
        }
        4 => {
            let begin: Vec<i64> = shape.iter().map(|&e| r.range_i64(0, e)).collect();
            let end: Vec<i64> = shape
                .iter()
                .zip(&begin)
                .map(|(&e, &s)| r.range_i64(s + 1, e + 1))
                .collect();
            let stride: Vec<i64> = (0..nd).map(|_| r.range_i64(1, 3)).collect();
            Some(vec![b.slice(&format!("sl{k}"), cur, &begin, &end, &stride)])
        }
        5 => {
            let lo: Vec<i64> = (0..nd).map(|_| r.range_i64(0, 2)).collect();
            let hi: Vec<i64> = (0..nd).map(|_| r.range_i64(0, 2)).collect();
            let new_numel: i64 = shape
                .iter()
                .zip(lo.iter().zip(&hi))
                .map(|(&e, (&l, &h))| e + l + h)
                .product();
            if new_numel > opts.max_elems {
                return None;
            }
            Some(vec![b.pad(&format!("pd{k}"), cur, &lo, &hi)])
        }
        6 => {
            // concat with a rank/shape-compatible partner (or with
            // itself — reading the same tensor twice is legal SSA)
            let axis = r.below(nd as u64) as usize;
            let partner = pool
                .iter()
                .copied()
                .find(|&t| {
                    let s = &b.graph().tensor(t).shape;
                    s.len() == nd
                        && s.iter()
                            .zip(&shape)
                            .enumerate()
                            .all(|(d, (a, c))| d == axis || a == c)
                })
                .unwrap_or(cur);
            let total = numel + b.graph().tensor(partner).numel();
            if total > opts.max_elems {
                return None;
            }
            Some(vec![b.concat(&format!("cat{k}"), &[cur, partner], axis)])
        }
        7 => {
            let axis = (0..nd).find(|&d| shape[d] % 2 == 0 && shape[d] >= 2)?;
            Some(b.split(&format!("sp{k}"), cur, axis, 2))
        }
        8 => Some(vec![b.identity(&format!("id{k}"), cur)]),
        9 => {
            let out = match r.below(4) {
                0 => b.relu(&format!("relu{k}"), cur),
                1 => b.tanh(&format!("tanh{k}"), cur),
                2 => b.sigmoid(&format!("sig{k}"), cur),
                _ => {
                    use crate::ir::op::UnaryFn;
                    b.apply(&format!("neg{k}"), OpKind::Unary(UnaryFn::Neg), &[cur])
                }
            };
            Some(vec![out])
        }
        10 => {
            use crate::ir::op::BinaryFn;
            let partner = pool
                .iter()
                .copied()
                .find(|&t| t != cur && b.graph().tensor(t).shape == shape)
                .unwrap_or(cur);
            let f = *r.choose(&[BinaryFn::Add, BinaryFn::Sub, BinaryFn::Mul, BinaryFn::Max]);
            Some(vec![b.apply(&format!("bin{k}"), OpKind::Binary(f), &[cur, partner])])
        }
        11 => {
            if nd != 2 {
                return None;
            }
            let m = r.range_i64(1, 5);
            // both the result and the created weight respect the cap
            if shape[0] * m > opts.max_elems || shape[1] * m > opts.max_elems {
                return None;
            }
            let w = b.weight(&format!("w{k}"), &[shape[1], m]);
            Some(vec![b.matmul(&format!("mm{k}"), cur, w)])
        }
        12 => {
            if *shape.last().unwrap() > 8 {
                return None;
            }
            Some(vec![b.apply(&format!("sm{k}"), OpKind::Softmax, &[cur])])
        }
        _ => {
            // padded conv2d / pooling on rank-4 tensors: exercises the
            // oob_zero legality path through DME
            if nd != 4 {
                return None;
            }
            let (c, h, w) = (shape[1], shape[2], shape[3]);
            if r.chance(0.5) {
                let co = r.range_i64(1, 5);
                let out_numel = shape[0] * co * h * w;
                // bound interpretation cost (domain = out × cin × 3 × 3)
                // and keep the created weight under the element cap too
                if out_numel > opts.max_elems
                    || co * c * 9 > opts.max_elems
                    || out_numel * c * 9 > 40_000
                {
                    return None;
                }
                let wt = b.weight(&format!("cw{k}"), &[co, c, 3, 3]);
                Some(vec![b.conv2d(&format!("cv{k}"), cur, wt, 1, 1)])
            } else {
                if h < 2 || w < 2 {
                    return None;
                }
                let kind = *r.choose(&[PoolKind::Max, PoolKind::Avg]);
                Some(vec![b.apply(
                    &format!("pool{k}"),
                    OpKind::Pool { kind, window: 2, stride: 1 },
                    &[cur],
                )])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::ir::Program;

    #[test]
    fn generated_graphs_are_valid() {
        for seed in 0..60u64 {
            let g = fuzz_graph(seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
            verify_graph(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            verify_program(&Program::lower(g))
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = fuzz_graph(42);
        let c = fuzz_graph(42);
        assert_eq!(a.nodes().len(), c.nodes().len());
        assert_eq!(a.tensors().count(), c.tensors().count());
        for (na, nc) in a.nodes().iter().zip(c.nodes()) {
            assert_eq!(na.name, nc.name);
            assert_eq!(na.inputs, nc.inputs);
        }
    }

    #[test]
    fn respects_element_cap() {
        let opts = FuzzOpts { ops: 16, max_elems: 64, ..Default::default() };
        for seed in 0..20u64 {
            let g = fuzz_graph_with(seed, &opts);
            for t in g.tensors() {
                assert!(t.numel() <= 64, "seed {seed}: {} elems", t.numel());
            }
        }
    }

    #[test]
    fn oversized_seeds_bust_a_tiny_scratchpad() {
        // every 4th seed must carry at least one tensor bigger than the
        // 4 KiB test scratchpad, and stay valid
        for k in 0..8u64 {
            let seed = 4 * k + 3;
            let g = fuzz_graph(seed);
            verify_graph(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let biggest = g.tensors().map(|t| t.size_bytes()).max().unwrap();
            assert!(
                biggest > 4096,
                "seed {seed}: biggest tensor {biggest} B fits the scratchpad"
            );
        }
    }

    #[test]
    fn mixes_memory_and_compute_ops() {
        // across a seed batch, both op families must appear
        let (mut mem, mut comp) = (0usize, 0usize);
        for seed in 0..30u64 {
            let g = fuzz_graph(seed);
            for n in g.nodes() {
                if n.kind.is_memory_bound() {
                    mem += 1;
                } else {
                    comp += 1;
                }
            }
        }
        assert!(mem > 0 && comp > 0, "mem={mem} comp={comp}");
    }
}
