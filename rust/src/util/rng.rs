//! Deterministic PRNGs for sampling and property tests.
//!
//! The offline build environment has no `rand` crate; SplitMix64 is a
//! tiny, well-understood generator that is more than adequate for test
//! sampling and synthetic workload generation (not cryptography).

/// SplitMix64 (Steele, Lea, Flood 2014). Passes BigCrush when used as a
/// 64-bit generator; trivially seedable.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0. Uses rejection to avoid
    /// modulo bias (matters for tiny n in long property-test loops).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = SplitMix64::new(42);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_i64_bounds() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
