//! Dense integer matrices over ℤ (`i64`) — just enough linear algebra
//! for affine-map composition and exact inversion: multiplication,
//! identity/permutation constructors, determinant (Bareiss,
//! fraction-free), and adjugate-based exact inverse for unimodular-ish
//! matrices. Larger solves go through [`crate::poly::smith`].

use std::fmt;

/// A dense `rows × cols` integer matrix, row-major.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct IMat {
    rows: usize,
    cols: usize,
    data: Vec<i64>,
}

impl IMat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        IMat { rows, cols, data: vec![0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = IMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1;
        }
        m
    }

    /// Build from a row-major slice of rows.
    pub fn from_rows(rows: &[&[i64]]) -> Self {
        assert!(!rows.is_empty(), "from_rows: empty");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "from_rows: ragged rows");
            data.extend_from_slice(r);
        }
        IMat { rows: rows.len(), cols, data }
    }

    /// Permutation matrix P with `P·e_j = e_{perm[j]}`, i.e. applying
    /// the matrix to a vector moves component `j` to row `perm[j]`.
    pub fn permutation(perm: &[usize]) -> Self {
        let n = perm.len();
        let mut m = IMat::zeros(n, n);
        let mut seen = vec![false; n];
        for (j, &p) in perm.iter().enumerate() {
            assert!(p < n && !seen[p], "permutation: not a permutation");
            seen[p] = true;
            m[(p, j)] = 1;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Matrix product `self · rhs`.
    pub fn mul(&self, rhs: &IMat) -> IMat {
        assert_eq!(self.cols, rhs.rows, "mul: dim mismatch");
        let mut out = IMat::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0 {
                    continue;
                }
                for j in 0..rhs.cols {
                    out[(i, j)] += a * rhs[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix-vector product `self · v`.
    pub fn mul_vec(&self, v: &[i64]) -> Vec<i64> {
        assert_eq!(self.cols, v.len(), "mul_vec: dim mismatch");
        (0..self.rows)
            .map(|i| (0..self.cols).map(|j| self[(i, j)] * v[j]).sum())
            .collect()
    }

    /// Transpose.
    pub fn transpose(&self) -> IMat {
        let mut out = IMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Determinant by the Bareiss fraction-free algorithm (exact over ℤ).
    /// Panics unless square.
    pub fn det(&self) -> i64 {
        assert_eq!(self.rows, self.cols, "det: not square");
        let n = self.rows;
        if n == 0 {
            return 1;
        }
        let mut a: Vec<i128> = self.data.iter().map(|&x| x as i128).collect();
        let idx = |i: usize, j: usize| i * n + j;
        let mut sign = 1i128;
        let mut prev = 1i128;
        for k in 0..n - 1 {
            if a[idx(k, k)] == 0 {
                // pivot search
                let mut piv = None;
                for i in k + 1..n {
                    if a[idx(i, k)] != 0 {
                        piv = Some(i);
                        break;
                    }
                }
                let Some(p) = piv else { return 0 };
                for j in 0..n {
                    a.swap(idx(k, j), idx(p, j));
                }
                sign = -sign;
            }
            for i in k + 1..n {
                for j in k + 1..n {
                    let v = a[idx(i, j)] * a[idx(k, k)] - a[idx(i, k)] * a[idx(k, j)];
                    a[idx(i, j)] = v / prev; // exact division (Bareiss invariant)
                }
            }
            prev = a[idx(k, k)];
        }
        let d = sign * a[idx(n - 1, n - 1)];
        i64::try_from(d).expect("det: overflow out of i64")
    }

    /// Exact integer inverse, if it exists over ℤ (i.e. `det == ±1`
    /// OR adjugate entries are all divisible by the determinant).
    /// Returns `None` for singular or non-integer-invertible matrices.
    pub fn inverse_exact(&self) -> Option<IMat> {
        assert_eq!(self.rows, self.cols, "inverse: not square");
        let n = self.rows;
        if n == 0 {
            return Some(IMat::zeros(0, 0));
        }
        let d = self.det();
        if d == 0 {
            return None;
        }
        let adj = self.adjugate();
        let mut out = IMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let v = adj[(i, j)];
                if v % d != 0 {
                    return None;
                }
                out[(i, j)] = v / d;
            }
        }
        Some(out)
    }

    /// Adjugate (classical adjoint): `adj(A)·A = det(A)·I`.
    fn adjugate(&self) -> IMat {
        let n = self.rows;
        let mut out = IMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let minor = self.minor(j, i); // note transpose
                let c = minor.det();
                out[(i, j)] = if (i + j) % 2 == 0 { c } else { -c };
            }
        }
        out
    }

    /// Delete row `ri` and column `ci`.
    fn minor(&self, ri: usize, ci: usize) -> IMat {
        let mut out = IMat::zeros(self.rows - 1, self.cols - 1);
        let mut oi = 0;
        for i in 0..self.rows {
            if i == ri {
                continue;
            }
            let mut oj = 0;
            for j in 0..self.cols {
                if j == ci {
                    continue;
                }
                out[(oi, oj)] = self[(i, j)];
                oj += 1;
            }
            oi += 1;
        }
        out
    }

    /// Rank over ℚ (Gaussian elimination with exact rational pivoting via
    /// integer row ops).
    pub fn rank(&self) -> usize {
        let mut a: Vec<i128> = self.data.iter().map(|&x| x as i128).collect();
        let (m, n) = (self.rows, self.cols);
        let idx = |i: usize, j: usize| i * n + j;
        let mut rank = 0;
        let mut row = 0;
        for col in 0..n {
            // find pivot
            let mut piv = None;
            for i in row..m {
                if a[idx(i, col)] != 0 {
                    piv = Some(i);
                    break;
                }
            }
            let Some(p) = piv else { continue };
            for j in 0..n {
                a.swap(idx(row, j), idx(p, j));
            }
            let pv = a[idx(row, col)];
            for i in row + 1..m {
                let f = a[idx(i, col)];
                if f == 0 {
                    continue;
                }
                for j in 0..n {
                    a[idx(i, j)] = a[idx(i, j)] * pv - f * a[idx(row, j)];
                }
            }
            row += 1;
            rank += 1;
            if row == m {
                break;
            }
        }
        rank
    }
}

impl std::ops::Index<(usize, usize)> for IMat {
    type Output = i64;
    fn index(&self, (i, j): (usize, usize)) -> &i64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for IMat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut i64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for IMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "IMat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            write!(f, "  [")?;
            for j in 0..self.cols {
                if j > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{}", self[(i, j)])?;
            }
            writeln!(f, "]")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_mul() {
        let i3 = IMat::identity(3);
        let a = IMat::from_rows(&[&[1, 2, 3], &[4, 5, 6], &[7, 8, 10]]);
        assert_eq!(i3.mul(&a), a);
        assert_eq!(a.mul(&i3), a);
    }

    #[test]
    fn det_small() {
        let a = IMat::from_rows(&[&[1, 2], &[3, 4]]);
        assert_eq!(a.det(), -2);
        let b = IMat::from_rows(&[&[2, 0, 0], &[0, 3, 0], &[0, 0, 4]]);
        assert_eq!(b.det(), 24);
        let s = IMat::from_rows(&[&[1, 2], &[2, 4]]);
        assert_eq!(s.det(), 0);
    }

    #[test]
    fn det_permutation_sign() {
        let p = IMat::permutation(&[1, 0, 2]);
        assert_eq!(p.det(), -1);
        let p3 = IMat::permutation(&[2, 0, 1]);
        assert_eq!(p3.det(), 1);
    }

    #[test]
    fn inverse_unimodular() {
        let a = IMat::from_rows(&[&[1, 1], &[0, 1]]);
        let inv = a.inverse_exact().unwrap();
        assert_eq!(a.mul(&inv), IMat::identity(2));
        assert_eq!(inv.mul(&a), IMat::identity(2));
    }

    #[test]
    fn inverse_permutation() {
        let p = IMat::permutation(&[2, 0, 1, 3]);
        let inv = p.inverse_exact().unwrap();
        assert_eq!(p.mul(&inv), IMat::identity(4));
    }

    #[test]
    fn inverse_rejects_strided() {
        // stride-2 map has det 2; its inverse is not integer.
        let a = IMat::from_rows(&[&[2]]);
        assert!(a.inverse_exact().is_none());
        // but a diagonal {1,-1} works
        let b = IMat::from_rows(&[&[1, 0], &[0, -1]]);
        assert!(b.inverse_exact().is_some());
    }

    #[test]
    fn inverse_singular_none() {
        let s = IMat::from_rows(&[&[1, 2], &[2, 4]]);
        assert!(s.inverse_exact().is_none());
    }

    #[test]
    fn mul_vec_works() {
        let a = IMat::from_rows(&[&[1, 0, 2], &[0, 3, 0]]);
        assert_eq!(a.mul_vec(&[1, 2, 3]), vec![7, 6]);
    }

    #[test]
    fn rank_examples() {
        assert_eq!(IMat::identity(4).rank(), 4);
        let s = IMat::from_rows(&[&[1, 2], &[2, 4]]);
        assert_eq!(s.rank(), 1);
        let r = IMat::from_rows(&[&[1, 0], &[0, 1], &[1, 1]]);
        assert_eq!(r.rank(), 2);
        assert_eq!(IMat::zeros(3, 3).rank(), 0);
    }

    #[test]
    fn transpose_involution() {
        let a = IMat::from_rows(&[&[1, 2, 3], &[4, 5, 6]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().rows(), 3);
    }
}
