//! Smith normal form over ℤ and exact integer linear solving.
//!
//! For a store access function `f_s(i) = C·i + b` the paper's DME pass
//! needs the *reverse* `f_s' : idx ↦ i` (§2.1). Reversing means solving
//! `C·i = idx − b` for `i` as an **affine integer function of idx**.
//! Such an affine reverse exists iff `C` has full column rank and its
//! Smith normal form `U·C·V = D` has all invariant factors equal to 1
//! (then `i = V·D⁺·U·(idx − b)` has integer coefficients).
//!
//! This module computes the SNF with explicit unimodular transforms and
//! derives the left inverse.

use super::matrix::IMat;

/// Result of the Smith decomposition `U · A · V = D` with `U`, `V`
/// unimodular and `D` diagonal with `d_1 | d_2 | … | d_r`.
#[derive(Debug, Clone)]
pub struct Smith {
    pub u: IMat,
    pub v: IMat,
    pub d: IMat,
}

/// Compute the Smith normal form of `a`.
pub fn smith_normal_form(a: &IMat) -> Smith {
    let m = a.rows();
    let n = a.cols();
    let mut d = a.clone();
    let mut u = IMat::identity(m);
    let mut v = IMat::identity(n);

    let mut t = 0; // current pivot position
    while t < m.min(n) {
        // Find a nonzero pivot in the remaining submatrix.
        let Some((pi, pj)) = find_pivot(&d, t) else { break };
        swap_rows(&mut d, &mut u, t, pi);
        swap_cols(&mut d, &mut v, t, pj);

        // Eliminate row and column t alternately until clean.
        loop {
            let mut dirty = false;
            // Clear column t below/above using row ops.
            for i in 0..m {
                if i == t || d[(i, t)] == 0 {
                    continue;
                }
                let (q, r) = div_rem_euclid(d[(i, t)], d[(t, t)]);
                row_axpy(&mut d, &mut u, i, t, -q);
                if r != 0 {
                    // remainder nonzero: swap to make it the pivot, retry
                    swap_rows(&mut d, &mut u, t, i);
                    dirty = true;
                }
            }
            // Clear row t using column ops.
            for j in 0..n {
                if j == t || d[(t, j)] == 0 {
                    continue;
                }
                let (q, r) = div_rem_euclid(d[(t, j)], d[(t, t)]);
                col_axpy(&mut d, &mut v, j, t, -q);
                if r != 0 {
                    swap_cols(&mut d, &mut v, t, j);
                    dirty = true;
                }
            }
            if !dirty && column_clear(&d, t) && row_clear(&d, t) {
                break;
            }
        }
        t += 1;
    }

    // Normalize signs.
    for k in 0..m.min(n) {
        if d[(k, k)] < 0 {
            negate_row(&mut d, &mut u, k);
        }
    }
    // Enforce divisibility chain d_k | d_{k+1}.
    let r = m.min(n);
    loop {
        let mut fixed = true;
        for k in 0..r.saturating_sub(1) {
            let (a0, b0) = (d[(k, k)], d[(k + 1, k + 1)]);
            if a0 != 0 && b0 != 0 && b0 % a0 != 0 {
                // standard trick: add column k+1 to column k then re-reduce 2x2 block
                col_axpy(&mut d, &mut v, k, k + 1, 1);
                reduce_block(&mut d, &mut u, &mut v, k);
                fixed = false;
            }
        }
        if fixed {
            break;
        }
    }

    Smith { u, v, d }
}

/// Re-run elimination on the trailing submatrix starting at `t` for the
/// 2x2 divisibility fix (cheap: touches two rows/cols).
fn reduce_block(d: &mut IMat, u: &mut IMat, v: &mut IMat, t: usize) {
    let m = d.rows();
    let n = d.cols();
    loop {
        let mut dirty = false;
        for i in 0..m {
            if i == t || d[(i, t)] == 0 {
                continue;
            }
            let (q, r) = div_rem_euclid(d[(i, t)], d[(t, t)]);
            row_axpy(d, u, i, t, -q);
            if r != 0 {
                swap_rows(d, u, t, i);
                dirty = true;
            }
        }
        for j in 0..n {
            if j == t || d[(t, j)] == 0 {
                continue;
            }
            let (q, r) = div_rem_euclid(d[(t, j)], d[(t, t)]);
            col_axpy(d, v, j, t, -q);
            if r != 0 {
                swap_cols(d, v, t, j);
                dirty = true;
            }
        }
        if !dirty && column_clear(d, t) && row_clear(d, t) {
            break;
        }
    }
    if d[(t, t)] < 0 {
        negate_row(d, u, t);
    }
    let k2 = t + 1;
    if k2 < m.min(n) && d[(k2, k2)] < 0 {
        negate_row(d, u, k2);
    }
}

fn find_pivot(d: &IMat, t: usize) -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize, i64)> = None;
    for i in t..d.rows() {
        for j in t..d.cols() {
            let v = d[(i, j)].abs();
            if v != 0 && best.map_or(true, |(_, _, bv)| v < bv) {
                best = Some((i, j, v));
            }
        }
    }
    best.map(|(i, j, _)| (i, j))
}

fn div_rem_euclid(a: i64, b: i64) -> (i64, i64) {
    let q = a.div_euclid(b);
    (q, a.rem_euclid(b))
}

fn swap_rows(d: &mut IMat, u: &mut IMat, a: usize, b: usize) {
    if a == b {
        return;
    }
    for j in 0..d.cols() {
        let t = d[(a, j)];
        d[(a, j)] = d[(b, j)];
        d[(b, j)] = t;
    }
    for j in 0..u.cols() {
        let t = u[(a, j)];
        u[(a, j)] = u[(b, j)];
        u[(b, j)] = t;
    }
}

fn swap_cols(d: &mut IMat, v: &mut IMat, a: usize, b: usize) {
    if a == b {
        return;
    }
    for i in 0..d.rows() {
        let t = d[(i, a)];
        d[(i, a)] = d[(i, b)];
        d[(i, b)] = t;
    }
    for i in 0..v.rows() {
        let t = v[(i, a)];
        v[(i, a)] = v[(i, b)];
        v[(i, b)] = t;
    }
}

/// row[i] += f * row[t] (applied to both D and U).
fn row_axpy(d: &mut IMat, u: &mut IMat, i: usize, t: usize, f: i64) {
    for j in 0..d.cols() {
        d[(i, j)] += f * d[(t, j)];
    }
    for j in 0..u.cols() {
        u[(i, j)] += f * u[(t, j)];
    }
}

/// col[j] += f * col[t] (applied to both D and V).
fn col_axpy(d: &mut IMat, v: &mut IMat, j: usize, t: usize, f: i64) {
    for i in 0..d.rows() {
        d[(i, j)] += f * d[(i, t)];
    }
    for i in 0..v.rows() {
        v[(i, j)] += f * v[(i, t)];
    }
}

fn negate_row(d: &mut IMat, u: &mut IMat, k: usize) {
    for j in 0..d.cols() {
        d[(k, j)] = -d[(k, j)];
    }
    for j in 0..u.cols() {
        u[(k, j)] = -u[(k, j)];
    }
}

fn column_clear(d: &IMat, t: usize) -> bool {
    (0..d.rows()).all(|i| i == t || d[(i, t)] == 0)
}

fn row_clear(d: &IMat, t: usize) -> bool {
    (0..d.cols()).all(|j| j == t || d[(t, j)] == 0)
}

/// Exact integer **left inverse**: `L` with `L·A = I_n`, for `A` m×n of
/// full column rank whose invariant factors are all 1. Returns `None`
/// otherwise (e.g. strided maps — `A = [2]` has factor 2).
pub fn left_inverse(a: &IMat) -> Option<IMat> {
    let n = a.cols();
    let s = smith_normal_form(a);
    // need rank n with all invariant factors == 1
    for k in 0..n {
        if k >= s.d.rows() || s.d[(k, k)] != 1 {
            return None;
        }
    }
    // A = U⁻¹ D V⁻¹  ⇒  L = V · D⁺ · U where D⁺ is n×m pseudo-inverse of D
    let mut dplus = IMat::zeros(n, a.rows());
    for k in 0..n {
        dplus[(k, k)] = 1; // d_k == 1
    }
    Some(s.v.mul(&dplus).mul(&s.u))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_snf(a: &IMat) {
        let s = smith_normal_form(a);
        // U·A·V == D
        assert_eq!(s.u.mul(a).mul(&s.v), s.d, "UAV != D for {a:?}");
        // U, V unimodular
        assert_eq!(s.u.det().abs(), 1, "U not unimodular");
        assert_eq!(s.v.det().abs(), 1, "V not unimodular");
        // D diagonal, nonneg, divisibility chain
        for i in 0..s.d.rows() {
            for j in 0..s.d.cols() {
                if i != j {
                    assert_eq!(s.d[(i, j)], 0, "D not diagonal");
                }
            }
        }
        let r = s.d.rows().min(s.d.cols());
        for k in 0..r {
            assert!(s.d[(k, k)] >= 0);
            if k + 1 < r && s.d[(k, k)] != 0 && s.d[(k + 1, k + 1)] != 0 {
                assert_eq!(
                    s.d[(k + 1, k + 1)] % s.d[(k, k)],
                    0,
                    "divisibility chain broken"
                );
            }
        }
    }

    #[test]
    fn snf_identity() {
        check_snf(&IMat::identity(3));
    }

    #[test]
    fn snf_permutation() {
        check_snf(&IMat::permutation(&[2, 0, 1]));
    }

    #[test]
    fn snf_classic() {
        let a = IMat::from_rows(&[&[2, 4, 4], &[-6, 6, 12], &[10, 4, 16]]);
        let s = smith_normal_form(&a);
        check_snf(&a);
        assert_eq!(s.d[(0, 0)], 2);
        assert_eq!(s.d[(1, 1)], 2);
        // det(A) = ±(2*2*d3); |det| = 2*2*d3
        assert_eq!(s.d[(2, 2)], (a.det().abs() / 4));
    }

    #[test]
    fn snf_rectangular() {
        let a = IMat::from_rows(&[&[1, 0], &[0, 1], &[1, 1]]);
        check_snf(&a);
        let b = IMat::from_rows(&[&[3, 0, 0], &[0, 5, 0]]);
        check_snf(&b);
    }

    #[test]
    fn snf_zero() {
        check_snf(&IMat::zeros(2, 3));
    }

    #[test]
    fn left_inverse_identitylike() {
        let a = IMat::from_rows(&[&[1, 0], &[0, 1], &[7, 3]]);
        let l = left_inverse(&a).unwrap();
        assert_eq!(l.mul(&a), IMat::identity(2));
    }

    #[test]
    fn left_inverse_permutation() {
        let p = IMat::permutation(&[3, 1, 0, 2]);
        let l = left_inverse(&p).unwrap();
        assert_eq!(l.mul(&p), IMat::identity(4));
    }

    #[test]
    fn left_inverse_rejects_stride() {
        // f(i) = 2i writes only even addresses: invariant factor 2.
        let a = IMat::from_rows(&[&[2]]);
        assert!(left_inverse(&a).is_none());
    }

    #[test]
    fn left_inverse_rejects_rank_deficient() {
        let a = IMat::from_rows(&[&[1, 2], &[2, 4]]);
        assert!(left_inverse(&a).is_none());
    }

    #[test]
    fn left_inverse_unimodular_mix() {
        let a = IMat::from_rows(&[&[1, 1, 0], &[0, 1, 0], &[0, 1, 1]]);
        let l = left_inverse(&a).unwrap();
        assert_eq!(l.mul(&a), IMat::identity(3));
    }
}
