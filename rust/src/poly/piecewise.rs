//! Piecewise quasi-affine maps.
//!
//! `split`, `concat` and `pad` have access functions that are affine
//! only on sub-boxes of the iteration/index space: a `concat` output at
//! index `i` reads input A when `i < s` and input B (shifted) when
//! `i ≥ s`. A [`PiecewiseMap`] is a finite disjoint union of
//! `(guard box, AccessMap)` pieces over a common input space, closed
//! under composition with plain affine maps on the inside.

use super::domain::IterDomain;
use super::map::AccessMap;
use std::fmt;

/// A half-open interval guard on one input dimension.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Guard {
    pub dim: usize,
    pub lo: i64,
    pub hi: i64, // exclusive
}

impl Guard {
    pub fn holds(&self, p: &[i64]) -> bool {
        let v = p[self.dim];
        v >= self.lo && v < self.hi
    }
}

/// One piece: a conjunction of guards and the map valid under them.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Piece {
    pub guards: Vec<Guard>,
    pub map: AccessMap,
}

impl Piece {
    pub fn holds(&self, p: &[i64]) -> bool {
        self.guards.iter().all(|g| g.holds(p))
    }
}

/// A piecewise map: the first piece whose guards hold applies. Pieces
/// are expected (and verified by [`PiecewiseMap::is_total_on`]) to
/// partition the domain.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct PiecewiseMap {
    in_dims: usize,
    pieces: Vec<Piece>,
}

impl PiecewiseMap {
    pub fn new(in_dims: usize, pieces: Vec<Piece>) -> Self {
        assert!(!pieces.is_empty(), "PiecewiseMap: no pieces");
        for p in &pieces {
            assert_eq!(p.map.in_dims(), in_dims, "piece arity mismatch");
            for g in &p.guards {
                assert!(g.dim < in_dims, "guard dim out of range");
                assert!(g.lo < g.hi, "empty guard");
            }
        }
        PiecewiseMap { in_dims, pieces }
    }

    /// Lift a plain map to a single-piece piecewise map.
    pub fn total(map: AccessMap) -> Self {
        let in_dims = map.in_dims();
        PiecewiseMap { in_dims, pieces: vec![Piece { guards: vec![], map }] }
    }

    pub fn in_dims(&self) -> usize {
        self.in_dims
    }

    pub fn pieces(&self) -> &[Piece] {
        &self.pieces
    }

    pub fn out_dims(&self) -> usize {
        self.pieces[0].map.out_dims()
    }

    /// True when a single piece with no guards remains.
    pub fn as_total(&self) -> Option<&AccessMap> {
        match &self.pieces[..] {
            [p] if p.guards.is_empty() => Some(&p.map),
            _ => None,
        }
    }

    /// Evaluate; panics if no piece covers the point (use
    /// `is_total_on` to validate coverage first).
    pub fn apply(&self, p: &[i64]) -> Vec<i64> {
        for piece in &self.pieces {
            if piece.holds(p) {
                return piece.map.apply(p);
            }
        }
        panic!("PiecewiseMap::apply: {p:?} not covered by any piece");
    }

    /// Every point of `dom` is covered by exactly one piece.
    pub fn is_total_on(&self, dom: &IterDomain) -> bool {
        let pts: Vec<Vec<i64>> = if dom.cardinality() <= 4096 {
            dom.points().collect()
        } else {
            dom.sample(512, 0xc0ffee)
        };
        pts.iter().all(|p| {
            self.pieces.iter().filter(|piece| piece.holds(p)).count() == 1
        })
    }

    /// Compose with an *affine* inner map: `self ∘ inner`. Guards are
    /// rewritten when the inner map's guarded component is itself a
    /// `1·dim + c` expression; otherwise composition falls back to
    /// keeping the guard on a fresh evaluation of the inner component —
    /// which our IR never needs, so we conservatively return `None`.
    pub fn compose_inner(&self, inner: &AccessMap) -> Option<PiecewiseMap> {
        let mut pieces = Vec::with_capacity(self.pieces.len());
        for piece in &self.pieces {
            let mut guards = Vec::with_capacity(piece.guards.len());
            for g in &piece.guards {
                // guard applies to inner's output component g.dim
                let comp = &inner.exprs()[g.dim];
                let (coeffs, cst) = comp.as_affine(inner.in_dims())?;
                // need the component to be c + 1·dim_k (unit coefficient)
                let nz: Vec<usize> =
                    coeffs.iter().enumerate().filter(|(_, &c)| c != 0).map(|(k, _)| k).collect();
                match nz.as_slice() {
                    [] => {
                        // constant component: guard is statically true/false
                        if cst >= g.lo && cst < g.hi {
                            continue; // guard always holds, drop it
                        } else {
                            guards.clear();
                            guards.push(Guard { dim: 0, lo: 0, hi: 0 }); // unsat marker
                            break;
                        }
                    }
                    [k] if coeffs[*k] == 1 => {
                        guards.push(Guard { dim: *k, lo: g.lo - cst, hi: g.hi - cst });
                    }
                    _ => return None,
                }
            }
            if guards.iter().any(|g| g.lo >= g.hi) {
                continue; // unsatisfiable piece, drop
            }
            pieces.push(Piece { guards, map: piece.map.compose(inner) });
        }
        if pieces.is_empty() {
            return None;
        }
        Some(PiecewiseMap { in_dims: inner.in_dims(), pieces })
    }
}

impl fmt::Debug for PiecewiseMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "PiecewiseMap {} pieces:", self.pieces.len())?;
        for p in &self.pieces {
            write!(f, "  [")?;
            for (k, g) in p.guards.iter().enumerate() {
                if k > 0 {
                    write!(f, " && ")?;
                }
                write!(f, "{} <= i{} < {}", g.lo, g.dim, g.hi)?;
            }
            writeln!(f, "] {:?}", p.map)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::expr::Expr;

    /// concat([A(4), B(6)]) read map: i<4 → A[i]; i>=4 → B[i-4].
    fn concat_map() -> PiecewiseMap {
        PiecewiseMap::new(
            1,
            vec![
                Piece {
                    guards: vec![Guard { dim: 0, lo: 0, hi: 4 }],
                    map: AccessMap::new(1, vec![Expr::dim(0)]),
                },
                Piece {
                    guards: vec![Guard { dim: 0, lo: 4, hi: 10 }],
                    map: AccessMap::new(1, vec![Expr::dim(0).add(Expr::cst(-4))]),
                },
            ],
        )
    }

    #[test]
    fn concat_semantics() {
        let m = concat_map();
        assert_eq!(m.apply(&[2]), vec![2]);
        assert_eq!(m.apply(&[7]), vec![3]);
        assert!(m.is_total_on(&IterDomain::new(&[10])));
    }

    #[test]
    fn total_lift() {
        let m = PiecewiseMap::total(AccessMap::identity(2));
        assert!(m.as_total().is_some());
        assert_eq!(m.apply(&[3, 4]), vec![3, 4]);
        assert!(m.is_total_on(&IterDomain::new(&[5, 5])));
    }

    #[test]
    fn overlap_detected() {
        let bad = PiecewiseMap::new(
            1,
            vec![
                Piece {
                    guards: vec![Guard { dim: 0, lo: 0, hi: 6 }],
                    map: AccessMap::identity(1),
                },
                Piece {
                    guards: vec![Guard { dim: 0, lo: 4, hi: 10 }],
                    map: AccessMap::identity(1),
                },
            ],
        );
        assert!(!bad.is_total_on(&IterDomain::new(&[10])));
    }

    #[test]
    fn compose_inner_shift() {
        // consumer reads concat output via j ↦ j + 2
        let m = concat_map();
        let inner = AccessMap::new(1, vec![Expr::dim(0).add(Expr::cst(2))]);
        let c = m.compose_inner(&inner).unwrap();
        for j in 0..8 {
            assert_eq!(c.apply(&[j]), m.apply(&[j + 2]));
        }
    }

    #[test]
    fn compose_inner_constant_guard_resolution() {
        let m = concat_map();
        // inner fixes the coordinate to 7 → only piece 2 survives, guard-free
        let inner = AccessMap::new(1, vec![Expr::cst(7)]);
        let c = m.compose_inner(&inner).unwrap();
        assert_eq!(c.pieces().len(), 1);
        assert!(c.pieces()[0].guards.is_empty());
        assert_eq!(c.apply(&[0]), vec![3]);
    }

    #[test]
    fn compose_inner_rejects_scaled_guard() {
        let m = concat_map();
        let inner = AccessMap::new(1, vec![Expr::dim(0).scale(2)]);
        assert!(m.compose_inner(&inner).is_none());
    }
}
