//! Integer (quasi-)affine algebra — the polyhedral substrate.
//!
//! The paper implements its affine-function *reverse* and *composition*
//! with the Integer Set Library (isl). isl is not available in this
//! environment, so `poly` is a from-scratch, integer-exact replacement
//! scoped to exactly what the paper's two passes need:
//!
//! * [`expr::Expr`] — quasi-affine expressions over loop indices:
//!   `c0 + Σ ck·ik` extended with `floordiv` and `mod` by positive
//!   constants (what isl calls quasi-affine; needed for `tile`/`repeat`
//!   whose load maps are `i mod n` / `i div n`).
//! * [`map::AccessMap`] — a vector of exprs mapping a loop space into a
//!   tensor index space; supports *composition* (paper eq. 1 and 2) and
//!   exact *reverse* of injective pure-affine maps (paper's `f_s'`),
//!   implemented with the Smith normal form over ℤ.
//! * [`domain::IterDomain`] — normalized rectangular iteration domains
//!   `[0,e0)×…×[0,en-1)`; every loop nest in the IR is normalized so
//!   its domain is such a box.
//! * [`piecewise::PiecewiseMap`] — a disjoint union of `(domain guard,
//!   AccessMap)` pieces, required by `split`/`concat`/`pad` whose access
//!   functions are affine only piecewise.
//!
//! All arithmetic is `i64` with checked overflow in debug builds; shapes
//! in this domain keep every intermediate well inside `i64`.

pub mod domain;
pub mod expr;
pub mod map;
pub mod matrix;
pub mod piecewise;
pub mod smith;

pub use domain::IterDomain;
pub use expr::Expr;
pub use map::AccessMap;
pub use matrix::IMat;
pub use piecewise::PiecewiseMap;
