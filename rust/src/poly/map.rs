//! Access maps: vectors of quasi-affine expressions mapping a loop
//! space into a tensor index space, with the two operations the paper's
//! DME pass is built on (§2.1):
//!
//! * **composition** — `g_ls = f_l ∘ f_s'` (paper eq. 1) and
//!   `g' = g_ls ∘ f_l'` (paper eq. 2) are [`AccessMap::compose`];
//! * **reverse** — `f_s' : idx ↦ i` is [`AccessMap::reverse`], the exact
//!   integer inversion of an injective affine map via the Smith normal
//!   form ([`crate::poly::smith::left_inverse`]).

use super::domain::IterDomain;
use super::expr::Expr;
use super::matrix::IMat;
use super::smith::left_inverse;
use std::fmt;

/// A map `f : ℤ^in_dims → ℤ^(exprs.len())`, `f(i) = (e0(i), …, em-1(i))`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct AccessMap {
    in_dims: usize,
    exprs: Vec<Expr>,
}

impl AccessMap {
    /// Build from expressions. `in_dims` must cover every dim mentioned.
    pub fn new(in_dims: usize, exprs: Vec<Expr>) -> Self {
        for e in &exprs {
            assert!(
                e.arity() <= in_dims,
                "AccessMap: expr {e} mentions dim >= in_dims {in_dims}"
            );
        }
        AccessMap { in_dims, exprs }
    }

    /// Identity map on `n` dims.
    pub fn identity(n: usize) -> Self {
        AccessMap::new(n, (0..n).map(Expr::dim).collect())
    }

    /// Pure-affine map from matrix + offset: `f(i) = C·i + b`.
    pub fn affine(c: &IMat, b: &[i64]) -> Self {
        assert_eq!(c.rows(), b.len(), "affine: C/b mismatch");
        let exprs = (0..c.rows())
            .map(|r| {
                let mut e = Expr::cst(b[r]);
                for j in 0..c.cols() {
                    let coef = c[(r, j)];
                    if coef != 0 {
                        e = e.add(Expr::dim(j).scale(coef));
                    }
                }
                e
            })
            .collect();
        AccessMap::new(c.cols(), exprs)
    }

    /// Dimension-permutation map: output `k` reads input dim `perm[k]`
    /// (i.e. `f(i)[k] = i[perm[k]]` — the access function of a
    /// `transpose` whose output axis `k` comes from input axis `perm[k]`).
    pub fn permute(perm: &[usize]) -> Self {
        AccessMap::new(perm.len(), perm.iter().map(|&p| Expr::dim(p)).collect())
    }

    pub fn in_dims(&self) -> usize {
        self.in_dims
    }

    pub fn out_dims(&self) -> usize {
        self.exprs.len()
    }

    pub fn exprs(&self) -> &[Expr] {
        &self.exprs
    }

    /// Evaluate at a point.
    pub fn apply(&self, p: &[i64]) -> Vec<i64> {
        assert_eq!(p.len(), self.in_dims, "apply: arity mismatch");
        self.exprs.iter().map(|e| e.eval(p)).collect()
    }

    /// Composition `self ∘ inner`: first apply `inner`, then `self`.
    /// `inner.out_dims()` must equal `self.in_dims()`.
    pub fn compose(&self, inner: &AccessMap) -> AccessMap {
        assert_eq!(
            inner.out_dims(),
            self.in_dims,
            "compose: inner out {} != self in {}",
            inner.out_dims(),
            self.in_dims
        );
        let exprs = self
            .exprs
            .iter()
            .map(|e| e.substitute(&inner.exprs))
            .collect();
        AccessMap::new(inner.in_dims, exprs)
    }

    /// True if every component is pure-affine (no div/mod).
    pub fn is_affine(&self) -> bool {
        self.exprs.iter().all(|e| e.is_affine())
    }

    /// Extract `(C, b)` with `f(i) = C·i + b` when pure-affine.
    pub fn as_affine(&self) -> Option<(IMat, Vec<i64>)> {
        let mut c = IMat::zeros(self.out_dims(), self.in_dims);
        let mut b = vec![0i64; self.out_dims()];
        for (r, e) in self.exprs.iter().enumerate() {
            let (coeffs, cst) = e.as_affine(self.in_dims)?;
            for (j, &v) in coeffs.iter().enumerate() {
                c[(r, j)] = v;
            }
            b[r] = cst;
        }
        Some((c, b))
    }

    /// The paper's *reverse* `f' : idx ↦ i` (§2.1): exact integer left
    /// inverse of an injective pure-affine map. Returns `None` when the
    /// map is quasi-affine, rank-deficient, or strided (invariant factor
    /// > 1) — i.e. when no affine reverse exists, matching isl behaviour
    /// restricted to single-valued affine reverses.
    pub fn reverse(&self) -> Option<AccessMap> {
        let (c, b) = self.as_affine()?;
        let l = left_inverse(&c)?;
        // i = L·(idx − b) = L·idx − L·b
        let neg_lb: Vec<i64> = l.mul_vec(&b).iter().map(|x| -x).collect();
        Some(AccessMap::affine(&l, &neg_lb))
    }

    /// Is this a pure dimension permutation (each component a distinct
    /// bare `Dim`, square)? Permutations map out-of-bounds points to
    /// out-of-bounds points, which makes them safe to compose under
    /// implicit-padding (`oob_zero`) reads.
    pub fn is_permutation(&self) -> bool {
        if self.in_dims != self.out_dims() {
            return false;
        }
        let mut seen = vec![false; self.in_dims];
        for e in &self.exprs {
            match e {
                Expr::Dim(d) if !seen[*d] => seen[*d] = true,
                _ => return false,
            }
        }
        true
    }

    /// Is the identity map (after simplification)?
    pub fn is_identity(&self) -> bool {
        self.in_dims == self.out_dims()
            && self
                .exprs
                .iter()
                .enumerate()
                .all(|(k, e)| matches!(e, Expr::Dim(d) if *d == k))
    }

    /// Simplify each component knowing the input domain extents.
    pub fn simplified_in(&self, dom: &IterDomain) -> AccessMap {
        assert_eq!(dom.ndim(), self.in_dims);
        AccessMap::new(
            self.in_dims,
            self.exprs
                .iter()
                .map(|e| e.clone().simplified_in(dom.extents()))
                .collect(),
        )
    }

    /// Conservative bounding box of the image over `dom`; `None` if the
    /// map mentions dims beyond the domain.
    pub fn image_bounds(&self, dom: &IterDomain) -> Option<Vec<(i64, i64)>> {
        self.exprs.iter().map(|e| e.range(dom.extents())).collect()
    }

    /// Check (by exhaustive or sampled evaluation) that the image over
    /// `dom` stays inside the tensor box `shape`. Exhaustive when the
    /// domain is small, sampled otherwise; the conservative
    /// `image_bounds` check runs first and is sufficient when it passes.
    pub fn image_within(&self, dom: &IterDomain, shape: &[i64]) -> bool {
        if let Some(bounds) = self.image_bounds(dom) {
            if bounds.len() == shape.len()
                && bounds
                    .iter()
                    .zip(shape)
                    .all(|(&(lo, hi), &s)| lo >= 0 && hi < s)
            {
                return true;
            }
        }
        // fall back to sampling (bounds are conservative, may be loose)
        let box_ = IterDomain::new(shape);
        let pts: Vec<Vec<i64>> = if dom.cardinality() <= 4096 {
            dom.points().collect()
        } else {
            dom.sample(512, 0x9e3779b97f4a7c15)
        };
        pts.iter().all(|p| box_.contains(&self.apply(p)))
    }

    /// Injectivity check over a domain. Affine maps are decided exactly
    /// via rank + invariant factors when possible; otherwise (and for
    /// quasi-affine maps) the check is by evaluation — exhaustive on
    /// small domains, sampled on large ones (sound in practice for the
    /// structured maps operators produce; the DME pass additionally
    /// requires an exact affine reverse before rewriting, so a sampling
    /// false-positive cannot produce a wrong rewrite).
    pub fn is_injective_on(&self, dom: &IterDomain) -> bool {
        if let Some((c, _)) = self.as_affine() {
            if c.rank() == self.in_dims {
                return true; // full column rank ⇒ injective on ℤ^n
            }
            if dom.ndim() == self.in_dims && dom.cardinality() > 1 {
                // rank-deficient affine: injective only on degenerate domains
                return dom
                    .extents()
                    .iter()
                    .enumerate()
                    .all(|(k, &e)| e == 1 || col_nonzero(&c, k));
            }
        }
        let pts: Vec<Vec<i64>> = if dom.cardinality() <= 4096 {
            dom.points().collect()
        } else {
            dom.sample(512, 0x51a5b1c3d5e7f901)
        };
        let mut seen = std::collections::HashSet::new();
        for p in &pts {
            if !seen.insert(self.apply(p)) {
                return false;
            }
        }
        true
    }
}

fn col_nonzero(c: &IMat, j: usize) -> bool {
    (0..c.rows()).any(|i| c[(i, j)] != 0)
}

impl fmt::Debug for AccessMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for k in 0..self.in_dims {
            if k > 0 {
                write!(f, ", ")?;
            }
            write!(f, "i{k}")?;
        }
        write!(f, ") -> [")?;
        for (k, e) in self.exprs.iter().enumerate() {
            if k > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{e}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_applies() {
        let id = AccessMap::identity(3);
        assert!(id.is_identity());
        assert_eq!(id.apply(&[4, 5, 6]), vec![4, 5, 6]);
    }

    #[test]
    fn permute_is_transpose_access() {
        // output[k0,k1] = input[k1,k0]: out axis 0 reads in axis 1
        let t = AccessMap::permute(&[1, 0]);
        assert_eq!(t.apply(&[3, 7]), vec![7, 3]);
    }

    #[test]
    fn compose_matches_pointwise() {
        let f = AccessMap::new(
            2,
            vec![Expr::dim(0).scale(2).add(Expr::dim(1)), Expr::dim(1).add(Expr::cst(3))],
        );
        let g = AccessMap::new(2, vec![Expr::dim(1), Expr::dim(0)]);
        let fg = f.compose(&g);
        let dom = IterDomain::new(&[5, 5]);
        for p in dom.points() {
            assert_eq!(fg.apply(&p), f.apply(&g.apply(&p)));
        }
    }

    #[test]
    fn reverse_of_permutation() {
        let t = AccessMap::permute(&[2, 0, 1]);
        let r = t.reverse().unwrap();
        let dom = IterDomain::new(&[3, 4, 5]);
        for p in dom.points() {
            assert_eq!(r.apply(&t.apply(&p)), p);
        }
    }

    #[test]
    fn reverse_of_offset_map() {
        // slice store-like: f(i) = i + 10 (1-D shift)
        let f = AccessMap::new(1, vec![Expr::dim(0).add(Expr::cst(10))]);
        let r = f.reverse().unwrap();
        assert_eq!(r.apply(&[17]), vec![7]);
    }

    #[test]
    fn reverse_rejects_stride_and_quasi() {
        let strided = AccessMap::new(1, vec![Expr::dim(0).scale(2)]);
        assert!(strided.reverse().is_none());
        let quasi = AccessMap::new(1, vec![Expr::dim(0).modulo(4)]);
        assert!(quasi.reverse().is_none());
    }

    #[test]
    fn reverse_unimodular_shear() {
        let c = IMat::from_rows(&[&[1, 1], &[0, 1]]);
        let f = AccessMap::affine(&c, &[5, -2]);
        let r = f.reverse().unwrap();
        let dom = IterDomain::new(&[6, 6]);
        for p in dom.points() {
            assert_eq!(r.apply(&f.apply(&p)), p);
        }
    }

    #[test]
    fn as_affine_roundtrip() {
        let c = IMat::from_rows(&[&[2, 0, 1], &[0, -3, 0]]);
        let f = AccessMap::affine(&c, &[7, 8]);
        let (c2, b2) = f.as_affine().unwrap();
        assert_eq!(c2, c);
        assert_eq!(b2, vec![7, 8]);
    }

    #[test]
    fn injectivity() {
        let dom = IterDomain::new(&[4, 4]);
        assert!(AccessMap::identity(2).is_injective_on(&dom));
        assert!(AccessMap::permute(&[1, 0]).is_injective_on(&dom));
        // broadcast-like map drops a dim: not injective
        let drop = AccessMap::new(2, vec![Expr::dim(0)]);
        assert!(!drop.is_injective_on(&dom));
        // tile read i mod 2 not injective on [0,4)
        let tile = AccessMap::new(1, vec![Expr::dim(0).modulo(2)]);
        assert!(!tile.is_injective_on(&IterDomain::new(&[4])));
    }

    #[test]
    fn image_within_checks() {
        let dom = IterDomain::new(&[4, 4]);
        let id = AccessMap::identity(2);
        assert!(id.image_within(&dom, &[4, 4]));
        assert!(!id.image_within(&dom, &[3, 4]));
        let shifted = AccessMap::new(2, vec![Expr::dim(0).add(Expr::cst(2)), Expr::dim(1)]);
        assert!(shifted.image_within(&dom, &[6, 4]));
        assert!(!shifted.image_within(&dom, &[4, 4]));
    }

    #[test]
    fn simplified_in_domain() {
        // repeat-load composed back often leaves (i mod n) with i < n
        let m = AccessMap::new(1, vec![Expr::dim(0).modulo(8)]);
        let s = m.simplified_in(&IterDomain::new(&[8]));
        assert!(s.is_identity());
    }
}
