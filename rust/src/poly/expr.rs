//! Quasi-affine expressions over loop indices.
//!
//! `Expr` is the scalar building block of access functions: affine terms
//! `c0 + Σ ck·ik` plus `floordiv`/`mod` by positive constants. The
//! div/mod forms are required because the *load* side of memory-bound
//! operators is only quasi-affine: `tile` reads `src[i mod n]`, `repeat`
//! reads `src[i div r]`. Composition (substitution) keeps the class
//! closed, exactly like isl's quasi-affine expressions.

use std::fmt;

/// A quasi-affine scalar expression over input dimensions `d0..dn`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub enum Expr {
    /// Integer constant.
    Cst(i64),
    /// Input dimension `i_k`.
    Dim(usize),
    /// Sum of two expressions.
    Add(Box<Expr>, Box<Expr>),
    /// Scalar multiple `c · e`.
    Mul(i64, Box<Expr>),
    /// Floor division `⌊e / d⌋`, `d > 0`.
    Div(Box<Expr>, i64),
    /// Euclidean remainder `e mod d`, `d > 0`.
    Mod(Box<Expr>, i64),
}

impl Expr {
    pub fn cst(c: i64) -> Expr {
        Expr::Cst(c)
    }

    pub fn dim(d: usize) -> Expr {
        Expr::Dim(d)
    }

    pub fn add(self, rhs: Expr) -> Expr {
        Expr::Add(Box::new(self), Box::new(rhs)).simplified()
    }

    pub fn sub(self, rhs: Expr) -> Expr {
        self.add(rhs.scale(-1))
    }

    pub fn scale(self, c: i64) -> Expr {
        Expr::Mul(c, Box::new(self)).simplified()
    }

    pub fn floordiv(self, d: i64) -> Expr {
        assert!(d > 0, "floordiv by non-positive {d}");
        Expr::Div(Box::new(self), d).simplified()
    }

    pub fn modulo(self, d: i64) -> Expr {
        assert!(d > 0, "mod by non-positive {d}");
        Expr::Mod(Box::new(self), d).simplified()
    }

    /// Evaluate at a concrete point.
    pub fn eval(&self, point: &[i64]) -> i64 {
        match self {
            Expr::Cst(c) => *c,
            Expr::Dim(d) => {
                assert!(*d < point.len(), "eval: dim {d} out of range");
                point[*d]
            }
            Expr::Add(a, b) => a.eval(point) + b.eval(point),
            Expr::Mul(c, e) => c * e.eval(point),
            Expr::Div(e, d) => e.eval(point).div_euclid(*d),
            Expr::Mod(e, d) => e.eval(point).rem_euclid(*d),
        }
    }

    /// Substitute each `Dim(k)` with `subs[k]` (composition).
    pub fn substitute(&self, subs: &[Expr]) -> Expr {
        match self {
            Expr::Cst(c) => Expr::Cst(*c),
            Expr::Dim(d) => {
                assert!(*d < subs.len(), "substitute: dim {d} out of range");
                subs[*d].clone()
            }
            Expr::Add(a, b) => a.substitute(subs).add(b.substitute(subs)),
            Expr::Mul(c, e) => e.substitute(subs).scale(*c),
            Expr::Div(e, d) => e.substitute(subs).floordiv(*d),
            Expr::Mod(e, d) => e.substitute(subs).modulo(*d),
        }
    }

    /// The number of input dims this expression mentions (1 + max dim).
    pub fn arity(&self) -> usize {
        match self {
            Expr::Cst(_) => 0,
            Expr::Dim(d) => d + 1,
            Expr::Add(a, b) => a.arity().max(b.arity()),
            Expr::Mul(_, e) | Expr::Div(e, _) | Expr::Mod(e, _) => e.arity(),
        }
    }

    /// True when the expression contains no Div/Mod.
    pub fn is_affine(&self) -> bool {
        match self {
            Expr::Cst(_) | Expr::Dim(_) => true,
            Expr::Add(a, b) => a.is_affine() && b.is_affine(),
            Expr::Mul(_, e) => e.is_affine(),
            Expr::Div(..) | Expr::Mod(..) => false,
        }
    }

    /// If affine, extract `(coeffs over n dims, constant)`.
    pub fn as_affine(&self, n_dims: usize) -> Option<(Vec<i64>, i64)> {
        let mut coeffs = vec![0i64; n_dims];
        let mut cst = 0i64;
        if self.accumulate_affine(1, &mut coeffs, &mut cst) {
            Some((coeffs, cst))
        } else {
            None
        }
    }

    fn accumulate_affine(&self, factor: i64, coeffs: &mut [i64], cst: &mut i64) -> bool {
        match self {
            Expr::Cst(c) => {
                *cst += factor * c;
                true
            }
            Expr::Dim(d) => {
                if *d >= coeffs.len() {
                    return false;
                }
                coeffs[*d] += factor;
                true
            }
            Expr::Add(a, b) => {
                a.accumulate_affine(factor, coeffs, cst)
                    && b.accumulate_affine(factor, coeffs, cst)
            }
            Expr::Mul(c, e) => e.accumulate_affine(factor * c, coeffs, cst),
            Expr::Div(..) | Expr::Mod(..) => false,
        }
    }

    /// Structural simplification: constant folding, dropping zero terms,
    /// collapsing nested scalings, resolving div/mod of constants.
    /// Normal form keeps Add right-leaning; not a full canonicalizer but
    /// enough to keep composed maps compact and to recognize identity.
    pub fn simplified(self) -> Expr {
        match self {
            Expr::Add(a, b) => {
                let a = a.simplified();
                let b = b.simplified();
                match (a, b) {
                    (Expr::Cst(x), Expr::Cst(y)) => Expr::Cst(x + y),
                    (Expr::Cst(0), e) | (e, Expr::Cst(0)) => e,
                    // hoist constants to the right: (c + e) -> (e + c)
                    (Expr::Cst(x), e) => Expr::Add(Box::new(e), Box::new(Expr::Cst(x))),
                    // merge linear terms in `k·d + k'·d`
                    (a, b) => {
                        if let Some(m) = merge_linear(&a, &b) {
                            m
                        } else {
                            Expr::Add(Box::new(a), Box::new(b))
                        }
                    }
                }
            }
            Expr::Mul(c, e) => {
                let e = e.simplified();
                match (c, e) {
                    (0, _) => Expr::Cst(0),
                    (1, e) => e,
                    (c, Expr::Cst(x)) => Expr::Cst(c * x),
                    (c, Expr::Mul(c2, e2)) => Expr::Mul(c * c2, e2).simplified(),
                    (c, Expr::Add(x, y)) => {
                        Expr::Add(Box::new(Expr::Mul(c, x)), Box::new(Expr::Mul(c, y)))
                            .simplified()
                    }
                    (c, e) => Expr::Mul(c, Box::new(e)),
                }
            }
            Expr::Div(e, d) => {
                let e = e.simplified();
                match e {
                    _ if d == 1 => e,
                    Expr::Cst(x) => Expr::Cst(x.div_euclid(d)),
                    // ⌊(d·q + r)/d⌋ = q when 0 ≤ r < d unknown; only fold exact scalings
                    Expr::Mul(c, inner) if c % d == 0 => {
                        Expr::Mul(c / d, inner).simplified()
                    }
                    e => Expr::Div(Box::new(e), d),
                }
            }
            Expr::Mod(e, d) => {
                let e = e.simplified();
                match e {
                    _ if d == 1 => Expr::Cst(0),
                    Expr::Cst(x) => Expr::Cst(x.rem_euclid(d)),
                    Expr::Mul(c, _) if c % d == 0 => Expr::Cst(0),
                    Expr::Mod(inner, d2) if d2 % d == 0 => {
                        // (e mod kd) mod d == e mod d
                        Expr::Mod(inner, d).simplified()
                    }
                    e => Expr::Mod(Box::new(e), d),
                }
            }
            other => other,
        }
    }

    /// Simplify with knowledge that each `Dim(k)` ranges over
    /// `[0, extents[k])`: resolves `Mod`/`Div` whose argument provably
    /// fits inside the modulus. Used after composition to erase
    /// redundant quasi-affine structure (e.g. `i mod n` when `i < n`).
    pub fn simplified_in(self, extents: &[i64]) -> Expr {
        let e = self.simplified();
        match e {
            Expr::Div(inner, d) => {
                let inner = inner.simplified_in(extents);
                if let Some((lo, hi)) = inner.range(extents) {
                    if lo >= 0 && hi < d {
                        return Expr::Cst(0);
                    }
                }
                Expr::Div(Box::new(inner), d)
            }
            Expr::Mod(inner, d) => {
                let inner = inner.simplified_in(extents);
                if let Some((lo, hi)) = inner.range(extents) {
                    if lo >= 0 && hi < d {
                        return inner;
                    }
                }
                Expr::Mod(Box::new(inner), d)
            }
            Expr::Add(a, b) => a.simplified_in(extents).add(b.simplified_in(extents)),
            Expr::Mul(c, e2) => e2.simplified_in(extents).scale(c),
            other => other,
        }
    }

    /// Conservative value range of the expression when dim `k` ranges
    /// over `[0, extents[k])`. Returns `None` if any dim is out of range.
    pub fn range(&self, extents: &[i64]) -> Option<(i64, i64)> {
        match self {
            Expr::Cst(c) => Some((*c, *c)),
            Expr::Dim(d) => {
                let e = *extents.get(*d)?;
                Some((0, e - 1))
            }
            Expr::Add(a, b) => {
                let (al, ah) = a.range(extents)?;
                let (bl, bh) = b.range(extents)?;
                Some((al + bl, ah + bh))
            }
            Expr::Mul(c, e) => {
                let (l, h) = e.range(extents)?;
                if *c >= 0 {
                    Some((c * l, c * h))
                } else {
                    Some((c * h, c * l))
                }
            }
            Expr::Div(e, d) => {
                let (l, h) = e.range(extents)?;
                Some((l.div_euclid(*d), h.div_euclid(*d)))
            }
            Expr::Mod(e, d) => {
                let (l, h) = e.range(extents)?;
                if l >= 0 && h < *d {
                    Some((l, h)) // no wrap
                } else {
                    Some((0, d - 1))
                }
            }
        }
    }

    /// Count of Div/Mod nodes (a complexity measure used by tests and
    /// the DME cost heuristics).
    pub fn quasi_ops(&self) -> usize {
        match self {
            Expr::Cst(_) | Expr::Dim(_) => 0,
            Expr::Add(a, b) => a.quasi_ops() + b.quasi_ops(),
            Expr::Mul(_, e) => e.quasi_ops(),
            Expr::Div(e, _) | Expr::Mod(e, _) => 1 + e.quasi_ops(),
        }
    }
}

/// Try to merge `c1·Dim(d) + c2·Dim(d)` shapes produced by composition.
fn merge_linear(a: &Expr, b: &Expr) -> Option<Expr> {
    fn as_scaled_dim(e: &Expr) -> Option<(i64, usize)> {
        match e {
            Expr::Dim(d) => Some((1, *d)),
            Expr::Mul(c, inner) => match inner.as_ref() {
                Expr::Dim(d) => Some((*c, *d)),
                _ => None,
            },
            _ => None,
        }
    }
    let (c1, d1) = as_scaled_dim(a)?;
    let (c2, d2) = as_scaled_dim(b)?;
    if d1 == d2 {
        Some(Expr::Mul(c1 + c2, Box::new(Expr::Dim(d1))).simplified())
    } else {
        None
    }
}

fn fmt_expr(e: &Expr, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match e {
        Expr::Cst(c) => write!(f, "{c}"),
        Expr::Dim(d) => write!(f, "i{d}"),
        Expr::Add(a, b) => write!(f, "({a} + {b})"),
        Expr::Mul(c, e) => write!(f, "{c}*{e}"),
        Expr::Div(e, d) => write!(f, "({e} div {d})"),
        Expr::Mod(e, d) => write!(f, "({e} mod {d})"),
    }
}

impl fmt::Debug for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_expr(self, f)
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_expr(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_basic() {
        // 3*i0 + i1 + 5
        let e = Expr::dim(0).scale(3).add(Expr::dim(1)).add(Expr::cst(5));
        assert_eq!(e.eval(&[2, 7]), 18);
    }

    #[test]
    fn eval_divmod_euclidean() {
        let d = Expr::dim(0).floordiv(4);
        let m = Expr::dim(0).modulo(4);
        // we only use nonneg indices, but semantics must be euclidean
        assert_eq!(d.eval(&[11]), 2);
        assert_eq!(m.eval(&[11]), 3);
    }

    #[test]
    fn simplify_folds_constants() {
        let e = Expr::cst(3).add(Expr::cst(4)).scale(2);
        assert_eq!(e, Expr::Cst(14));
        let z = Expr::dim(0).scale(0);
        assert_eq!(z, Expr::Cst(0));
        let one = Expr::dim(2).scale(1);
        assert_eq!(one, Expr::Dim(2));
    }

    #[test]
    fn simplify_divmod() {
        assert_eq!(Expr::dim(0).scale(8).floordiv(4), Expr::dim(0).scale(2));
        assert_eq!(Expr::dim(0).scale(8).modulo(4), Expr::Cst(0));
        assert_eq!(Expr::dim(0).floordiv(1), Expr::Dim(0));
        assert_eq!(Expr::dim(0).modulo(1), Expr::Cst(0));
    }

    #[test]
    fn substitution_is_composition() {
        // f(i) = 2i + 1; g(j) = j + 3; f∘g (j) = 2j + 7
        let fexpr = Expr::dim(0).scale(2).add(Expr::cst(1));
        let g = Expr::dim(0).add(Expr::cst(3));
        let fg = fexpr.substitute(&[g]);
        for j in 0..20 {
            assert_eq!(fg.eval(&[j]), 2 * j + 7);
        }
    }

    #[test]
    fn substitution_through_mod() {
        // tile read: src[i mod 5]; composed with i = 5a + b (b<5)
        let tile = Expr::dim(0).modulo(5);
        let sub = Expr::dim(0).scale(5).add(Expr::dim(1));
        let c = tile.substitute(&[sub]);
        for a in 0..3 {
            for b in 0..5 {
                assert_eq!(c.eval(&[a, b]), b);
            }
        }
    }

    #[test]
    fn domain_aware_simplify() {
        // i1 mod 8 with i1 in [0,8) is i1
        let e = Expr::dim(1).modulo(8).simplified_in(&[4, 8]);
        assert_eq!(e, Expr::Dim(1));
        // (4*i0 + i1) div 8 with i0<2,i1<4 → max 7 → 0
        let e2 = Expr::dim(0)
            .scale(4)
            .add(Expr::dim(1))
            .floordiv(8)
            .simplified_in(&[2, 4]);
        assert_eq!(e2, Expr::Cst(0));
    }

    #[test]
    fn as_affine_extraction() {
        let e = Expr::dim(0).scale(3).add(Expr::dim(2).scale(-2)).add(Expr::cst(7));
        let (c, b) = e.as_affine(3).unwrap();
        assert_eq!(c, vec![3, 0, -2]);
        assert_eq!(b, 7);
        assert!(Expr::dim(0).modulo(2).as_affine(1).is_none());
    }

    #[test]
    fn range_analysis() {
        let e = Expr::dim(0).scale(3).add(Expr::cst(-1));
        assert_eq!(e.range(&[4]), Some((-1, 8)));
        let m = Expr::dim(0).modulo(10);
        assert_eq!(m.range(&[5]), Some((0, 4))); // no wrap
        assert_eq!(m.range(&[50]), Some((0, 9))); // wraps
    }

    #[test]
    fn merge_linear_terms() {
        let e = Expr::dim(0).scale(2).add(Expr::dim(0).scale(3));
        assert_eq!(e, Expr::dim(0).scale(5));
    }

    #[test]
    fn quasi_ops_count() {
        assert_eq!(Expr::dim(0).quasi_ops(), 0);
        assert_eq!(Expr::dim(0).modulo(3).quasi_ops(), 1);
        assert_eq!(Expr::dim(0).modulo(3).floordiv(2).quasi_ops(), 2);
    }
}
