//! Rectangular iteration domains.
//!
//! Every loop nest in the IR is normalized so loop `k` ranges over
//! `[0, extents[k])` with step 1 — the standard normalization before
//! polyhedral analysis. A tensor's index space is the same shape box.

use std::fmt;

/// A box domain `[0,e0) × [0,e1) × … × [0,en-1)`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct IterDomain {
    extents: Vec<i64>,
}

impl IterDomain {
    /// Build from extents; all extents must be ≥ 1.
    pub fn new(extents: &[i64]) -> Self {
        assert!(
            extents.iter().all(|&e| e >= 1),
            "IterDomain: non-positive extent in {extents:?}"
        );
        IterDomain { extents: extents.to_vec() }
    }

    /// 0-dimensional (single point) domain.
    pub fn point() -> Self {
        IterDomain { extents: vec![] }
    }

    pub fn ndim(&self) -> usize {
        self.extents.len()
    }

    pub fn extents(&self) -> &[i64] {
        &self.extents
    }

    /// Number of points (product of extents).
    pub fn cardinality(&self) -> i64 {
        self.extents.iter().product()
    }

    /// Membership test.
    pub fn contains(&self, p: &[i64]) -> bool {
        p.len() == self.extents.len()
            && p.iter().zip(&self.extents).all(|(&x, &e)| x >= 0 && x < e)
    }

    /// Lexicographic iterator over all points. Only used by tests and
    /// small-shape verification — never on full-size model tensors.
    pub fn points(&self) -> DomainIter {
        DomainIter { dom: self.clone(), cur: vec![0; self.extents.len()], done: self.cardinality() == 0 }
    }

    /// Deterministic pseudo-random sample of up to `n` points, seeded —
    /// the workhorse of sampling-based map equivalence checks on big
    /// domains.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<Vec<i64>> {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let p: Vec<i64> = self
                .extents
                .iter()
                .map(|&e| (rng.next_u64() % (e as u64)) as i64)
                .collect();
            out.push(p);
        }
        out
    }

    /// Row-major linearization of a point (used to map an index vector
    /// to a flat offset for traffic/trace accounting).
    pub fn linearize(&self, p: &[i64]) -> i64 {
        debug_assert!(self.contains(p), "linearize: {p:?} outside {self:?}");
        let mut off = 0i64;
        for (x, e) in p.iter().zip(&self.extents) {
            off = off * e + x;
        }
        off
    }

    /// Inverse of [`Self::linearize`].
    pub fn delinearize(&self, mut off: i64) -> Vec<i64> {
        let mut p = vec![0i64; self.extents.len()];
        for k in (0..self.extents.len()).rev() {
            p[k] = off.rem_euclid(self.extents[k]);
            off = off.div_euclid(self.extents[k]);
        }
        p
    }
}

/// Lexicographic point iterator.
pub struct DomainIter {
    dom: IterDomain,
    cur: Vec<i64>,
    done: bool,
}

impl Iterator for DomainIter {
    type Item = Vec<i64>;
    fn next(&mut self) -> Option<Vec<i64>> {
        if self.done {
            return None;
        }
        let out = self.cur.clone();
        // increment like an odometer
        let mut k = self.cur.len();
        loop {
            if k == 0 {
                self.done = true;
                break;
            }
            k -= 1;
            self.cur[k] += 1;
            if self.cur[k] < self.dom.extents[k] {
                break;
            }
            self.cur[k] = 0;
        }
        if self.cur.iter().all(|&x| x == 0) && !out.iter().all(|&x| x == 0) {
            self.done = true;
        }
        Some(out)
    }
}

impl fmt::Debug for IterDomain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Dom{:?}", self.extents)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cardinality_and_contains() {
        let d = IterDomain::new(&[2, 3, 4]);
        assert_eq!(d.cardinality(), 24);
        assert!(d.contains(&[1, 2, 3]));
        assert!(!d.contains(&[2, 0, 0]));
        assert!(!d.contains(&[0, -1, 0]));
        assert!(!d.contains(&[0, 0]));
    }

    #[test]
    fn point_domain() {
        let d = IterDomain::point();
        assert_eq!(d.cardinality(), 1);
        let pts: Vec<_> = d.points().collect();
        assert_eq!(pts, vec![Vec::<i64>::new()]);
    }

    #[test]
    fn points_enumerates_all() {
        let d = IterDomain::new(&[2, 3]);
        let pts: Vec<_> = d.points().collect();
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0], vec![0, 0]);
        assert_eq!(pts[5], vec![1, 2]);
        // all distinct
        let mut sorted = pts.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 6);
    }

    #[test]
    fn linearize_roundtrip() {
        let d = IterDomain::new(&[3, 4, 5]);
        for p in d.points() {
            let off = d.linearize(&p);
            assert_eq!(d.delinearize(off), p);
        }
        assert_eq!(d.linearize(&[0, 0, 0]), 0);
        assert_eq!(d.linearize(&[2, 3, 4]), 59);
    }

    #[test]
    fn sample_in_domain_and_deterministic() {
        let d = IterDomain::new(&[7, 11]);
        let s1 = d.sample(100, 42);
        let s2 = d.sample(100, 42);
        assert_eq!(s1, s2);
        assert!(s1.iter().all(|p| d.contains(p)));
        let s3 = d.sample(100, 43);
        assert_ne!(s1, s3);
    }
}
