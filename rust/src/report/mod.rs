//! Paper-table formatting: render the experiment results the way the
//! paper's evaluation section states them, next to the paper's own
//! numbers, for the bench harness and the CLI.

use crate::accel::SimReport;
use crate::passes::bank::BankStats;
use crate::passes::dme::DmeStats;
use crate::util::json::Json;

/// A simple fixed-width table writer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (k, c) in row.iter().enumerate() {
                widths[k] = widths[k].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (k, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[k]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str("|");
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Percent-reduction helper (positive = reduced).
pub fn pct_reduction(before: i64, after: i64) -> f64 {
    if before == 0 {
        return 0.0;
    }
    100.0 * (1.0 - after as f64 / before as f64)
}

pub fn mb(bytes: i64) -> String {
    format!("{:.1} MB", bytes as f64 / 1e6)
}

/// The E1 table (paper §3, Parallel WaveNet + DME).
pub fn e1_table(stats: &DmeStats, before: &SimReport, after: &SimReport) -> String {
    let mut t = Table::new(&["metric", "paper", "measured"]);
    t.row(&[
        "load-store pairs eliminated".into(),
        "123 / 124".into(),
        format!("{} / {}", stats.pairs_eliminated, stats.pairs_before),
    ]);
    t.row(&[
        "intermediate tensor bytes eliminated".into(),
        "145 MB / 146 MB".into(),
        format!("{} / {}", mb(stats.bytes_eliminated), mb(stats.bytes_before)),
    ]);
    t.row(&[
        "on-chip movement saved".into(),
        "10%".into(),
        format!(
            "{:.1}%  ({} -> {})",
            pct_reduction(
                before.onchip_movement_total(),
                after.onchip_movement_total()
            ),
            mb(before.onchip_movement_total()),
            mb(after.onchip_movement_total())
        ),
    ]);
    t.row(&[
        "off-chip traffic saved".into(),
        "11%".into(),
        format!(
            "{:.1}%  ({} -> {})",
            pct_reduction(before.offchip_total(), after.offchip_total()),
            mb(before.offchip_total()),
            mb(after.offchip_total())
        ),
    ]);
    t.row(&[
        "estimated latency".into(),
        "n/a".into(),
        format!("{:.2} ms -> {:.2} ms", before.seconds * 1e3, after.seconds * 1e3),
    ]);
    t.render()
}

/// The E2 table (paper §3, ResNet-50 local vs global bank mapping).
pub fn e2_table(
    local_stats: &BankStats,
    global_stats: &BankStats,
    local_sim: &SimReport,
    global_sim: &SimReport,
) -> String {
    let mut t = Table::new(&["metric", "paper", "measured"]);
    t.row(&[
        "on-chip copy bytes eliminated".into(),
        "76%".into(),
        format!(
            "{:.1}%  ({} -> {})",
            pct_reduction(local_sim.onchip_copy_total(), global_sim.onchip_copy_total()),
            mb(local_sim.onchip_copy_total()),
            mb(global_sim.onchip_copy_total())
        ),
    ]);
    t.row(&[
        "off-chip copy bytes eliminated".into(),
        "37%".into(),
        format!(
            "{:.1}%  ({} -> {})",
            pct_reduction(
                local_sim.offchip_copy_total(),
                global_sim.offchip_copy_total()
            ),
            mb(local_sim.offchip_copy_total()),
            mb(global_sim.offchip_copy_total())
        ),
    ]);
    t.row(&[
        "remap copies inserted".into(),
        "n/a".into(),
        format!(
            "local {} / global {}",
            local_stats.copies_inserted, global_stats.copies_inserted
        ),
    ]);
    t.row(&[
        "estimated latency".into(),
        "n/a".into(),
        format!(
            "local {:.2} ms / global {:.2} ms",
            local_sim.seconds * 1e3,
            global_sim.seconds * 1e3
        ),
    ]);
    t.render()
}

/// The E3 table (this repo's extension experiment: planned vs dynamic
/// residency on one model).
pub fn e3_table(
    model: &str,
    dynamic: &SimReport,
    planned: &SimReport,
    plan: &crate::alloc::MemoryPlan,
) -> String {
    let s = &plan.stats;
    let mut t = Table::new(&["metric", "dynamic", "planned"]);
    t.row(&[
        format!("{model}: off-chip bytes"),
        mb(dynamic.offchip_total()),
        mb(planned.offchip_total()),
    ]);
    t.row(&[
        "off-chip copy bytes (spill churn)".into(),
        mb(dynamic.offchip_copy_total()),
        mb(planned.offchip_copy_total()),
    ]);
    t.row(&[
        "on-chip movement bytes".into(),
        mb(dynamic.onchip_movement_total()),
        mb(planned.onchip_movement_total()),
    ]);
    t.row(&[
        "peak scratchpad".into(),
        mb(dynamic.peak_scratchpad),
        mb(planned.peak_scratchpad),
    ]);
    t.row(&[
        "residency decisions".into(),
        "replay-time (Belady)".into(),
        format!(
            "compile-time ({} spill pairs, {} splits, {} streamed)",
            s.spill_pairs, s.window_splits, s.streamed
        ),
    ]);
    t.row(&[
        "schedule".into(),
        "builder order".into(),
        format!(
            "min-footprint ({} -> {} peak live, {} moved)",
            mb(s.peak_live_before),
            mb(s.peak_live_after),
            s.moved_nodes
        ),
    ]);
    t.render()
}

/// The unified `simulate` comparison table: one column per compiled
/// mode (dynamic / planned / tiled / opt), one row per metric — every
/// mode measured by the same [`SimReport`] vocabulary.
pub fn compare_table(model: &str, modes: &[(&str, &SimReport)]) -> String {
    let header: Vec<&str> = std::iter::once("metric")
        .chain(modes.iter().map(|&(n, _)| n))
        .collect();
    let mut t = Table::new(&header);
    let rows: Vec<(String, Vec<String>)> = vec![
        (
            format!("{model}: off-chip bytes"),
            modes.iter().map(|(_, s)| mb(s.offchip_total())).collect(),
        ),
        (
            "off-chip copy bytes (spill churn)".to_string(),
            modes.iter().map(|(_, s)| mb(s.offchip_copy_total())).collect(),
        ),
        (
            "on-chip movement bytes".to_string(),
            modes.iter().map(|(_, s)| mb(s.onchip_movement_total())).collect(),
        ),
        (
            "peak scratchpad".to_string(),
            modes.iter().map(|(_, s)| mb(s.peak_scratchpad)).collect(),
        ),
        (
            "estimated latency".to_string(),
            modes.iter().map(|(_, s)| format!("{:.3} ms", s.seconds * 1e3)).collect(),
        ),
    ];
    for (label, cells) in rows {
        let mut r = vec![label];
        r.extend(cells);
        t.row(&r);
    }
    t.render()
}

/// One mode's entry in the shared comparison JSON: the [`sim_to_json`]
/// record under `"sim"`, plus any mode-specific extras (plan, tile or
/// opt statistics).
pub fn mode_json(sim: &SimReport, extras: Vec<(&'static str, Json)>) -> Json {
    let mut pairs = vec![("sim", sim_to_json(sim))];
    pairs.extend(extras);
    Json::obj(pairs)
}

/// The shared machine-readable schema of the unified `simulate`
/// comparison: `{"model", "accel", "modes": {<name>: mode_json…}}`.
pub fn compare_json(model: &str, accel: Json, modes: Vec<(&'static str, Json)>) -> Json {
    Json::obj(vec![
        ("model", Json::Str(model.to_string())),
        ("accel", accel),
        ("modes", Json::obj(modes)),
    ])
}

/// JSON record for one planned-vs-dynamic comparison, reusing the
/// [`sim_to_json`] shape for both replays.
pub fn planned_vs_dynamic_json(
    model: &str,
    dynamic: &SimReport,
    planned: &SimReport,
    plan: &crate::alloc::MemoryPlan,
) -> Json {
    Json::obj(vec![
        ("model", Json::Str(model.to_string())),
        ("dynamic", sim_to_json(dynamic)),
        ("planned", sim_to_json(planned)),
        ("plan", plan.to_json()),
    ])
}

/// Node name lookup for attribution rows ([`EXTERNAL_NODE`] and any
/// id not in the graph render as `<external>`).
fn node_name(graph: &crate::ir::Graph, id: crate::ir::graph::NodeId) -> String {
    graph
        .nodes()
        .iter()
        .find(|n| n.id == id)
        .map(|n| n.name.clone())
        .unwrap_or_else(|| "<external>".to_string())
}

/// Per-layer traffic attribution table: the top-`top` nodes by
/// off-chip bytes, with the off-chip total split by cause, plus a
/// TOTAL row over *all* nodes (so the table's bottom line equals the
/// simulator's counters even when rows are elided).
pub fn attribution_table(
    graph: &crate::ir::Graph,
    attr: &crate::accel::trace::Attribution,
    top: usize,
) -> String {
    use crate::accel::TrafficClass as Tc;
    let mut t = Table::new(&[
        "layer",
        "off-chip",
        "weights",
        "inputs",
        "spill+reload",
        "copies",
        "output",
        "on-chip",
    ]);
    let row_cells = |name: String, get: &dyn Fn(Tc) -> i64| -> Vec<String> {
        let offchip: i64 = Tc::ALL.iter().filter(|c| c.is_offchip()).map(|&c| get(c)).sum();
        vec![
            name,
            mb(offchip),
            mb(get(Tc::WeightLoad)),
            mb(get(Tc::InputLoad)),
            mb(get(Tc::Spill) + get(Tc::Reload)),
            mb(get(Tc::OffchipCopy) + get(Tc::OffchipRemap)),
            mb(get(Tc::OutputStore)),
            mb(get(Tc::OnchipCopy) + get(Tc::OnchipRemap)),
        ]
    };
    for (node, _) in attr.per_node_offchip().into_iter().take(top) {
        t.row(&row_cells(node_name(graph, node), &|c| attr.get(node, c)));
    }
    let totals = attr.totals();
    t.row(&row_cells("TOTAL".to_string(), &|c| totals.get(c)));
    t.render()
}

/// Machine-readable attribution: the top-`top` per-layer rows (each
/// with its per-class byte cells) plus the class totals.
pub fn attribution_json(
    graph: &crate::ir::Graph,
    attr: &crate::accel::trace::Attribution,
    top: usize,
) -> Json {
    use crate::accel::TrafficClass;
    let top_layers: Vec<Json> = attr
        .per_node_offchip()
        .into_iter()
        .take(top)
        .map(|(node, offchip)| {
            let classes = TrafficClass::ALL
                .iter()
                .filter(|&&c| attr.get(node, c) != 0)
                .map(|&c| (c.label().to_string(), Json::Int(attr.get(node, c))))
                .collect();
            Json::obj(vec![
                ("node", Json::Int(node.0 as i64)),
                ("name", Json::Str(node_name(graph, node))),
                ("offchip", Json::Int(offchip)),
                ("classes", Json::Obj(classes)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("top_layers", Json::Arr(top_layers)),
        ("totals", attr.totals().to_json()),
    ])
}

/// JSON form of a sim report for machine-readable experiment logs.
pub fn sim_to_json(rep: &SimReport) -> Json {
    Json::obj(vec![
        ("traffic", rep.traffic.to_json()),
        ("seconds", Json::Num(rep.seconds)),
        ("peak_scratchpad", Json::Int(rep.peak_scratchpad)),
        ("nests", Json::Int(rep.nests_executed as i64)),
        ("copy_nests", Json::Int(rep.copy_nests_executed as i64)),
        (
            "onchip_movement_total",
            Json::Int(rep.onchip_movement_total()),
        ),
        ("offchip_total", Json::Int(rep.offchip_total())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "metric_name"]);
        t.row(&["1".into(), "x".into()]);
        t.row(&["2222".into(), "yyyy".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn pct_reduction_cases() {
        assert!((pct_reduction(100, 24) - 76.0).abs() < 1e-9);
        assert_eq!(pct_reduction(0, 5), 0.0);
        assert!((pct_reduction(200, 200)).abs() < 1e-9);
    }

    #[test]
    fn attribution_table_ranks_and_totals() {
        use crate::accel::trace::{Attribution, EXTERNAL_NODE};
        use crate::accel::TrafficClass;
        use crate::ir::builder::GraphBuilder;
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 4]);
        let t = b.transpose("t0", x, &[1, 0]);
        let r = b.relu("r0", t);
        b.mark_output(r);
        let g = b.finish();
        let (t_id, r_id) = (g.nodes()[0].id, g.nodes()[1].id);
        let mut a = Attribution::default();
        a.add(t_id, TrafficClass::InputLoad, 5_000_000);
        a.add(r_id, TrafficClass::OutputStore, 1_000_000);
        a.add(EXTERNAL_NODE, TrafficClass::OutputStore, 2_000_000);
        let table = attribution_table(&g, &a, 2);
        let lines: Vec<&str> = table.lines().collect();
        // header + rule + 2 rows + TOTAL
        assert_eq!(lines.len(), 5);
        assert!(lines[2].contains("t0"), "{table}");
        assert!(lines[3].contains("<external>"), "{table}");
        assert!(lines[4].contains("TOTAL") && lines[4].contains("8.0 MB"), "{table}");
        let j = attribution_json(&g, &a, 2);
        let top = j.get("top_layers").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].get("name").and_then(|v| v.as_str()), Some("t0"));
        assert_eq!(top[0].get("offchip").and_then(|v| v.as_i64()), Some(5_000_000));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
