//! Graph ⇄ JSON serialization: the model-exchange format of the CLI
//! (`polymem compile --graph model.json`) and of downstream tooling.
//!
//! Schema:
//! ```json
//! {
//!   "tensors": [{"id": 0, "name": "x", "shape": [1,3,32,32],
//!                "dtype": "f32", "kind": "input"}, …],
//!   "nodes":   [{"name": "conv1", "op": "conv2d",
//!                "attrs": {"stride": 1, "pad": 1},
//!                "inputs": [0, 1], "output": 2}, …]
//! }
//! ```

use super::graph::Graph;
use super::op::{BinaryFn, OpKind, PoolKind, UnaryFn};
use super::tensor::{DType, TensorId, TensorKind};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Serialization/deserialization error.
#[derive(Debug, Clone)]
pub struct SerdeError(pub String);

impl std::fmt::Display for SerdeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "graph serde: {}", self.0)
    }
}

impl std::error::Error for SerdeError {}

fn err<T>(m: impl Into<String>) -> Result<T, SerdeError> {
    Err(SerdeError(m.into()))
}

fn dtype_str(d: DType) -> &'static str {
    match d {
        DType::F32 => "f32",
        DType::BF16 => "bf16",
        DType::F16 => "f16",
        DType::I32 => "i32",
        DType::I8 => "i8",
    }
}

fn dtype_parse(s: &str) -> Result<DType, SerdeError> {
    Ok(match s {
        "f32" => DType::F32,
        "bf16" => DType::BF16,
        "f16" => DType::F16,
        "i32" => DType::I32,
        "i8" => DType::I8,
        other => return err(format!("unknown dtype '{other}'")),
    })
}

fn kind_str(k: TensorKind) -> &'static str {
    match k {
        TensorKind::Input => "input",
        TensorKind::Weight => "weight",
        TensorKind::Intermediate => "intermediate",
        TensorKind::Output => "output",
    }
}

fn kind_parse(s: &str) -> Result<TensorKind, SerdeError> {
    Ok(match s {
        "input" => TensorKind::Input,
        "weight" => TensorKind::Weight,
        "intermediate" => TensorKind::Intermediate,
        "output" => TensorKind::Output,
        other => return err(format!("unknown tensor kind '{other}'")),
    })
}

fn ints(v: &[i64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Int(x)).collect())
}

fn usizes(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Int(x as i64)).collect())
}

fn get_ints(j: &Json, key: &str) -> Result<Vec<i64>, SerdeError> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_i64()).collect::<Vec<_>>())
        .ok_or_else(|| SerdeError(format!("missing int array '{key}'")))
}

fn get_i64(j: &Json, key: &str) -> Result<i64, SerdeError> {
    j.get(key)
        .and_then(|v| v.as_i64())
        .ok_or_else(|| SerdeError(format!("missing int '{key}'")))
}

fn get_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, SerdeError> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| SerdeError(format!("missing string '{key}'")))
}

fn op_to_json(kind: &OpKind) -> (&'static str, Json) {
    let empty = Json::obj(vec![]);
    match kind {
        OpKind::Conv2d { stride, pad } => (
            "conv2d",
            Json::obj(vec![("stride", Json::Int(*stride)), ("pad", Json::Int(*pad))]),
        ),
        OpKind::DepthwiseConv2d { stride, pad } => (
            "depthwise_conv2d",
            Json::obj(vec![("stride", Json::Int(*stride)), ("pad", Json::Int(*pad))]),
        ),
        OpKind::MatMul => ("matmul", empty),
        OpKind::Pool { kind, window, stride } => (
            "pool",
            Json::obj(vec![
                (
                    "kind",
                    Json::Str(if *kind == PoolKind::Max { "max" } else { "avg" }.into()),
                ),
                ("window", Json::Int(*window)),
                ("stride", Json::Int(*stride)),
            ]),
        ),
        OpKind::GlobalAvgPool => ("global_avg_pool", empty),
        OpKind::Unary(f) => (
            "unary",
            Json::obj(vec![(
                "fn",
                Json::Str(
                    match f {
                        UnaryFn::Relu => "relu",
                        UnaryFn::Sigmoid => "sigmoid",
                        UnaryFn::Tanh => "tanh",
                        UnaryFn::Exp => "exp",
                        UnaryFn::Neg => "neg",
                    }
                    .into(),
                ),
            )]),
        ),
        OpKind::Binary(f) => (
            "binary",
            Json::obj(vec![(
                "fn",
                Json::Str(
                    match f {
                        BinaryFn::Add => "add",
                        BinaryFn::Sub => "sub",
                        BinaryFn::Mul => "mul",
                        BinaryFn::Max => "max",
                    }
                    .into(),
                ),
            )]),
        ),
        OpKind::BatchNorm => ("batchnorm", empty),
        OpKind::BiasAdd => ("bias_add", empty),
        OpKind::Softmax => ("softmax", empty),
        OpKind::Conv1d { dilation } => (
            "conv1d",
            Json::obj(vec![("dilation", Json::Int(*dilation))]),
        ),
        OpKind::Transpose { perm } => ("transpose", Json::obj(vec![("perm", usizes(perm))])),
        OpKind::Reshape { shape } => ("reshape", Json::obj(vec![("shape", ints(shape))])),
        OpKind::Tile { reps } => ("tile", Json::obj(vec![("reps", ints(reps))])),
        OpKind::Repeat { axis, n } => (
            "repeat",
            Json::obj(vec![("axis", Json::Int(*axis as i64)), ("n", Json::Int(*n))]),
        ),
        OpKind::StridedSlice { begin, end, stride } => (
            "strided_slice",
            Json::obj(vec![
                ("begin", ints(begin)),
                ("end", ints(end)),
                ("stride", ints(stride)),
            ]),
        ),
        OpKind::Concat { axis } => (
            "concat",
            Json::obj(vec![("axis", Json::Int(*axis as i64))]),
        ),
        OpKind::Pad { lo, hi } => (
            "pad",
            Json::obj(vec![("lo", ints(lo)), ("hi", ints(hi))]),
        ),
        OpKind::Identity => ("identity", empty),
        OpKind::MemCopy => ("memcopy", empty),
    }
}

fn op_from_json(op: &str, attrs: &Json) -> Result<OpKind, SerdeError> {
    Ok(match op {
        "conv2d" => OpKind::Conv2d {
            stride: get_i64(attrs, "stride")?,
            pad: get_i64(attrs, "pad")?,
        },
        "depthwise_conv2d" => OpKind::DepthwiseConv2d {
            stride: get_i64(attrs, "stride")?,
            pad: get_i64(attrs, "pad")?,
        },
        "matmul" => OpKind::MatMul,
        "pool" => OpKind::Pool {
            kind: match get_str(attrs, "kind")? {
                "max" => PoolKind::Max,
                "avg" => PoolKind::Avg,
                other => return err(format!("unknown pool kind '{other}'")),
            },
            window: get_i64(attrs, "window")?,
            stride: get_i64(attrs, "stride")?,
        },
        "global_avg_pool" => OpKind::GlobalAvgPool,
        "unary" => OpKind::Unary(match get_str(attrs, "fn")? {
            "relu" => UnaryFn::Relu,
            "sigmoid" => UnaryFn::Sigmoid,
            "tanh" => UnaryFn::Tanh,
            "exp" => UnaryFn::Exp,
            "neg" => UnaryFn::Neg,
            other => return err(format!("unknown unary fn '{other}'")),
        }),
        "binary" => OpKind::Binary(match get_str(attrs, "fn")? {
            "add" => BinaryFn::Add,
            "sub" => BinaryFn::Sub,
            "mul" => BinaryFn::Mul,
            "max" => BinaryFn::Max,
            other => return err(format!("unknown binary fn '{other}'")),
        }),
        "batchnorm" => OpKind::BatchNorm,
        "bias_add" => OpKind::BiasAdd,
        "softmax" => OpKind::Softmax,
        "conv1d" => OpKind::Conv1d { dilation: get_i64(attrs, "dilation")? },
        "transpose" => OpKind::Transpose {
            perm: get_ints(attrs, "perm")?.iter().map(|&x| x as usize).collect(),
        },
        "reshape" => OpKind::Reshape { shape: get_ints(attrs, "shape")? },
        "tile" => OpKind::Tile { reps: get_ints(attrs, "reps")? },
        "repeat" => OpKind::Repeat {
            axis: get_i64(attrs, "axis")? as usize,
            n: get_i64(attrs, "n")?,
        },
        "strided_slice" => OpKind::StridedSlice {
            begin: get_ints(attrs, "begin")?,
            end: get_ints(attrs, "end")?,
            stride: get_ints(attrs, "stride")?,
        },
        "concat" => OpKind::Concat { axis: get_i64(attrs, "axis")? as usize },
        "pad" => OpKind::Pad {
            lo: get_ints(attrs, "lo")?,
            hi: get_ints(attrs, "hi")?,
        },
        "identity" => OpKind::Identity,
        "memcopy" => OpKind::MemCopy,
        other => return err(format!("unknown op '{other}'")),
    })
}

/// Serialize a graph to the JSON exchange format.
pub fn graph_to_json(g: &Graph) -> Json {
    let tensors: Vec<Json> = g
        .tensors()
        .map(|t| {
            Json::obj(vec![
                ("id", Json::Int(t.id.0 as i64)),
                ("name", Json::Str(t.name.clone())),
                ("shape", ints(&t.shape)),
                ("dtype", Json::Str(dtype_str(t.dtype).into())),
                ("kind", Json::Str(kind_str(t.kind).into())),
            ])
        })
        .collect();
    let nodes: Vec<Json> = g
        .nodes()
        .iter()
        .map(|n| {
            let (op, attrs) = op_to_json(&n.kind);
            Json::obj(vec![
                ("name", Json::Str(n.name.clone())),
                ("op", Json::Str(op.into())),
                ("attrs", attrs),
                (
                    "inputs",
                    Json::Arr(n.inputs.iter().map(|t| Json::Int(t.0 as i64)).collect()),
                ),
                ("output", Json::Int(n.output.0 as i64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("tensors", Json::Arr(tensors)),
        ("nodes", Json::Arr(nodes)),
    ])
}

/// Deserialize a graph from the JSON exchange format. Tensor ids are
/// remapped densely; node order must be topological (verified by the
/// caller via [`crate::ir::verify::verify_graph`]).
pub fn graph_from_json(j: &Json) -> Result<Graph, SerdeError> {
    let mut g = Graph::new();
    let mut idmap: BTreeMap<i64, TensorId> = BTreeMap::new();
    let tensors = j
        .get("tensors")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| SerdeError("missing 'tensors'".into()))?;
    for t in tensors {
        let ext_id = get_i64(t, "id")?;
        let name = get_str(t, "name")?;
        let shape = get_ints(t, "shape")?;
        let dtype = dtype_parse(get_str(t, "dtype")?)?;
        let kind = kind_parse(get_str(t, "kind")?)?;
        if shape.iter().any(|&e| e < 1) {
            return err(format!("tensor '{name}': bad shape {shape:?}"));
        }
        let id = g.add_tensor(name, &shape, dtype, kind);
        if idmap.insert(ext_id, id).is_some() {
            return err(format!("duplicate tensor id {ext_id}"));
        }
    }
    let nodes = j
        .get("nodes")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| SerdeError("missing 'nodes'".into()))?;
    for n in nodes {
        let name = get_str(n, "name")?;
        let op = get_str(n, "op")?;
        let attrs = n.get("attrs").cloned().unwrap_or(Json::obj(vec![]));
        let kind = op_from_json(op, &attrs)?;
        let inputs: Vec<TensorId> = get_ints(n, "inputs")?
            .iter()
            .map(|x| {
                idmap
                    .get(x)
                    .copied()
                    .ok_or_else(|| SerdeError(format!("node '{name}': unknown input {x}")))
            })
            .collect::<Result<_, _>>()?;
        let output = idmap
            .get(&get_i64(n, "output")?)
            .copied()
            .ok_or_else(|| SerdeError(format!("node '{name}': unknown output tensor")))?;
        g.add_node(name, kind, inputs, output);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify_graph;
    use crate::util::json::parse;

    fn roundtrip(g: &Graph) {
        let j = graph_to_json(g);
        let text = j.to_string_pretty();
        let back = graph_from_json(&parse(&text).unwrap()).unwrap();
        verify_graph(&back).unwrap();
        assert_eq!(back.nodes().len(), g.nodes().len());
        assert_eq!(back.tensors().count(), g.tensors().count());
        for (a, b) in g.nodes().iter().zip(back.nodes()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.inputs.len(), b.inputs.len());
        }
        for (a, b) in g.tensors().zip(back.tensors()) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.dtype, b.dtype);
        }
    }

    #[test]
    fn roundtrips_model_zoo() {
        roundtrip(&crate::models::mlp(2, 16, 8, 4, 2));
        roundtrip(&crate::models::resnet18(1));
        roundtrip(&crate::models::transformer_block(16, 32, 2, 64));
        roundtrip(&crate::models::inception_stack(1, 1));
        roundtrip(&crate::models::wavenet::parallel_wavenet_with(
            crate::models::wavenet::WaveNetConfig {
                flows: 1,
                layers_per_flow: 2,
                channels: 4,
                time: 16,
                kernel: 2,
                dilation_cycle: 2,
            },
        ));
    }

    #[test]
    fn rejects_malformed() {
        assert!(graph_from_json(&parse("{}").unwrap()).is_err());
        let bad_op = r#"{"tensors": [{"id":0,"name":"x","shape":[2],"dtype":"f32","kind":"input"}],
                          "nodes": [{"name":"n","op":"warp","attrs":{},"inputs":[0],"output":0}]}"#;
        assert!(graph_from_json(&parse(bad_op).unwrap()).is_err());
        let bad_ref = r#"{"tensors": [{"id":0,"name":"x","shape":[2],"dtype":"f32","kind":"input"}],
                          "nodes": [{"name":"n","op":"identity","attrs":{},"inputs":[9],"output":0}]}"#;
        assert!(graph_from_json(&parse(bad_ref).unwrap()).is_err());
        let bad_shape = r#"{"tensors": [{"id":0,"name":"x","shape":[0],"dtype":"f32","kind":"input"}],
                            "nodes": []}"#;
        assert!(graph_from_json(&parse(bad_shape).unwrap()).is_err());
    }

    #[test]
    fn external_ids_remapped() {
        let text = r#"{
          "tensors": [
            {"id": 100, "name": "x", "shape": [4], "dtype": "f32", "kind": "input"},
            {"id": 7,   "name": "y", "shape": [4], "dtype": "f32", "kind": "output"}
          ],
          "nodes": [
            {"name": "id", "op": "identity", "attrs": {}, "inputs": [100], "output": 7}
          ]
        }"#;
        let g = graph_from_json(&parse(text).unwrap()).unwrap();
        verify_graph(&g).unwrap();
        assert_eq!(g.nodes().len(), 1);
    }
}
