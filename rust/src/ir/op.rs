//! Operator kinds with shape inference.
//!
//! Two families, mirroring the paper's distinction:
//! * **compute-bound** operators (conv/matmul/pool/elementwise/…)
//!   execute on the systolic array or the vector engine; their loop
//!   nests carry opaque compute bodies and bank-mapping constraints;
//! * **memory-bound** operators (`transpose`, `reshape`, `tile`,
//!   `repeat`, `strided_slice`, `split`→slices, `concat`, `pad`,
//!   `identity`) lower to pure copy nests — the targets of §2.1 DME.

/// Pooling flavor.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Elementwise unary functions (vector engine).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum UnaryFn {
    Relu,
    Sigmoid,
    Tanh,
    Exp,
    Neg,
}

/// Elementwise binary functions (vector engine).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BinaryFn {
    Add,
    Sub,
    Mul,
    Max,
}

/// Operator kind. Shapes below refer to the op's input tensors in order.
#[derive(Clone, PartialEq, Debug)]
pub enum OpKind {
    // ---- compute-bound ----
    /// 2-D convolution, NCHW × [Cout, Cin, Kh, Kw], symmetric padding.
    Conv2d { stride: i64, pad: i64 },
    /// Depthwise variant (per-channel), weights [C, 1, Kh, Kw].
    DepthwiseConv2d { stride: i64, pad: i64 },
    /// `[M, K] · [K, N] → [M, N]`.
    MatMul,
    /// Window pooling over NCHW spatial dims.
    Pool { kind: PoolKind, window: i64, stride: i64 },
    /// Global average pool → [N, C, 1, 1].
    GlobalAvgPool,
    /// Elementwise unary.
    Unary(UnaryFn),
    /// Elementwise binary (same-shape operands).
    Binary(BinaryFn),
    /// Folded batch-norm: per-channel scale+shift on NCHW (weights
    /// [C] scale, [C] shift).
    BatchNorm,
    /// Bias add over the last dim of a matmul output ([N] bias).
    BiasAdd,
    /// Softmax over the last dim.
    Softmax,
    /// 1-D dilated causal convolution for WaveNet stacks:
    /// input [N, C, T] × weights [Cout, Cin, K] with dilation.
    Conv1d { dilation: i64 },

    // ---- memory-bound (copy nests; DME targets) ----
    /// Output axis `k` takes input axis `perm[k]`.
    Transpose { perm: Vec<usize> },
    /// Row-major reinterpretation to `shape` (same numel).
    Reshape { shape: Vec<i64> },
    /// Repeat the whole tensor `reps[d]` times along each axis
    /// (NumPy `tile`): out[i] = in[i mod shape].
    Tile { reps: Vec<i64> },
    /// Repeat each element `n` times along `axis`
    /// (NumPy `repeat`): out[.., i, ..] = in[.., i div n, ..].
    Repeat { axis: usize, n: i64 },
    /// out[i] = in[begin + i*stride] per axis.
    StridedSlice { begin: Vec<i64>, end: Vec<i64>, stride: Vec<i64> },
    /// Concatenate along `axis` (2+ inputs).
    Concat { axis: usize },
    /// Zero-pad `lo`/`hi` per axis. Lowers to a copy of the interior;
    /// the zero fill is a compute (memset) statement.
    Pad { lo: Vec<i64>, hi: Vec<i64> },
    /// Pure copy (layout change placeholder / graph glue).
    Identity,
    /// Inter-bank relocation inserted by the bank-mapping passes —
    /// never created by model builders, never eliminated by DME.
    MemCopy,
}

impl OpKind {
    /// True for operators that lower to pure copy nests (DME targets).
    pub fn is_memory_bound(&self) -> bool {
        matches!(
            self,
            OpKind::Transpose { .. }
                | OpKind::Reshape { .. }
                | OpKind::Tile { .. }
                | OpKind::Repeat { .. }
                | OpKind::StridedSlice { .. }
                | OpKind::Concat { .. }
                | OpKind::Pad { .. }
                | OpKind::Identity
                | OpKind::MemCopy
        )
    }

    /// Short mnemonic for debugging and reports.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::DepthwiseConv2d { .. } => "dwconv2d",
            OpKind::MatMul => "matmul",
            OpKind::Pool { .. } => "pool",
            OpKind::GlobalAvgPool => "gap",
            OpKind::Unary(_) => "unary",
            OpKind::Binary(_) => "binary",
            OpKind::BatchNorm => "batchnorm",
            OpKind::BiasAdd => "biasadd",
            OpKind::Softmax => "softmax",
            OpKind::Conv1d { .. } => "conv1d",
            OpKind::Transpose { .. } => "transpose",
            OpKind::Reshape { .. } => "reshape",
            OpKind::Tile { .. } => "tile",
            OpKind::Repeat { .. } => "repeat",
            OpKind::StridedSlice { .. } => "strided_slice",
            OpKind::Concat { .. } => "concat",
            OpKind::Pad { .. } => "pad",
            OpKind::Identity => "identity",
            OpKind::MemCopy => "memcopy",
        }
    }

    /// Infer the output shape from input shapes. Returns `Err` with a
    /// description on rank/shape mismatch.
    pub fn infer_shape(&self, inputs: &[&[i64]]) -> Result<Vec<i64>, String> {
        let need = |n: usize| -> Result<(), String> {
            if inputs.len() != n {
                Err(format!("{}: expected {n} inputs, got {}", self.mnemonic(), inputs.len()))
            } else {
                Ok(())
            }
        };
        match self {
            OpKind::Conv2d { stride, pad } => {
                need(2)?;
                let (x, w) = (inputs[0], inputs[1]);
                if x.len() != 4 || w.len() != 4 {
                    return Err("conv2d: need NCHW input and OIHW weights".into());
                }
                if x[1] != w[1] {
                    return Err(format!("conv2d: Cin mismatch {} vs {}", x[1], w[1]));
                }
                let oh = conv_out(x[2], w[2], *stride, *pad)?;
                let ow = conv_out(x[3], w[3], *stride, *pad)?;
                Ok(vec![x[0], w[0], oh, ow])
            }
            OpKind::DepthwiseConv2d { stride, pad } => {
                need(2)?;
                let (x, w) = (inputs[0], inputs[1]);
                if x.len() != 4 || w.len() != 4 || w[1] != 1 {
                    return Err("dwconv2d: need NCHW and [C,1,Kh,Kw]".into());
                }
                if x[1] != w[0] {
                    return Err("dwconv2d: channel mismatch".into());
                }
                let oh = conv_out(x[2], w[2], *stride, *pad)?;
                let ow = conv_out(x[3], w[3], *stride, *pad)?;
                Ok(vec![x[0], x[1], oh, ow])
            }
            OpKind::MatMul => {
                need(2)?;
                let (a, b) = (inputs[0], inputs[1]);
                if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
                    return Err(format!("matmul: bad shapes {a:?} x {b:?}"));
                }
                Ok(vec![a[0], b[1]])
            }
            OpKind::Pool { window, stride, .. } => {
                need(1)?;
                let x = inputs[0];
                if x.len() != 4 {
                    return Err("pool: need NCHW".into());
                }
                let oh = conv_out(x[2], *window, *stride, 0)?;
                let ow = conv_out(x[3], *window, *stride, 0)?;
                Ok(vec![x[0], x[1], oh, ow])
            }
            OpKind::GlobalAvgPool => {
                need(1)?;
                let x = inputs[0];
                if x.len() != 4 {
                    return Err("gap: need NCHW".into());
                }
                Ok(vec![x[0], x[1], 1, 1])
            }
            OpKind::Unary(_) | OpKind::Identity | OpKind::MemCopy | OpKind::Softmax => {
                need(1)?;
                Ok(inputs[0].to_vec())
            }
            OpKind::Binary(_) => {
                need(2)?;
                if inputs[0] != inputs[1] {
                    return Err(format!(
                        "binary: shape mismatch {:?} vs {:?}",
                        inputs[0], inputs[1]
                    ));
                }
                Ok(inputs[0].to_vec())
            }
            OpKind::BatchNorm => {
                need(3)?;
                let x = inputs[0];
                if x.len() != 4 || inputs[1] != &[x[1]] || inputs[2] != &[x[1]] {
                    return Err("batchnorm: need NCHW + [C] scale + [C] shift".into());
                }
                Ok(x.to_vec())
            }
            OpKind::BiasAdd => {
                need(2)?;
                let x = inputs[0];
                if inputs[1] != &[x[x.len() - 1]] {
                    return Err("biasadd: bias must match last dim".into());
                }
                Ok(x.to_vec())
            }
            OpKind::Conv1d { dilation } => {
                need(2)?;
                let (x, w) = (inputs[0], inputs[1]);
                if x.len() != 3 || w.len() != 3 || x[1] != w[1] {
                    return Err("conv1d: need [N,C,T] and [Cout,Cin,K]".into());
                }
                // causal: output length preserved (left pad (K-1)*dilation
                // is materialized by an explicit Pad op in model builders)
                let k_span = (w[2] - 1) * dilation + 1;
                if x[2] < k_span {
                    return Err("conv1d: input shorter than dilated kernel".into());
                }
                Ok(vec![x[0], w[0], x[2] - k_span + 1])
            }
            OpKind::Transpose { perm } => {
                need(1)?;
                let x = inputs[0];
                if perm.len() != x.len() {
                    return Err("transpose: perm rank mismatch".into());
                }
                let mut seen = vec![false; x.len()];
                for &p in perm {
                    if p >= x.len() || seen[p] {
                        return Err("transpose: invalid perm".into());
                    }
                    seen[p] = true;
                }
                Ok(perm.iter().map(|&p| x[p]).collect())
            }
            OpKind::Reshape { shape } => {
                need(1)?;
                let n: i64 = inputs[0].iter().product();
                let m: i64 = shape.iter().product();
                if n != m {
                    return Err(format!("reshape: numel {n} != {m}"));
                }
                Ok(shape.clone())
            }
            OpKind::Tile { reps } => {
                need(1)?;
                let x = inputs[0];
                if reps.len() != x.len() || reps.iter().any(|&r| r < 1) {
                    return Err("tile: bad reps".into());
                }
                Ok(x.iter().zip(reps).map(|(&s, &r)| s * r).collect())
            }
            OpKind::Repeat { axis, n } => {
                need(1)?;
                let x = inputs[0];
                if *axis >= x.len() || *n < 1 {
                    return Err("repeat: bad axis/n".into());
                }
                let mut out = x.to_vec();
                out[*axis] *= n;
                Ok(out)
            }
            OpKind::StridedSlice { begin, end, stride } => {
                need(1)?;
                let x = inputs[0];
                if begin.len() != x.len() || end.len() != x.len() || stride.len() != x.len() {
                    return Err("strided_slice: rank mismatch".into());
                }
                let mut out = Vec::with_capacity(x.len());
                for d in 0..x.len() {
                    if stride[d] < 1 || begin[d] < 0 || end[d] > x[d] || begin[d] >= end[d] {
                        return Err(format!("strided_slice: bad range on dim {d}"));
                    }
                    out.push((end[d] - begin[d] + stride[d] - 1) / stride[d]);
                }
                Ok(out)
            }
            OpKind::Concat { axis } => {
                if inputs.len() < 2 {
                    return Err("concat: need 2+ inputs".into());
                }
                let first = inputs[0];
                if *axis >= first.len() {
                    return Err("concat: bad axis".into());
                }
                let mut total = 0;
                for x in inputs {
                    if x.len() != first.len() {
                        return Err("concat: rank mismatch".into());
                    }
                    for d in 0..first.len() {
                        if d != *axis && x[d] != first[d] {
                            return Err("concat: non-axis dim mismatch".into());
                        }
                    }
                    total += x[*axis];
                }
                let mut out = first.to_vec();
                out[*axis] = total;
                Ok(out)
            }
            OpKind::Pad { lo, hi } => {
                need(1)?;
                let x = inputs[0];
                if lo.len() != x.len() || hi.len() != x.len() {
                    return Err("pad: rank mismatch".into());
                }
                if lo.iter().chain(hi).any(|&p| p < 0) {
                    return Err("pad: negative padding".into());
                }
                Ok(x.iter()
                    .zip(lo.iter().zip(hi))
                    .map(|(&s, (&l, &h))| s + l + h)
                    .collect())
            }
        }
    }
}

fn conv_out(size: i64, k: i64, stride: i64, pad: i64) -> Result<i64, String> {
    if stride < 1 {
        return Err("conv: stride < 1".into());
    }
    let padded = size + 2 * pad;
    if padded < k {
        return Err(format!("conv: size {size}+2*{pad} < kernel {k}"));
    }
    Ok((padded - k) / stride + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_shapes() {
        let k = OpKind::Conv2d { stride: 2, pad: 3 };
        let out = k.infer_shape(&[&[1, 3, 224, 224], &[64, 3, 7, 7]]).unwrap();
        assert_eq!(out, vec![1, 64, 112, 112]);
        let k1 = OpKind::Conv2d { stride: 1, pad: 1 };
        assert_eq!(
            k1.infer_shape(&[&[1, 64, 56, 56], &[64, 64, 3, 3]]).unwrap(),
            vec![1, 64, 56, 56]
        );
        assert!(k1.infer_shape(&[&[1, 32, 56, 56], &[64, 64, 3, 3]]).is_err());
    }

    #[test]
    fn matmul_pool_gap() {
        assert_eq!(
            OpKind::MatMul.infer_shape(&[&[8, 512], &[512, 1000]]).unwrap(),
            vec![8, 1000]
        );
        assert!(OpKind::MatMul.infer_shape(&[&[8, 512], &[256, 1000]]).is_err());
        let p = OpKind::Pool { kind: PoolKind::Max, window: 3, stride: 2 };
        assert_eq!(
            p.infer_shape(&[&[1, 64, 112, 112]]).unwrap(),
            vec![1, 64, 55, 55]
        );
        assert_eq!(
            OpKind::GlobalAvgPool.infer_shape(&[&[1, 2048, 7, 7]]).unwrap(),
            vec![1, 2048, 1, 1]
        );
    }

    #[test]
    fn memory_ops_shapes() {
        let t = OpKind::Transpose { perm: vec![0, 2, 3, 1] };
        assert_eq!(
            t.infer_shape(&[&[1, 64, 56, 48]]).unwrap(),
            vec![1, 56, 48, 64]
        );
        let r = OpKind::Reshape { shape: vec![4, 6] };
        assert_eq!(r.infer_shape(&[&[2, 12]]).unwrap(), vec![4, 6]);
        assert!(r.infer_shape(&[&[2, 11]]).is_err());
        let tile = OpKind::Tile { reps: vec![2, 3] };
        assert_eq!(tile.infer_shape(&[&[4, 5]]).unwrap(), vec![8, 15]);
        let rep = OpKind::Repeat { axis: 1, n: 4 };
        assert_eq!(rep.infer_shape(&[&[2, 3]]).unwrap(), vec![2, 12]);
        let ss = OpKind::StridedSlice {
            begin: vec![0, 2],
            end: vec![4, 10],
            stride: vec![1, 2],
        };
        assert_eq!(ss.infer_shape(&[&[4, 10]]).unwrap(), vec![4, 4]);
        let c = OpKind::Concat { axis: 1 };
        assert_eq!(
            c.infer_shape(&[&[2, 3], &[2, 5]]).unwrap(),
            vec![2, 8]
        );
        let pd = OpKind::Pad { lo: vec![0, 2], hi: vec![0, 2] };
        assert_eq!(pd.infer_shape(&[&[1, 10]]).unwrap(), vec![1, 14]);
    }

    #[test]
    fn conv1d_dilated() {
        let k = OpKind::Conv1d { dilation: 4 };
        // K=2 dilated by 4: span 5 → T_out = T - 4
        assert_eq!(
            k.infer_shape(&[&[1, 64, 104], &[64, 64, 2]]).unwrap(),
            vec![1, 64, 100]
        );
    }

    #[test]
    fn memory_bound_classification() {
        assert!(OpKind::Transpose { perm: vec![0] }.is_memory_bound());
        assert!(OpKind::Identity.is_memory_bound());
        assert!(OpKind::MemCopy.is_memory_bound());
        assert!(!OpKind::MatMul.is_memory_bound());
        assert!(!OpKind::Unary(UnaryFn::Relu).is_memory_bound());
    }

    #[test]
    fn transpose_rejects_bad_perm() {
        let t = OpKind::Transpose { perm: vec![0, 0] };
        assert!(t.infer_shape(&[&[2, 3]]).is_err());
    }
}
