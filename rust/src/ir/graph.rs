//! The operator graph: SSA tensors, nodes, def-use indexes, topological
//! order.

use super::op::OpKind;
use super::tensor::{DType, TensorId, TensorInfo, TensorKind};
use std::collections::BTreeMap;
use std::fmt;

/// Stable node identity within one [`Graph`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Debug for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// One operator application.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
    /// Set by DME when the node's loads were rewritten to bypass an
    /// eliminated tensor: `kind` then describes the *original* operator
    /// while the true access pattern lives in the node's loop nests, so
    /// shape inference no longer applies and bank-mapping transfer
    /// functions treat the node as opaque.
    pub rewritten: bool,
}

/// The model graph. Nodes are stored in insertion order, which builders
/// guarantee to be topological (verified by [`crate::ir::verify`]).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub(crate) tensors: BTreeMap<TensorId, TensorInfo>,
    pub(crate) nodes: Vec<Node>,
    next_tensor: u32,
    next_node: u32,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    /// Build a graph from pre-existing tensor/node records *preserving
    /// their original ids* — the shard stage extractor relies on this so
    /// per-tensor seeded buffers and cut-edge identities line up across
    /// stages. The id counters resume past the largest preserved id, so
    /// later pass-inserted tensors/nodes (bank-mapping `MemCopy`
    /// splices) can never collide with a preserved id.
    pub(crate) fn from_parts(tensors: BTreeMap<TensorId, TensorInfo>, nodes: Vec<Node>) -> Self {
        let next_tensor = tensors.keys().map(|t| t.0 + 1).max().unwrap_or(0);
        let next_node = nodes.iter().map(|n| n.id.0 + 1).max().unwrap_or(0);
        Graph { tensors, nodes, next_tensor, next_node }
    }

    /// Register a new tensor.
    pub fn add_tensor(
        &mut self,
        name: impl Into<String>,
        shape: &[i64],
        dtype: DType,
        kind: TensorKind,
    ) -> TensorId {
        assert!(shape.iter().all(|&e| e >= 1), "tensor with empty dim: {shape:?}");
        let id = TensorId(self.next_tensor);
        self.next_tensor += 1;
        self.tensors.insert(
            id,
            TensorInfo { id, name: name.into(), shape: shape.to_vec(), dtype, kind },
        );
        id
    }

    /// Append a node (inputs must exist; output shape is the caller's
    /// responsibility — [`crate::ir::GraphBuilder`] always infers it).
    pub fn add_node(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<TensorId>,
        output: TensorId,
    ) -> NodeId {
        for t in &inputs {
            assert!(self.tensors.contains_key(t), "add_node: unknown input {t:?}");
        }
        assert!(self.tensors.contains_key(&output), "add_node: unknown output");
        let id = NodeId(self.next_node);
        self.next_node += 1;
        self.nodes.push(Node { id, name: name.into(), kind, inputs, output, rewritten: false });
        id
    }

    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[&id]
    }

    pub fn tensor_mut(&mut self, id: TensorId) -> &mut TensorInfo {
        self.tensors.get_mut(&id).unwrap()
    }

    pub fn tensors(&self) -> impl Iterator<Item = &TensorInfo> {
        self.tensors.values()
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> &Node {
        self.nodes.iter().find(|n| n.id == id).expect("node not found")
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes.iter_mut().find(|n| n.id == id).expect("node not found")
    }

    /// Producer node of a tensor (None for inputs/weights).
    pub fn producer(&self, t: TensorId) -> Option<&Node> {
        self.nodes.iter().find(|n| n.output == t)
    }

    /// All nodes reading a tensor.
    pub fn consumers(&self, t: TensorId) -> Vec<&Node> {
        self.nodes.iter().filter(|n| n.inputs.contains(&t)).collect()
    }

    /// Graph output tensors.
    pub fn outputs(&self) -> Vec<TensorId> {
        self.tensors
            .values()
            .filter(|t| t.kind == TensorKind::Output)
            .map(|t| t.id)
            .collect()
    }

    /// Graph input tensors (activations only, not weights).
    pub fn inputs(&self) -> Vec<TensorId> {
        self.tensors
            .values()
            .filter(|t| t.kind == TensorKind::Input)
            .map(|t| t.id)
            .collect()
    }

    /// Remove a node and (if now dead) its output tensor. Panics if the
    /// output still has consumers or is a graph output.
    pub fn remove_node(&mut self, id: NodeId) {
        let idx = self
            .nodes
            .iter()
            .position(|n| n.id == id)
            .expect("remove_node: not found");
        let out = self.nodes[idx].output;
        assert!(
            self.consumers(out).is_empty(),
            "remove_node: output {out:?} still has consumers"
        );
        assert!(
            self.tensor(out).kind != TensorKind::Output,
            "remove_node: output {out:?} is a graph output"
        );
        self.nodes.remove(idx);
        self.tensors.remove(&out);
    }

    /// Insert a node immediately before another node (preserves
    /// topological order when the new node feeds `before`). Used by the
    /// bank-mapping passes to materialize `MemCopy` nodes.
    pub fn insert_node_before(
        &mut self,
        before: NodeId,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<TensorId>,
        output: TensorId,
    ) -> NodeId {
        for t in &inputs {
            assert!(self.tensors.contains_key(t), "insert_node: unknown input {t:?}");
        }
        let pos = self
            .nodes
            .iter()
            .position(|n| n.id == before)
            .expect("insert_node_before: anchor not found");
        let id = NodeId(self.next_node);
        self.next_node += 1;
        self.nodes.insert(pos, Node { id, name: name.into(), kind, inputs, output, rewritten: false });
        id
    }

    /// Total bytes of tensors of a given kind.
    pub fn bytes_of_kind(&self, kind: TensorKind) -> i64 {
        self.tensors
            .values()
            .filter(|t| t.kind == kind)
            .map(|t| t.size_bytes())
            .sum()
    }

    /// Count nodes matching a predicate.
    pub fn count_nodes(&self, pred: impl Fn(&Node) -> bool) -> usize {
        self.nodes.iter().filter(|n| pred(n)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::UnaryFn;

    fn tiny() -> (Graph, TensorId, TensorId, NodeId) {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[1, 8], DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", &[1, 8], DType::F32, TensorKind::Output);
        let n = g.add_node("relu", OpKind::Unary(UnaryFn::Relu), vec![x], y);
        (g, x, y, n)
    }

    #[test]
    fn def_use_indexes() {
        let (g, x, y, n) = tiny();
        assert_eq!(g.producer(y).unwrap().id, n);
        assert!(g.producer(x).is_none());
        assert_eq!(g.consumers(x).len(), 1);
        assert!(g.consumers(y).is_empty());
        assert_eq!(g.inputs(), vec![x]);
        assert_eq!(g.outputs(), vec![y]);
    }

    #[test]
    fn remove_node_cleans_tensor() {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[4], DType::F32, TensorKind::Input);
        let t = g.add_tensor("t", &[4], DType::F32, TensorKind::Intermediate);
        let id = g.add_node("id", OpKind::Identity, vec![x], t);
        g.remove_node(id);
        assert_eq!(g.nodes().len(), 0);
        assert_eq!(g.tensors().count(), 1);
    }

    #[test]
    #[should_panic(expected = "still has consumers")]
    fn remove_live_node_panics() {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[4], DType::F32, TensorKind::Input);
        let t = g.add_tensor("t", &[4], DType::F32, TensorKind::Intermediate);
        let y = g.add_tensor("y", &[4], DType::F32, TensorKind::Output);
        let id = g.add_node("id", OpKind::Identity, vec![x], t);
        g.add_node("relu", OpKind::Unary(UnaryFn::Relu), vec![t], y);
        g.remove_node(id);
    }

    #[test]
    fn bytes_accounting() {
        let (g, ..) = tiny();
        assert_eq!(g.bytes_of_kind(TensorKind::Input), 32);
        assert_eq!(g.bytes_of_kind(TensorKind::Output), 32);
        assert_eq!(g.bytes_of_kind(TensorKind::Weight), 0);
    }
}
