//! Loop-nest lowering: the paper's §2 program representation.
//!
//! Every operator lowers to one or more *normalized* loop nests: the
//! iteration domain is a box `[0,e0)×…×[0,en-1)` and the body consists
//! of element-wise loads `v = t[f(i)]` and one store `t_out[f_s(i)] = v`
//! with quasi-affine access functions (`poly::AccessMap`).
//!
//! * Memory-bound operators lower to **copy nests** ([`Body::Copy`]):
//!   the loaded value feeds the store directly — exactly the
//!   `(v = t_l[f_l(i)], t_s[f_s(i)] = v)` pattern §2.1 eliminates.
//! * Compute operators lower to nests with [`Body::Compute`]; DME never
//!   removes them but *does* rewrite their loads when the tensor they
//!   read is eliminated.
//!
//! Loads are **piecewise** ([`LoadStmt::pieces`]): `pad` reads the
//! input on its interior and a synthesized zero elsewhere, and DME
//! rewrites through `concat` produce multi-source piecewise loads.

use super::graph::{Graph, Node, NodeId};
use super::op::{OpKind, PoolKind};
use super::tensor::TensorId;
use crate::poly::piecewise::Guard;
use crate::poly::{AccessMap, Expr, IterDomain};

/// One piece of a (piecewise) load: under `guards`, read
/// `tensor[map(i)]`; `tensor == None` means the piece evaluates to a
/// constant zero (pad border). `oob_zero` marks hardware-padded compute
/// reads (conv with implicit padding) whose map may step outside the
/// tensor box — such reads return 0 and are exempt from bounds
/// verification.
#[derive(Clone, Debug)]
pub struct Access {
    pub guards: Vec<Guard>,
    pub tensor: Option<TensorId>,
    pub map: AccessMap,
    pub oob_zero: bool,
}

impl Access {
    pub fn total(tensor: TensorId, map: AccessMap) -> Self {
        Access { guards: vec![], tensor: Some(tensor), map, oob_zero: false }
    }

    pub fn holds(&self, p: &[i64]) -> bool {
        self.guards.iter().all(|g| g.holds(p))
    }
}

/// A load statement: disjoint pieces covering the loop domain.
#[derive(Clone, Debug)]
pub struct LoadStmt {
    pub pieces: Vec<Access>,
}

impl LoadStmt {
    pub fn total(tensor: TensorId, map: AccessMap) -> Self {
        LoadStmt { pieces: vec![Access::total(tensor, map)] }
    }

    /// The single source tensor if this load is non-piecewise.
    pub fn single(&self) -> Option<(TensorId, &AccessMap)> {
        match &self.pieces[..] {
            [a] if a.guards.is_empty() => a.tensor.map(|t| (t, &a.map)),
            _ => None,
        }
    }

    /// All tensors this load may read.
    pub fn tensors(&self) -> Vec<TensorId> {
        let mut ts: Vec<TensorId> = self.pieces.iter().filter_map(|p| p.tensor).collect();
        ts.sort();
        ts.dedup();
        ts
    }

    /// Resolve the piece applying at a point (tests / replay).
    pub fn at(&self, p: &[i64]) -> Option<(Option<TensorId>, Vec<i64>)> {
        self.pieces
            .iter()
            .find(|piece| piece.holds(p))
            .map(|piece| (piece.tensor, piece.map.apply(p)))
    }
}

/// The store statement: `tensor[map(i)] = v`.
#[derive(Clone, Debug)]
pub struct StoreStmt {
    pub tensor: TensorId,
    pub map: AccessMap,
}

/// Loop-nest body.
#[derive(Clone, Debug)]
pub enum Body {
    /// Pure data movement: store(load(i)). The §2.1 DME target.
    Copy { load: LoadStmt },
    /// Opaque compute over the listed loads (matmul/conv/pool/…).
    Compute { loads: Vec<LoadStmt>, flops_per_point: i64 },
}

impl Body {
    pub fn loads(&self) -> &[LoadStmt] {
        match self {
            Body::Copy { load } => std::slice::from_ref(load),
            Body::Compute { loads, .. } => loads,
        }
    }

    pub fn loads_mut(&mut self) -> &mut [LoadStmt] {
        match self {
            Body::Copy { load } => std::slice::from_mut(load),
            Body::Compute { loads, .. } => loads,
        }
    }

    pub fn is_copy(&self) -> bool {
        matches!(self, Body::Copy { .. })
    }
}

/// Tiling provenance of a nest (attached by `crate::tile::transform`).
///
/// All tile nests strip-mined from one original nest — or from one
/// fused producer/consumer chain — share a `group`; `index` is the
/// lexicographic tile number and `count` the group's tile total. The
/// tag rides on the nest itself so spill insertion and any later
/// reordering cannot desynchronize it from the schedule; the static
/// planner uses it to detect tile-staged intermediates and the
/// pipelined simulator mode uses it to form double-buffer runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct TileTag {
    pub group: u32,
    pub index: u32,
    pub count: u32,
}

/// A normalized loop nest.
#[derive(Clone, Debug)]
pub struct LoopNest {
    /// Node this nest was lowered from.
    pub node: NodeId,
    pub name: String,
    pub domain: IterDomain,
    pub store: StoreStmt,
    pub body: Body,
    /// `Some` when this nest is one tile of a strip-mined nest.
    pub tile: Option<TileTag>,
}

impl LoopNest {
    /// Bytes moved by this nest if executed as-is (elements × loads+store).
    pub fn copied_elems(&self) -> i64 {
        self.domain.cardinality()
    }
}

/// A lowered program: the graph plus its loop nests in topological
/// order. Passes transform `nests` (DME) and `graph` (bank mapping).
#[derive(Clone, Debug)]
pub struct Program {
    pub graph: Graph,
    pub nests: Vec<LoopNest>,
}

impl Program {
    /// Lower every node of a graph.
    pub fn lower(graph: Graph) -> Program {
        let mut nests = Vec::new();
        for node in graph.nodes() {
            nests.extend(lower_node(&graph, node));
        }
        Program { graph, nests }
    }

    /// Copy nests currently in the program (DME candidates).
    pub fn copy_nests(&self) -> impl Iterator<Item = &LoopNest> {
        self.nests.iter().filter(|n| n.body.is_copy())
    }

    /// Count of load-store pairs (≡ copy nests).
    pub fn load_store_pairs(&self) -> usize {
        self.copy_nests().count()
    }

    /// All nests writing tensor `t`.
    pub fn writers(&self, t: TensorId) -> Vec<usize> {
        self.nests
            .iter()
            .enumerate()
            .filter(|(_, n)| n.store.tensor == t)
            .map(|(i, _)| i)
            .collect()
    }

    /// All nests with a load piece reading tensor `t`.
    pub fn readers(&self, t: TensorId) -> Vec<usize> {
        self.nests
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                n.body
                    .loads()
                    .iter()
                    .any(|l| l.pieces.iter().any(|p| p.tensor == Some(t)))
            })
            .map(|(i, _)| i)
            .collect()
    }
}

/// Lower one node to its loop nests.
pub fn lower_node(g: &Graph, node: &Node) -> Vec<LoopNest> {
    let out = node.output;
    let out_shape = g.tensor(out).shape.clone();
    let nd = out_shape.len();
    let ident_store = |t| StoreStmt { tensor: t, map: AccessMap::identity(nd) };
    let dom_out = IterDomain::new(&out_shape);

    match &node.kind {
        // ---------------- memory-bound: copy nests ----------------
        OpKind::Identity | OpKind::MemCopy => {
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy { load: LoadStmt::total(node.inputs[0], AccessMap::identity(nd)) },
            }]
        }
        OpKind::Transpose { perm } => {
            // out[i] = in[perm applied]: out axis k comes from in axis perm[k],
            // so reading uses map placing loop dim k at input dim perm[k]:
            // in_idx[d] = i[pos of d in perm]
            let mut exprs = vec![Expr::cst(0); nd];
            for (k, &p) in perm.iter().enumerate() {
                exprs[p] = Expr::dim(k);
            }
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy {
                    load: LoadStmt::total(node.inputs[0], AccessMap::new(nd, exprs)),
                },
            }]
        }
        OpKind::Reshape { .. } => {
            // row-major: linearize output index, delinearize by input shape
            let in_shape = &g.tensor(node.inputs[0]).shape;
            let lin = linearize_expr(&out_shape);
            let exprs = delinearize_exprs(lin, in_shape);
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy {
                    load: LoadStmt::total(node.inputs[0], AccessMap::new(nd, exprs)),
                },
            }]
        }
        OpKind::Tile { .. } => {
            let in_shape = &g.tensor(node.inputs[0]).shape;
            let exprs = (0..nd)
                .map(|d| Expr::dim(d).modulo(in_shape[d]))
                .collect();
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy {
                    load: LoadStmt::total(node.inputs[0], AccessMap::new(nd, exprs)),
                },
            }]
        }
        OpKind::Repeat { axis, n } => {
            let exprs = (0..nd)
                .map(|d| {
                    if d == *axis {
                        Expr::dim(d).floordiv(*n)
                    } else {
                        Expr::dim(d)
                    }
                })
                .collect();
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy {
                    load: LoadStmt::total(node.inputs[0], AccessMap::new(nd, exprs)),
                },
            }]
        }
        OpKind::StridedSlice { begin, stride, .. } => {
            let exprs = (0..nd)
                .map(|d| Expr::dim(d).scale(stride[d]).add(Expr::cst(begin[d])))
                .collect();
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy {
                    load: LoadStmt::total(node.inputs[0], AccessMap::new(nd, exprs)),
                },
            }]
        }
        OpKind::Concat { axis } => {
            // one source-indexed nest per input: store through an offset map
            let mut nests = Vec::with_capacity(node.inputs.len());
            let mut offset = 0i64;
            for (k, &inp) in node.inputs.iter().enumerate() {
                let in_shape = g.tensor(inp).shape.clone();
                let store_exprs = (0..nd)
                    .map(|d| {
                        if d == *axis {
                            Expr::dim(d).add(Expr::cst(offset))
                        } else {
                            Expr::dim(d)
                        }
                    })
                    .collect();
                nests.push(LoopNest {
                    node: node.id,
                    tile: None,
                    name: format!("{}#{k}", node.name),
                    domain: IterDomain::new(&in_shape),
                    store: StoreStmt { tensor: out, map: AccessMap::new(nd, store_exprs) },
                    body: Body::Copy {
                        load: LoadStmt::total(inp, AccessMap::identity(nd)),
                    },
                });
                offset += in_shape[*axis];
            }
            nests
        }
        OpKind::Pad { lo, .. } => {
            // destination-indexed with a piecewise load: the interior
            // reads in[i - lo]; the border pieces synthesize zero.
            let in_shape = g.tensor(node.inputs[0]).shape.clone();
            let interior_map = AccessMap::new(
                nd,
                (0..nd)
                    .map(|d| Expr::dim(d).add(Expr::cst(-lo[d])))
                    .collect(),
            );
            let interior_guards: Vec<Guard> = (0..nd)
                .filter(|&d| lo[d] != 0 || in_shape[d] != out_shape[d] - lo[d])
                .map(|d| Guard { dim: d, lo: lo[d], hi: lo[d] + in_shape[d] })
                .collect();
            let mut pieces = vec![Access {
                guards: interior_guards.clone(),
                tensor: Some(node.inputs[0]),
                map: interior_map,
                oob_zero: false,
            }];
            // border = complement of the interior box, decomposed into
            // disjoint slabs: for each guarded dim d, the parts below and
            // above it (with earlier guarded dims constrained to interior).
            let mut prefix: Vec<Guard> = vec![];
            for gd in &interior_guards {
                let d = gd.dim;
                if gd.lo > 0 {
                    let mut gs = prefix.clone();
                    gs.push(Guard { dim: d, lo: 0, hi: gd.lo });
                    pieces.push(zero_piece(gs, nd));
                }
                if gd.hi < out_shape[d] {
                    let mut gs = prefix.clone();
                    gs.push(Guard { dim: d, lo: gd.hi, hi: out_shape[d] });
                    pieces.push(zero_piece(gs, nd));
                }
                prefix.push(*gd);
            }
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Copy { load: LoadStmt { pieces } },
            }]
        }

        // ---------------- compute-bound ----------------
        OpKind::Conv2d { stride, pad } => {
            let w_shape = g.tensor(node.inputs[1]).shape.clone();
            let (ci, kh, kw) = (w_shape[1], w_shape[2], w_shape[3]);
            // domain: n, co, oh, ow, ci, kh, kw
            let dom = IterDomain::new(&[out_shape[0], out_shape[1], out_shape[2], out_shape[3], ci, kh, kw]);
            let x_map = AccessMap::new(
                7,
                vec![
                    Expr::dim(0),
                    Expr::dim(4),
                    Expr::dim(2).scale(*stride).add(Expr::dim(5)).add(Expr::cst(-pad)),
                    Expr::dim(3).scale(*stride).add(Expr::dim(6)).add(Expr::cst(-pad)),
                ],
            );
            let w_map = AccessMap::new(
                7,
                vec![Expr::dim(1), Expr::dim(4), Expr::dim(5), Expr::dim(6)],
            );
            let store_map = AccessMap::new(
                7,
                vec![Expr::dim(0), Expr::dim(1), Expr::dim(2), Expr::dim(3)],
            );
            let mut x_load = LoadStmt::total(node.inputs[0], x_map);
            x_load.pieces[0].oob_zero = *pad > 0;
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom,
                store: StoreStmt { tensor: out, map: store_map },
                body: Body::Compute {
                    loads: vec![x_load, LoadStmt::total(node.inputs[1], w_map)],
                    flops_per_point: 2,
                },
            }]
        }
        OpKind::DepthwiseConv2d { stride, pad } => {
            let w_shape = g.tensor(node.inputs[1]).shape.clone();
            let (kh, kw) = (w_shape[2], w_shape[3]);
            let dom = IterDomain::new(&[out_shape[0], out_shape[1], out_shape[2], out_shape[3], kh, kw]);
            let x_map = AccessMap::new(
                6,
                vec![
                    Expr::dim(0),
                    Expr::dim(1),
                    Expr::dim(2).scale(*stride).add(Expr::dim(4)).add(Expr::cst(-pad)),
                    Expr::dim(3).scale(*stride).add(Expr::dim(5)).add(Expr::cst(-pad)),
                ],
            );
            let w_map = AccessMap::new(
                6,
                vec![Expr::dim(1), Expr::cst(0), Expr::dim(4), Expr::dim(5)],
            );
            let store_map = AccessMap::new(
                6,
                vec![Expr::dim(0), Expr::dim(1), Expr::dim(2), Expr::dim(3)],
            );
            let mut x_load = LoadStmt::total(node.inputs[0], x_map);
            x_load.pieces[0].oob_zero = *pad > 0;
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom,
                store: StoreStmt { tensor: out, map: store_map },
                body: Body::Compute {
                    loads: vec![x_load, LoadStmt::total(node.inputs[1], w_map)],
                    flops_per_point: 2,
                },
            }]
        }
        OpKind::MatMul => {
            let k = g.tensor(node.inputs[0]).shape[1];
            let dom = IterDomain::new(&[out_shape[0], out_shape[1], k]);
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom,
                store: StoreStmt {
                    tensor: out,
                    map: AccessMap::new(3, vec![Expr::dim(0), Expr::dim(1)]),
                },
                body: Body::Compute {
                    loads: vec![
                        LoadStmt::total(
                            node.inputs[0],
                            AccessMap::new(3, vec![Expr::dim(0), Expr::dim(2)]),
                        ),
                        LoadStmt::total(
                            node.inputs[1],
                            AccessMap::new(3, vec![Expr::dim(2), Expr::dim(1)]),
                        ),
                    ],
                    flops_per_point: 2,
                },
            }]
        }
        OpKind::Pool { window, stride, kind } => {
            let dom = IterDomain::new(&[out_shape[0], out_shape[1], out_shape[2], out_shape[3], *window, *window]);
            let x_map = AccessMap::new(
                6,
                vec![
                    Expr::dim(0),
                    Expr::dim(1),
                    Expr::dim(2).scale(*stride).add(Expr::dim(4)),
                    Expr::dim(3).scale(*stride).add(Expr::dim(5)),
                ],
            );
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom,
                store: StoreStmt {
                    tensor: out,
                    map: AccessMap::new(
                        6,
                        vec![Expr::dim(0), Expr::dim(1), Expr::dim(2), Expr::dim(3)],
                    ),
                },
                body: Body::Compute {
                    loads: vec![LoadStmt::total(node.inputs[0], x_map)],
                    flops_per_point: if *kind == PoolKind::Avg { 2 } else { 1 },
                },
            }]
        }
        OpKind::GlobalAvgPool => {
            let in_shape = g.tensor(node.inputs[0]).shape.clone();
            let dom = IterDomain::new(&in_shape);
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom,
                store: StoreStmt {
                    tensor: out,
                    map: AccessMap::new(
                        4,
                        vec![Expr::dim(0), Expr::dim(1), Expr::cst(0), Expr::cst(0)],
                    ),
                },
                body: Body::Compute {
                    loads: vec![LoadStmt::total(node.inputs[0], AccessMap::identity(4))],
                    flops_per_point: 1,
                },
            }]
        }
        OpKind::Conv1d { dilation } => {
            let w_shape = g.tensor(node.inputs[1]).shape.clone();
            let (ci, kk) = (w_shape[1], w_shape[2]);
            // domain: n, co, t, ci, k
            let dom = IterDomain::new(&[out_shape[0], out_shape[1], out_shape[2], ci, kk]);
            let x_map = AccessMap::new(
                5,
                vec![
                    Expr::dim(0),
                    Expr::dim(3),
                    Expr::dim(2).add(Expr::dim(4).scale(*dilation)),
                ],
            );
            let w_map = AccessMap::new(5, vec![Expr::dim(1), Expr::dim(3), Expr::dim(4)]);
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom,
                store: StoreStmt {
                    tensor: out,
                    map: AccessMap::new(5, vec![Expr::dim(0), Expr::dim(1), Expr::dim(2)]),
                },
                body: Body::Compute {
                    loads: vec![
                        LoadStmt::total(node.inputs[0], x_map),
                        LoadStmt::total(node.inputs[1], w_map),
                    ],
                    flops_per_point: 2,
                },
            }]
        }
        OpKind::Unary(_) | OpKind::Softmax => {
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Compute {
                    loads: vec![LoadStmt::total(node.inputs[0], AccessMap::identity(nd))],
                    flops_per_point: if matches!(node.kind, OpKind::Softmax) { 6 } else { 1 },
                },
            }]
        }
        OpKind::Binary(_) => {
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Compute {
                    loads: vec![
                        LoadStmt::total(node.inputs[0], AccessMap::identity(nd)),
                        LoadStmt::total(node.inputs[1], AccessMap::identity(nd)),
                    ],
                    flops_per_point: 1,
                },
            }]
        }
        OpKind::BatchNorm => {
            let c_map = AccessMap::new(nd, vec![Expr::dim(1)]);
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Compute {
                    loads: vec![
                        LoadStmt::total(node.inputs[0], AccessMap::identity(nd)),
                        LoadStmt::total(node.inputs[1], c_map.clone()),
                        LoadStmt::total(node.inputs[2], c_map),
                    ],
                    flops_per_point: 2,
                },
            }]
        }
        OpKind::BiasAdd => {
            let b_map = AccessMap::new(nd, vec![Expr::dim(nd - 1)]);
            vec![LoopNest {
                node: node.id,
                tile: None,
                name: node.name.clone(),
                domain: dom_out,
                store: ident_store(out),
                body: Body::Compute {
                    loads: vec![
                        LoadStmt::total(node.inputs[0], AccessMap::identity(nd)),
                        LoadStmt::total(node.inputs[1], b_map),
                    ],
                    flops_per_point: 1,
                },
            }]
        }
    }
}

fn zero_piece(guards: Vec<Guard>, nd: usize) -> Access {
    Access { guards, tensor: None, map: AccessMap::identity(nd), oob_zero: false }
}

/// Row-major linearization expression of an index vector of `shape`.
fn linearize_expr(shape: &[i64]) -> Expr {
    let mut e = Expr::cst(0);
    for (d, &s) in shape.iter().enumerate() {
        e = e.scale(s).add(Expr::dim(d));
    }
    e
}

/// Delinearize a flat expression into indices of `shape` (row-major).
fn delinearize_exprs(lin: Expr, shape: &[i64]) -> Vec<Expr> {
    let mut exprs = vec![Expr::cst(0); shape.len()];
    let mut stride = 1i64;
    for d in (0..shape.len()).rev() {
        let e = lin.clone().floordiv(stride).modulo(shape[d]);
        exprs[d] = e;
        stride *= shape[d];
    }
    // outermost dim needs no mod (value already < shape[0]) but keeping
    // it is harmless; simplified_in removes it when provable.
    exprs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{BinaryFn, UnaryFn};
    use crate::ir::tensor::{DType, TensorKind};

    fn g_with(shape: &[i64]) -> (Graph, TensorId) {
        let mut g = Graph::new();
        let x = g.add_tensor("x", shape, DType::F32, TensorKind::Input);
        (g, x)
    }

    /// Execute a copy nest interpretively: returns out[idx] = source idx.
    fn run_copy(_g: &Graph, nest: &LoopNest) -> Vec<(Vec<i64>, Option<TensorId>, Vec<i64>)> {
        let Body::Copy { load } = &nest.body else { panic!("not a copy") };
        nest.domain
            .points()
            .map(|p| {
                let (t, src) = load.at(&p).expect("load not covered");
                (nest.store.map.apply(&p), t, src)
            })
            .collect()
    }

    #[test]
    fn transpose_lowering_semantics() {
        let (mut g, x) = g_with(&[2, 3, 4]);
        let y = g.add_tensor("y", &[4, 2, 3], DType::F32, TensorKind::Output);
        let n = g.add_node(
            "t",
            OpKind::Transpose { perm: vec![2, 0, 1] },
            vec![x],
            y,
        );
        let nests = lower_node(&g, g.node(n));
        assert_eq!(nests.len(), 1);
        for (out_idx, t, src_idx) in run_copy(&g, &nests[0]) {
            assert_eq!(t, Some(x));
            // out[a,b,c] = in[b,c,a]
            assert_eq!(src_idx, vec![out_idx[1], out_idx[2], out_idx[0]]);
        }
    }

    #[test]
    fn reshape_lowering_row_major() {
        let (mut g, x) = g_with(&[2, 6]);
        let y = g.add_tensor("y", &[3, 4], DType::F32, TensorKind::Output);
        let n = g.add_node("r", OpKind::Reshape { shape: vec![3, 4] }, vec![x], y);
        let nests = lower_node(&g, g.node(n));
        let in_dom = IterDomain::new(&[2, 6]);
        let out_dom = IterDomain::new(&[3, 4]);
        for (out_idx, t, src_idx) in run_copy(&g, &nests[0]) {
            assert_eq!(t, Some(x));
            assert_eq!(in_dom.linearize(&src_idx), out_dom.linearize(&out_idx));
        }
    }

    #[test]
    fn tile_and_repeat_semantics() {
        let (mut g, x) = g_with(&[3]);
        let y = g.add_tensor("y", &[6], DType::F32, TensorKind::Output);
        let n = g.add_node("tile", OpKind::Tile { reps: vec![2] }, vec![x], y);
        let nests = lower_node(&g, g.node(n));
        for (out_idx, _, src_idx) in run_copy(&g, &nests[0]) {
            assert_eq!(src_idx[0], out_idx[0] % 3);
        }

        let (mut g2, x2) = g_with(&[3]);
        let y2 = g2.add_tensor("y", &[6], DType::F32, TensorKind::Output);
        let n2 = g2.add_node("rep", OpKind::Repeat { axis: 0, n: 2 }, vec![x2], y2);
        let nests2 = lower_node(&g2, g2.node(n2));
        for (out_idx, _, src_idx) in run_copy(&g2, &nests2[0]) {
            assert_eq!(src_idx[0], out_idx[0] / 2);
        }
    }

    #[test]
    fn strided_slice_semantics() {
        let (mut g, x) = g_with(&[10]);
        let y = g.add_tensor("y", &[4], DType::F32, TensorKind::Output);
        let n = g.add_node(
            "ss",
            OpKind::StridedSlice { begin: vec![2], end: vec![10], stride: vec![2] },
            vec![x],
            y,
        );
        let nests = lower_node(&g, g.node(n));
        for (out_idx, _, src_idx) in run_copy(&g, &nests[0]) {
            assert_eq!(src_idx[0], 2 + 2 * out_idx[0]);
        }
    }

    #[test]
    fn concat_offset_stores() {
        let mut g = Graph::new();
        let a = g.add_tensor("a", &[2, 3], DType::F32, TensorKind::Input);
        let b = g.add_tensor("b", &[2, 5], DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", &[2, 8], DType::F32, TensorKind::Output);
        let n = g.add_node("c", OpKind::Concat { axis: 1 }, vec![a, b], y);
        let nests = lower_node(&g, g.node(n));
        assert_eq!(nests.len(), 2);
        // every output element written exactly once
        let mut written = std::collections::HashSet::new();
        for nest in &nests {
            for (out_idx, t, src_idx) in run_copy(&g, nest) {
                assert!(written.insert(out_idx.clone()), "double write {out_idx:?}");
                if t == Some(a) {
                    assert_eq!(out_idx, src_idx);
                } else {
                    assert_eq!(out_idx[1], src_idx[1] + 3);
                }
            }
        }
        assert_eq!(written.len(), 16);
    }

    #[test]
    fn pad_piecewise_covers_domain() {
        let (mut g, x) = g_with(&[2, 3]);
        let y = g.add_tensor("y", &[4, 7], DType::F32, TensorKind::Output);
        let n = g.add_node(
            "p",
            OpKind::Pad { lo: vec![1, 2], hi: vec![1, 2] },
            vec![x],
            y,
        );
        let nests = lower_node(&g, g.node(n));
        let Body::Copy { load } = &nests[0].body else { panic!() };
        let mut zeros = 0;
        let mut reads = 0;
        for p in nests[0].domain.points() {
            let covering: Vec<_> = load.pieces.iter().filter(|a| a.holds(&p)).collect();
            assert_eq!(covering.len(), 1, "point {p:?} covered {} times", covering.len());
            match covering[0].tensor {
                Some(t) => {
                    assert_eq!(t, x);
                    let src = covering[0].map.apply(&p);
                    assert_eq!(src, vec![p[0] - 1, p[1] - 2]);
                    reads += 1;
                }
                None => zeros += 1,
            }
        }
        assert_eq!(reads, 6);
        assert_eq!(zeros, 28 - 6);
    }

    #[test]
    fn conv2d_lowering_accesses() {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[1, 2, 5, 5], DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", &[4, 2, 3, 3], DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", &[1, 4, 5, 5], DType::F32, TensorKind::Output);
        let n = g.add_node("cv", OpKind::Conv2d { stride: 1, pad: 1 }, vec![x, w], y);
        let nests = lower_node(&g, g.node(n));
        assert_eq!(nests.len(), 1);
        let nest = &nests[0];
        assert_eq!(nest.domain.extents(), &[1, 4, 5, 5, 2, 3, 3]);
        let Body::Compute { loads, .. } = &nest.body else { panic!() };
        assert!(loads[0].pieces[0].oob_zero);
        // spot-check x access: p = (n,co,oh,ow,ci,kh,kw)
        let p = vec![0, 1, 2, 3, 1, 0, 2];
        let (t, idx) = loads[0].at(&p).unwrap();
        assert_eq!(t, Some(x));
        assert_eq!(idx, vec![0, 1, 2 + 0 - 1, 3 + 2 - 1]);
        let (tw, widx) = loads[1].at(&p).unwrap();
        assert_eq!(tw, Some(w));
        assert_eq!(widx, vec![1, 1, 0, 2]);
        assert_eq!(nest.store.map.apply(&p), vec![0, 1, 2, 3]);
    }

    #[test]
    fn conv1d_dilated_access() {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[1, 2, 12], DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", &[3, 2, 2], DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", &[1, 3, 8], DType::F32, TensorKind::Output);
        let n = g.add_node("cv1", OpKind::Conv1d { dilation: 4 }, vec![x, w], y);
        let nests = lower_node(&g, g.node(n));
        let Body::Compute { loads, .. } = &nests[0].body else { panic!() };
        // p = (n, co, t, ci, k): x[t + 4k]
        let (_, idx) = loads[0].at(&[0, 2, 3, 1, 1]).unwrap();
        assert_eq!(idx, vec![0, 1, 7]);
    }

    #[test]
    fn program_lowering_and_indexes() {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[4, 4], DType::F32, TensorKind::Input);
        let t = g.add_tensor("t", &[4, 4], DType::F32, TensorKind::Intermediate);
        let y = g.add_tensor("y", &[4, 4], DType::F32, TensorKind::Output);
        g.add_node("tr", OpKind::Transpose { perm: vec![1, 0] }, vec![x], t);
        g.add_node("relu", OpKind::Unary(UnaryFn::Relu), vec![t], y);
        let prog = Program::lower(g);
        assert_eq!(prog.nests.len(), 2);
        assert_eq!(prog.load_store_pairs(), 1);
        let tid = t;
        assert_eq!(prog.writers(tid).len(), 1);
        assert_eq!(prog.readers(tid).len(), 1);
        assert_eq!(prog.readers(x).len(), 1);
    }

    #[test]
    fn binary_loads_two_tensors() {
        let mut g = Graph::new();
        let a = g.add_tensor("a", &[4], DType::F32, TensorKind::Input);
        let b = g.add_tensor("b", &[4], DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", &[4], DType::F32, TensorKind::Output);
        let n = g.add_node("add", OpKind::Binary(BinaryFn::Add), vec![a, b], y);
        let nests = lower_node(&g, g.node(n));
        let Body::Compute { loads, .. } = &nests[0].body else { panic!() };
        assert_eq!(loads.len(), 2);
        assert_eq!(loads[0].single().unwrap().0, a);
        assert_eq!(loads[1].single().unwrap().0, b);
    }
}
