//! Tensor metadata: identity, shape, element type, role.

use std::fmt;

/// Stable tensor identity within one [`crate::ir::Graph`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

impl fmt::Debug for TensorId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Element types supported by the accelerator model.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DType {
    F32,
    BF16,
    F16,
    I32,
    I8,
}

impl DType {
    /// Bytes per element.
    pub fn size_bytes(self) -> i64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
            DType::I8 => 1,
        }
    }
}

/// Role of a tensor in the model graph.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum TensorKind {
    /// External model input (activations fed at inference time).
    Input,
    /// Constant parameter resident in DRAM (weights, folded BN scales).
    Weight,
    /// Produced and consumed inside the graph.
    Intermediate,
    /// External model output; never eliminable by DME.
    Output,
}

/// Full tensor record.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub id: TensorId,
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: DType,
    pub kind: TensorKind,
}

impl TensorInfo {
    /// Number of elements.
    pub fn numel(&self) -> i64 {
        self.shape.iter().product()
    }

    /// Total bytes.
    pub fn size_bytes(&self) -> i64 {
        self.numel() * self.dtype.size_bytes()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        let t = TensorInfo {
            id: TensorId(0),
            name: "x".into(),
            shape: vec![1, 64, 56, 56],
            dtype: DType::F32,
            kind: TensorKind::Intermediate,
        };
        assert_eq!(t.numel(), 64 * 56 * 56);
        assert_eq!(t.size_bytes(), 64 * 56 * 56 * 4);
        assert_eq!(t.ndim(), 4);
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::I8.size_bytes(), 1);
        assert_eq!(DType::I32.size_bytes(), 4);
    }
}
