//! Structural verification of graphs and lowered programs.
//!
//! Run by tests and by the pass manager between passes: a pass that
//! produces an inconsistent program is a bug, and catching it at the
//! pass boundary localizes the fault.

use super::graph::Graph;
use super::loopnest::Program;
use super::tensor::TensorKind;
use std::collections::HashSet;

/// A verification failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyError(pub String);

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "verify: {}", self.0)
    }
}

impl std::error::Error for VerifyError {}

/// Verify graph-level invariants:
/// * SSA: every tensor has at most one producing node (`concat`'s
///   multiple nests still belong to a single node);
/// * topological node order (inputs produced before use);
/// * every intermediate tensor has a producer and at least one consumer;
/// * outputs have producers; inputs/weights have none;
/// * all shapes agree with `OpKind::infer_shape`.
pub fn verify_graph(g: &Graph) -> Result<(), VerifyError> {
    // one-pass consumer counts (§Perf: replaces per-tensor
    // `consumers()` scans, which made verification O(tensors × nodes))
    let mut consumed: HashSet<crate::ir::TensorId> = HashSet::new();
    for node in g.nodes() {
        consumed.extend(node.inputs.iter().copied());
    }
    let mut produced = HashSet::new();
    for node in g.nodes() {
        for inp in &node.inputs {
            let info = g.tensor(*inp);
            match info.kind {
                TensorKind::Input | TensorKind::Weight => {}
                _ => {
                    if !produced.contains(inp) {
                        return Err(VerifyError(format!(
                            "node {} uses {:?} before production (topo order broken)",
                            node.name, inp
                        )));
                    }
                }
            }
        }
        if !produced.insert(node.output) {
            return Err(VerifyError(format!(
                "tensor {:?} produced by more than one node (SSA broken at {})",
                node.output, node.name
            )));
        }
        // shape check — skipped for DME-rewritten nodes, whose OpKind no
        // longer describes their (composed) access pattern
        if !node.rewritten {
            let shapes: Vec<Vec<i64>> = node
                .inputs
                .iter()
                .map(|t| g.tensor(*t).shape.clone())
                .collect();
            let refs: Vec<&[i64]> = shapes.iter().map(|s| s.as_slice()).collect();
            let inferred = node
                .kind
                .infer_shape(&refs)
                .map_err(|e| VerifyError(format!("node {}: {e}", node.name)))?;
            if inferred != g.tensor(node.output).shape {
                return Err(VerifyError(format!(
                    "node {}: output shape {:?} != inferred {:?}",
                    node.name,
                    g.tensor(node.output).shape,
                    inferred
                )));
            }
        }
    }
    for t in g.tensors() {
        match t.kind {
            TensorKind::Input | TensorKind::Weight => {
                if produced.contains(&t.id) {
                    return Err(VerifyError(format!(
                        "input/weight {:?} has a producer",
                        t.id
                    )));
                }
            }
            TensorKind::Intermediate => {
                if !produced.contains(&t.id) {
                    return Err(VerifyError(format!(
                        "intermediate {:?} ({}) has no producer",
                        t.id, t.name
                    )));
                }
                if !consumed.contains(&t.id) {
                    return Err(VerifyError(format!(
                        "intermediate {:?} ({}) is dead (no consumers)",
                        t.id, t.name
                    )));
                }
            }
            TensorKind::Output => {
                if !produced.contains(&t.id) {
                    return Err(VerifyError(format!("output {:?} has no producer", t.id)));
                }
            }
        }
    }
    Ok(())
}

/// Verify program-level invariants on the lowered nests:
/// * every nest's store tensor exists; its map arity matches the domain
///   and its image stays inside the tensor box;
/// * every load piece's map arity matches; in-bounds unless `oob_zero`;
/// * copy-nest load pieces cover the domain disjointly;
/// * every tensor read by a nest is a graph input/weight or written by
///   an earlier nest (schedule order).
pub fn verify_program(p: &Program) -> Result<(), VerifyError> {
    let g = &p.graph;
    let mut written: HashSet<_> = g
        .tensors()
        .filter(|t| matches!(t.kind, TensorKind::Input | TensorKind::Weight))
        .map(|t| t.id)
        .collect();

    for nest in &p.nests {
        let dom = &nest.domain;
        // store checks
        let out_info = g.tensor(nest.store.tensor);
        if nest.store.map.in_dims() != dom.ndim() {
            return Err(VerifyError(format!(
                "nest {}: store arity {} != domain {}",
                nest.name,
                nest.store.map.in_dims(),
                dom.ndim()
            )));
        }
        if nest.store.map.out_dims() != out_info.ndim() {
            return Err(VerifyError(format!(
                "nest {}: store rank {} != tensor rank {}",
                nest.name,
                nest.store.map.out_dims(),
                out_info.ndim()
            )));
        }
        if !nest.store.map.image_within(dom, &out_info.shape) {
            return Err(VerifyError(format!(
                "nest {}: store image escapes {:?}",
                nest.name, out_info.shape
            )));
        }
        // load checks
        for load in nest.body.loads() {
            if load.pieces.is_empty() {
                return Err(VerifyError(format!("nest {}: empty load", nest.name)));
            }
            for piece in &load.pieces {
                if piece.map.in_dims() != dom.ndim() {
                    return Err(VerifyError(format!(
                        "nest {}: load arity mismatch",
                        nest.name
                    )));
                }
                if let Some(t) = piece.tensor {
                    if !written.contains(&t) {
                        return Err(VerifyError(format!(
                            "nest {}: reads {:?} before any writer",
                            nest.name, t
                        )));
                    }
                    let t_info = g.tensor(t);
                    if piece.map.out_dims() != t_info.ndim() {
                        return Err(VerifyError(format!(
                            "nest {}: load rank mismatch on {:?}",
                            nest.name, t
                        )));
                    }
                    if !piece.oob_zero
                        && piece.guards.is_empty()
                        && !piece.map.image_within(dom, &t_info.shape)
                    {
                        return Err(VerifyError(format!(
                            "nest {}: load image escapes {:?} {:?}",
                            nest.name, t, t_info.shape
                        )));
                    }
                }
            }
            // piecewise coverage (sampled for big domains)
            if load.pieces.len() > 1 || !load.pieces[0].guards.is_empty() {
                let pts: Vec<Vec<i64>> = if dom.cardinality() <= 2048 {
                    dom.points().collect()
                } else {
                    dom.sample(256, 0xdead_beef)
                };
                for pt in &pts {
                    let n = load.pieces.iter().filter(|a| a.holds(pt)).count();
                    if n != 1 {
                        return Err(VerifyError(format!(
                            "nest {}: load pieces cover {pt:?} {n} times",
                            nest.name
                        )));
                    }
                }
            }
        }
        written.insert(nest.store.tensor);
    }

    // every output tensor must be written by some nest
    for out in g.outputs() {
        if !written.contains(&out) {
            return Err(VerifyError(format!("output {out:?} never written")));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::op::OpKind;
    use crate::ir::tensor::DType;

    fn sample_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 8, 8]);
        let w = b.weight("w", &[8, 4, 3, 3]);
        let c = b.conv2d("conv", x, w, 1, 1);
        let t = b.transpose("tr", c, &[0, 2, 3, 1]);
        let r = b.reshape("rs", t, &[1, 64, 8]);
        b.mark_output(r);
        b.finish()
    }

    #[test]
    fn good_graph_passes() {
        let g = sample_graph();
        verify_graph(&g).unwrap();
        let p = Program::lower(g);
        verify_program(&p).unwrap();
    }

    #[test]
    fn detects_bad_shape() {
        let mut g = sample_graph();
        // corrupt a shape
        let out = g.outputs()[0];
        g.tensor_mut(out).shape = vec![1, 64, 9];
        assert!(verify_graph(&g).is_err());
    }

    #[test]
    fn detects_dead_intermediate() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4]);
        let dead = b.identity("dead", x);
        let live = b.identity("live", x);
        b.mark_output(live);
        let g = b.finish();
        let err = verify_graph(&g).unwrap_err();
        assert!(err.0.contains("dead"), "{err}");
        let _ = dead;
    }

    #[test]
    fn detects_out_of_order_reads() {
        // hand-build a program whose nest order violates def-before-use
        let g = sample_graph();
        let mut p = Program::lower(g);
        p.nests.swap(0, 2);
        assert!(verify_program(&p).is_err());
    }

    #[test]
    fn pad_and_concat_programs_verify() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 3, 10]);
        let p1 = b.pad("pad", x, &[0, 0, 2], &[0, 0, 0]);
        let s = b.split("sp", p1, 1, 3);
        let c = b.concat("cat", &s, 2);
        b.mark_output(c);
        let g = b.finish();
        verify_graph(&g).unwrap();
        verify_program(&Program::lower(g)).unwrap();
    }

    #[test]
    fn ssa_violation_detected() {
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[4], DType::F32, crate::ir::TensorKind::Input);
        let y = g.add_tensor("y", &[4], DType::F32, crate::ir::TensorKind::Output);
        g.add_node("a", OpKind::Identity, vec![x], y);
        g.add_node("b", OpKind::Identity, vec![x], y);
        assert!(verify_graph(&g).is_err());
    }
}
