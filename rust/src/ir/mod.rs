//! Tensor-operator IR.
//!
//! The compiler front end of the reproduction: a DL model is a directed
//! graph of operator [`Node`]s over SSA [`TensorInfo`] values
//! ([`graph::Graph`]), and every operator lowers to one or more
//! normalized affine [`loopnest::LoopNest`]s with explicit load/store
//! statements — the paper's §2 program representation on which both
//! passes operate.
//!
//! Conventions:
//! * Feature maps are NCHW; weights are `[Cout, Cin, Kh, Kw]`.
//! * Tensors are SSA: written only by their producing node (a node may
//!   lower to several nests writing disjoint regions, e.g. `concat`).
//! * Loop nests are destination-indexed where natural (`transpose`,
//!   `slice`, `tile`, … iterate the output box with an identity store)
//!   and source-indexed for scatter ops (`concat`, `pad` iterate each
//!   input box and store through an offset map) — this is what makes
//!   the paper's store-reversal step (`f_s'`) non-trivial.

pub mod builder;
pub mod graph;
pub mod loopnest;
pub mod op;
pub mod serde;
pub mod tensor;
pub mod verify;

pub use builder::GraphBuilder;
pub use graph::{Graph, Node, NodeId};
pub use loopnest::{Access, Body, LoopNest, Program, StoreStmt, TileTag};
pub use op::OpKind;
pub use tensor::{DType, TensorId, TensorInfo, TensorKind};
