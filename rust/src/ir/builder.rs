//! Fluent graph construction with shape inference.
//!
//! Model builders (`models/*`) use this API; it guarantees topological
//! insertion order, infers every output shape through
//! [`OpKind::infer_shape`], and names intermediate tensors after the
//! producing node.

use super::graph::{Graph, NodeId};
use super::op::{BinaryFn, OpKind, PoolKind, UnaryFn};
use super::tensor::{DType, TensorId, TensorKind};

/// Builder over an owned [`Graph`].
pub struct GraphBuilder {
    g: Graph,
    default_dtype: DType,
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder { g: Graph::new(), default_dtype: DType::F32 }
    }

    pub fn with_dtype(dtype: DType) -> Self {
        GraphBuilder { g: Graph::new(), default_dtype: dtype }
    }

    /// Declare a model input.
    pub fn input(&mut self, name: &str, shape: &[i64]) -> TensorId {
        self.g.add_tensor(name, shape, self.default_dtype, TensorKind::Input)
    }

    /// Declare a weight/constant.
    pub fn weight(&mut self, name: &str, shape: &[i64]) -> TensorId {
        self.g.add_tensor(name, shape, self.default_dtype, TensorKind::Weight)
    }

    /// Apply an operator; infers the output shape.
    pub fn apply(&mut self, name: &str, kind: OpKind, inputs: &[TensorId]) -> TensorId {
        let shapes: Vec<Vec<i64>> = inputs
            .iter()
            .map(|t| self.g.tensor(*t).shape.clone())
            .collect();
        let shape_refs: Vec<&[i64]> = shapes.iter().map(|s| s.as_slice()).collect();
        let out_shape = kind
            .infer_shape(&shape_refs)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let out = self.g.add_tensor(
            format!("{name}_out"),
            &out_shape,
            self.default_dtype,
            TensorKind::Intermediate,
        );
        self.g.add_node(name, kind, inputs.to_vec(), out);
        out
    }

    /// Mark a tensor as a graph output.
    pub fn mark_output(&mut self, t: TensorId) {
        self.g.tensor_mut(t).kind = TensorKind::Output;
    }

    pub fn finish(self) -> Graph {
        self.g
    }

    pub fn graph(&self) -> &Graph {
        &self.g
    }

    pub fn last_node(&self) -> Option<NodeId> {
        self.g.nodes().last().map(|n| n.id)
    }

    // ---- convenience wrappers used throughout models/ ----

    pub fn conv2d(&mut self, name: &str, x: TensorId, w: TensorId, stride: i64, pad: i64) -> TensorId {
        self.apply(name, OpKind::Conv2d { stride, pad }, &[x, w])
    }

    pub fn conv1d(&mut self, name: &str, x: TensorId, w: TensorId, dilation: i64) -> TensorId {
        self.apply(name, OpKind::Conv1d { dilation }, &[x, w])
    }

    pub fn matmul(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.apply(name, OpKind::MatMul, &[a, b])
    }

    pub fn relu(&mut self, name: &str, x: TensorId) -> TensorId {
        self.apply(name, OpKind::Unary(UnaryFn::Relu), &[x])
    }

    pub fn sigmoid(&mut self, name: &str, x: TensorId) -> TensorId {
        self.apply(name, OpKind::Unary(UnaryFn::Sigmoid), &[x])
    }

    pub fn tanh(&mut self, name: &str, x: TensorId) -> TensorId {
        self.apply(name, OpKind::Unary(UnaryFn::Tanh), &[x])
    }

    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.apply(name, OpKind::Binary(BinaryFn::Add), &[a, b])
    }

    pub fn mul(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        self.apply(name, OpKind::Binary(BinaryFn::Mul), &[a, b])
    }

    pub fn batchnorm(&mut self, name: &str, x: TensorId) -> TensorId {
        let c = self.g.tensor(x).shape[1];
        let scale = self.weight(&format!("{name}_scale"), &[c]);
        let shift = self.weight(&format!("{name}_shift"), &[c]);
        self.apply(name, OpKind::BatchNorm, &[x, scale, shift])
    }

    pub fn maxpool(&mut self, name: &str, x: TensorId, window: i64, stride: i64) -> TensorId {
        self.apply(name, OpKind::Pool { kind: PoolKind::Max, window, stride }, &[x])
    }

    pub fn gap(&mut self, name: &str, x: TensorId) -> TensorId {
        self.apply(name, OpKind::GlobalAvgPool, &[x])
    }

    pub fn transpose(&mut self, name: &str, x: TensorId, perm: &[usize]) -> TensorId {
        self.apply(name, OpKind::Transpose { perm: perm.to_vec() }, &[x])
    }

    pub fn reshape(&mut self, name: &str, x: TensorId, shape: &[i64]) -> TensorId {
        self.apply(name, OpKind::Reshape { shape: shape.to_vec() }, &[x])
    }

    pub fn tile(&mut self, name: &str, x: TensorId, reps: &[i64]) -> TensorId {
        self.apply(name, OpKind::Tile { reps: reps.to_vec() }, &[x])
    }

    pub fn repeat(&mut self, name: &str, x: TensorId, axis: usize, n: i64) -> TensorId {
        self.apply(name, OpKind::Repeat { axis, n }, &[x])
    }

    pub fn slice(
        &mut self,
        name: &str,
        x: TensorId,
        begin: &[i64],
        end: &[i64],
        stride: &[i64],
    ) -> TensorId {
        self.apply(
            name,
            OpKind::StridedSlice {
                begin: begin.to_vec(),
                end: end.to_vec(),
                stride: stride.to_vec(),
            },
            &[x],
        )
    }

    /// NumPy-style `split` along an axis into `parts` equal pieces —
    /// lowered, as in most importers, to `parts` strided-slice nodes.
    pub fn split(&mut self, name: &str, x: TensorId, axis: usize, parts: i64) -> Vec<TensorId> {
        let shape = self.g.tensor(x).shape.clone();
        assert_eq!(shape[axis] % parts, 0, "split: uneven");
        let step = shape[axis] / parts;
        (0..parts)
            .map(|k| {
                let mut begin = vec![0; shape.len()];
                let mut end = shape.clone();
                begin[axis] = k * step;
                end[axis] = (k + 1) * step;
                self.slice(
                    &format!("{name}.{k}"),
                    x,
                    &begin,
                    &end,
                    &vec![1; shape.len()],
                )
            })
            .collect()
    }

    pub fn concat(&mut self, name: &str, xs: &[TensorId], axis: usize) -> TensorId {
        self.apply(name, OpKind::Concat { axis }, xs)
    }

    pub fn pad(&mut self, name: &str, x: TensorId, lo: &[i64], hi: &[i64]) -> TensorId {
        self.apply(name, OpKind::Pad { lo: lo.to_vec(), hi: hi.to_vec() }, &[x])
    }

    pub fn identity(&mut self, name: &str, x: TensorId) -> TensorId {
        self.apply(name, OpKind::Identity, &[x])
    }
}

impl Default for GraphBuilder {
    fn default() -> Self {
        GraphBuilder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_conv_chain() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 3, 32, 32]);
        let w = b.weight("w1", &[16, 3, 3, 3]);
        let c = b.conv2d("conv1", x, w, 1, 1);
        let r = b.relu("relu1", c);
        b.mark_output(r);
        let g = b.finish();
        assert_eq!(g.nodes().len(), 2);
        assert_eq!(g.tensor(r).shape, vec![1, 16, 32, 32]);
        assert_eq!(g.outputs().len(), 1);
    }

    #[test]
    fn split_makes_slices() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[2, 8]);
        let parts = b.split("s", x, 1, 4);
        assert_eq!(parts.len(), 4);
        for p in &parts {
            assert_eq!(b.graph().tensor(*p).shape, vec![2, 2]);
        }
        assert_eq!(b.graph().nodes().len(), 4);
    }

    #[test]
    #[should_panic(expected = "Cin mismatch")]
    fn shape_errors_panic_with_name() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 3, 8, 8]);
        let w = b.weight("w", &[4, 5, 3, 3]);
        b.conv2d("bad", x, w, 1, 1);
    }

    #[test]
    fn batchnorm_creates_weights() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 4, 4]);
        let y = b.batchnorm("bn", x);
        let g = b.finish();
        assert_eq!(g.tensor(y).shape, vec![1, 8, 4, 4]);
        assert_eq!(g.bytes_of_kind(TensorKind::Weight), 2 * 8 * 4);
    }
}
