//! Whole-model joint memory optimization.
//!
//! The staged pipeline makes its memory decisions greedily and in
//! isolation: schedule, then fusion + tile sizes, then residency, then
//! spills, each against its own local proxy. This module searches the
//! *joint* space instead — the paper's "analyze all operators of a DL
//! model together", taken to its conclusion per Li et al. (arXiv
//! 2311.18246): a beam search with branch-and-bound pruning over
//! [`DecisionVector`]s, where every candidate is **realized** through
//! the real pipeline (tile → bank map + copy splice → static plan) and
//! scored by the unified cost model ([`crate::cost::model`]), whose
//! byte-exactness against the planned replay means the search
//! optimizes the actual measurement, not an estimate of it.
//!
//! Structure of the search:
//!
//! 1. **Fusion/tiling axis** — candidates over `{untiled, elementwise,
//!    wide, conv-chain} × {budget fractions}`, seeded with the
//!    caller's configured staged-greedy vector (the tile/alloc stage
//!    options; [`DecisionVector::baseline`] when unconfigured); the
//!    best `beam_width` survive. This is where recompute-vs-stage is
//!    decided: the conv-chain candidates *recompute* kernel halos to
//!    keep boundary tensors staged, and win exactly when the cost
//!    model says the recomputed overlap is cheaper than streaming the
//!    intermediate through DRAM.
//! 2. **Allocation axis** — for each survivor, scheduler lookahead and
//!    spill-flavor variants.
//!
//! Branch-and-bound: no plan can beat the compulsory floor (each used
//! input/weight's cheapest single-reader image plus every output's
//! write-back — [`crate::cost::compulsory_offchip`]); once a candidate
//! reaches it the remaining candidates are pruned. Spill-flavor
//! variants are also pruned when the incumbent's plan had no spill
//! activity for the flavor to change.
//!
//! The search is deterministic, so the winning tiled program plus its
//! [`AllocOpts`] replayed by the pass manager's downstream stages
//! reproduce the winning plan exactly — which is how the differential
//! oracle can hold the `opt` snapshot to the same bit-identity bar as
//! every other stage (lower → dme → **opt** → bank → plan).

use crate::accel::config::AccelConfig;
use crate::alloc::{AllocOpts, PlanError, PlanStats, SpillFlavor};
use crate::cost::{
    compulsory_offchip, evaluate, AllocDecision, CostBreakdown, DecisionVector, TileDecision,
};
use crate::ir::loopnest::Program;
use crate::passes::bank::BankConfig;
use crate::passes::manager::BankMode;
use crate::tile::{FusePolicy, TileOpts, TileStats};
use crate::util::json::Json;
use std::time::Instant;

/// Joint-search configuration.
#[derive(Clone, Copy, Debug)]
pub struct OptOpts {
    /// Fusion/tiling candidates surviving into the allocation stage.
    pub beam_width: usize,
}

impl Default for OptOpts {
    fn default() -> Self {
        OptOpts { beam_width: 3 }
    }
}

/// Per-axis search-profile row: what one beam stage generated,
/// realized and pruned, and the best off-chip bytes seen by its end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenerationStats {
    /// Which decision axis the stage explored: `"tile"` or `"alloc"`.
    pub axis: &'static str,
    /// Decision vectors the stage enumerated.
    pub generated: usize,
    /// Vectors fully realized (tile + bank + plan + cost).
    pub realized: usize,
    /// Vectors skipped by branch-and-bound or plan failure.
    pub pruned: usize,
    /// Best predicted off-chip bytes at the end of the stage.
    pub best_offchip: i64,
}

impl GenerationStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("axis", Json::Str(self.axis.to_string())),
            ("generated", Json::Int(self.generated as i64)),
            ("realized", Json::Int(self.realized as i64)),
            ("pruned", Json::Int(self.pruned as i64)),
            ("best_offchip", Json::Int(self.best_offchip)),
        ])
    }
}

/// What the joint search did and found.
#[derive(Clone, Debug)]
pub struct OptStats {
    /// Decision vectors fully realized (tile + bank + plan + cost).
    pub candidates: usize,
    /// Candidates skipped by branch-and-bound or plan failure.
    pub pruned: usize,
    /// Predicted off-chip bytes of the staged-greedy baseline vector.
    pub baseline_offchip: i64,
    /// Predicted off-chip bytes of the winning vector.
    pub best_offchip: i64,
    /// Predicted pipelined seconds of the winning vector.
    pub best_pipelined_seconds: f64,
    /// Human-readable winning decision vector.
    pub decision: String,
    /// Per-stage search profile, in stage order.
    pub generations: Vec<GenerationStats>,
    /// Best-cost trajectory: the running-minimum predicted off-chip
    /// bytes after each realized candidate (one entry per realization).
    pub trajectory: Vec<i64>,
    /// Wall time of the whole search.
    pub search_seconds: f64,
}

impl OptStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("candidates", Json::Int(self.candidates as i64)),
            ("pruned", Json::Int(self.pruned as i64)),
            ("baseline_offchip", Json::Int(self.baseline_offchip)),
            ("best_offchip", Json::Int(self.best_offchip)),
            ("best_pipelined_seconds", Json::Num(self.best_pipelined_seconds)),
            ("decision", Json::Str(self.decision.clone())),
            (
                "generations",
                Json::Arr(self.generations.iter().map(|g| g.to_json()).collect()),
            ),
            (
                "trajectory",
                Json::Arr(self.trajectory.iter().map(|&v| Json::Int(v)).collect()),
            ),
            ("search_seconds", Json::Num(self.search_seconds)),
        ])
    }
}

/// The search's product: the winning candidate's transformed (tiled,
/// pre-bank) program, the planner configuration that reproduces its
/// plan downstream, and the stats.
#[derive(Clone, Debug)]
pub struct OptOutcome {
    pub program: Program,
    pub alloc_opts: AllocOpts,
    pub tile_stats: Option<TileStats>,
    pub stats: OptStats,
}

/// One fully realized candidate.
struct Realized {
    dv: DecisionVector,
    tiled: Program,
    tile_stats: Option<TileStats>,
    plan_stats: PlanStats,
    cost: CostBreakdown,
}

/// Is `a` a strictly better outcome than `b`? Primary objective is
/// predicted off-chip bytes; predicted pipelined latency breaks ties.
fn better(a: &CostBreakdown, b: &CostBreakdown) -> bool {
    let (ao, bo) = (a.offchip_total(), b.offchip_total());
    ao < bo || (ao == bo && a.pipelined_seconds < b.pipelined_seconds)
}

/// Realize one decision vector end to end: clone the (post-DME)
/// program, tile it per the vector, run the configured bank mapping,
/// splice the remap copies, plan memory, and score with the cost
/// model.
fn realize(
    program: &Program,
    dv: DecisionVector,
    bank_mode: BankMode,
    bank_cfg: &BankConfig,
    accel: &AccelConfig,
    base_tile: &TileOpts,
    base_alloc: &AllocOpts,
) -> Result<Realized, PlanError> {
    let mut prog = program.clone();
    let tile_stats = dv.tile.map(|td| {
        crate::tile::run_tiling_with(
            &mut prog,
            accel,
            &td.to_opts_on(*base_tile),
            &crate::cost::GreedyPolicy,
        )
    });
    let tiled = prog.clone();
    let bank = match bank_mode {
        BankMode::None => None,
        BankMode::Local => Some(crate::passes::bank_local::run_local(&prog.graph, bank_cfg)),
        BankMode::Global => {
            Some(crate::passes::bank_global::run_global(&prog.graph, bank_cfg))
        }
    };
    if let Some(b) = &bank {
        crate::passes::manager::splice_memcopies(&mut prog, &b.graph);
    }
    let res =
        crate::alloc::plan_memory(prog, bank.as_ref(), accel, &dv.alloc.to_opts_on(*base_alloc))?;
    let cost = evaluate(&res.program, &res.plan, accel);
    Ok(Realized {
        dv,
        tiled,
        tile_stats,
        plan_stats: res.plan.stats,
        cost,
    })
}

/// The fusion/tiling axis explored in stage 1: the caller's seed
/// first, then untiled, then the fixed exploration set (minus any
/// entry equal to the seed).
fn tile_candidates(seed: TileDecision) -> Vec<Option<TileDecision>> {
    let mut out: Vec<Option<TileDecision>> = vec![Some(seed), None];
    for cand in [
        TileDecision { budget_fraction: 0.5, fuse: FusePolicy::Elementwise },
        TileDecision { budget_fraction: 0.25, fuse: FusePolicy::Elementwise },
        TileDecision { budget_fraction: 0.5, fuse: FusePolicy::Wide },
        TileDecision { budget_fraction: 0.5, fuse: FusePolicy::ConvChain { depth: 2 } },
        TileDecision { budget_fraction: 0.25, fuse: FusePolicy::ConvChain { depth: 1 } },
    ] {
        if Some(cand) != out[0] {
            out.push(Some(cand));
        }
    }
    out
}

/// Run the joint search over `program` (the post-DME snapshot). The
/// baseline vector must realize (its error propagates); every other
/// candidate that fails to plan is pruned. `base_tile` and
/// `base_alloc` carry the caller's configured stage options — the
/// search varies only its own axes (budget fraction, fusion policy,
/// lookahead, spill flavor) on top of them, so settings like
/// `max_tiles`, `require_fit` and `max_rounds` hold for every
/// candidate, and the seed vector is exactly the caller's staged
/// greedy.
pub fn search(
    program: &Program,
    bank_mode: BankMode,
    bank_cfg: &BankConfig,
    accel: &AccelConfig,
    base_tile: &TileOpts,
    base_alloc: &AllocOpts,
    opts: &OptOpts,
) -> Result<OptOutcome, PlanError> {
    let t_search = Instant::now();
    let floor = compulsory_offchip(program);
    let mut candidates = 0usize;
    let mut pruned = 0usize;
    // search profile: running-min off-chip after each realization, plus
    // per-stage generation rows
    let mut trajectory: Vec<i64> = Vec::new();
    let mut best_so_far = i64::MAX;

    // ---- stage 1: fusion/tiling axis ----
    // the seed's coordinates are the *caller's* (the true staged-greedy
    // baseline), not the crate defaults
    let seed_alloc = AllocDecision { lookahead: base_alloc.lookahead, spill: base_alloc.spill };
    let mut beam: Vec<Realized> = Vec::new();
    let mut baseline_offchip = 0i64;
    let tiles = tile_candidates(TileDecision::from_opts(base_tile));
    for (i, tile) in tiles.iter().enumerate() {
        if beam.first().map(|b| b.cost.offchip_total() == floor).unwrap_or(false) {
            pruned += tiles.len() - i;
            crate::obs::add("opt.pruned", (tiles.len() - i) as i64);
            break; // branch-and-bound: the incumbent hit the floor
        }
        let dv = DecisionVector { tile: *tile, alloc: seed_alloc };
        match realize(program, dv, bank_mode, bank_cfg, accel, base_tile, base_alloc) {
            Ok(r) => {
                candidates += 1;
                crate::obs::add("opt.realized", 1);
                best_so_far = best_so_far.min(r.cost.offchip_total());
                trajectory.push(best_so_far);
                if i == 0 {
                    baseline_offchip = r.cost.offchip_total();
                }
                let at = beam
                    .iter()
                    .position(|b| better(&r.cost, &b.cost))
                    .unwrap_or(beam.len());
                beam.insert(at, r);
                beam.truncate(opts.beam_width.max(1));
            }
            Err(e) => {
                if i == 0 {
                    return Err(e); // the staged-greedy seed must plan
                }
                pruned += 1;
                crate::obs::add("opt.pruned", 1);
            }
        }
    }
    debug_assert!(!beam.is_empty());
    let mut generations = vec![GenerationStats {
        axis: "tile",
        generated: tiles.len(),
        realized: candidates,
        pruned,
        best_offchip: best_so_far,
    }];

    // ---- stage 2: allocation axis over the surviving beam ----
    let alloc_variants = [
        AllocDecision { lookahead: seed_alloc.lookahead, spill: SpillFlavor::Traffic },
        AllocDecision {
            lookahead: 2 * seed_alloc.lookahead.max(1),
            spill: seed_alloc.spill,
        },
    ];
    let mut extra: Vec<Realized> = Vec::new();
    let (s2_cand0, s2_pruned0) = (candidates, pruned);
    let mut s2_generated = 0usize;
    for b in &beam {
        if b.cost.offchip_total() == floor {
            continue; // already optimal
        }
        let idle_spiller = b.plan_stats.spill_pairs == 0
            && b.plan_stats.window_splits == 0
            && b.plan_stats.streamed == 0;
        for av in alloc_variants {
            s2_generated += 1;
            if av == seed_alloc {
                pruned += 1; // identical to the beam entry already scored
                crate::obs::add("opt.pruned", 1);
                continue;
            }
            if av.spill == SpillFlavor::Traffic && idle_spiller {
                pruned += 1; // flavor cannot change an untouched plan
                crate::obs::add("opt.pruned", 1);
                continue;
            }
            let dv = DecisionVector { tile: b.dv.tile, alloc: av };
            match realize(program, dv, bank_mode, bank_cfg, accel, base_tile, base_alloc) {
                Ok(r) => {
                    candidates += 1;
                    crate::obs::add("opt.realized", 1);
                    best_so_far = best_so_far.min(r.cost.offchip_total());
                    trajectory.push(best_so_far);
                    extra.push(r);
                }
                Err(_) => {
                    pruned += 1;
                    crate::obs::add("opt.pruned", 1);
                }
            }
        }
    }
    generations.push(GenerationStats {
        axis: "alloc",
        generated: s2_generated,
        realized: candidates - s2_cand0,
        pruned: pruned - s2_pruned0,
        best_offchip: best_so_far,
    });

    // ---- pick the winner ----
    let mut best: Option<Realized> = None;
    for r in beam.into_iter().chain(extra) {
        let take = match &best {
            None => true,
            Some(b) => better(&r.cost, &b.cost),
        };
        if take {
            best = Some(r);
        }
    }
    let best = best.expect("baseline candidate realized");
    let search_seconds = t_search.elapsed().as_secs_f64();
    crate::obs::phase("opt.search", search_seconds);
    let stats = OptStats {
        candidates,
        pruned,
        baseline_offchip,
        best_offchip: best.cost.offchip_total(),
        best_pipelined_seconds: best.cost.pipelined_seconds,
        decision: best.dv.describe(),
        generations,
        trajectory,
        search_seconds,
    };
    Ok(OptOutcome {
        program: best.tiled,
        alloc_opts: best.dv.alloc.to_opts_on(*base_alloc),
        tile_stats: best.tile_stats,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::passes::manager::{AllocStage, OptStage, PassManager};

    /// conv → bn → relu → conv with 16 KiB feature maps: on a tiny
    /// chip the relu output cannot be bank-resident, so the staged
    /// greedy streams it at the chain boundary while the conv-chain
    /// candidate keeps it staged.
    fn conv_conv() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 32, 32]);
        let w1 = b.weight("w1", &[4, 4, 3, 3]);
        let c1 = b.conv2d("c1", x, w1, 1, 1);
        let n = b.batchnorm("bn", c1);
        let r = b.relu("r", n);
        let w2 = b.weight("w2", &[6, 4, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        b.finish()
    }

    #[test]
    fn search_never_loses_to_the_baseline() {
        let g = conv_conv();
        let prog = Program::lower(g);
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        assert!(out.stats.candidates >= 1);
        assert!(
            out.stats.best_offchip <= out.stats.baseline_offchip,
            "{:?}",
            out.stats
        );
        assert!(out.stats.best_offchip >= crate::cost::compulsory_offchip(&out.program));
    }

    #[test]
    fn search_beats_staged_greedy_on_conv_boundary() {
        // the conv→conv boundary tensor streams under elementwise
        // fusion; the conv-chain candidate stages it, so the joint
        // result must be strictly better than the baseline vector
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        assert!(
            out.stats.best_offchip < out.stats.baseline_offchip,
            "joint search found nothing on a conv-boundary workload: {:?}",
            out.stats
        );
    }

    #[test]
    fn search_profile_is_consistent() {
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        let s = &out.stats;
        assert_eq!(s.generations.len(), 2);
        assert_eq!(s.generations[0].axis, "tile");
        assert_eq!(s.generations[1].axis, "alloc");
        // per-stage rows sum back to the totals
        assert_eq!(s.generations.iter().map(|g| g.realized).sum::<usize>(), s.candidates);
        assert_eq!(s.generations.iter().map(|g| g.pruned).sum::<usize>(), s.pruned);
        // one trajectory point per realization, nonincreasing, landing
        // on the winner (the primary objective is off-chip bytes)
        assert_eq!(s.trajectory.len(), s.candidates);
        assert!(s.trajectory.windows(2).all(|w| w[1] <= w[0]));
        assert_eq!(s.trajectory.last().copied(), Some(s.best_offchip));
        assert!(s.search_seconds >= 0.0);
        let j = s.to_json();
        assert_eq!(
            j.get("generations").and_then(|g| g.as_arr()).map(|a| a.len()),
            Some(2)
        );
        assert!(j.get("search_seconds").and_then(|v| v.as_f64()).is_some());
    }

    #[test]
    fn search_counters_land_in_global_collector() {
        // serialize with every test that toggles the global gate
        let _g = crate::obs::TEST_GATE.lock().unwrap();
        crate::obs::global().reset();
        crate::obs::set_enabled(true);
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        crate::obs::set_enabled(false);
        let snap = crate::obs::global().snapshot();
        assert!(
            snap.counters.get("opt.realized").copied().unwrap_or(0)
                >= out.stats.candidates as i64
        );
        assert!(snap.phases.iter().any(|p| p.name == "opt.search"));
    }

    #[test]
    fn manager_replays_the_winner_exactly() {
        // the pass manager's downstream stages must reproduce the
        // winning candidate's plan: same program, same predicted cost
        let cfg = AccelConfig::tiny(8 * 1024);
        let pm = PassManager {
            opt: Some(OptStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(conv_conv()).unwrap();
        let stats = rep.opt.expect("opt stage ran");
        let plan = rep.plan.expect("alloc stage ran");
        let cost = evaluate(&rep.program, &plan, &cfg);
        assert_eq!(cost.offchip_total(), stats.best_offchip);
        let sim = crate::accel::simulate_planned(&rep.program, &plan, &cfg, None).unwrap();
        assert_eq!(sim.offchip_total(), stats.best_offchip);
    }
}
