//! Whole-model joint memory optimization.
//!
//! The staged pipeline makes its memory decisions greedily and in
//! isolation: schedule, then fusion + tile sizes, then residency, then
//! spills, each against its own local proxy. This module searches the
//! *joint* space instead — the paper's "analyze all operators of a DL
//! model together", taken to its conclusion per Li et al. (arXiv
//! 2311.18246): a beam search with branch-and-bound pruning over
//! [`DecisionVector`]s, where every candidate is **realized** through
//! the real pipeline (tile → bank map + copy splice → static plan) and
//! scored by the unified cost model ([`crate::cost::model`]), whose
//! byte-exactness against the planned replay means the search
//! optimizes the actual measurement, not an estimate of it.
//!
//! Structure of the search:
//!
//! 1. **Fusion/tiling axis** — candidates over `{untiled, elementwise,
//!    wide, conv-chain} × {budget fractions}`, seeded with the
//!    caller's configured staged-greedy vector (the tile/alloc stage
//!    options; [`DecisionVector::baseline`] when unconfigured); the
//!    best `beam_width` survive. This is where recompute-vs-stage is
//!    decided: the conv-chain candidates *recompute* kernel halos to
//!    keep boundary tensors staged, and win exactly when the cost
//!    model says the recomputed overlap is cheaper than streaming the
//!    intermediate through DRAM.
//! 2. **Allocation axis** — for each survivor, scheduler lookahead and
//!    spill-flavor variants.
//!
//! Branch-and-bound: no plan can beat the compulsory floor (each used
//! input/weight's cheapest single-reader image plus every output's
//! write-back — [`crate::cost::compulsory_offchip`]); once a candidate
//! reaches it the remaining candidates are pruned. Spill-flavor
//! variants are also pruned when the incumbent's plan had no spill
//! activity for the flavor to change.
//!
//! # Incremental realization (the memoization tiers)
//!
//! Realization is factored so work shared between neighboring decision
//! vectors is computed once instead of per candidate:
//!
//! * **tier 0, once per search** — the bank mapping. Tiling rewrites
//!   only `Program::nests`; the graph the bank passes consume is
//!   untouched by every tiling decision, so one assignment (and its
//!   remap graph) serves every candidate. The old path recomputed it
//!   on each realization.
//! * **tier 1, once per tiling decision** — [`stage_tile`] produces a
//!   [`Staged`] artifact: the tiled program plus the copy-spliced
//!   planning input. Every alloc-axis variant of one tile survivor
//!   shares it through an `Arc` (the old path re-tiled and re-spliced
//!   per spill-flavor/lookahead variant).
//! * **tier 2, per decision vector** — [`realize_alloc`]: static plan
//!   plus [`evaluate`] on the shared staged artifact. This is the only
//!   per-candidate work, and it *is* the score — no approximation is
//!   introduced anywhere, which is why the memoized scores are
//!   byte-identical to the from-scratch path ([`realize_full`], held
//!   to bit-exactness by `tests/opt_calibration.rs`).
//!
//! # Parallel realization and the determinism contract
//!
//! Each stage's generation is realized concurrently by a zero-dep
//! scoped worker pool ([`pool`]) — [`OptOpts::threads`], with the
//! `POLYMEM_SEARCH_THREADS` env override — and then **reduced in
//! candidate-generation order**, replaying exactly the serial search's
//! branch-and-bound decisions: the compulsory-floor cut depends only
//! on already-reduced candidates, and stage-2 pruning (seed-equal and
//! idle-spiller variants) is decided from stage-1 results before any
//! stage-2 job is enqueued. Parallelism can therefore only realize
//! candidates *speculatively past* a serial cut (counted as pruned,
//! exactly as the serial search counts them) — `trajectory`,
//! [`GenerationStats`], and the winning [`DecisionVector`] are
//! independent of thread count (`tests/opt_threads.rs`), so the
//! differential oracle's lower → dme → **opt** → bank → plan
//! bit-identity holds at any thread count.

mod pool;

use crate::accel::config::AccelConfig;
use crate::alloc::{AllocOpts, PlanError, PlanStats, SpillFlavor};
use crate::cost::{
    compulsory_offchip, evaluate, AllocDecision, CostBreakdown, DecisionVector, TileDecision,
};
use crate::ir::loopnest::Program;
use crate::passes::bank::{BankAssignment, BankConfig};
use crate::passes::manager::BankMode;
use crate::tile::{FusePolicy, TileOpts, TileStats};
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

/// Joint-search configuration.
#[derive(Clone, Copy, Debug)]
pub struct OptOpts {
    /// Fusion/tiling candidates surviving into the allocation stage.
    /// The winner is monotone in this width (a wider beam only adds
    /// candidates to a min), and the extra stage-2 expansions ride the
    /// cheap memoized tier — which is what paid for raising the
    /// default from 3 to 8.
    pub beam_width: usize,
    /// Worker threads for candidate realization. `0` means auto:
    /// `POLYMEM_SEARCH_THREADS` if set, else all available cores.
    /// Never affects the search outcome — only wall time.
    pub threads: usize,
}

impl Default for OptOpts {
    fn default() -> Self {
        OptOpts { beam_width: 8, threads: 0 }
    }
}

impl OptOpts {
    /// The worker count [`search`] will actually use: an explicit
    /// `threads` wins, else the `POLYMEM_SEARCH_THREADS` environment
    /// override, else the machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        if let Ok(v) = std::env::var("POLYMEM_SEARCH_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Per-axis search-profile row: what one beam stage generated,
/// realized and pruned, and the best off-chip bytes seen by its end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenerationStats {
    /// Which decision axis the stage explored: `"tile"` or `"alloc"`.
    pub axis: &'static str,
    /// Decision vectors the stage enumerated.
    pub generated: usize,
    /// Vectors fully realized (tile + bank + plan + cost).
    pub realized: usize,
    /// Vectors skipped by branch-and-bound or plan failure.
    pub pruned: usize,
    /// Best predicted off-chip bytes at the end of the stage.
    pub best_offchip: i64,
}

impl GenerationStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("axis", Json::Str(self.axis.to_string())),
            ("generated", Json::Int(self.generated as i64)),
            ("realized", Json::Int(self.realized as i64)),
            ("pruned", Json::Int(self.pruned as i64)),
            ("best_offchip", Json::Int(self.best_offchip)),
        ])
    }
}

/// What the joint search did and found.
#[derive(Clone, Debug)]
pub struct OptStats {
    /// Decision vectors fully realized (tile + bank + plan + cost).
    pub candidates: usize,
    /// Candidates skipped by branch-and-bound or plan failure.
    pub pruned: usize,
    /// Predicted off-chip bytes of the staged-greedy baseline vector.
    pub baseline_offchip: i64,
    /// Predicted off-chip bytes of the winning vector.
    pub best_offchip: i64,
    /// Predicted pipelined seconds of the winning vector.
    pub best_pipelined_seconds: f64,
    /// Human-readable winning decision vector.
    pub decision: String,
    /// Per-stage search profile, in stage order.
    pub generations: Vec<GenerationStats>,
    /// Best-cost trajectory: the running-minimum predicted off-chip
    /// bytes after each realized candidate (one entry per realization).
    pub trajectory: Vec<i64>,
    /// Wall time of the whole search.
    pub search_seconds: f64,
    /// Worker threads the search actually used (resolved from
    /// [`OptOpts::threads`] / `POLYMEM_SEARCH_THREADS` / core count).
    pub threads: usize,
}

impl OptStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("candidates", Json::Int(self.candidates as i64)),
            ("pruned", Json::Int(self.pruned as i64)),
            ("baseline_offchip", Json::Int(self.baseline_offchip)),
            ("best_offchip", Json::Int(self.best_offchip)),
            ("best_pipelined_seconds", Json::Num(self.best_pipelined_seconds)),
            ("decision", Json::Str(self.decision.clone())),
            (
                "generations",
                Json::Arr(self.generations.iter().map(|g| g.to_json()).collect()),
            ),
            (
                "trajectory",
                Json::Arr(self.trajectory.iter().map(|&v| Json::Int(v)).collect()),
            ),
            ("search_seconds", Json::Num(self.search_seconds)),
            ("threads", Json::Int(self.threads as i64)),
        ])
    }
}

/// The search's product: the winning candidate's transformed (tiled,
/// pre-bank) program, the planner configuration that reproduces its
/// plan downstream, the stats, and the audit trail — every realized
/// candidate with its memoized score, in realization order (what the
/// calibration test replays through [`realize_full`]).
#[derive(Clone, Debug)]
pub struct OptOutcome {
    pub program: Program,
    pub alloc_opts: AllocOpts,
    pub tile_stats: Option<TileStats>,
    pub stats: OptStats,
    pub audit: Vec<(DecisionVector, CostBreakdown)>,
}

/// Everything the search holds constant across candidates, plus the
/// tier-0 memo: the bank assignment, computed once per search (tiling
/// never touches the graph the bank passes read).
struct SearchCtx<'a> {
    program: &'a Program,
    bank: Option<BankAssignment>,
    accel: &'a AccelConfig,
    base_tile: &'a TileOpts,
    base_alloc: &'a AllocOpts,
}

/// Tier-1 memo: everything downstream of one fusion/tiling decision
/// that is invariant across its alloc-axis variants. Shared by `Arc` —
/// stage 2 never re-tiles or re-splices.
struct Staged {
    tile: Option<TileDecision>,
    /// The tiled, pre-bank program (what [`OptOutcome::program`]
    /// carries for the winner).
    tiled: Program,
    /// The tiled program with bank remap copies spliced in: the
    /// planning input for every alloc variant of this tile decision.
    spliced: Program,
    tile_stats: Option<TileStats>,
}

/// One fully realized candidate.
struct Realized {
    dv: DecisionVector,
    staged: Arc<Staged>,
    plan_stats: PlanStats,
    cost: CostBreakdown,
}

/// Is `a` a strictly better outcome than `b`? Primary objective is
/// predicted off-chip bytes; predicted pipelined latency breaks ties.
fn better(a: &CostBreakdown, b: &CostBreakdown) -> bool {
    let (ao, bo) = (a.offchip_total(), b.offchip_total());
    ao < bo || (ao == bo && a.pipelined_seconds < b.pipelined_seconds)
}

/// Tier 1: tile the program per the decision and splice the (shared)
/// bank's remap copies — the artifact every alloc-axis variant of this
/// tiling decision reuses.
fn stage_tile(ctx: &SearchCtx, tile: Option<TileDecision>) -> Staged {
    let mut prog = ctx.program.clone();
    let tile_stats = tile.map(|td| {
        crate::tile::run_tiling_with(
            &mut prog,
            ctx.accel,
            &td.to_opts_on(*ctx.base_tile),
            &crate::cost::GreedyPolicy,
        )
    });
    let tiled = prog.clone();
    if let Some(b) = &ctx.bank {
        crate::passes::manager::splice_memcopies(&mut prog, &b.graph);
    }
    Staged { tile, tiled, spliced: prog, tile_stats }
}

/// Tier 2: plan and score one alloc variant on a shared staged
/// artifact. This is the per-candidate work — and the score it
/// produces is the full cost model on the fully planned program, not
/// an estimate.
fn realize_alloc(
    ctx: &SearchCtx,
    staged: &Arc<Staged>,
    av: AllocDecision,
) -> Result<Realized, PlanError> {
    let res = crate::alloc::plan_memory(
        staged.spliced.clone(),
        ctx.bank.as_ref(),
        ctx.accel,
        &av.to_opts_on(*ctx.base_alloc),
    )?;
    let cost = evaluate(&res.program, &res.plan, ctx.accel);
    Ok(Realized {
        dv: DecisionVector { tile: staged.tile, alloc: av },
        staged: Arc::clone(staged),
        plan_stats: res.plan.stats,
        cost,
    })
}

/// Realize one decision vector **from scratch** through the full
/// tile → bank → splice → plan → score path, sharing nothing between
/// candidates: the pre-memoization reference implementation. The
/// incremental search is calibrated against it —
/// `tests/opt_calibration.rs` holds every audited candidate score to
/// byte-exact (seconds bit-exact) equality with this path, and
/// `bench_compile_time` times it over the audited candidate set to
/// measure the memoization speedup honestly.
pub fn realize_full(
    program: &Program,
    dv: DecisionVector,
    bank_mode: BankMode,
    bank_cfg: &BankConfig,
    accel: &AccelConfig,
    base_tile: &TileOpts,
    base_alloc: &AllocOpts,
) -> Result<CostBreakdown, PlanError> {
    let mut prog = program.clone();
    if let Some(td) = dv.tile {
        crate::tile::run_tiling_with(
            &mut prog,
            accel,
            &td.to_opts_on(*base_tile),
            &crate::cost::GreedyPolicy,
        );
    }
    let bank = match bank_mode {
        BankMode::None => None,
        BankMode::Local => Some(crate::passes::bank_local::run_local(&prog.graph, bank_cfg)),
        BankMode::Global => {
            Some(crate::passes::bank_global::run_global(&prog.graph, bank_cfg))
        }
    };
    if let Some(b) = &bank {
        crate::passes::manager::splice_memcopies(&mut prog, &b.graph);
    }
    let res =
        crate::alloc::plan_memory(prog, bank.as_ref(), accel, &dv.alloc.to_opts_on(*base_alloc))?;
    Ok(evaluate(&res.program, &res.plan, accel))
}

/// The fusion/tiling axis explored in stage 1: the caller's seed
/// first, then untiled, then the fixed exploration set — minus any
/// entry equal to one already pushed (the seed may coincide with any
/// member of the fixed set, not just `out[0]`).
fn tile_candidates(seed: TileDecision) -> Vec<Option<TileDecision>> {
    let mut out: Vec<Option<TileDecision>> = vec![Some(seed), None];
    for cand in [
        TileDecision { budget_fraction: 0.5, fuse: FusePolicy::Elementwise },
        TileDecision { budget_fraction: 0.25, fuse: FusePolicy::Elementwise },
        TileDecision { budget_fraction: 0.5, fuse: FusePolicy::Wide },
        TileDecision { budget_fraction: 0.5, fuse: FusePolicy::ConvChain { depth: 2 } },
        TileDecision { budget_fraction: 0.25, fuse: FusePolicy::ConvChain { depth: 1 } },
    ] {
        if !out.contains(&Some(cand)) {
            out.push(Some(cand));
        }
    }
    out
}

/// Fold a worker pool's per-thread activity into the global telemetry
/// collector in one locked absorb (workers never touch the collector
/// themselves, so realization stays side-effect free and reorderable).
fn merge_pool_obs(stage: &str, rep: &pool::PoolReport) {
    if !crate::obs::enabled() {
        return;
    }
    let mut snap = crate::obs::ObsSnapshot::default();
    snap.counters.insert(format!("{stage}.workers"), rep.per_thread.len() as i64);
    snap.counters.insert(format!("{stage}.jobs"), rep.jobs() as i64);
    for (t, st) in rep.per_thread.iter().enumerate() {
        snap.counters.insert(format!("{stage}.worker{t}.jobs"), st.jobs as i64);
        snap.phases.push(crate::obs::PhaseSample::new(
            &format!("{stage}.worker{t}.busy"),
            st.busy_seconds,
        ));
    }
    crate::obs::global().absorb(&snap);
}

/// Run the joint search over `program` (the post-DME snapshot). The
/// baseline vector must realize (its error propagates); every other
/// candidate that fails to plan is pruned. `base_tile` and
/// `base_alloc` carry the caller's configured stage options — the
/// search varies only its own axes (budget fraction, fusion policy,
/// lookahead, spill flavor) on top of them, so settings like
/// `max_tiles`, `require_fit` and `max_rounds` hold for every
/// candidate, and the seed vector is exactly the caller's staged
/// greedy.
pub fn search(
    program: &Program,
    bank_mode: BankMode,
    bank_cfg: &BankConfig,
    accel: &AccelConfig,
    base_tile: &TileOpts,
    base_alloc: &AllocOpts,
    opts: &OptOpts,
) -> Result<OptOutcome, PlanError> {
    let t_search = Instant::now();
    let threads = opts.resolved_threads();
    let floor = compulsory_offchip(program);

    // tier 0: one bank mapping serves every candidate — tiling only
    // rewrites nests, so the graph the bank passes read is identical
    // for all of them (the differential suite pins this: the spliced
    // programs match the old per-candidate recomputation bit-exactly)
    let t_bank = Instant::now();
    let bank = match bank_mode {
        BankMode::None => None,
        BankMode::Local => Some(crate::passes::bank_local::run_local(&program.graph, bank_cfg)),
        BankMode::Global => {
            Some(crate::passes::bank_global::run_global(&program.graph, bank_cfg))
        }
    };
    crate::obs::phase("opt.bank_once", t_bank.elapsed().as_secs_f64());
    let ctx = SearchCtx { program, bank, accel, base_tile, base_alloc };

    let mut candidates = 0usize;
    let mut pruned = 0usize;
    // search profile: running-min off-chip after each realization, plus
    // per-stage generation rows and the per-candidate audit trail
    let mut trajectory: Vec<i64> = Vec::new();
    let mut audit: Vec<(DecisionVector, CostBreakdown)> = Vec::new();
    let mut best_so_far = i64::MAX;

    // ---- stage 1: fusion/tiling axis ----
    // the seed's coordinates are the *caller's* (the true staged-greedy
    // baseline), not the crate defaults
    let seed_alloc = AllocDecision { lookahead: base_alloc.lookahead, spill: base_alloc.spill };
    let tiles = tile_candidates(TileDecision::from_opts(base_tile));
    let realize_tile = |tile: &Option<TileDecision>| {
        let staged = Arc::new(stage_tile(&ctx, *tile));
        realize_alloc(&ctx, &staged, seed_alloc)
    };
    // multi-threaded: realize the whole generation speculatively, then
    // reduce in generation order below (work past the floor cut is
    // discarded). single-threaded: realize lazily inside the reduction
    // so the cut skips the work exactly like the pre-parallel search.
    let results: Box<dyn Iterator<Item = Result<Realized, PlanError>> + '_> = if threads > 1 {
        let (r, rep) = pool::parallel_map(&tiles, threads, |_, tile| realize_tile(tile));
        merge_pool_obs("opt.pool.tile", &rep);
        Box::new(r.into_iter())
    } else {
        Box::new(tiles.iter().map(&realize_tile))
    };

    let mut results = results;
    let mut beam: Vec<Realized> = Vec::new();
    let mut baseline_offchip = 0i64;
    let mut i = 0usize;
    loop {
        // check the cut BEFORE pulling the next result: on the lazy
        // serial path this skips the realization itself, exactly like
        // the pre-parallel search
        if beam.first().map(|b| b.cost.offchip_total() == floor).unwrap_or(false) {
            pruned += tiles.len() - i;
            crate::obs::add("opt.pruned", (tiles.len() - i) as i64);
            break; // branch-and-bound: the incumbent hit the floor
        }
        let Some(res) = results.next() else { break };
        match res {
            Ok(r) => {
                candidates += 1;
                crate::obs::add("opt.realized", 1);
                best_so_far = best_so_far.min(r.cost.offchip_total());
                trajectory.push(best_so_far);
                audit.push((r.dv, r.cost.clone()));
                if i == 0 {
                    baseline_offchip = r.cost.offchip_total();
                }
                let at = beam
                    .iter()
                    .position(|b| better(&r.cost, &b.cost))
                    .unwrap_or(beam.len());
                beam.insert(at, r);
                beam.truncate(opts.beam_width.max(1));
            }
            Err(e) => {
                if i == 0 {
                    return Err(e); // the staged-greedy seed must plan
                }
                pruned += 1;
                crate::obs::add("opt.pruned", 1);
            }
        }
        i += 1;
    }
    drop(results);
    debug_assert!(!beam.is_empty());
    let mut generations = vec![GenerationStats {
        axis: "tile",
        generated: tiles.len(),
        realized: candidates,
        pruned,
        best_offchip: best_so_far,
    }];

    // ---- stage 2: allocation axis over the surviving beam ----
    // pruning here (floor survivors, seed-equal variants, idle-spiller
    // flavors) depends only on stage-1 results, so it is decided while
    // building the job list — before any parallel work — and the
    // realized jobs reduce in the same generation order the serial
    // search visited them.
    let alloc_variants = [
        AllocDecision { lookahead: seed_alloc.lookahead, spill: SpillFlavor::Traffic },
        AllocDecision {
            lookahead: 2 * seed_alloc.lookahead.max(1),
            spill: seed_alloc.spill,
        },
    ];
    let (s2_cand0, s2_pruned0) = (candidates, pruned);
    let mut s2_generated = 0usize;
    let mut s2_jobs: Vec<(Arc<Staged>, AllocDecision)> = Vec::new();
    for b in &beam {
        if b.cost.offchip_total() == floor {
            continue; // already optimal
        }
        let idle_spiller = b.plan_stats.spill_pairs == 0
            && b.plan_stats.window_splits == 0
            && b.plan_stats.streamed == 0;
        for av in alloc_variants {
            s2_generated += 1;
            if av == seed_alloc {
                pruned += 1; // identical to the beam entry already scored
                crate::obs::add("opt.pruned", 1);
                continue;
            }
            if av.spill == SpillFlavor::Traffic && idle_spiller {
                pruned += 1; // flavor cannot change an untouched plan
                crate::obs::add("opt.pruned", 1);
                continue;
            }
            s2_jobs.push((Arc::clone(&b.staged), av));
        }
    }
    let (s2_results, s2_rep) =
        pool::parallel_map(&s2_jobs, threads, |_, job| realize_alloc(&ctx, &job.0, job.1));
    if threads > 1 {
        merge_pool_obs("opt.pool.alloc", &s2_rep);
    }
    let mut extra: Vec<Realized> = Vec::new();
    for res in s2_results {
        match res {
            Ok(r) => {
                candidates += 1;
                crate::obs::add("opt.realized", 1);
                best_so_far = best_so_far.min(r.cost.offchip_total());
                trajectory.push(best_so_far);
                audit.push((r.dv, r.cost.clone()));
                extra.push(r);
            }
            Err(_) => {
                pruned += 1;
                crate::obs::add("opt.pruned", 1);
            }
        }
    }
    generations.push(GenerationStats {
        axis: "alloc",
        generated: s2_generated,
        realized: candidates - s2_cand0,
        pruned: pruned - s2_pruned0,
        best_offchip: best_so_far,
    });

    // ---- pick the winner ----
    let mut best: Option<Realized> = None;
    for r in beam.into_iter().chain(extra) {
        let take = match &best {
            None => true,
            Some(b) => better(&r.cost, &b.cost),
        };
        if take {
            best = Some(r);
        }
    }
    let best = best.expect("baseline candidate realized");
    let search_seconds = t_search.elapsed().as_secs_f64();
    crate::obs::phase("opt.search", search_seconds);
    let stats = OptStats {
        candidates,
        pruned,
        baseline_offchip,
        best_offchip: best.cost.offchip_total(),
        best_pipelined_seconds: best.cost.pipelined_seconds,
        decision: best.dv.describe(),
        generations,
        trajectory,
        search_seconds,
        threads,
    };
    Ok(OptOutcome {
        program: best.staged.tiled.clone(),
        alloc_opts: best.dv.alloc.to_opts_on(*base_alloc),
        tile_stats: best.staged.tile_stats,
        stats,
        audit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::passes::manager::{AllocStage, OptStage, PassManager};

    /// conv → bn → relu → conv with 16 KiB feature maps: on a tiny
    /// chip the relu output cannot be bank-resident, so the staged
    /// greedy streams it at the chain boundary while the conv-chain
    /// candidate keeps it staged.
    fn conv_conv() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 32, 32]);
        let w1 = b.weight("w1", &[4, 4, 3, 3]);
        let c1 = b.conv2d("c1", x, w1, 1, 1);
        let n = b.batchnorm("bn", c1);
        let r = b.relu("r", n);
        let w2 = b.weight("w2", &[6, 4, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        b.finish()
    }

    fn search_with_threads(threads: usize) -> OptOutcome {
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts { threads, ..OptOpts::default() },
        )
        .unwrap()
    }

    #[test]
    fn search_never_loses_to_the_baseline() {
        let g = conv_conv();
        let prog = Program::lower(g);
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        assert!(out.stats.candidates >= 1);
        assert!(
            out.stats.best_offchip <= out.stats.baseline_offchip,
            "{:?}",
            out.stats
        );
        assert!(out.stats.best_offchip >= crate::cost::compulsory_offchip(&out.program));
    }

    #[test]
    fn search_beats_staged_greedy_on_conv_boundary() {
        // the conv→conv boundary tensor streams under elementwise
        // fusion; the conv-chain candidate stages it, so the joint
        // result must be strictly better than the baseline vector
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        assert!(
            out.stats.best_offchip < out.stats.baseline_offchip,
            "joint search found nothing on a conv-boundary workload: {:?}",
            out.stats
        );
    }

    #[test]
    fn search_profile_is_consistent() {
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        let s = &out.stats;
        assert_eq!(s.generations.len(), 2);
        assert_eq!(s.generations[0].axis, "tile");
        assert_eq!(s.generations[1].axis, "alloc");
        // per-stage rows sum back to the totals
        assert_eq!(s.generations.iter().map(|g| g.realized).sum::<usize>(), s.candidates);
        assert_eq!(s.generations.iter().map(|g| g.pruned).sum::<usize>(), s.pruned);
        // one trajectory point per realization, nonincreasing, landing
        // on the winner (the primary objective is off-chip bytes)
        assert_eq!(s.trajectory.len(), s.candidates);
        assert!(s.trajectory.windows(2).all(|w| w[1] <= w[0]));
        assert_eq!(s.trajectory.last().copied(), Some(s.best_offchip));
        // the audit trail mirrors the trajectory one-to-one
        assert_eq!(out.audit.len(), s.candidates);
        assert!(s.search_seconds >= 0.0);
        assert!(s.threads >= 1);
        let j = s.to_json();
        assert_eq!(
            j.get("generations").and_then(|g| g.as_arr()).map(|a| a.len()),
            Some(2)
        );
        assert!(j.get("search_seconds").and_then(|v| v.as_f64()).is_some());
        assert!(j.get("threads").and_then(|v| v.as_i64()).is_some());
    }

    #[test]
    fn search_outcome_is_thread_count_invariant() {
        // the broad invariance suite lives in tests/opt_threads.rs;
        // this is the in-crate smoke version on the conv boundary
        let base = search_with_threads(1);
        for threads in [2usize, 4] {
            let alt = search_with_threads(threads);
            assert_eq!(base.stats.decision, alt.stats.decision, "threads={threads}");
            assert_eq!(base.stats.best_offchip, alt.stats.best_offchip);
            assert_eq!(base.stats.trajectory, alt.stats.trajectory);
            assert_eq!(base.stats.generations, alt.stats.generations);
            assert_eq!(base.audit.len(), alt.audit.len());
            for ((d1, c1), (d2, c2)) in base.audit.iter().zip(&alt.audit) {
                assert_eq!(d1.describe(), d2.describe());
                assert!(c1.bits_eq(c2), "threads={threads}: {} diverged", d1.describe());
            }
        }
    }

    #[test]
    fn tile_candidates_dedup_against_all_entries() {
        // a seed distinct from the fixed set keeps every entry
        let distinct = TileDecision { budget_fraction: 0.75, fuse: FusePolicy::Elementwise };
        assert_eq!(tile_candidates(distinct).len(), 7);
        // a seed equal to ANY fixed-set member (not just the first)
        // must not be realized twice
        for fixed in [
            TileDecision { budget_fraction: 0.5, fuse: FusePolicy::Elementwise },
            TileDecision { budget_fraction: 0.25, fuse: FusePolicy::Elementwise },
            TileDecision { budget_fraction: 0.5, fuse: FusePolicy::Wide },
            TileDecision { budget_fraction: 0.5, fuse: FusePolicy::ConvChain { depth: 2 } },
            TileDecision { budget_fraction: 0.25, fuse: FusePolicy::ConvChain { depth: 1 } },
        ] {
            let out = tile_candidates(fixed);
            assert_eq!(out.len(), 6, "seed {fixed:?} duplicated");
            for (a, entry) in out.iter().enumerate() {
                for other in &out[a + 1..] {
                    assert_ne!(entry, other, "duplicate candidate for seed {fixed:?}");
                }
            }
        }
    }

    #[test]
    fn explicit_threads_win_over_env_auto() {
        let explicit = OptOpts { threads: 3, ..OptOpts::default() };
        assert_eq!(explicit.resolved_threads(), 3);
        let auto = OptOpts { threads: 0, ..OptOpts::default() };
        assert!(auto.resolved_threads() >= 1);
    }

    #[test]
    fn search_counters_land_in_global_collector() {
        // serialize with every test that toggles the global gate
        let _g = crate::obs::TEST_GATE.lock().unwrap();
        crate::obs::global().reset();
        crate::obs::set_enabled(true);
        let prog = Program::lower(conv_conv());
        let cfg = AccelConfig::tiny(8 * 1024);
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &OptOpts::default(),
        )
        .unwrap();
        crate::obs::set_enabled(false);
        let snap = crate::obs::global().snapshot();
        assert!(
            snap.counters.get("opt.realized").copied().unwrap_or(0)
                >= out.stats.candidates as i64
        );
        assert!(snap.phases.iter().any(|p| p.name == "opt.search"));
        assert!(snap.phases.iter().any(|p| p.name == "opt.bank_once"));
    }

    #[test]
    fn manager_replays_the_winner_exactly() {
        // the pass manager's downstream stages must reproduce the
        // winning candidate's plan: same program, same predicted cost
        let cfg = AccelConfig::tiny(8 * 1024);
        let pm = PassManager {
            opt: Some(OptStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(conv_conv()).unwrap();
        let stats = rep.opt.expect("opt stage ran");
        let plan = rep.plan.expect("alloc stage ran");
        let cost = evaluate(&rep.program, &plan, &cfg);
        assert_eq!(cost.offchip_total(), stats.best_offchip);
        let sim = crate::accel::simulate_planned(&rep.program, &plan, &cfg, None).unwrap();
        assert_eq!(sim.offchip_total(), stats.best_offchip);
    }
}
