//! Zero-dependency worker pool for parallel candidate realization.
//!
//! [`parallel_map`] fans a slice of jobs over `threads` scoped
//! `std::thread` workers and returns the results **indexed by job
//! position**, so the caller can reduce them in candidate-generation
//! order regardless of completion order. Work is handed out through an
//! atomic cursor (dynamic load balancing: a worker that drew a cheap
//! candidate immediately pulls the next one) and results come back
//! over an mpsc channel; per-thread activity is returned in a
//! [`PoolReport`] so the search can merge worker telemetry into the
//! global collector in one step.
//!
//! Determinism contract: the pool affects *scheduling* only. Each
//! job's result is a pure function of the job itself, and the caller
//! consumes the returned `Vec` in index order — so every statistic
//! derived from it is independent of the thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

/// What one worker thread did.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct ThreadStats {
    /// Jobs this worker pulled and completed.
    pub jobs: usize,
    /// Wall time spent inside job closures.
    pub busy_seconds: f64,
}

/// Per-pool telemetry: one entry per worker thread (a single entry on
/// the serial fast path).
#[derive(Clone, Debug, Default)]
pub(crate) struct PoolReport {
    pub per_thread: Vec<ThreadStats>,
}

impl PoolReport {
    pub fn jobs(&self) -> usize {
        self.per_thread.iter().map(|t| t.jobs).sum()
    }

    fn serial(jobs: usize, busy_seconds: f64) -> PoolReport {
        PoolReport { per_thread: vec![ThreadStats { jobs, busy_seconds }] }
    }
}

/// Map `f` over `jobs` on up to `threads` workers; `out[i]` is
/// `f(i, &jobs[i])`. With `threads <= 1` (or at most one job) no
/// thread is spawned and the map runs inline — the parallel and serial
/// paths produce identical vectors by construction, differing only in
/// wall time.
pub(crate) fn parallel_map<J, R, F>(jobs: &[J], threads: usize, f: F) -> (Vec<R>, PoolReport)
where
    J: Sync,
    R: Send,
    F: Fn(usize, &J) -> R + Sync,
{
    let n = jobs.len();
    let workers = threads.min(n).max(1);
    if workers == 1 {
        let t0 = Instant::now();
        let out: Vec<R> = jobs.iter().enumerate().map(|(i, j)| f(i, j)).collect();
        return (out, PoolReport::serial(n, t0.elapsed().as_secs_f64()));
    }

    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let report = std::thread::scope(|s| {
        let cursor = &cursor;
        let f = &f;
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let tx = tx.clone();
            handles.push(s.spawn(move || {
                let mut st = ThreadStats::default();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let t0 = Instant::now();
                    let r = f(i, &jobs[i]);
                    st.busy_seconds += t0.elapsed().as_secs_f64();
                    st.jobs += 1;
                    if tx.send((i, r)).is_err() {
                        break; // receiver gone: a sibling panicked mid-scope
                    }
                }
                st
            }));
        }
        drop(tx); // workers hold the remaining senders; rx drains until they finish
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        PoolReport {
            per_thread: handles
                .into_iter()
                .map(|h| h.join().expect("search worker panicked"))
                .collect(),
        }
    });
    let out: Vec<R> = slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("worker pool lost job {i}")))
        .collect();
    (out, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_index_ordered_for_every_thread_count() {
        let jobs: Vec<u64> = (0..97).collect();
        for threads in [0usize, 1, 2, 3, 8, 128] {
            let (out, report) = parallel_map(&jobs, threads, |i, j| (i as u64) * 1000 + j * j);
            let want: Vec<u64> = jobs.iter().enumerate().map(|(i, j)| (i as u64) * 1000 + j * j).collect();
            assert_eq!(out, want, "threads={threads}");
            assert_eq!(report.jobs(), jobs.len(), "threads={threads}");
            // never more workers than jobs, always at least one
            assert!(!report.per_thread.is_empty());
            assert!(report.per_thread.len() <= jobs.len().max(1));
        }
    }

    #[test]
    fn empty_and_singleton_inputs_stay_serial() {
        let none: Vec<u32> = vec![];
        let (out, report) = parallel_map(&none, 8, |_, j| *j);
        assert!(out.is_empty());
        assert_eq!(report.per_thread.len(), 1);
        assert_eq!(report.jobs(), 0);

        let one = [41u32];
        let (out, report) = parallel_map(&one, 8, |_, j| j + 1);
        assert_eq!(out, vec![42]);
        assert_eq!(report.per_thread.len(), 1);
        assert_eq!(report.jobs(), 1);
    }

    #[test]
    fn fallible_jobs_round_trip() {
        let jobs: Vec<i32> = (0..20).collect();
        let (out, _) = parallel_map(&jobs, 4, |_, j| if j % 3 == 0 { Err(*j) } else { Ok(j * 2) });
        for (j, r) in jobs.iter().zip(&out) {
            match r {
                Ok(v) => assert_eq!(*v, j * 2),
                Err(e) => assert_eq!(e, j),
            }
        }
    }
}
