//! Double-buffer schedule extraction.
//!
//! The tiled program is plain nests; what makes it a *pipeline* is the
//! replay policy: within one tile group, the DMA engine prefetches tile
//! `t+1`'s operands while the compute engine works on tile `t`, and
//! tile `t−1`'s results ride the same DMA queue out. This module turns
//! a schedule region into [`crate::accel::engine::PipeStep`]s — one per
//! tile index, merging the fused chain members that share the index —
//! and the simulator's pipelined mode feeds them to
//! [`crate::accel::engine::pipeline_seconds`] in place of the per-nest
//! `max(compute, dma)` estimate.

use crate::accel::engine::PipeStep;
use crate::ir::loopnest::Program;

/// Per-nest cost decomposition the simulator computes during replay.
#[derive(Clone, Copy, Debug, Default)]
pub struct NestCost {
    /// Compute-engine seconds.
    pub compute: f64,
    /// DMA seconds for operand staging (off-chip reads + on-chip
    /// deposits) this nest triggers.
    pub dma_in: f64,
    /// DMA seconds for result write-back (spills / streamed stores).
    pub dma_out: f64,
}

/// Maximal schedule runs `[start, end]` (inclusive) of nests sharing
/// one tile group; untagged nests are singleton runs.
pub fn tile_runs(prog: &Program) -> Vec<(usize, usize)> {
    let mut runs = Vec::new();
    let mut i = 0usize;
    while i < prog.nests.len() {
        match prog.nests[i].tile {
            None => {
                runs.push((i, i));
                i += 1;
            }
            Some(tag) => {
                let mut j = i;
                while j + 1 < prog.nests.len()
                    && prog.nests[j + 1]
                        .tile
                        .map(|t| t.group == tag.group)
                        .unwrap_or(false)
                {
                    j += 1;
                }
                runs.push((i, j));
                i = j + 1;
            }
        }
    }
    runs
}

/// Collapse the nests of one tile-group run into pipeline steps, one
/// per tile index in schedule order (fused chain members of a tile
/// merge into its step).
pub fn run_steps(prog: &Program, run: (usize, usize), costs: &[NestCost]) -> Vec<PipeStep> {
    let mut steps: Vec<(u32, PipeStep)> = Vec::new();
    for pos in run.0..=run.1 {
        let idx = prog.nests[pos].tile.map(|t| t.index).unwrap_or(0);
        let c = costs[pos];
        match steps.last_mut() {
            Some((last, step)) if *last == idx => {
                step.dma_in += c.dma_in;
                step.compute += c.compute;
                step.dma_out += c.dma_out;
            }
            _ => steps.push((
                idx,
                PipeStep { dma_in: c.dma_in, compute: c.compute, dma_out: c.dma_out },
            )),
        }
    }
    steps.into_iter().map(|(_, s)| s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::{Program, TileTag};

    fn tagged_prog() -> Program {
        // 4 nests: untagged, two tiles of group 0, untagged
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8]);
        let a = b.relu("a", x);
        let c = b.relu("c", a);
        let d = b.relu("d", c);
        let e = b.relu("e", d);
        b.mark_output(e);
        let mut prog = Program::lower(b.finish());
        prog.nests[1].tile = Some(TileTag { group: 0, index: 0, count: 2 });
        prog.nests[2].tile = Some(TileTag { group: 0, index: 1, count: 2 });
        prog
    }

    #[test]
    fn runs_split_on_group_boundaries() {
        let prog = tagged_prog();
        assert_eq!(tile_runs(&prog), vec![(0, 0), (1, 2), (3, 3)]);
    }

    #[test]
    fn steps_merge_same_index_members() {
        let mut prog = tagged_prog();
        // make nest 2 a second member of tile 0 instead of tile 1
        prog.nests[2].tile = Some(TileTag { group: 0, index: 0, count: 1 });
        let costs = vec![
            NestCost::default(),
            NestCost { compute: 1.0, dma_in: 2.0, dma_out: 0.5 },
            NestCost { compute: 3.0, dma_in: 0.25, dma_out: 4.0 },
            NestCost::default(),
        ];
        let steps = run_steps(&prog, (1, 2), &costs);
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].compute, 4.0);
        assert_eq!(steps[0].dma_in, 2.25);
        assert_eq!(steps[0].dma_out, 4.5);
    }

    #[test]
    fn distinct_indexes_stay_distinct_steps() {
        let prog = tagged_prog();
        let costs = vec![NestCost { compute: 1.0, dma_in: 1.0, dma_out: 1.0 }; 4];
        let steps = run_steps(&prog, (1, 2), &costs);
        assert_eq!(steps.len(), 2);
    }
}
