//! Strip-mining loop nests into tile nests.
//!
//! A tile of a nest with domain `[0,E)` is the same nest restricted to
//! the sub-box `o + [0, min(S, E-o))`: the domain becomes the tile box,
//! every access map is composed with the shift `j ↦ j + o`, and guards
//! (which constrain loop dims directly) are translated and clipped into
//! tile-local coordinates. Tiles partition the original domain exactly
//! — non-divisible extents produce smaller *boundary* tiles, never
//! overlap or gaps — so the transformed program is just more nests of
//! the ordinary kind: every downstream pass, the planner and the
//! reference interpreter run on it unchanged.
//!
//! **Fused chains.** A producer followed by elementwise consumers of
//! its output (conv → batch-norm → relu) is tiled as one *chain* on a
//! shared grid over the producer's output space, with the members'
//! tiles interleaved (`A@0 B@0 C@0 A@1 B@1 …`). The chain intermediates
//! are then written and read tile-by-tile within a few schedule
//! positions — the structure `crate::alloc` detects to give them
//! double-buffered staging regions instead of whole-tensor residency,
//! which is what lets tensors bigger than the scratchpad stay off DRAM
//! entirely.
//!
//! Reduction dims (domain dims the store map drops) are never split:
//! each output element keeps its full accumulation inside one tile
//! nest, in the same lexicographic order — the determinism contract the
//! differential oracle holds every pass to.

use super::footprint::{shift_map, store_dim_map};
use crate::ir::loopnest::{Access, Body, LoadStmt, LoopNest, TileTag};
use crate::poly::piecewise::Guard;
use crate::poly::IterDomain;

/// One member of a (possibly length-1) fused chain: a nest position
/// plus, per domain dim, the grid dim tiling it (`None` = keep full).
#[derive(Clone, Debug)]
pub struct ChainMember {
    pub pos: usize,
    pub dim_of_grid: Vec<Option<usize>>,
    /// Per **grid dim**: how far this member's tile box extends beyond
    /// the grid slice `[go, go+s)` on each side. Zero for ordinary
    /// (elementwise-aligned) members; nonzero on members *upstream of a
    /// halo-consuming conv follower*, whose tiles must recompute the
    /// overlap region so the consumer's same-index tile reads a
    /// complete slice (overlapped tiling — the recompute side of the
    /// recompute-vs-stage trade). Overlap writes store identical bits:
    /// each output element's full accumulation runs inside every tile
    /// that computes it, in unchanged lexicographic order.
    pub halo: Vec<(i64, i64)>,
}

impl ChainMember {
    /// A member with no halo (the common case).
    pub fn plain(pos: usize, dim_of_grid: Vec<Option<usize>>, grid_rank: usize) -> ChainMember {
        ChainMember { pos, dim_of_grid, halo: vec![(0, 0); grid_rank] }
    }
}

/// A tiling unit: consecutive nest positions sharing a tile grid over
/// `grid_shape` (the head's output index space).
#[derive(Clone, Debug)]
pub struct Chain {
    pub members: Vec<ChainMember>,
    pub grid_shape: Vec<i64>,
    /// Grid dims the size search must never split — e.g. the channel
    /// dim once a conv follower reduces over it (splitting would make
    /// the follower read channels its producer tile never wrote).
    pub frozen: Vec<bool>,
}

impl Chain {
    pub fn head(&self) -> usize {
        self.members[0].pos
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Tile count for grid sizes `s`.
    pub fn n_tiles(&self, s: &[i64]) -> i64 {
        self.grid_shape
            .iter()
            .zip(s)
            .map(|(&e, &t)| (e + t - 1) / t)
            .product()
    }

    /// Tile-box `(offsets, extents)` of `member` for grid tile `go`
    /// with grid sizes `s`: grid-tiled dims take the (clipped) grid
    /// slice — expanded by the member's halo and re-clipped to the
    /// domain — reduction dims stay full.
    pub fn member_box(
        &self,
        nest: &LoopNest,
        member: &ChainMember,
        go: &[i64],
        s: &[i64],
    ) -> (Vec<i64>, Vec<i64>) {
        let ext = nest.domain.extents();
        let mut offs = vec![0i64; ext.len()];
        let mut exts = ext.to_vec();
        for (d, grid) in member.dim_of_grid.iter().enumerate() {
            if let Some(k) = *grid {
                let (hlo, hhi) = member.halo.get(k).copied().unwrap_or((0, 0));
                let end = (go[k] + s[k].min(self.grid_shape[k] - go[k]) + hhi).min(ext[d]);
                let start = (go[k] - hlo).max(0);
                offs[d] = start;
                exts[d] = end - start;
            }
        }
        (offs, exts)
    }

    /// Lexicographic grid-tile origins for grid sizes `s`.
    pub fn tile_origins(&self, s: &[i64]) -> Vec<Vec<i64>> {
        let counts: Vec<i64> = self
            .grid_shape
            .iter()
            .zip(s)
            .map(|(&e, &t)| (e + t - 1) / t)
            .collect();
        let mut origins = Vec::with_capacity(counts.iter().product::<i64>() as usize);
        let mut cur = vec![0i64; counts.len()];
        loop {
            origins.push(cur.iter().zip(s).map(|(&c, &t)| c * t).collect());
            let mut d = counts.len();
            loop {
                if d == 0 {
                    return origins;
                }
                d -= 1;
                cur[d] += 1;
                if cur[d] < counts[d] {
                    break;
                }
                cur[d] = 0;
            }
        }
    }
}

/// Restrict one nest to the tile box `offsets + [0, extents)`.
pub fn tile_of(nest: &LoopNest, offsets: &[i64], extents: &[i64], tag: TileTag) -> LoopNest {
    let dom = IterDomain::new(extents);
    let shift = shift_map(offsets);
    let store_map = nest.store.map.compose(&shift).simplified_in(&dom);

    let retile_load = |load: &LoadStmt| -> LoadStmt {
        let mut pieces = Vec::with_capacity(load.pieces.len());
        for piece in &load.pieces {
            let mut guards = Vec::with_capacity(piece.guards.len());
            let mut sat = true;
            for g in &piece.guards {
                // guard on loop dim `g.dim`: translate into tile-local
                // coordinates and clip to the tile box
                let lo = (g.lo - offsets[g.dim]).max(0);
                let hi = (g.hi - offsets[g.dim]).min(extents[g.dim]);
                if lo >= hi {
                    sat = false; // piece never applies inside this tile
                    break;
                }
                if lo > 0 || hi < extents[g.dim] {
                    guards.push(Guard { dim: g.dim, lo, hi });
                }
                // else: guard covers the whole tile range — drop it
            }
            if !sat {
                continue;
            }
            pieces.push(Access {
                guards,
                tensor: piece.tensor,
                map: piece.map.compose(&shift).simplified_in(&dom),
                oob_zero: piece.oob_zero,
            });
        }
        LoadStmt { pieces }
    };

    let body = match &nest.body {
        Body::Copy { load } => Body::Copy { load: retile_load(load) },
        Body::Compute { loads, flops_per_point } => Body::Compute {
            loads: loads.iter().map(retile_load).collect(),
            flops_per_point: *flops_per_point,
        },
    };
    LoopNest {
        node: nest.node,
        tile: Some(tag),
        name: format!("{}@t{}", nest.name, tag.index),
        domain: dom,
        store: crate::ir::loopnest::StoreStmt { tensor: nest.store.tensor, map: store_map },
        body,
    }
}

/// Emit the interleaved tile nests of a chain under grid sizes `s`, in
/// schedule order: all members at tile 0, then all members at tile 1, …
pub fn tile_chain(nests: &[LoopNest], chain: &Chain, s: &[i64], group: u32) -> Vec<LoopNest> {
    let origins = chain.tile_origins(s);
    let count = origins.len() as u32;
    let mut out = Vec::with_capacity(origins.len() * chain.len());
    for (idx, go) in origins.iter().enumerate() {
        for m in &chain.members {
            let nest = &nests[m.pos];
            let (offs, exts) = chain.member_box(nest, m, go, s);
            let tag = TileTag { group, index: idx as u32, count };
            out.push(tile_of(nest, &offs, &exts, tag));
        }
    }
    out
}

/// The head-member grid map: grid dim `k` (an output-space dim) tiles
/// the domain dim its store component forwards; constant components
/// (reduction-collapsed output dims) tile nothing.
pub fn head_dim_map(nest: &LoopNest) -> Option<Vec<Option<usize>>> {
    let sm = store_dim_map(nest)?;
    let in_dims = nest.store.map.in_dims();
    let mut dim_of_grid = vec![None; in_dims];
    for (k, d) in sm.iter().enumerate() {
        if let Some(d) = *d {
            dim_of_grid[d] = Some(k);
        }
    }
    Some(dim_of_grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;
    use std::collections::HashSet;

    fn single_chain(prog: &Program, pos: usize) -> Chain {
        let nest = &prog.nests[pos];
        let dim_of_grid = head_dim_map(nest).expect("tileable store");
        let grid_shape: Vec<i64> = prog.graph.tensor(nest.store.tensor).shape.clone();
        let rank = grid_shape.len();
        Chain {
            members: vec![ChainMember::plain(pos, dim_of_grid, rank)],
            frozen: vec![false; rank],
            grid_shape,
        }
    }

    #[test]
    fn tiles_partition_domain_exactly_with_prime_extent() {
        // 13 is prime: tile size 4 gives boundary tiles of extent 1
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[13, 6]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        // t's nest domain is the output box [6, 13]
        let chain = single_chain(&prog, 0);
        let s = vec![4, 4];
        let tiles = tile_chain(&prog.nests, &chain, &s, 0);
        assert_eq!(tiles.len(), 2 * 4);
        // every original domain point covered exactly once: collect the
        // store images (store is identity on the output box)
        let mut seen: HashSet<Vec<i64>> = HashSet::new();
        for tile in &tiles {
            for p in tile.domain.points() {
                let stored = tile.store.map.apply(&p);
                assert!(seen.insert(stored.clone()), "double cover at {stored:?}");
            }
        }
        assert_eq!(seen.len() as i64, prog.nests[0].domain.cardinality());
    }

    #[test]
    fn tiled_copy_reads_same_sources() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[7, 5]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        let chain = single_chain(&prog, 0);
        let tiles = tile_chain(&prog.nests, &chain, &[3, 2], 0);
        // per output element, source index must match the untiled nest
        let orig = &prog.nests[0];
        for tile in &tiles {
            let Body::Copy { load } = &tile.body else { panic!() };
            for p in tile.domain.points() {
                let out_idx = tile.store.map.apply(&p);
                let (src_t, src_idx) = load.at(&p).unwrap();
                // find the untiled point producing the same output
                let q = out_idx.clone(); // identity store on the output box
                let (ot, oidx) = {
                    let Body::Copy { load } = &orig.body else { panic!() };
                    let (a, b2) = load.at(&q).unwrap();
                    (a, b2)
                };
                assert_eq!(src_t, ot);
                assert_eq!(src_idx, oidx);
            }
        }
    }

    #[test]
    fn guards_rewritten_per_tile() {
        // pad produces piecewise loads with guards; tiling must keep
        // exactly-once coverage inside every tile
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[5]);
        let p = b.pad("p", x, &[2], &[3]); // out extent 10
        b.mark_output(p);
        let prog = Program::lower(b.finish());
        let chain = single_chain(&prog, 0);
        let tiles = tile_chain(&prog.nests, &chain, &[3], 0);
        assert_eq!(tiles.len(), 4); // 3+3+3+1
        for tile in &tiles {
            let Body::Copy { load } = &tile.body else { panic!() };
            for pt in tile.domain.points() {
                let n = load.pieces.iter().filter(|a| a.holds(&pt)).count();
                assert_eq!(n, 1, "tile {} point {pt:?} covered {n}x", tile.name);
            }
        }
    }

    #[test]
    fn halo_member_boxes_overlap_and_cover() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8]);
        let r = b.relu("r", x);
        b.mark_output(r);
        let prog = Program::lower(b.finish());
        let chain = Chain {
            members: vec![ChainMember {
                pos: 0,
                dim_of_grid: vec![Some(0)],
                halo: vec![(1, 1)],
            }],
            grid_shape: vec![8],
            frozen: vec![false],
        };
        let s = vec![4i64];
        let nest = &prog.nests[0];
        let origins = chain.tile_origins(&s);
        assert_eq!(origins, vec![vec![0], vec![4]]);
        let (o0, e0) = chain.member_box(nest, &chain.members[0], &origins[0], &s);
        let (o1, e1) = chain.member_box(nest, &chain.members[0], &origins[1], &s);
        // first tile: [0, 5) (halo above clipped below at 0)
        assert_eq!((o0[0], e0[0]), (0, 5));
        // second tile: [3, 8) — overlapping the first by the halo
        assert_eq!((o1[0], e1[0]), (3, 5));
        // union covers the whole grid
        assert!(o0[0] == 0 && o1[0] + e1[0] == 8 && o1[0] <= o0[0] + e0[0]);
    }

    #[test]
    fn chain_interleaves_members() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8]);
        let t = b.relu("r", x);
        let y = b.identity("y", t);
        b.mark_output(y);
        let prog = Program::lower(b.finish());
        let chain = Chain {
            members: vec![
                ChainMember::plain(0, vec![Some(0)], 1),
                ChainMember::plain(1, vec![Some(0)], 1),
            ],
            grid_shape: vec![8],
            frozen: vec![false],
        };
        let tiles = tile_chain(&prog.nests, &chain, &[4], 3);
        let names: Vec<&str> = tiles.iter().map(|n| n.name.as_str()).collect();
        assert_eq!(names, vec!["r@t0", "y@t0", "r@t1", "y@t1"]);
        for (i, tile) in tiles.iter().enumerate() {
            let tag = tile.tile.unwrap();
            assert_eq!(tag.group, 3);
            assert_eq!(tag.count, 2);
            assert_eq!(tag.index as usize, i / 2);
        }
    }
}
