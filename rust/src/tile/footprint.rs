//! Per-tile working-set analysis.
//!
//! The sizing question tiling has to answer — "what is the largest tile
//! whose double-buffered working set fits the scratchpad?" — reduces to
//! *imaging a box through the nest's access maps*: for a candidate tile
//! box `o + [0,S)` the bytes a load touches are the (clipped) bounding
//! box of `map(o + [0,S))`, which [`crate::poly::Expr::range`] computes
//! exactly for affine components and conservatively (never under) for
//! quasi-affine ones. This is the same access-map machinery the DME and
//! bank passes are built on, pointed at transfer sizing the way Zheng
//! et al. size their staging buffers.
//!
//! Conventions:
//! * footprints are measured in **bytes of the touched bounding box**,
//!   clipped to the tensor box (`oob_zero` halo reads cost nothing
//!   outside the tensor — the hardware synthesizes zeros);
//! * piecewise loads take the bounding box of the union of their
//!   pieces (guards are ignored — an over-approximation, sound for
//!   capacity);
//! * a tensor touched by several loads of one nest is counted once,
//!   at the union bounding box.

use crate::ir::graph::Graph;
use crate::ir::loopnest::LoopNest;
use crate::ir::tensor::TensorId;
use crate::poly::{AccessMap, Expr, IterDomain};
use std::collections::BTreeMap;

/// The map `j ↦ j + offsets` on `offsets.len()` dims — the inner shift
/// that turns a nest-local access map into a tile-local one.
pub fn shift_map(offsets: &[i64]) -> AccessMap {
    AccessMap::new(
        offsets.len(),
        offsets
            .iter()
            .enumerate()
            .map(|(d, &o)| Expr::dim(d).add(Expr::cst(o)))
            .collect(),
    )
}

/// Bounding box `(lo, hi)` (inclusive) per tensor dim of `map` over the
/// box `offsets + [0, extents)`, unclipped.
fn image_box(map: &AccessMap, offsets: &[i64], extents: &[i64]) -> Vec<(i64, i64)> {
    let shifted = map.compose(&shift_map(offsets));
    shifted
        .exprs()
        .iter()
        .map(|e| e.range(extents).expect("tile box covers every map dim"))
        .collect()
}

/// Merge `b` into the running union box `acc`.
fn union_box(acc: &mut Vec<(i64, i64)>, b: &[(i64, i64)]) {
    if acc.is_empty() {
        acc.extend_from_slice(b);
        return;
    }
    for (a, &(lo, hi)) in acc.iter_mut().zip(b) {
        a.0 = a.0.min(lo);
        a.1 = a.1.max(hi);
    }
}

/// Bytes of a union box clipped to the tensor's shape (0 if empty).
fn box_bytes(bbox: &[(i64, i64)], shape: &[i64], elem_bytes: i64) -> i64 {
    let mut elems = 1i64;
    for (&(lo, hi), &s) in bbox.iter().zip(shape) {
        let lo = lo.max(0);
        let hi = hi.min(s - 1);
        if hi < lo {
            return 0;
        }
        elems *= hi - lo + 1;
    }
    elems * elem_bytes
}

/// Bytes of every tensor a nest touches (loads and store), measured as
/// clipped image bounding boxes over the sub-box `offsets + [0,
/// extents)` of the nest's domain. Pass `offsets = 0…0` and `extents =
/// domain` for the whole-nest working set.
pub fn touched_bytes_in(
    g: &Graph,
    nest: &LoopNest,
    offsets: &[i64],
    extents: &[i64],
) -> BTreeMap<TensorId, i64> {
    // per tensor: union bounding box across every load piece + store
    let mut boxes: BTreeMap<TensorId, Vec<(i64, i64)>> = BTreeMap::new();
    for load in nest.body.loads() {
        for piece in &load.pieces {
            let Some(t) = piece.tensor else { continue };
            let b = image_box(&piece.map, offsets, extents);
            union_box(boxes.entry(t).or_default(), &b);
        }
    }
    let sb = image_box(&nest.store.map, offsets, extents);
    union_box(boxes.entry(nest.store.tensor).or_default(), &sb);

    boxes
        .into_iter()
        .map(|(t, bbox)| {
            let info = g.tensor(t);
            (t, box_bytes(&bbox, &info.shape, info.dtype.size_bytes()))
        })
        .collect()
}

/// Whole-nest working set: bytes of every tensor the nest touches.
pub fn nest_touched_bytes(g: &Graph, nest: &LoopNest) -> BTreeMap<TensorId, i64> {
    let ext = nest.domain.extents().to_vec();
    touched_bytes_in(g, nest, &vec![0; ext.len()], &ext)
}

/// Offset-independent per-tensor **upper bound** on the bytes any tile
/// of extents `extents` touches: per tensor dim, the unclipped image
/// width of the tile box (affine widths do not depend on the tile's
/// position) capped at the tensor extent. Exact for interior tiles of
/// affine accesses; never below any real tile's clipped footprint.
pub fn touched_bytes_bound(
    g: &Graph,
    nest: &LoopNest,
    extents: &[i64],
) -> BTreeMap<TensorId, i64> {
    let zeros = vec![0i64; extents.len()];
    let mut boxes: BTreeMap<TensorId, Vec<(i64, i64)>> = BTreeMap::new();
    for load in nest.body.loads() {
        for piece in &load.pieces {
            let Some(t) = piece.tensor else { continue };
            let b = image_box(&piece.map, &zeros, extents);
            union_box(boxes.entry(t).or_default(), &b);
        }
    }
    let sb = image_box(&nest.store.map, &zeros, extents);
    union_box(boxes.entry(nest.store.tensor).or_default(), &sb);

    boxes
        .into_iter()
        .map(|(t, bbox)| {
            let info = g.tensor(t);
            let elems: i64 = bbox
                .iter()
                .zip(&info.shape)
                .map(|(&(lo, hi), &s)| (hi - lo + 1).min(s).max(0))
                .product();
            (t, elems * info.dtype.size_bytes())
        })
        .collect()
}

/// Bytes of one tensor a nest touches (0 when untouched). The planned
/// simulator charges exactly this per tile nest for DRAM-homed
/// operands, and [`crate::alloc::verify_plan`] checks tile-staged
/// regions against it. (Delegates to [`nest_tensor_box`] so hot
/// callers never image the nest's *other* tensors.)
pub fn nest_tensor_bytes(g: &Graph, nest: &LoopNest, t: TensorId) -> i64 {
    nest_tensor_box(g, nest, t).map(|(_, b)| b).unwrap_or(0)
}

/// Clipped image box (inclusive per-dim bounds) and byte count of one
/// tensor under a nest; `None` when the nest does not touch it or the
/// touch clips to nothing. Tile nests carry their shift inside their
/// maps, so boxes are in absolute tensor coordinates and comparable
/// across tiles — the pipelined simulator uses box identity between
/// consecutive tiles to recognize operand slices that stay resident in
/// the staging buffer (a weight slice reused by every spatial tile of
/// one output-channel block is fetched once, not per tile).
pub fn nest_tensor_box(
    g: &Graph,
    nest: &LoopNest,
    t: TensorId,
) -> Option<(Vec<(i64, i64)>, i64)> {
    let ext = nest.domain.extents().to_vec();
    let offs = vec![0i64; ext.len()];
    let mut bbox: Vec<(i64, i64)> = Vec::new();
    let mut found = false;
    for load in nest.body.loads() {
        for piece in &load.pieces {
            if piece.tensor == Some(t) {
                union_box(&mut bbox, &image_box(&piece.map, &offs, &ext));
                found = true;
            }
        }
    }
    if nest.store.tensor == t {
        union_box(&mut bbox, &image_box(&nest.store.map, &offs, &ext));
        found = true;
    }
    if !found {
        return None;
    }
    let info = g.tensor(t);
    let mut clipped = Vec::with_capacity(bbox.len());
    let mut elems = 1i64;
    for (&(lo, hi), &s) in bbox.iter().zip(&info.shape) {
        let lo = lo.max(0);
        let hi = hi.min(s - 1);
        if hi < lo {
            return None;
        }
        elems *= hi - lo + 1;
        clipped.push((lo, hi));
    }
    Some((clipped, elems * info.dtype.size_bytes()))
}

/// Does any load of tensor `t` in this nest index through domain dim
/// `d`? (Read side only — used by the tile-size search to predict
/// which grid splits change the slice a tile reads.)
pub fn tensor_read_uses_dim(nest: &LoopNest, t: TensorId, d: usize) -> bool {
    nest.body.loads().iter().any(|l| {
        l.pieces.iter().any(|p| {
            p.tensor == Some(t) && p.map.exprs().iter().any(|e| expr_uses_dim(e, d))
        })
    })
}

/// Sum of a nest's touched bytes — its working set if staged whole.
pub fn nest_working_set(g: &Graph, nest: &LoopNest) -> i64 {
    nest_touched_bytes(g, nest).values().sum()
}

/// Does `e` mention loop dim `d`?
pub(crate) fn expr_uses_dim(e: &Expr, d: usize) -> bool {
    match e {
        Expr::Cst(_) => false,
        Expr::Dim(k) => *k == d,
        Expr::Add(a, b) => expr_uses_dim(a, d) || expr_uses_dim(b, d),
        Expr::Mul(_, inner) | Expr::Div(inner, _) | Expr::Mod(inner, _) => {
            expr_uses_dim(inner, d)
        }
    }
}

/// Is tensor `t` tile-invariant in `nest` under the given tiled domain
/// dims — i.e. none of its access-map components mention a tiled dim?
/// Invariant tensors (conv weights under spatial tiling) are staged
/// once and reused by every tile, so they count 1× (not 2×) in the
/// double-buffer budget.
pub fn tensor_tile_invariant(nest: &LoopNest, t: TensorId, tiled_dims: &[usize]) -> bool {
    let uses_tiled = |m: &AccessMap| {
        m.exprs()
            .iter()
            .any(|e| tiled_dims.iter().any(|&d| expr_uses_dim(e, d)))
    };
    for load in nest.body.loads() {
        for piece in &load.pieces {
            if piece.tensor == Some(t) && uses_tiled(&piece.map) {
                return false;
            }
        }
    }
    !(nest.store.tensor == t && uses_tiled(&nest.store.map))
}

/// The per-out-dim domain source of a nest's store map: `Some(d)` when
/// component `k` is `i_d + c` (unit coefficient), `None` when it is a
/// constant (reduction-collapsed dims, e.g. pooling's spatial outputs
/// of a GlobalAvgPool). Returns `None` overall when any component is
/// non-affine, has a non-unit coefficient, or two components read the
/// same domain dim — the shapes whose tile store-images could overlap,
/// which tiling must refuse.
pub fn store_dim_map(nest: &LoopNest) -> Option<Vec<Option<usize>>> {
    let in_dims = nest.store.map.in_dims();
    let mut seen = vec![false; in_dims];
    let mut out = Vec::with_capacity(nest.store.map.out_dims());
    for e in nest.store.map.exprs() {
        let (coeffs, _cst) = e.as_affine(in_dims)?;
        let nz: Vec<usize> = coeffs
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0)
            .map(|(d, _)| d)
            .collect();
        match nz.as_slice() {
            [] => out.push(None),
            [d] if coeffs[*d] == 1 && !seen[*d] => {
                seen[*d] = true;
                out.push(Some(*d));
            }
            _ => return None,
        }
    }
    Some(out)
}

/// Domain dims of a nest that tiling may strip-mine: the dims its store
/// map forwards with unit coefficient. Dims absent from the store map
/// are reduction dims — splitting one would split an accumulation
/// across nests and change the result.
pub fn tileable_dims(nest: &LoopNest) -> Option<Vec<usize>> {
    Some(store_dim_map(nest)?.into_iter().flatten().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;

    fn conv_prog() -> Program {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 8, 8]);
        let w = b.weight("w", &[6, 4, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        b.mark_output(c);
        Program::lower(b.finish())
    }

    #[test]
    fn whole_nest_touches_whole_tensors() {
        let prog = conv_prog();
        let nest = &prog.nests[0];
        let touched = nest_touched_bytes(&prog.graph, nest);
        // x, w and the output are each touched in full
        for t in prog.graph.tensors() {
            assert_eq!(
                touched.get(&t.id).copied().unwrap_or(0),
                t.size_bytes(),
                "tensor {}",
                t.name
            );
        }
    }

    #[test]
    fn tile_box_shrinks_varying_tensors_only() {
        let prog = conv_prog();
        let nest = &prog.nests[0];
        // domain (n, co, oh, ow, ci, kh, kw) = [1,6,8,8,4,3,3];
        // take the output-row half-tile oh in [0,4)
        let offs = vec![0, 0, 0, 0, 0, 0, 0];
        let ext = vec![1, 6, 4, 8, 4, 3, 3];
        let touched = touched_bytes_in(&prog.graph, nest, &offs, &ext);
        let (x, w, y) = {
            let mut it = prog.graph.tensors();
            let x = it.next().unwrap().id;
            let w = it.next().unwrap().id;
            let y = it.next().unwrap().id;
            (x, w, y)
        };
        // weights untouched by spatial tiling
        assert_eq!(touched[&w], prog.graph.tensor(w).size_bytes());
        // output: half the rows
        assert_eq!(touched[&y], prog.graph.tensor(y).size_bytes() / 2);
        // input: rows -1..=4 clipped to 0..=4 -> 5 of 8 rows
        assert_eq!(touched[&x], 4 * 5 * 8 * 4);
        assert!(tensor_tile_invariant(nest, w, &[2, 3]));
        assert!(!tensor_tile_invariant(nest, x, &[2, 3]));
        assert!(!tensor_tile_invariant(nest, y, &[2, 3]));
    }

    #[test]
    fn boundary_tile_clips_to_tensor_box() {
        let prog = conv_prog();
        let nest = &prog.nests[0];
        // last output-row stripe: oh in [6,8) reads x rows 5..=8 -> clip
        let offs = vec![0, 0, 6, 0, 0, 0, 0];
        let ext = vec![1, 6, 2, 8, 4, 3, 3];
        let touched = touched_bytes_in(&prog.graph, nest, &offs, &ext);
        let x = prog.graph.tensors().next().unwrap().id;
        // rows 5..=7 survive the clip (row 8 is oob_zero halo): 3 rows
        assert_eq!(touched[&x], 4 * 3 * 8 * 4);
    }

    #[test]
    fn store_dim_map_shapes() {
        let prog = conv_prog();
        // conv store (d0,d1,d2,d3) over a 7-dim domain
        assert_eq!(
            store_dim_map(&prog.nests[0]),
            Some(vec![Some(0), Some(1), Some(2), Some(3)])
        );
        assert_eq!(tileable_dims(&prog.nests[0]), Some(vec![0, 1, 2, 3]));

        let mut b = GraphBuilder::new();
        let x = b.input("x", &[2, 3, 4, 4]);
        let g1 = b.gap("g", x);
        b.mark_output(g1);
        let p = Program::lower(b.finish());
        // GAP store (d0,d1,0,0): spatial dims are reductions
        assert_eq!(
            store_dim_map(&p.nests[0]),
            Some(vec![Some(0), Some(1), None, None])
        );
        assert_eq!(tileable_dims(&p.nests[0]), Some(vec![0, 1]));
    }

    #[test]
    fn strided_store_is_refused() {
        use crate::ir::loopnest::{Body, LoadStmt, LoopNest, StoreStmt};
        use crate::ir::tensor::{DType, TensorKind};
        let mut g = Graph::new();
        let x = g.add_tensor("x", &[8], DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", &[16], DType::F32, TensorKind::Output);
        let n = g.add_node("s", crate::ir::op::OpKind::Identity, vec![x], y);
        let nest = LoopNest {
            node: n,
            tile: None,
            name: "s".into(),
            domain: IterDomain::new(&[8]),
            store: StoreStmt {
                tensor: y,
                map: AccessMap::new(1, vec![Expr::dim(0).scale(2)]),
            },
            body: Body::Copy { load: LoadStmt::total(x, AccessMap::identity(1)) },
        };
        assert_eq!(store_dim_map(&nest), None);
    }
}
