//! Polyhedral loop tiling — staging tensors larger than the scratchpad.
//!
//! The planner (`crate::alloc`) can only make a tensor resident when it
//! fits; anything larger fell back to DRAM streaming, so the workloads
//! the paper cares most about — feature maps bigger than on-chip SRAM —
//! were never actually *staged*. This subsystem closes that gap with
//! three cooperating parts:
//!
//! * [`footprint`] — sizes tiles by imaging candidate tile boxes
//!   through the nests' access maps (the `poly` machinery the passes
//!   already use), picking the largest grid whose **double-buffered**
//!   working set (2× tile-varying tensors + 1× tile-invariant ones,
//!   e.g. conv weights) fits the configured budget;
//! * [`transform`] — strip-mines the chosen nests into ordinary tile
//!   nests (exact boundary tiles on non-divisible extents, guards and
//!   access maps rewritten), interleaving fused producer→elementwise
//!   chains on a shared grid so chain intermediates are produced and
//!   consumed within a few schedule positions;
//! * [`pipeline`] — extracts the double-buffer schedule (prefetch tile
//!   *t+1* while computing tile *t*, write back *t−1*) that the
//!   simulator's pipelined mode replays with a two-engine overlap model
//!   instead of the per-nest `max(compute, dma)` fiction.
//!
//! Downstream, `alloc` detects chain intermediates whose every writer
//! and reader is a tile nest of one group and plans them into
//! double-buffered staging regions ([`crate::alloc::Home::Staged`])
//! instead of whole-tensor residency — the step that finally takes
//! oversized intermediates off DRAM.
//!
//! Run as an optional [`crate::passes::manager::PassManager`] stage
//! between DME and bank mapping; the differential oracle proves the
//! transformed program bit-identical (tiling never splits reduction
//! dims, so accumulation order is preserved).

pub mod footprint;
pub mod pipeline;
pub mod transform;

use crate::accel::config::AccelConfig;
use crate::ir::loopnest::{LoopNest, Program};
use crate::ir::op::OpKind;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::util::json::Json;
use std::collections::BTreeMap;

use self::transform::{Chain, ChainMember};

/// Tiling configuration.
#[derive(Clone, Copy, Debug)]
pub struct TileOpts {
    /// Fraction of the total scratchpad the double-buffered tile
    /// working set may use (the rest is headroom for co-resident
    /// weights and the planner's other windows).
    pub budget_fraction: f64,
    /// Hard cap on tiles per chain (bounds schedule growth).
    pub max_tiles: usize,
    /// Fuse elementwise consumers onto their producer's grid.
    pub fuse: bool,
}

impl Default for TileOpts {
    fn default() -> Self {
        TileOpts { budget_fraction: 0.5, max_tiles: 1024, fuse: true }
    }
}

/// What the tiling stage did.
#[derive(Clone, Copy, Debug, Default)]
pub struct TileStats {
    /// Tile groups emitted (one per tiled nest/chain).
    pub groups: usize,
    /// Original nests that were strip-mined.
    pub nests_tiled: usize,
    /// Tile nests emitted in their place.
    pub tiles_emitted: usize,
    /// Groups that fused ≥ 2 members onto one grid.
    pub fused_chains: usize,
    /// Longest fused chain.
    pub max_chain_len: usize,
}

impl TileStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("groups", Json::Int(self.groups as i64)),
            ("nests_tiled", Json::Int(self.nests_tiled as i64)),
            ("tiles_emitted", Json::Int(self.tiles_emitted as i64)),
            ("fused_chains", Json::Int(self.fused_chains as i64)),
            ("max_chain_len", Json::Int(self.max_chain_len as i64)),
        ])
    }
}

/// Op kinds tiling may strip-mine. Copy bodies are always eligible;
/// `Softmax` is excluded (its row reduction spans the whole domain and
/// the interpreter's lowering contract pins its store to the full box).
fn tileable_kind(kind: &OpKind, nest: &LoopNest) -> bool {
    if nest.body.is_copy() {
        return true;
    }
    matches!(
        kind,
        OpKind::Conv2d { .. }
            | OpKind::DepthwiseConv2d { .. }
            | OpKind::Conv1d { .. }
            | OpKind::MatMul
            | OpKind::Pool { .. }
            | OpKind::GlobalAvgPool
            | OpKind::Unary(_)
            | OpKind::Binary(_)
            | OpKind::BatchNorm
            | OpKind::BiasAdd
    )
}

/// Can this head accept fused followers? Requires a pure projection
/// store (`i_d` / constant components, no offsets) whose grid equals
/// the output tensor box, so follower domains align with the grid.
fn fusable_head(prog: &Program, nest: &LoopNest, grid_shape: &[i64]) -> bool {
    use crate::poly::Expr;
    nest.store
        .map
        .exprs()
        .iter()
        .all(|e| matches!(e, Expr::Dim(_) | Expr::Cst(_)))
        && prog.graph.tensor(nest.store.tensor).shape == grid_shape
}

/// Is nest `q` an eligible elementwise follower consuming `y`?
fn elementwise_follower(prog: &Program, q: usize, y: TensorId, grid_shape: &[i64]) -> bool {
    let nest = &prog.nests[q];
    let node = prog.graph.node(nest.node);
    if !tileable_kind(&node.kind, nest) {
        return false;
    }
    if !nest.store.map.is_identity() || nest.domain.extents() != grid_shape {
        return false;
    }
    // every read of y must be a plain identity load
    for load in nest.body.loads() {
        for piece in &load.pieces {
            if piece.tensor == Some(y)
                && !(piece.guards.is_empty() && !piece.oob_zero && piece.map.is_identity())
            {
                return false;
            }
        }
    }
    true
}

/// Detect the tiling chain starting at nest position `p`: the nest
/// itself (if tileable), extended — when `fuse` — over consecutive
/// sole-consumer elementwise nests on the same grid.
fn detect_chain(prog: &Program, p: usize, opts: &TileOpts) -> Option<Chain> {
    let head = &prog.nests[p];
    let node = prog.graph.node(head.node);
    if !tileable_kind(&node.kind, head) {
        return None;
    }
    let dim_of_grid = transform::head_dim_map(head)?;
    let sm = footprint::store_dim_map(head)?;
    let ext = head.domain.extents();
    let grid_shape: Vec<i64> = sm
        .iter()
        .map(|d| d.map(|d| ext[d]).unwrap_or(1))
        .collect();
    let mut chain = Chain {
        members: vec![ChainMember { pos: p, dim_of_grid }],
        grid_shape,
    };

    if opts.fuse && fusable_head(prog, head, &chain.grid_shape) {
        let mut y = head.store.tensor;
        let mut q = p + 1;
        while q < prog.nests.len() {
            let info = prog.graph.tensor(y);
            if info.kind != TensorKind::Intermediate {
                break;
            }
            if prog.graph.consumers(y).len() != 1 {
                break;
            }
            if prog.writers(y) != vec![q - 1] || prog.readers(y) != vec![q] {
                break;
            }
            if !elementwise_follower(prog, q, y, &chain.grid_shape) {
                break;
            }
            let nd = chain.grid_shape.len();
            chain.members.push(ChainMember {
                pos: q,
                dim_of_grid: (0..nd).map(Some).collect(),
            });
            y = prog.nests[q].store.tensor;
            q += 1;
        }
    }
    Some(chain)
}

/// Worst-case double-buffered tile working set of a chain under grid
/// sizes `s`: per sampled tile, tile-varying tensors count twice (the
/// live tile plus its prefetch/writeback partner), tile-invariant ones
/// (weights under spatial tiling) once.
pub fn chain_tile_footprint(prog: &Program, chain: &Chain, s: &[i64]) -> i64 {
    let g = &prog.graph;
    // which grid dims actually split under s
    let split: Vec<bool> = chain
        .grid_shape
        .iter()
        .zip(s)
        .map(|(&e, &t)| t < e)
        .collect();
    // per member, the domain dims that vary across tiles
    let member_tiled: Vec<Vec<usize>> = chain
        .members
        .iter()
        .map(|m| {
            m.dim_of_grid
                .iter()
                .enumerate()
                .filter_map(|(d, k)| match k {
                    Some(k) if split[*k] => Some(d),
                    _ => None,
                })
                .collect()
        })
        .collect();
    // a tensor is invariant iff invariant in every member touching it
    let mut invariant: BTreeMap<TensorId, bool> = BTreeMap::new();
    for (mi, m) in chain.members.iter().enumerate() {
        let nest = &prog.nests[m.pos];
        for (t, _) in footprint::nest_touched_bytes(g, nest) {
            let inv = footprint::tensor_tile_invariant(nest, t, &member_tiled[mi]);
            invariant
                .entry(t)
                .and_modify(|v| *v = *v && inv)
                .or_insert(inv);
        }
    }

    // Affine access maps have offset-independent image widths, so a
    // single analytic bound — unclipped widths of a full-size tile box,
    // capped at the tensor extent — dominates every real tile
    // (`footprint::touched_bytes_bound`). Quasi-affine maps (div/mod
    // from reshape/tile/repeat) vary with the tile's position, so those
    // chains evaluate every tile origin exactly (they are capped at
    // `max_tiles` anyway).
    let all_affine = chain.members.iter().all(|m| {
        let nest = &prog.nests[m.pos];
        nest.store.map.is_affine()
            && nest
                .body
                .loads()
                .iter()
                .all(|l| l.pieces.iter().all(|p| p.map.is_affine()))
    });

    let mut worst = 0i64;
    if all_affine {
        let mut per_tensor: BTreeMap<TensorId, i64> = BTreeMap::new();
        for m in &chain.members {
            let nest = &prog.nests[m.pos];
            // full-size tile box of this member (boundary tiles only shrink)
            let ext = nest.domain.extents();
            let exts: Vec<i64> = m
                .dim_of_grid
                .iter()
                .enumerate()
                .map(|(d, k)| match k {
                    Some(k) => s[*k].min(chain.grid_shape[*k]),
                    None => ext[d],
                })
                .collect();
            for (t, b) in footprint::touched_bytes_bound(g, nest, &exts) {
                let e = per_tensor.entry(t).or_insert(0);
                *e = (*e).max(b);
            }
        }
        worst = per_tensor
            .iter()
            .map(|(t, &b)| if invariant[t] { b } else { 2 * b })
            .sum();
    } else {
        for go in &chain.tile_origins(s) {
            let mut per_tensor: BTreeMap<TensorId, i64> = BTreeMap::new();
            for m in &chain.members {
                let nest = &prog.nests[m.pos];
                let (offs, exts) = chain.member_box(nest, m, go, s);
                for (t, b) in footprint::touched_bytes_in(g, nest, &offs, &exts) {
                    let e = per_tensor.entry(t).or_insert(0);
                    *e = (*e).max(b);
                }
            }
            let total: i64 = per_tensor
                .iter()
                .map(|(t, &b)| if invariant[t] { b } else { 2 * b })
                .sum();
            worst = worst.max(total);
        }
    }
    worst
}

/// Predicted excess DRAM traffic of grid sizes `s`: for every tensor a
/// member *reads* that cannot be scratchpad-resident (its whole-tensor
/// slice exceeds a bank, so the planner will stream it), each grid dim
/// the tensor does **not** vary in, sitting outside (lexicographically
/// above) a dim it does vary in, multiplies how often its slices must
/// be re-fetched — e.g. splitting output channels makes every
/// channel block re-sweep the whole input. Dims the tensor varies in
/// are counted at the *full* grid (they will usually be split later),
/// so the penalty is visible before the inner split happens — which is
/// what steers the greedy search away from such splits up front.
pub fn chain_stream_penalty(
    prog: &Program,
    chain: &Chain,
    s: &[i64],
    cfg: &AccelConfig,
) -> i64 {
    let g = &prog.graph;
    let counts: Vec<i64> = chain
        .grid_shape
        .iter()
        .zip(s)
        .map(|(&e, &t)| (e + t - 1) / t)
        .collect();
    // per read tensor: the grid dims it (potentially) varies in
    let mut varies: BTreeMap<TensorId, Vec<bool>> = BTreeMap::new();
    for m in &chain.members {
        let nest = &prog.nests[m.pos];
        for load in nest.body.loads() {
            for piece in &load.pieces {
                let Some(t) = piece.tensor else { continue };
                let v = varies
                    .entry(t)
                    .or_insert_with(|| vec![false; chain.grid_shape.len()]);
                for (d, k) in m.dim_of_grid.iter().enumerate() {
                    if let Some(k) = *k {
                        if chain.grid_shape[k] > 1
                            && footprint::tensor_read_uses_dim(nest, t, d)
                        {
                            v[k] = true;
                        }
                    }
                }
            }
        }
    }
    let mut penalty = 0i64;
    for (t, v) in &varies {
        let info = g.tensor(*t);
        if crate::alloc::offsets::per_bank_bytes(info.size_bytes(), cfg.banks)
            <= cfg.bank_bytes
        {
            continue; // can be resident — reuse is free
        }
        let Some(kmax) = v.iter().rposition(|&x| x) else { continue };
        let repeat: i64 = (0..=kmax).filter(|&k| !v[k]).map(|k| counts[k]).product();
        if repeat > 1 {
            penalty += (repeat - 1) * info.size_bytes();
        }
    }
    penalty
}

/// Greedy tile-size search: start at the whole grid and repeatedly
/// halve a dim until the worst-case double-buffered footprint fits
/// `budget`. Candidates are ranked by `(stream penalty, footprint)`:
/// first avoid splits that multiply re-streaming of DRAM-bound operands
/// ([`chain_stream_penalty`]), then shrink the working set fastest.
/// `None` when the chain already fits untiled (measured 1×: a single
/// "tile" needs no buddy buffer), or when even the finest split within
/// the tile cap cannot fit (e.g. an un-splittable invariant operand
/// dominates).
///
/// Terminates because every step strictly shrinks one grid dim: at
/// most `Σ ceil(log2 grid[k])` iterations.
pub fn choose_grid_sizes(
    prog: &Program,
    chain: &Chain,
    budget: i64,
    max_tiles: usize,
    cfg: &AccelConfig,
) -> Option<Vec<i64>> {
    let mut s = chain.grid_shape.clone();
    if chain_tile_footprint(prog, chain, &s) <= budget {
        return None; // fits whole — no tiling needed
    }
    loop {
        let mut best: Option<(i64, i64, usize)> = None;
        for k in 0..s.len() {
            if s[k] <= 1 {
                continue;
            }
            let mut s2 = s.clone();
            s2[k] = (s[k] + 1) / 2;
            if chain.n_tiles(&s2) > max_tiles as i64 {
                continue;
            }
            let fp = chain_tile_footprint(prog, chain, &s2);
            let pen = chain_stream_penalty(prog, chain, &s2, cfg);
            if best.map(|(bp, bf, _)| (pen, fp) < (bp, bf)).unwrap_or(true) {
                best = Some((pen, fp, k));
            }
        }
        let (_, fp, k) = best?;
        s[k] = (s[k] + 1) / 2;
        if fp <= budget {
            return Some(s);
        }
    }
}

/// Run the tiling stage over a lowered (post-DME) program: detect
/// oversized nests/chains, choose grids, strip-mine in place.
pub fn run_tiling(prog: &mut Program, cfg: &AccelConfig, opts: &TileOpts) -> TileStats {
    let budget = (cfg.scratchpad_bytes() as f64 * opts.budget_fraction) as i64;
    let mut stats = TileStats::default();
    let mut out: Vec<LoopNest> = Vec::with_capacity(prog.nests.len());
    let mut group: u32 = 0;
    let mut p = 0usize;
    while p < prog.nests.len() {
        let tiled = match detect_chain(prog, p, opts) {
            Some(chain) => match choose_grid_sizes(prog, &chain, budget, opts.max_tiles, cfg) {
                Some(s) => {
                    let tiles = transform::tile_chain(&prog.nests, &chain, &s, group);
                    stats.groups += 1;
                    stats.nests_tiled += chain.len();
                    stats.tiles_emitted += tiles.len();
                    if chain.len() > 1 {
                        stats.fused_chains += 1;
                    }
                    stats.max_chain_len = stats.max_chain_len.max(chain.len());
                    out.extend(tiles);
                    group += 1;
                    Some(chain.len())
                }
                None => None,
            },
            None => None,
        };
        match tiled {
            Some(len) => p += len,
            None => {
                out.push(prog.nests[p].clone());
                p += 1;
            }
        }
    }
    prog.nests = out;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::poly::IterDomain;

    /// conv → bn → relu with a 16 KiB feature map on a 4 KiB chip.
    fn chain_graph() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 16, 16]);
        let w = b.weight("w", &[4, 4, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let n = b.batchnorm("bn", c);
        let r = b.relu("r", n);
        b.mark_output(r);
        b.finish()
    }

    #[test]
    fn oversized_chain_is_tiled_and_fused() {
        let mut prog = Program::lower(chain_graph());
        let cfg = AccelConfig::tiny(4 * 1024);
        let stats = run_tiling(&mut prog, &cfg, &TileOpts::default());
        assert!(stats.groups >= 1, "{stats:?}");
        assert!(stats.fused_chains >= 1, "conv->bn->relu should fuse: {stats:?}");
        assert!(stats.max_chain_len >= 3, "{stats:?}");
        assert!(stats.tiles_emitted > stats.nests_tiled);
        verify_graph(&prog.graph).unwrap();
        verify_program(&prog).unwrap();
        // every tile nest's working set fits the double-buffer budget
        let budget = cfg.scratchpad_bytes() / 2;
        for nest in prog.nests.iter().filter(|n| n.tile.is_some()) {
            let ws = footprint::nest_working_set(&prog.graph, nest);
            assert!(ws <= budget, "{}: {ws} bytes > {budget}", nest.name);
        }
    }

    #[test]
    fn roomy_chip_tiles_nothing() {
        let mut prog = Program::lower(chain_graph());
        let before = prog.nests.len();
        let stats = run_tiling(&mut prog, &AccelConfig::inferentia_like(), &TileOpts::default());
        assert_eq!(stats.groups, 0);
        assert_eq!(prog.nests.len(), before);
        assert!(prog.nests.iter().all(|n| n.tile.is_none()));
    }

    #[test]
    fn tiling_preserves_semantics_on_prime_sized_conv() {
        // 13×13 spatial extent: boundary tiles everywhere
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 3, 13, 13]);
        let w = b.weight("w", &[5, 3, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let r = b.relu("r", c);
        b.mark_output(r);
        let g = b.finish();
        let baseline = Program::lower(g.clone());
        let mut tiled = Program::lower(g);
        let stats = run_tiling(&mut tiled, &AccelConfig::tiny(2 * 1024), &TileOpts::default());
        assert!(stats.groups >= 1, "conv must tile on a 2 KiB chip: {stats:?}");
        verify_program(&tiled).unwrap();
        crate::interp::diff::assert_equivalent(&baseline, &tiled, 0xA11CE);
    }

    #[test]
    fn fusion_off_still_tiles_but_never_fuses() {
        let mut prog = Program::lower(chain_graph());
        let opts = TileOpts { fuse: false, ..Default::default() };
        let stats = run_tiling(&mut prog, &AccelConfig::tiny(4 * 1024), &opts);
        assert!(stats.groups >= 1);
        assert_eq!(stats.fused_chains, 0);
        verify_program(&prog).unwrap();
    }

    #[test]
    fn grid_size_search_respects_budget() {
        let prog = Program::lower(chain_graph());
        let chain = detect_chain(&prog, 0, &TileOpts::default()).unwrap();
        let budget = 2048;
        let cfg = AccelConfig::tiny(4 * 1024);
        let s = choose_grid_sizes(&prog, &chain, budget, 1024, &cfg).unwrap();
        assert!(chain_tile_footprint(&prog, &chain, &s) <= budget);
        assert!(chain.n_tiles(&s) >= 2);
        // boundary tiles cover the grid exactly
        let covered: i64 = chain
            .tile_origins(&s)
            .iter()
            .map(|go| {
                chain
                    .grid_shape
                    .iter()
                    .zip(s.iter().zip(go))
                    .map(|(&e, (&t, &o))| t.min(e - o))
                    .product::<i64>()
            })
            .sum();
        assert_eq!(covered, IterDomain::new(&chain.grid_shape).cardinality());
    }
}
