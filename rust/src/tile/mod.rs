//! Polyhedral loop tiling — staging tensors larger than the scratchpad.
//!
//! The planner (`crate::alloc`) can only make a tensor resident when it
//! fits; anything larger fell back to DRAM streaming, so the workloads
//! the paper cares most about — feature maps bigger than on-chip SRAM —
//! were never actually *staged*. This subsystem closes that gap with
//! three cooperating parts:
//!
//! * [`footprint`] — sizes tiles by imaging candidate tile boxes
//!   through the nests' access maps (the `poly` machinery the passes
//!   already use), picking the largest grid whose **double-buffered**
//!   working set (2× tile-varying tensors + 1× tile-invariant ones,
//!   e.g. conv weights) fits the configured budget;
//! * [`transform`] — strip-mines the chosen nests into ordinary tile
//!   nests (exact boundary tiles on non-divisible extents, guards and
//!   access maps rewritten), interleaving fused producer→elementwise
//!   chains on a shared grid so chain intermediates are produced and
//!   consumed within a few schedule positions;
//! * [`pipeline`] — extracts the double-buffer schedule (prefetch tile
//!   *t+1* while computing tile *t*, write back *t−1*) that the
//!   simulator's pipelined mode replays with a two-engine overlap model
//!   instead of the per-nest `max(compute, dma)` fiction.
//!
//! Downstream, `alloc` detects chain intermediates whose every writer
//! and reader is a tile nest of one group and plans them into
//! double-buffered staging regions ([`crate::alloc::Home::Staged`])
//! instead of whole-tensor residency — the step that finally takes
//! oversized intermediates off DRAM.
//!
//! Run as an optional [`crate::passes::manager::PassManager`] stage
//! between DME and bank mapping; the differential oracle proves the
//! transformed program bit-identical (tiling never splits reduction
//! dims, so accumulation order is preserved).

pub mod footprint;
pub mod pipeline;
pub mod transform;

use crate::accel::config::AccelConfig;
use crate::cost::policy::{DecisionPolicy, GreedyPolicy};
use crate::ir::loopnest::{LoopNest, Program};
use crate::ir::op::OpKind;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::poly::Expr;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

pub use self::transform::{Chain, ChainMember};

/// Fusion grouping rule for chain detection.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FusePolicy {
    /// No fusion: every tileable nest tiles alone.
    None,
    /// Producer → sole-consumer elementwise chains on the producer's
    /// grid (the historical rule; the default).
    Elementwise,
    /// Widened legality: followers may read *any* chain tensor (not
    /// just the immediately preceding one), a chain tensor may feed
    /// several followers, and grid-shaped independent members
    /// (converging branches — a projection conv next to the main path,
    /// both feeding a residual add) may interleave into the group.
    Wide,
    /// [`FusePolicy::Wide`] plus halo-aware "same"-convolution
    /// followers: a stride-1 conv may consume a chain tensor tile by
    /// tile, with every upstream member's tiles expanded by the
    /// kernel halo (bounded recompute of the overlap) so each consumer
    /// tile reads a completely-written slice. At most `depth` such
    /// joins per chain. Whether recompute beats staging/streaming is
    /// not decided here — the joint optimizer (`crate::opt`) realizes
    /// both and lets the cost model pick.
    ConvChain { depth: usize },
}

/// Caps for the widened detector: halo cells a recompute join may add
/// per grid dim (beyond this the dim is frozen instead), and members
/// per chain (bounds the interleave the planner has to reason about).
const MAX_HALO: i64 = 8;
const MAX_CHAIN_MEMBERS: usize = 12;

/// Tiling configuration.
#[derive(Clone, Copy, Debug)]
pub struct TileOpts {
    /// Fraction of the total scratchpad the double-buffered tile
    /// working set may use (the rest is headroom for co-resident
    /// weights and the planner's other windows).
    pub budget_fraction: f64,
    /// Hard cap on tiles per chain (bounds schedule growth).
    pub max_tiles: usize,
    /// Fuse consumers onto their producer's grid at all.
    pub fuse: bool,
    /// Which fusion legality rule applies when `fuse` is on.
    pub fuse_policy: FusePolicy,
}

impl Default for TileOpts {
    fn default() -> Self {
        TileOpts {
            budget_fraction: 0.5,
            max_tiles: 1024,
            fuse: true,
            fuse_policy: FusePolicy::Elementwise,
        }
    }
}

/// What the tiling stage did.
#[derive(Clone, Copy, Debug, Default)]
pub struct TileStats {
    /// Tile groups emitted (one per tiled nest/chain).
    pub groups: usize,
    /// Original nests that were strip-mined.
    pub nests_tiled: usize,
    /// Tile nests emitted in their place.
    pub tiles_emitted: usize,
    /// Groups that fused ≥ 2 members onto one grid.
    pub fused_chains: usize,
    /// Longest fused chain.
    pub max_chain_len: usize,
}

impl TileStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("groups", Json::Int(self.groups as i64)),
            ("nests_tiled", Json::Int(self.nests_tiled as i64)),
            ("tiles_emitted", Json::Int(self.tiles_emitted as i64)),
            ("fused_chains", Json::Int(self.fused_chains as i64)),
            ("max_chain_len", Json::Int(self.max_chain_len as i64)),
        ])
    }
}

/// Op kinds tiling may strip-mine. Copy bodies are always eligible;
/// `Softmax` is excluded (its row reduction spans the whole domain and
/// the interpreter's lowering contract pins its store to the full box).
fn tileable_kind(kind: &OpKind, nest: &LoopNest) -> bool {
    if nest.body.is_copy() {
        return true;
    }
    matches!(
        kind,
        OpKind::Conv2d { .. }
            | OpKind::DepthwiseConv2d { .. }
            | OpKind::Conv1d { .. }
            | OpKind::MatMul
            | OpKind::Pool { .. }
            | OpKind::GlobalAvgPool
            | OpKind::Unary(_)
            | OpKind::Binary(_)
            | OpKind::BatchNorm
            | OpKind::BiasAdd
    )
}

/// Can this head accept fused followers? Requires a pure projection
/// store (`i_d` / constant components, no offsets) whose grid equals
/// the output tensor box, so follower domains align with the grid.
fn fusable_head(prog: &Program, nest: &LoopNest, grid_shape: &[i64]) -> bool {
    use crate::poly::Expr;
    nest.store
        .map
        .exprs()
        .iter()
        .all(|e| matches!(e, Expr::Dim(_) | Expr::Cst(_)))
        && prog.graph.tensor(nest.store.tensor).shape == grid_shape
}

/// Is nest `q` an eligible elementwise follower consuming `y`?
fn elementwise_follower(prog: &Program, q: usize, y: TensorId, grid_shape: &[i64]) -> bool {
    let nest = &prog.nests[q];
    let node = prog.graph.node(nest.node);
    if !tileable_kind(&node.kind, nest) {
        return false;
    }
    if !nest.store.map.is_identity() || nest.domain.extents() != grid_shape {
        return false;
    }
    // every read of y must be a plain identity load
    for load in nest.body.loads() {
        for piece in &load.pieces {
            if piece.tensor == Some(y)
                && !(piece.guards.is_empty() && !piece.oob_zero && piece.map.is_identity())
            {
                return false;
            }
        }
    }
    true
}

/// Detect the tiling chain starting at nest position `p`: the nest
/// itself (if tileable), extended per `policy` over consecutive
/// fusable followers.
fn detect_chain(prog: &Program, p: usize, policy: FusePolicy) -> Option<Chain> {
    let head = &prog.nests[p];
    let node = prog.graph.node(head.node);
    if !tileable_kind(&node.kind, head) {
        return None;
    }
    let dim_of_grid = transform::head_dim_map(head)?;
    let sm = footprint::store_dim_map(head)?;
    let ext = head.domain.extents();
    let grid_shape: Vec<i64> = sm
        .iter()
        .map(|d| d.map(|d| ext[d]).unwrap_or(1))
        .collect();
    let rank = grid_shape.len();
    let mut chain = Chain {
        members: vec![ChainMember::plain(p, dim_of_grid, rank)],
        frozen: vec![false; rank],
        grid_shape,
    };

    if policy == FusePolicy::Elementwise && fusable_head(prog, head, &chain.grid_shape) {
        // the historical rule, verbatim: sole-consumer elementwise
        // followers on the producer's grid, strictly adjacent
        let mut y = head.store.tensor;
        let mut q = p + 1;
        while q < prog.nests.len() {
            let info = prog.graph.tensor(y);
            if info.kind != TensorKind::Intermediate {
                break;
            }
            if prog.graph.consumers(y).len() != 1 {
                break;
            }
            if prog.writers(y) != vec![q - 1] || prog.readers(y) != vec![q] {
                break;
            }
            if !elementwise_follower(prog, q, y, &chain.grid_shape) {
                break;
            }
            let nd = chain.grid_shape.len();
            chain.members.push(ChainMember::plain(q, (0..nd).map(Some).collect(), rank));
            y = prog.nests[q].store.tensor;
            q += 1;
        }
    } else if matches!(policy, FusePolicy::Wide | FusePolicy::ConvChain { .. })
        && fusable_head(prog, head, &chain.grid_shape)
    {
        let mut convs_left = match policy {
            FusePolicy::ConvChain { depth } => depth,
            _ => 0,
        };
        let mut chain_tensors: BTreeSet<TensorId> = BTreeSet::new();
        chain_tensors.insert(head.store.tensor);
        let mut q = p + 1;
        while q < prog.nests.len() && chain.members.len() < MAX_CHAIN_MEMBERS {
            let Some(join) = widened_member(prog, q, &chain, &chain_tensors, convs_left)
            else {
                break;
            };
            convs_left -= join.convs_used;
            // every upstream member recomputes the new follower's halo
            for m in &mut chain.members {
                for k in 0..rank {
                    m.halo[k].0 += join.halo_add[k].0;
                    m.halo[k].1 += join.halo_add[k].1;
                }
            }
            for k in 0..rank {
                chain.frozen[k] |= join.freeze[k];
            }
            chain.members.push(ChainMember {
                pos: q,
                dim_of_grid: join.dim_of_grid,
                halo: vec![(0, 0); rank],
            });
            chain_tensors.insert(prog.nests[q].store.tensor);
            q += 1;
        }
    }
    Some(chain)
}

/// What joining nest `q` to a widened chain requires.
struct WidenedJoin {
    dim_of_grid: Vec<Option<usize>>,
    /// Halo every *upstream* member must add, per grid dim.
    halo_add: Vec<(i64, i64)>,
    /// Grid dims the join freezes (must never split).
    freeze: Vec<bool>,
    /// Conv-budget consumed (1 for a halo/reduction-reading conv).
    convs_used: usize,
}

/// Is nest `q` an eligible widened-chain follower, and at what cost?
///
/// Legality is derived from the access maps (no per-op kernel/pad
/// arithmetic), with one layout convention: the **rank-4 NCHW channel
/// dim (index 1)** is the only dim allowed to diverge between a
/// member and the grid — divergence freezes the grid channel dim so
/// every tile spans full channels, which keeps channel-divergent
/// members consistent. (Rank-3 Conv1d chains therefore never fuse
/// across channel changes; lifting that means deriving the exempt dim
/// from the maps instead of the NCHW convention.) The rules:
/// * the store is an offset-free projection covering the member's own
///   output box; output dims must match the grid except the rank-4
///   channel dim, whose divergence freezes the grid channel dim
///   (tiles then always span full channels, so channel-divergent
///   members stay consistent);
/// * every read of a chain-produced tensor is a guard-free affine
///   single-dim access per tensor dim: an aligned unit-coefficient
///   read contributes its probe-image halo (the kernel overhang of a
///   "same" conv); a tile-invariant read (a conv reducing over the
///   producer's channels) freezes that grid dim; anything else is
///   rejected;
/// * nonzero halo or a read-induced freeze marks a recompute join,
///   which only a stride-1 conv under [`FusePolicy::ConvChain`] with
///   remaining depth may make.
fn widened_member(
    prog: &Program,
    q: usize,
    chain: &Chain,
    chain_tensors: &BTreeSet<TensorId>,
    convs_left: usize,
) -> Option<WidenedJoin> {
    let nest = &prog.nests[q];
    let node = prog.graph.node(nest.node);
    if !tileable_kind(&node.kind, nest) {
        return None;
    }
    // multi-nest nodes (concat) would need cross-nest coordination
    if prog.writers(nest.store.tensor) != vec![q] {
        return None;
    }
    let rank = chain.grid_shape.len();
    let out_shape = prog.graph.tensor(nest.store.tensor).shape.clone();
    if out_shape.len() != rank {
        return None;
    }
    let ext = nest.domain.extents().to_vec();
    let sm = footprint::store_dim_map(nest)?;
    if !nest
        .store
        .map
        .exprs()
        .iter()
        .all(|e| matches!(e, Expr::Dim(_)) || matches!(e, Expr::Cst(0)))
    {
        return None;
    }
    let mut dim_of_grid: Vec<Option<usize>> = vec![None; ext.len()];
    let mut freeze = vec![false; rank];
    for (j, src) in sm.iter().enumerate() {
        match src {
            Some(d) => {
                if ext[*d] != out_shape[j] {
                    return None; // store must cover the member's own box
                }
                if out_shape[j] == chain.grid_shape[j] {
                    dim_of_grid[*d] = Some(j);
                } else if rank == 4 && j == 1 {
                    freeze[1] = true;
                } else {
                    return None;
                }
            }
            None => {
                if chain.grid_shape[j] != 1 || out_shape[j] != 1 {
                    return None;
                }
            }
        }
    }

    let mut halo_add = vec![(0i64, 0i64); rank];
    let mut read_freeze = false;
    // unit-tile probe: grid-mapped dims pinned to extent 1 at the
    // origin; affine image widths then scale linearly with the tile
    let probe: Vec<i64> = ext
        .iter()
        .enumerate()
        .map(|(d, &e)| if dim_of_grid[d].is_some() { 1 } else { e })
        .collect();
    for load in nest.body.loads() {
        for piece in &load.pieces {
            let Some(t) = piece.tensor else { continue };
            if !chain_tensors.contains(&t) {
                continue;
            }
            if !piece.guards.is_empty() || !piece.map.is_affine() {
                return None;
            }
            let tinfo = prog.graph.tensor(t);
            if tinfo.shape.len() != rank {
                return None;
            }
            for (j, e) in piece.map.exprs().iter().enumerate() {
                if tinfo.shape[j] != chain.grid_shape[j] {
                    // channel-divergent chain tensor: its producer
                    // writes the dim in full every tile
                    if rank == 4 && j == 1 {
                        continue;
                    }
                    return None;
                }
                let (coeffs, _c) = e.as_affine(ext.len())?;
                let mapped: Vec<usize> = coeffs
                    .iter()
                    .enumerate()
                    .filter(|(d, &c)| c != 0 && dim_of_grid[*d].is_some())
                    .map(|(d, _)| d)
                    .collect();
                match mapped.as_slice() {
                    [] => {
                        // tile-invariant read range (e.g. a conv
                        // reducing over the producer's channels): the
                        // producer covers it only if the dim never
                        // splits
                        if chain.grid_shape[j] > 1 {
                            freeze[j] = true;
                            read_freeze = true;
                        }
                    }
                    [d] if coeffs[*d] == 1 && dim_of_grid[*d] == Some(j) => {
                        let (lo, hi) = e.range(&probe)?;
                        let hlo = (-lo).max(0);
                        let hhi = hi.max(0);
                        if hlo + hhi > MAX_HALO {
                            if chain.grid_shape[j] > 1 {
                                freeze[j] = true;
                                read_freeze = true;
                            }
                        } else if hlo > 0 || hhi > 0 {
                            halo_add[j].0 = halo_add[j].0.max(hlo);
                            halo_add[j].1 = halo_add[j].1.max(hhi);
                        }
                    }
                    _ => return None,
                }
            }
        }
    }

    let mut convs_used = 0usize;
    if halo_add.iter().any(|&(a, b)| a > 0 || b > 0) || read_freeze {
        // recompute join: only a stride-1 conv may make it, and only
        // while the chain has conv depth left
        let stride_ok = match &node.kind {
            OpKind::Conv2d { stride, .. } | OpKind::DepthwiseConv2d { stride, .. } => {
                *stride == 1
            }
            _ => false,
        };
        if !stride_ok || convs_left == 0 {
            return None;
        }
        convs_used = 1;
    }
    Some(WidenedJoin { dim_of_grid, halo_add, freeze, convs_used })
}

/// Worst-case double-buffered tile working set of a chain under grid
/// sizes `s`: per sampled tile, tile-varying tensors count twice (the
/// live tile plus its prefetch/writeback partner), tile-invariant ones
/// (weights under spatial tiling) once.
pub fn chain_tile_footprint(prog: &Program, chain: &Chain, s: &[i64]) -> i64 {
    let g = &prog.graph;
    // which grid dims actually split under s
    let split: Vec<bool> = chain
        .grid_shape
        .iter()
        .zip(s)
        .map(|(&e, &t)| t < e)
        .collect();
    // per member, the domain dims that vary across tiles
    let member_tiled: Vec<Vec<usize>> = chain
        .members
        .iter()
        .map(|m| {
            m.dim_of_grid
                .iter()
                .enumerate()
                .filter_map(|(d, k)| match k {
                    Some(k) if split[*k] => Some(d),
                    _ => None,
                })
                .collect()
        })
        .collect();
    // a tensor is invariant iff invariant in every member touching it
    let mut invariant: BTreeMap<TensorId, bool> = BTreeMap::new();
    for (mi, m) in chain.members.iter().enumerate() {
        let nest = &prog.nests[m.pos];
        for (t, _) in footprint::nest_touched_bytes(g, nest) {
            let inv = footprint::tensor_tile_invariant(nest, t, &member_tiled[mi]);
            invariant
                .entry(t)
                .and_modify(|v| *v = *v && inv)
                .or_insert(inv);
        }
    }

    // Affine access maps have offset-independent image widths, so a
    // single analytic bound — unclipped widths of a full-size tile box,
    // capped at the tensor extent — dominates every real tile
    // (`footprint::touched_bytes_bound`). Quasi-affine maps (div/mod
    // from reshape/tile/repeat) vary with the tile's position, so those
    // chains evaluate every tile origin exactly (they are capped at
    // `max_tiles` anyway).
    let all_affine = chain.members.iter().all(|m| {
        let nest = &prog.nests[m.pos];
        nest.store.map.is_affine()
            && nest
                .body
                .loads()
                .iter()
                .all(|l| l.pieces.iter().all(|p| p.map.is_affine()))
    });

    let mut worst = 0i64;
    if all_affine {
        let mut per_tensor: BTreeMap<TensorId, i64> = BTreeMap::new();
        for m in &chain.members {
            let nest = &prog.nests[m.pos];
            // full-size tile box of this member, halo included
            // (boundary tiles only shrink)
            let ext = nest.domain.extents();
            let exts: Vec<i64> = m
                .dim_of_grid
                .iter()
                .enumerate()
                .map(|(d, k)| match k {
                    Some(k) => {
                        let (hlo, hhi) = m.halo.get(*k).copied().unwrap_or((0, 0));
                        (s[*k].min(chain.grid_shape[*k]) + hlo + hhi).min(ext[d])
                    }
                    None => ext[d],
                })
                .collect();
            for (t, b) in footprint::touched_bytes_bound(g, nest, &exts) {
                let e = per_tensor.entry(t).or_insert(0);
                *e = (*e).max(b);
            }
        }
        worst = per_tensor
            .iter()
            .map(|(t, &b)| if invariant[t] { b } else { 2 * b })
            .sum();
    } else {
        for go in &chain.tile_origins(s) {
            let mut per_tensor: BTreeMap<TensorId, i64> = BTreeMap::new();
            for m in &chain.members {
                let nest = &prog.nests[m.pos];
                let (offs, exts) = chain.member_box(nest, m, go, s);
                for (t, b) in footprint::touched_bytes_in(g, nest, &offs, &exts) {
                    let e = per_tensor.entry(t).or_insert(0);
                    *e = (*e).max(b);
                }
            }
            let total: i64 = per_tensor
                .iter()
                .map(|(t, &b)| if invariant[t] { b } else { 2 * b })
                .sum();
            worst = worst.max(total);
        }
    }
    worst
}

/// Predicted excess DRAM traffic of grid sizes `s`: for every tensor a
/// member *reads* that cannot be scratchpad-resident (its whole-tensor
/// slice exceeds a bank, so the planner will stream it), each grid dim
/// the tensor does **not** vary in, sitting outside (lexicographically
/// above) a dim it does vary in, multiplies how often its slices must
/// be re-fetched — e.g. splitting output channels makes every
/// channel block re-sweep the whole input. Dims the tensor varies in
/// are counted at the *full* grid (they will usually be split later),
/// so the penalty is visible before the inner split happens — which is
/// what steers the greedy search away from such splits up front.
pub fn chain_stream_penalty(
    prog: &Program,
    chain: &Chain,
    s: &[i64],
    cfg: &AccelConfig,
) -> i64 {
    let g = &prog.graph;
    let counts: Vec<i64> = chain
        .grid_shape
        .iter()
        .zip(s)
        .map(|(&e, &t)| (e + t - 1) / t)
        .collect();
    // per read tensor: the grid dims it (potentially) varies in
    let mut varies: BTreeMap<TensorId, Vec<bool>> = BTreeMap::new();
    for m in &chain.members {
        let nest = &prog.nests[m.pos];
        for load in nest.body.loads() {
            for piece in &load.pieces {
                let Some(t) = piece.tensor else { continue };
                let v = varies
                    .entry(t)
                    .or_insert_with(|| vec![false; chain.grid_shape.len()]);
                for (d, k) in m.dim_of_grid.iter().enumerate() {
                    if let Some(k) = *k {
                        if chain.grid_shape[k] > 1
                            && footprint::tensor_read_uses_dim(nest, t, d)
                        {
                            v[k] = true;
                        }
                    }
                }
            }
        }
    }
    let mut penalty = 0i64;
    for (t, v) in &varies {
        let info = g.tensor(*t);
        if crate::alloc::offsets::per_bank_bytes(info.size_bytes(), cfg.banks)
            <= cfg.bank_bytes
        {
            continue; // can be resident — reuse is free
        }
        let Some(kmax) = v.iter().rposition(|&x| x) else { continue };
        let repeat: i64 = (0..=kmax).filter(|&k| !v[k]).map(|k| counts[k]).product();
        if repeat > 1 {
            penalty += (repeat - 1) * info.size_bytes();
        }
    }
    penalty
}

/// Greedy tile-size search: start at the whole grid and repeatedly
/// halve a dim until the worst-case double-buffered footprint fits
/// `budget`. Candidates are ranked by the [`DecisionPolicy`]'s
/// [`DecisionPolicy::tile_grid_key`] — under [`GreedyPolicy`] that is
/// the historical `(stream penalty, footprint)` pair: first avoid
/// splits that multiply re-streaming of DRAM-bound operands
/// ([`chain_stream_penalty`]), then shrink the working set fastest.
/// Frozen grid dims (conv-reduced channels of a widened chain) are
/// never split. `None` when the chain already fits untiled (measured
/// 1×: a single "tile" needs no buddy buffer), or when even the
/// finest split within the tile cap cannot fit (e.g. an un-splittable
/// invariant operand dominates).
///
/// Terminates because every step strictly shrinks one grid dim: at
/// most `Σ ceil(log2 grid[k])` iterations.
pub fn choose_grid_sizes(
    prog: &Program,
    chain: &Chain,
    budget: i64,
    max_tiles: usize,
    cfg: &AccelConfig,
) -> Option<Vec<i64>> {
    choose_grid_sizes_with(prog, chain, budget, max_tiles, cfg, &GreedyPolicy)
}

/// [`choose_grid_sizes`] with an explicit scoring policy.
pub fn choose_grid_sizes_with(
    prog: &Program,
    chain: &Chain,
    budget: i64,
    max_tiles: usize,
    cfg: &AccelConfig,
    policy: &dyn DecisionPolicy,
) -> Option<Vec<i64>> {
    let mut s = chain.grid_shape.clone();
    if chain_tile_footprint(prog, chain, &s) <= budget {
        return None; // fits whole — no tiling needed
    }
    loop {
        // key contract: `.1` is the candidate's double-buffered footprint
        let mut best: Option<((i64, i64), usize)> = None;
        for k in 0..s.len() {
            if s[k] <= 1 || chain.frozen[k] {
                continue;
            }
            let mut s2 = s.clone();
            s2[k] = (s[k] + 1) / 2;
            if chain.n_tiles(&s2) > max_tiles as i64 {
                continue;
            }
            let key = policy.tile_grid_key(prog, chain, &s2, cfg);
            if best.map(|(bk, _)| key < bk).unwrap_or(true) {
                best = Some((key, k));
            }
        }
        let (key, k) = best?;
        s[k] = (s[k] + 1) / 2;
        if key.1 <= budget {
            return Some(s);
        }
    }
}

/// Size the chain at nest position `p`, trying the configured fusion
/// policy first and downgrading (`ConvChain` → `Wide` → `Elementwise`)
/// when a wider chain cannot be sized within the budget and tile caps
/// — a merged chain whose invariant operands dominate must not cost
/// the tiling the narrower chains would have delivered. `None` means
/// "leave position `p` untiled" (not tileable, fits untiled, or
/// unsizable at every fusion level).
fn plan_chain_at(
    prog: &Program,
    p: usize,
    cfg: &AccelConfig,
    opts: &TileOpts,
    budget: i64,
    policy: &dyn DecisionPolicy,
) -> Option<(Chain, Vec<i64>)> {
    let effective = if opts.fuse { opts.fuse_policy } else { FusePolicy::None };
    let ladder: Vec<FusePolicy> = match effective {
        FusePolicy::ConvChain { .. } => {
            vec![effective, FusePolicy::Wide, FusePolicy::Elementwise]
        }
        FusePolicy::Wide => vec![effective, FusePolicy::Elementwise],
        other => vec![other],
    };
    for pol in ladder {
        let chain = detect_chain(prog, p, pol)?;
        if chain_tile_footprint(prog, &chain, &chain.grid_shape) <= budget {
            return None; // fits whole — no tiling needed at `p`
        }
        if let Some(s) =
            choose_grid_sizes_with(prog, &chain, budget, opts.max_tiles, cfg, policy)
        {
            return Some((chain, s));
        }
    }
    None
}

/// Run the tiling stage over a lowered (post-DME) program: detect
/// oversized nests/chains, choose grids, strip-mine in place.
pub fn run_tiling(prog: &mut Program, cfg: &AccelConfig, opts: &TileOpts) -> TileStats {
    run_tiling_with(prog, cfg, opts, &GreedyPolicy)
}

/// [`run_tiling`] with an explicit grid-scoring policy. Every caller
/// — including the joint optimizer's candidate realization — routes
/// grid ranking through [`DecisionPolicy::tile_grid_key`]; the
/// shipped policies all rank grids greedily today, and this seam is
/// where a cost-model-driven grid scorer plugs in without touching
/// the search loop.
pub fn run_tiling_with(
    prog: &mut Program,
    cfg: &AccelConfig,
    opts: &TileOpts,
    policy: &dyn DecisionPolicy,
) -> TileStats {
    let budget = (cfg.scratchpad_bytes() as f64 * opts.budget_fraction) as i64;
    let mut stats = TileStats::default();
    let mut out: Vec<LoopNest> = Vec::with_capacity(prog.nests.len());
    let mut group: u32 = 0;
    let mut p = 0usize;
    while p < prog.nests.len() {
        match plan_chain_at(prog, p, cfg, opts, budget, policy) {
            Some((chain, s)) => {
                let tiles = transform::tile_chain(&prog.nests, &chain, &s, group);
                stats.groups += 1;
                stats.nests_tiled += chain.len();
                stats.tiles_emitted += tiles.len();
                if chain.len() > 1 {
                    stats.fused_chains += 1;
                }
                stats.max_chain_len = stats.max_chain_len.max(chain.len());
                out.extend(tiles);
                group += 1;
                p += chain.len();
            }
            None => {
                out.push(prog.nests[p].clone());
                p += 1;
            }
        }
    }
    prog.nests = out;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::poly::IterDomain;

    /// conv → bn → relu with a 16 KiB feature map on a 4 KiB chip.
    fn chain_graph() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 16, 16]);
        let w = b.weight("w", &[4, 4, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let n = b.batchnorm("bn", c);
        let r = b.relu("r", n);
        b.mark_output(r);
        b.finish()
    }

    #[test]
    fn oversized_chain_is_tiled_and_fused() {
        let mut prog = Program::lower(chain_graph());
        let cfg = AccelConfig::tiny(4 * 1024);
        let stats = run_tiling(&mut prog, &cfg, &TileOpts::default());
        assert!(stats.groups >= 1, "{stats:?}");
        assert!(stats.fused_chains >= 1, "conv->bn->relu should fuse: {stats:?}");
        assert!(stats.max_chain_len >= 3, "{stats:?}");
        assert!(stats.tiles_emitted > stats.nests_tiled);
        verify_graph(&prog.graph).unwrap();
        verify_program(&prog).unwrap();
        // every tile nest's working set fits the double-buffer budget
        let budget = cfg.scratchpad_bytes() / 2;
        for nest in prog.nests.iter().filter(|n| n.tile.is_some()) {
            let ws = footprint::nest_working_set(&prog.graph, nest);
            assert!(ws <= budget, "{}: {ws} bytes > {budget}", nest.name);
        }
    }

    #[test]
    fn roomy_chip_tiles_nothing() {
        let mut prog = Program::lower(chain_graph());
        let before = prog.nests.len();
        let stats = run_tiling(&mut prog, &AccelConfig::inferentia_like(), &TileOpts::default());
        assert_eq!(stats.groups, 0);
        assert_eq!(prog.nests.len(), before);
        assert!(prog.nests.iter().all(|n| n.tile.is_none()));
    }

    #[test]
    fn tiling_preserves_semantics_on_prime_sized_conv() {
        // 13×13 spatial extent: boundary tiles everywhere
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 3, 13, 13]);
        let w = b.weight("w", &[5, 3, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let r = b.relu("r", c);
        b.mark_output(r);
        let g = b.finish();
        let baseline = Program::lower(g.clone());
        let mut tiled = Program::lower(g);
        let stats = run_tiling(&mut tiled, &AccelConfig::tiny(2 * 1024), &TileOpts::default());
        assert!(stats.groups >= 1, "conv must tile on a 2 KiB chip: {stats:?}");
        verify_program(&tiled).unwrap();
        crate::interp::diff::assert_equivalent(&baseline, &tiled, 0xA11CE);
    }

    #[test]
    fn fusion_off_still_tiles_but_never_fuses() {
        let mut prog = Program::lower(chain_graph());
        let opts = TileOpts { fuse: false, ..Default::default() };
        let stats = run_tiling(&mut prog, &AccelConfig::tiny(4 * 1024), &opts);
        assert!(stats.groups >= 1);
        assert_eq!(stats.fused_chains, 0);
        verify_program(&prog).unwrap();
    }

    /// Residual-shaped graph: conv → bn on the main path, an
    /// independent projection conv beside it, converging in add → relu.
    fn residual_graph() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 16, 16]);
        let w = b.weight("w", &[4, 4, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let n = b.batchnorm("bn", c);
        let wp = b.weight("wp", &[4, 4, 1, 1]);
        let pj = b.conv2d("proj", x, wp, 1, 0);
        let a = b.add("a", n, pj);
        let r = b.relu("r", a);
        b.mark_output(r);
        b.finish()
    }

    /// conv → bn → relu → conv: the chain the elementwise rule must
    /// break at the second conv and `ConvChain` may not.
    fn conv_conv_graph() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 16, 16]);
        let w1 = b.weight("w1", &[4, 4, 3, 3]);
        let c1 = b.conv2d("c1", x, w1, 1, 1);
        let n = b.batchnorm("bn", c1);
        let r = b.relu("r", n);
        let w2 = b.weight("w2", &[6, 4, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        b.finish()
    }

    #[test]
    fn wide_policy_merges_converging_branches() {
        let prog = Program::lower(residual_graph());
        let narrow = detect_chain(&prog, 0, FusePolicy::Elementwise).unwrap();
        assert_eq!(narrow.len(), 2, "elementwise must stop at the proj conv");
        let wide = detect_chain(&prog, 0, FusePolicy::Wide).unwrap();
        assert_eq!(wide.len(), 5, "wide must absorb proj, add and relu");
        assert!(wide.members.iter().all(|m| m.halo.iter().all(|&h| h == (0, 0))));
    }

    #[test]
    fn wide_fusion_is_bit_identical() {
        let g = residual_graph();
        let baseline = Program::lower(g.clone());
        let mut tiled = Program::lower(g);
        let opts = TileOpts { fuse_policy: FusePolicy::Wide, ..Default::default() };
        let stats = run_tiling(&mut tiled, &AccelConfig::tiny(4 * 1024), &opts);
        assert!(stats.groups >= 1, "{stats:?}");
        assert!(stats.max_chain_len >= 5, "{stats:?}");
        verify_program(&tiled).unwrap();
        crate::interp::diff::assert_equivalent(&baseline, &tiled, 0x31DE);
    }

    #[test]
    fn conv_chain_joins_with_halo_and_freezes_channels() {
        let prog = Program::lower(conv_conv_graph());
        let chain = detect_chain(&prog, 0, FusePolicy::ConvChain { depth: 1 }).unwrap();
        assert_eq!(chain.len(), 4, "c1, bn, relu and c2 must fuse");
        // the conv join reduces over the producer's channels: frozen
        assert!(chain.frozen[1], "{:?}", chain.frozen);
        // every upstream member recomputes the 3×3 kernel's halo
        for m in &chain.members[..3] {
            assert_eq!(m.halo[2], (1, 1), "{:?}", m.halo);
            assert_eq!(m.halo[3], (1, 1), "{:?}", m.halo);
        }
        assert_eq!(chain.members[3].halo[2], (0, 0));
        // without conv depth the same chain stops before c2
        let wide = detect_chain(&prog, 0, FusePolicy::Wide).unwrap();
        assert_eq!(wide.len(), 3);
    }

    #[test]
    fn conv_chain_halo_recompute_is_bit_identical() {
        let g = conv_conv_graph();
        let baseline = Program::lower(g.clone());
        let mut tiled = Program::lower(g);
        let opts = TileOpts {
            fuse_policy: FusePolicy::ConvChain { depth: 1 },
            ..Default::default()
        };
        let stats = run_tiling(&mut tiled, &AccelConfig::tiny(8 * 1024), &opts);
        assert!(stats.groups >= 1, "{stats:?}");
        verify_program(&tiled).unwrap();
        crate::interp::diff::assert_equivalent(&baseline, &tiled, 0xC04C);
    }

    #[test]
    fn conv_chain_stages_the_conv_boundary_tensor() {
        // with the conv joined, the relu output's every writer and
        // reader sits in one tile group: the planner must stage it
        // instead of streaming it through DRAM
        let g = conv_conv_graph();
        let cfg = AccelConfig::tiny(8 * 1024);
        let mut prog = Program::lower(g);
        let opts = TileOpts {
            fuse_policy: FusePolicy::ConvChain { depth: 1 },
            ..Default::default()
        };
        let stats = run_tiling(&mut prog, &cfg, &opts);
        assert!(stats.max_chain_len >= 4, "{stats:?}");
        let res = crate::alloc::plan_memory(
            prog,
            None,
            &cfg,
            &crate::alloc::AllocOpts::default(),
        )
        .unwrap();
        crate::alloc::verify_plan(&res.program, &res.plan, &cfg).unwrap();
        assert!(res.plan.stats.tile_staged >= 1, "{:?}", res.plan.stats);
    }

    #[test]
    fn grid_size_search_respects_budget() {
        let prog = Program::lower(chain_graph());
        let chain = detect_chain(&prog, 0, FusePolicy::Elementwise).unwrap();
        let budget = 2048;
        let cfg = AccelConfig::tiny(4 * 1024);
        let s = choose_grid_sizes(&prog, &chain, budget, 1024, &cfg).unwrap();
        assert!(chain_tile_footprint(&prog, &chain, &s) <= budget);
        assert!(chain.n_tiles(&s) >= 2);
        // boundary tiles cover the grid exactly
        let covered: i64 = chain
            .tile_origins(&s)
            .iter()
            .map(|go| {
                chain
                    .grid_shape
                    .iter()
                    .zip(s.iter().zip(go))
                    .map(|(&e, (&t, &o))| t.min(e - o))
                    .product::<i64>()
            })
            .sum();
        assert_eq!(covered, IterDomain::new(&chain.grid_shape).cardinality());
    }
}
