//! PJRT client wrapper: one process-wide CPU client, many compiled
//! executables.

use crate::util::error::{Context, Result};
use std::path::Path;

use super::executable::LoadedModel;

/// Wraps `xla::PjRtClient` and compiles HLO-text artifacts.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModel::new(path.to_path_buf(), exe))
    }

    /// Load from an HLO text string (tests, generated modules).
    pub fn load_hlo_str(&self, name: &str, hlo_text: &str) -> Result<LoadedModel> {
        let dir = std::env::temp_dir().join("polymem_hlo");
        std::fs::create_dir_all(&dir)?;
        // unique-ish path per content to avoid cross-test clashes
        let mut h = 0xcbf29ce484222325u64;
        for b in hlo_text.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let path = dir.join(format!("{name}_{h:016x}.hlo.txt"));
        std::fs::write(&path, hlo_text)?;
        self.load_hlo_text(&path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written HLO text: the runtime must be exercisable without
    /// the Python toolchain present.
    const ADD_HLO: &str = r#"
HloModule tiny_add

ENTRY main {
  p0 = f32[2,2]{1,0} parameter(0)
  p1 = f32[2,2]{1,0} parameter(1)
  ROOT add = f32[2,2]{1,0} add(p0, p1)
}
"#;

    #[test]
    fn cpu_client_comes_up() {
        let rt = RuntimeClient::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn compiles_and_runs_handwritten_hlo() {
        let rt = RuntimeClient::cpu().unwrap();
        let model = rt.load_hlo_str("tiny_add", ADD_HLO).unwrap();
        let a = vec![1f32, 2.0, 3.0, 4.0];
        let b = vec![10f32, 20.0, 30.0, 40.0];
        let out = model
            .run_f32(&[(&a, &[2, 2]), (&b, &[2, 2])])
            .unwrap();
        assert_eq!(out, vec![11f32, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn bad_hlo_is_an_error() {
        let rt = RuntimeClient::cpu().unwrap();
        assert!(rt.load_hlo_str("broken", "HloModule broken\nENTRY {").is_err());
    }
}
