//! Stub runtime used when the `pjrt` feature is disabled (the default
//! on images without the `xla` crate cache). Mirrors the public API of
//! [`super::client`] / [`super::executable`]; every entry point that
//! would touch PJRT fails with a descriptive error at run time, so the
//! compiler/simulator stack — which never executes artifacts — builds
//! and tests cleanly offline.

use crate::util::error::Result;
use std::path::{Path, PathBuf};

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: polymem was built without the `pjrt` feature \
     (requires the `xla` crate; see DESIGN.md)";

/// Stand-in for the PJRT client wrapper.
pub struct RuntimeClient {
    _private: (),
}

impl RuntimeClient {
    /// Always fails: no PJRT in this build.
    pub fn cpu() -> Result<Self> {
        Err(crate::format_err!("{UNAVAILABLE}"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedModel> {
        Err(crate::format_err!("{UNAVAILABLE}"))
    }

    pub fn load_hlo_str(&self, _name: &str, _hlo_text: &str) -> Result<LoadedModel> {
        Err(crate::format_err!("{UNAVAILABLE}"))
    }
}

/// Stand-in for a compiled PJRT executable.
pub struct LoadedModel {
    path: PathBuf,
}

impl LoadedModel {
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        Err(crate::format_err!("{UNAVAILABLE}"))
    }

    pub fn run_f32_multi(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(crate::format_err!("{UNAVAILABLE}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let err = RuntimeClient::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
