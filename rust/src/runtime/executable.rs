//! A compiled model artifact ready to execute.

use crate::util::error::{Context, Result};
use std::path::PathBuf;

/// A compiled PJRT executable plus bookkeeping.
pub struct LoadedModel {
    path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    pub(crate) fn new(path: PathBuf, exe: xla::PjRtLoadedExecutable) -> Self {
        LoadedModel { path, exe }
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Execute with f32 inputs, returning the (first) f32 output
    /// flattened. Inputs are `(data, shape)` pairs; jax-lowered modules
    /// return a 1-tuple (lowered with `return_tuple=True`), which is
    /// unwrapped transparently; plain HLO roots pass through.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let outs = self.run_f32_multi(inputs)?;
        outs.into_iter()
            .next()
            .context("executable produced no outputs")
    }

    /// Execute and return every f32 output flattened.
    pub fn run_f32_multi(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let numel: i64 = shape.iter().product();
            crate::ensure!(
                numel as usize == data.len(),
                "input data len {} != shape {:?}",
                data.len(),
                shape
            );
            literals.push(xla::Literal::vec1(data).reshape(shape).context("reshaping input literal")?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing PJRT computation")?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // jax lowers with return_tuple=True → unwrap tuples of any arity
        let parts = match literal.shape().context("reading result shape")? {
            xla::Shape::Tuple(_) => literal.to_tuple().context("unpacking result tuple")?,
            _ => vec![literal],
        };
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::client::RuntimeClient;

    const TUPLE_HLO: &str = r#"
HloModule tuple_out

ENTRY main {
  p0 = f32[3]{0} parameter(0)
  doubled = f32[3]{0} add(p0, p0)
  ROOT out = (f32[3]{0}) tuple(doubled)
}
"#;

    const TWO_OUT_HLO: &str = r#"
HloModule two_out

ENTRY main {
  p0 = f32[2]{0} parameter(0)
  d = f32[2]{0} add(p0, p0)
  q = f32[2]{0} multiply(p0, p0)
  ROOT out = (f32[2]{0}, f32[2]{0}) tuple(d, q)
}
"#;

    #[test]
    fn tuple_outputs_unwrapped() {
        let rt = RuntimeClient::cpu().unwrap();
        let m = rt.load_hlo_str("tuple_out", TUPLE_HLO).unwrap();
        let out = m.run_f32(&[(&[1.0, 2.0, 3.0], &[3])]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn multi_outputs_all_returned() {
        let rt = RuntimeClient::cpu().unwrap();
        let m = rt.load_hlo_str("two_out", TWO_OUT_HLO).unwrap();
        let outs = m.run_f32_multi(&[(&[3.0, 4.0], &[2])]).unwrap();
        assert_eq!(outs, vec![vec![6.0, 8.0], vec![9.0, 16.0]]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let rt = RuntimeClient::cpu().unwrap();
        let m = rt.load_hlo_str("tuple_out2", TUPLE_HLO).unwrap();
        assert!(m.run_f32(&[(&[1.0, 2.0], &[3])]).is_err());
    }
}
