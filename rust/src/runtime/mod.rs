//! PJRT execution of AOT-compiled artifacts.
//!
//! The build-time Python layer (`python/compile/aot.py`) lowers the
//! JAX/Pallas model to **HLO text** (the interchange format this
//! image's xla_extension 0.5.1 can parse — jax≥0.5 serialized protos
//! are rejected, see DESIGN.md). This module loads those artifacts and
//! executes them on the PJRT CPU client from the request path — Python
//! is never involved at runtime.

pub mod client;
pub mod executable;

pub use client::RuntimeClient;
pub use executable::LoadedModel;
