//! PJRT execution of AOT-compiled artifacts.
//!
//! The build-time Python layer (`python/compile/aot.py`) lowers the
//! JAX/Pallas model to **HLO text** (the interchange format the
//! original image's xla_extension 0.5.1 can parse — jax≥0.5 serialized
//! protos are rejected, see DESIGN.md). This module loads those
//! artifacts and executes them on the PJRT CPU client from the request
//! path — Python is never involved at runtime.
//!
//! The real client wraps the `xla` crate, which is **not** part of the
//! default offline build: it is compiled only with `--features pjrt`
//! (and requires adding the `xla` dependency back to `Cargo.toml` on an
//! image that caches it). Without the feature, [`stub`] provides the
//! same API surface with run-time errors, keeping the compiler and
//! simulator stack — which never executes artifacts — fully usable.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use client::RuntimeClient;
#[cfg(feature = "pjrt")]
pub use executable::LoadedModel;
#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, RuntimeClient};
