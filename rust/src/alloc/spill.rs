//! Compile-time spill planning.
//!
//! When the offset allocator cannot place a residency window, space
//! must be freed by evicting something across an idle stretch of its
//! lifetime. The victim policy is the same furthest-next-use rule the
//! dynamic simulator applied at replay time — expressed statically:
//! among the windows contending for the full region, pick the tensor
//! with the largest *use gap* overlapping the failing window (the gap
//! start is exactly the point whose next use is furthest away), and
//! make the eviction explicit:
//!
//! * **weights / inputs** are clean copies of DRAM data, so eviction is
//!   free and re-staging is an ordinary reload: the planner just splits
//!   the residency window at the gap (no IR is needed — and unlike the
//!   dynamic simulator, no spill write-back is charged).
//! * **intermediates** hold values that exist nowhere else, so the
//!   planner inserts an explicit `spill.*` copy nest (scratchpad →
//!   DRAM-homed tensor) at the gap start and a `reload.*` copy nest
//!   (DRAM → fresh tensor) right before the next use, re-pointing the
//!   remaining consumers. The spill traffic is thereby *IR the passes
//!   can see* — future DME generalizations can attack redundant
//!   spill/reload pairs the way they attack layout copies.
//!
//! If no contender has a usable gap the failing tensor itself is
//! demoted to DRAM (streamed), mirroring the dynamic simulator's
//! refusal to admit tensors that cannot be held.

use super::offsets::Conflict;
use crate::cost::policy::DecisionPolicy;
use crate::ir::loopnest::{Body, LoadStmt, LoopNest, Program, StoreStmt};
use crate::ir::op::OpKind;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::passes::liveness::Liveness;
use crate::poly::{AccessMap, IterDomain};
use std::collections::{BTreeMap, BTreeSet};

/// Which victim-ranking rule the spill planner applies — the
/// plan-level knob the joint optimizer ([`crate::opt`]) explores.
/// Maps to a [`DecisionPolicy`]: [`SpillFlavor::FurthestGap`] is
/// [`crate::cost::GreedyPolicy`] (the historical furthest-next-use
/// rule), [`SpillFlavor::Traffic`] is [`crate::cost::TrafficPolicy`]
/// (rank victims by the DRAM bytes their eviction costs).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SpillFlavor {
    FurthestGap,
    Traffic,
}

impl SpillFlavor {
    /// The scoring policy this flavor stands for.
    pub fn policy(self) -> &'static dyn DecisionPolicy {
        match self {
            SpillFlavor::FurthestGap => &crate::cost::GreedyPolicy,
            SpillFlavor::Traffic => &crate::cost::TrafficPolicy,
        }
    }
}

/// What one resolution round did (for stats).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillAction {
    /// Split an input/weight residency window (plan-only).
    SplitWindow { tensor: TensorId },
    /// Inserted a spill/reload copy-nest pair for an intermediate.
    SpillPair { tensor: TensorId, bytes: i64 },
    /// Demoted the failing tensor to DRAM streaming.
    Stream { tensor: TensorId },
}

/// Resolve one allocation conflict. Mutates the program (for
/// intermediate spills), `dram` (for demotions) and `evictions` (for
/// window splits); the caller re-runs allocation afterwards. The
/// victim is the candidate the `policy` ranks highest
/// ([`DecisionPolicy::spill_victim_key`]).
pub(crate) fn resolve(
    prog: &mut Program,
    lv: &Liveness,
    conflict: &Conflict,
    dram: &mut BTreeSet<TensorId>,
    evictions: &mut BTreeMap<TensorId, BTreeSet<usize>>,
    policy: &dyn DecisionPolicy,
) -> SpillAction {
    // Victim candidates: every contender, including the failing window
    // itself. For each, the largest idle gap between consecutive needs
    // that intersects the failing window.
    let mut contenders: Vec<(TensorId, usize, usize)> = conflict.overlapping.clone();
    contenders.push((conflict.tensor, conflict.start, conflict.end));

    let mut best: Option<((i64, i64), TensorId, usize, usize)> = None; // (key, t, from, to)
    for &(t, _ws, _we) in &contenders {
        let Some((from, to)) = largest_gap(prog, lv, evictions, t, conflict.start, conflict.end)
        else {
            continue;
        };
        let key = policy.spill_victim_key(prog, t, (from, to));
        let better = match best {
            None => true,
            Some((bk, bt, ..)) => key > bk || (key == bk && t < bt),
        };
        if better {
            best = Some((key, t, from, to));
        }
    }

    match best {
        Some((_, t, from, to)) => {
            let kind = prog.graph.tensor(t).kind;
            if matches!(kind, TensorKind::Input | TensorKind::Weight) {
                // split between the use at-or-before `from` and the one at `to`
                let uses = lv.use_positions(t);
                let k = uses.partition_point(|&u| u <= from) - 1;
                evictions.entry(t).or_default().insert(k);
                SpillAction::SplitWindow { tensor: t }
            } else {
                let bytes = prog.graph.tensor(t).size_bytes();
                let (t_sp, _t_rel) = insert_spill_pair(prog, t, from, to);
                // the DRAM-side copy must never get a scratchpad region
                dram.insert(t_sp);
                SpillAction::SpillPair { tensor: t, bytes }
            }
        }
        None => {
            dram.insert(conflict.tensor);
            SpillAction::Stream { tensor: conflict.tensor }
        }
    }
}

/// The largest stretch `(from, to)` with `from < to`, `to - from >= 2`,
/// between consecutive *needs* of `t` (its def and reads), overlapping
/// `[c_start, c_end]`, during which `t` is currently planned resident.
/// Returns `None` when `t` has no such idle stretch.
fn largest_gap(
    prog: &Program,
    lv: &Liveness,
    evictions: &BTreeMap<TensorId, BTreeSet<usize>>,
    t: TensorId,
    c_start: usize,
    c_end: usize,
) -> Option<(usize, usize)> {
    let info = prog.graph.tensor(t);
    let mut needs: Vec<usize> = lv.use_positions(t).to_vec();
    if matches!(info.kind, TensorKind::Intermediate | TensorKind::Output) {
        // every write is a need: multi-nest nodes (`concat`) write the
        // tensor at several positions, and a gap must never span one —
        // the spill copy would snapshot a half-written tensor
        lv.ranges.get(&t)?;
        needs.extend(prog.writers(t));
        needs.sort_unstable();
        needs.dedup();
    }
    let already = evictions.get(&t);
    let mut best: Option<(usize, usize)> = None;
    for (k, pair) in needs.windows(2).enumerate() {
        let (a, b) = (pair[0], pair[1]);
        if b - a < 2 {
            continue; // no free position strictly inside
        }
        // an intermediate's gap must end at a *read*: the reload's
        // consumers are re-pointed, which only makes sense for loads
        if matches!(info.kind, TensorKind::Intermediate | TensorKind::Output)
            && !lv.read_at(t, b)
        {
            continue;
        }
        // the idle stretch must help the failing window
        if b.saturating_sub(1) < c_start || a + 1 > c_end {
            continue;
        }
        // for inputs/weights, skip gaps already split
        if matches!(info.kind, TensorKind::Input | TensorKind::Weight) {
            // needs == uses here; break index k sits between use k and k+1
            if already.map(|s| s.contains(&k)).unwrap_or(false) {
                continue;
            }
        }
        let better = match best {
            None => true,
            Some((ba, bb)) => b - a > bb - ba,
        };
        if better {
            best = Some((a, b));
        }
    }
    best
}

/// Insert `spill.t` (at gap start) and `reload.t` (before the next
/// use) copy nests, re-pointing every read of `t` at or after the
/// reload to the reloaded tensor. `from` is the last position that
/// needs `t`; `to` is the next read after the gap. Returns the
/// DRAM-side tensor and the reloaded tensor.
fn insert_spill_pair(
    prog: &mut Program,
    t: TensorId,
    from: usize,
    to: usize,
) -> (TensorId, TensorId) {
    let info = prog.graph.tensor(t).clone();
    let nd = info.shape.len();
    let t_sp = prog.graph.add_tensor(
        format!("spill.{}", info.name),
        &info.shape,
        info.dtype,
        TensorKind::Intermediate,
    );
    let t_rel = prog.graph.add_tensor(
        format!("reload.{}", info.name),
        &info.shape,
        info.dtype,
        TensorKind::Intermediate,
    );

    // Graph nodes, inserted just before the consumer at `to` (topological:
    // producer(t) is earlier, consumers of t_rel are `to` and later).
    let consumer_node = prog.nests[to].node;
    let sp_node = prog.graph.insert_node_before(
        consumer_node,
        format!("spill.{}@{}", info.name, from + 1),
        OpKind::MemCopy,
        vec![t],
        t_sp,
    );
    let rel_node = prog.graph.insert_node_before(
        consumer_node,
        format!("reload.{}@{}", info.name, to),
        OpKind::MemCopy,
        vec![t_sp],
        t_rel,
    );

    // Re-point reads of `t` in nests at/after the reload, and in the
    // corresponding graph nodes.
    let mut repointed_nodes: BTreeSet<crate::ir::NodeId> = BTreeSet::new();
    for nest in prog.nests.iter_mut().skip(to) {
        let mut touched = false;
        for load in nest.body.loads_mut() {
            for piece in &mut load.pieces {
                if piece.tensor == Some(t) {
                    piece.tensor = Some(t_rel);
                    touched = true;
                }
            }
        }
        if touched {
            repointed_nodes.insert(nest.node);
        }
    }
    for id in repointed_nodes {
        let node = prog.graph.node_mut(id);
        for inp in &mut node.inputs {
            if *inp == t {
                *inp = t_rel;
            }
        }
    }

    // Nests: reload right before the old position `to`, spill right
    // after `from`. Insert the later index first so both stay valid.
    let copy_nest = |node, name: String, src, dst| LoopNest {
        node,
        tile: None,
        name,
        domain: IterDomain::new(&info.shape),
        store: StoreStmt { tensor: dst, map: AccessMap::identity(nd) },
        body: Body::Copy { load: LoadStmt::total(src, AccessMap::identity(nd)) },
    };
    prog.nests.insert(
        to,
        copy_nest(
            rel_node,
            format!("reload.{}@{}", info.name, to),
            t_sp,
            t_rel,
        ),
    );
    prog.nests.insert(
        from + 1,
        copy_nest(
            sp_node,
            format!("spill.{}@{}", info.name, from + 1),
            t,
            t_sp,
        ),
    );
    (t_sp, t_rel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::{verify_graph, verify_program};

    /// x is produced early, idle for a long stretch, then read again:
    /// the classic spill shape.
    fn long_gap_prog() -> (Program, TensorId) {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[16, 16]);
        let early = b.transpose("early", x, &[1, 0]); // the victim
        let mut cur = b.transpose("w0", x, &[1, 0]);
        for k in 1..5 {
            cur = b.transpose(&format!("w{k}"), cur, &[1, 0]);
        }
        let late = b.add("late", cur, early); // early's far next use
        b.mark_output(late);
        (Program::lower(b.finish()), early)
    }

    #[test]
    fn spill_pair_is_valid_ir() {
        let (mut prog, victim) = long_gap_prog();
        let lv = Liveness::analyze(&prog);
        // gap of `victim`: def at 0, next read at the final add
        let uses = lv.use_positions(victim).to_vec();
        let def = lv.ranges[&victim].def;
        insert_spill_pair(&mut prog, victim, def, uses[0]);
        verify_graph(&prog.graph).unwrap();
        verify_program(&prog).unwrap();
        // the add now reads reload.early_out, not early_out
        let reload_reads = prog
            .nests
            .iter()
            .filter(|n| n.name.starts_with("reload."))
            .count();
        let spill_reads = prog
            .nests
            .iter()
            .filter(|n| n.name.starts_with("spill."))
            .count();
        assert_eq!(reload_reads, 1);
        assert_eq!(spill_reads, 1);
        // liveness of the victim now ends at the spill copy
        let lv2 = Liveness::analyze(&prog);
        assert!(lv2.ranges[&victim].last_use <= def + 1);
    }

    #[test]
    fn resolve_prefers_largest_gap() {
        let (mut prog, victim) = long_gap_prog();
        let lv = Liveness::analyze(&prog);
        let uses = lv.use_positions(victim).to_vec();
        let conflict = Conflict {
            tensor: victim,
            start: lv.ranges[&victim].def,
            end: uses[0],
            per_bank_bytes: 64,
            overlapping: vec![],
        };
        let mut dram = BTreeSet::new();
        let mut ev = BTreeMap::new();
        let action = resolve(&mut prog, &lv, &conflict, &mut dram, &mut ev, &crate::cost::GreedyPolicy);
        assert!(
            matches!(action, SpillAction::SpillPair { tensor, .. } if tensor == victim),
            "{action:?}"
        );
        verify_program(&prog).unwrap();
    }

    #[test]
    fn weight_window_splits_without_ir() {
        // a weight used at positions 0 and far later: resolve must
        // split the window, not touch the IR
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 8]);
        let w = b.weight("w", &[8, 8]);
        let m1 = b.matmul("m1", x, w);
        let mut cur = m1;
        for k in 0..4 {
            cur = b.transpose(&format!("t{k}"), cur, &[1, 0]);
        }
        let m2 = b.matmul("m2", cur, w); // w read again at the end
        b.mark_output(m2);
        let mut prog = Program::lower(b.finish());
        let n_before = prog.nests.len();
        let lv = Liveness::analyze(&prog);
        let uses = lv.use_positions(w).to_vec();
        assert_eq!(uses.len(), 2);
        let conflict = Conflict {
            tensor: w,
            start: uses[0],
            end: uses[1],
            per_bank_bytes: 64,
            overlapping: vec![],
        };
        let mut dram = BTreeSet::new();
        let mut ev = BTreeMap::new();
        let action = resolve(&mut prog, &lv, &conflict, &mut dram, &mut ev, &crate::cost::GreedyPolicy);
        assert!(matches!(action, SpillAction::SplitWindow { tensor } if tensor == w));
        assert_eq!(prog.nests.len(), n_before);
        assert_eq!(ev[&w], BTreeSet::from([0]));
    }

    #[test]
    fn gapless_conflict_streams() {
        // three tensors all strictly live together with no idle gaps:
        // nothing can be evicted, the failing tensor is demoted
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let s = b.add("s", t1, x);
        b.mark_output(s);
        let mut prog = Program::lower(b.finish());
        let lv = Liveness::analyze(&prog);
        let conflict = Conflict {
            tensor: t1,
            start: 0,
            end: 1,
            per_bank_bytes: 64,
            overlapping: vec![(x, 0, 1)],
        };
        let mut dram = BTreeSet::new();
        let mut ev = BTreeMap::new();
        let action = resolve(&mut prog, &lv, &conflict, &mut dram, &mut ev, &crate::cost::GreedyPolicy);
        assert!(matches!(action, SpillAction::Stream { tensor } if tensor == t1));
        assert!(dram.contains(&t1));
    }
}
