//! Static `(bank group, offset, size)` assignment.
//!
//! Every tensor staged on chip receives a concrete region of the
//! banked scratchpad at compile time: the bank group its
//! [`Placement`] names (Row or Col), a byte offset inside each bank of
//! that group, and a per-bank slice size (the tensor is spread across
//! all `banks` banks of its group at the same offset, the layout the
//! bank-mapping passes assume). Two tensors may share addresses exactly
//! when their residency windows do not overlap in time — the address
//! reuse a static allocator gets for free from liveness.
//!
//! The allocator is interval-overlap first-fit: windows are placed in
//! schedule order, each at the lowest offset not overlapping any
//! time-conflicting placed window of the same group. A window that fits
//! in neither its preferred group nor (crossbar fallback, see below)
//! the other group is returned as a [`Conflict`] for the spill planner
//! to resolve.
//!
//! **Group fallback.** The eviction crossbar can deposit a result into
//! either bank group at equal cost when the destination is known at
//! schedule time (`passes/bank.rs` §"compiler degree of freedom") —
//! and a static plan knows it. When the preferred group is full the
//! allocator therefore borrows space in the other group rather than
//! spilling, counting the event in
//! [`AllocOutcome::cross_group`]. The traffic model (like the dynamic
//! simulator, which is group-blind) charges no penalty; a finer
//! crossbar-contention model is future work.

use crate::accel::config::AccelConfig;
use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::passes::bank::{Align, Placement};
use crate::passes::liveness::Liveness;
use std::collections::{BTreeMap, BTreeSet};

/// Region granularity: offsets and sizes are rounded to this many
/// bytes per bank (DMA burst granularity).
pub const ALLOC_ALIGN: i64 = 64;

/// A concrete scratchpad region.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Region {
    /// Bank group (the `banks` banks of this group each hold a slice).
    pub group: Align,
    /// Byte offset inside each bank of the group.
    pub offset: i64,
    /// Slice bytes per bank (aligned); `banks * per_bank_bytes` total.
    pub per_bank_bytes: i64,
}

impl Region {
    pub fn end(&self) -> i64 {
        self.offset + self.per_bank_bytes
    }

    pub fn total_bytes(&self, banks: usize) -> i64 {
        self.per_bank_bytes * banks as i64
    }
}

/// Where a tensor lives during one residency window.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Home {
    /// Planned into the scratchpad at a concrete region.
    Scratch(Region),
    /// Tile-staged: the tensor as a whole never materializes anywhere —
    /// its tiles are produced and consumed through this double-buffered
    /// staging region by the tile nests of one group
    /// (`crate::tile`). The region holds at most two live tiles, so
    /// tensors far larger than the scratchpad cost zero DRAM traffic.
    Staged(Region),
    /// Streamed from/to DRAM (too big, or the spill planner demoted
    /// it); occupies no scratchpad space.
    Dram,
}

impl Home {
    /// Is the tensor on-chip under this home (whole or tile-staged)?
    pub fn on_chip(&self) -> bool {
        !matches!(self, Home::Dram)
    }

    /// The scratchpad region this home occupies, if any.
    pub fn region(&self) -> Option<Region> {
        match self {
            Home::Scratch(r) | Home::Staged(r) => Some(*r),
            Home::Dram => None,
        }
    }
}

/// One residency window: the tensor occupies `home` for schedule
/// positions `start..=end`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct PlanWindow {
    pub start: usize,
    pub end: usize,
    pub home: Home,
}

/// Per-tensor plan: disjoint, sorted residency windows.
#[derive(Clone, Debug, Default)]
pub struct TensorPlan {
    pub windows: Vec<PlanWindow>,
}

impl TensorPlan {
    pub fn window_at(&self, pos: usize) -> Option<&PlanWindow> {
        self.windows.iter().find(|w| w.start <= pos && pos <= w.end)
    }
}

/// Successful allocation of every window.
#[derive(Clone, Debug)]
pub struct AllocOutcome {
    pub tensors: BTreeMap<TensorId, TensorPlan>,
    /// Per-bank offset high-water mark, Row group.
    pub peak_row_offset: i64,
    /// Per-bank offset high-water mark, Col group.
    pub peak_col_offset: i64,
    /// Windows placed outside their preferred group.
    pub cross_group: usize,
}

/// A window that fit in neither group: the spill planner must free
/// space (or demote a tensor to DRAM) and allocation is retried.
#[derive(Clone, Debug)]
pub struct Conflict {
    pub tensor: TensorId,
    pub start: usize,
    pub end: usize,
    pub per_bank_bytes: i64,
    /// Scratch windows (tensor, start, end) overlapping this window in
    /// time — the victim candidates.
    pub overlapping: Vec<(TensorId, usize, usize)>,
}

/// Per-bank slice size for a tensor spread across `banks` banks.
pub fn per_bank_bytes(total_bytes: i64, banks: usize) -> i64 {
    let per = (total_bytes + banks as i64 - 1) / banks as i64;
    (per + ALLOC_ALIGN - 1) / ALLOC_ALIGN * ALLOC_ALIGN
}

#[derive(Clone, Copy)]
struct Placed {
    tensor: TensorId,
    start: usize,
    end: usize,
    offset: i64,
    per_bank: i64,
    group: Align,
}

/// Residency windows of every tensor over the program schedule,
/// derived from liveness: intermediates/outputs live `[def, last
/// read]`, inputs/weights `[first read, last read]` split at the
/// eviction breaks the spill planner recorded (`evictions[t]` holds
/// use-indexes `k` meaning "not resident between use k and use k+1").
pub(crate) fn residency_windows(
    prog: &Program,
    lv: &Liveness,
    evictions: &BTreeMap<TensorId, BTreeSet<usize>>,
) -> Vec<(TensorId, usize, usize)> {
    // last writing nest per tensor (multi-nest nodes like `concat`
    // write their output at several positions; liveness only records
    // the first)
    let mut last_write: BTreeMap<TensorId, usize> = BTreeMap::new();
    for (pos, nest) in prog.nests.iter().enumerate() {
        last_write.insert(nest.store.tensor, pos);
    }
    let mut out = Vec::new();
    for t in prog.graph.tensors() {
        let uses = lv.use_positions(t.id);
        match t.kind {
            TensorKind::Input | TensorKind::Weight => {
                if uses.is_empty() {
                    continue;
                }
                let breaks = evictions.get(&t.id);
                let mut run_start = uses[0];
                for k in 0..uses.len() {
                    let broken = breaks.map(|b| b.contains(&k)).unwrap_or(false);
                    let last = k + 1 == uses.len();
                    if broken || last {
                        out.push((t.id, run_start, uses[k]));
                        if !last {
                            run_start = uses[k + 1];
                        }
                    }
                }
            }
            TensorKind::Intermediate | TensorKind::Output => {
                let Some(r) = lv.ranges.get(&t.id) else { continue };
                let lw = last_write.get(&t.id).copied().unwrap_or(r.def);
                let end = uses.last().copied().unwrap_or(r.def).max(r.def).max(lw);
                out.push((t.id, r.def, end));
            }
        }
    }
    out.sort_by_key(|&(t, s, e)| (s, e, t));
    out
}

/// Do two windows conflict in time? Touching at a single position `p`
/// is permitted ("handoff") when one window is the output being
/// *defined* at `p` and the other is an operand whose last read is at
/// `p`: the result may reuse the operand's banks as the nest consumes
/// it — exactly what the dynamic simulator's release-after-step allows.
pub(crate) fn windows_conflict(
    lv: &Liveness,
    prog: &Program,
    a: (TensorId, usize, usize),
    b: (TensorId, usize, usize),
) -> bool {
    let s = a.1.max(b.1);
    let e = a.2.min(b.2);
    if s > e {
        return false;
    }
    if s < e {
        return true;
    }
    // single shared position: allow operand -> output handoff
    let def_at = |t: TensorId, p: usize| {
        matches!(
            prog.graph.tensor(t).kind,
            TensorKind::Intermediate | TensorKind::Output
        ) && lv.ranges.get(&t).map(|r| r.def == p).unwrap_or(false)
    };
    let handoff = |read: (TensorId, usize, usize), def: (TensorId, usize, usize)| {
        def.1 == s && def_at(def.0, s) && read.2 == s && lv.read_at(read.0, s)
    };
    !(handoff(a, b) || handoff(b, a))
}

/// Allocate a region for every residency window. `dram` lists tensors
/// the caller streams (no region); `staged` maps tile-staged tensors
/// (see [`Home::Staged`]) to their double-buffered per-bank region
/// size, which replaces the whole-tensor size. Returns the first
/// unplaceable window as `Err` so the spill planner can make room.
pub(crate) fn allocate(
    prog: &Program,
    lv: &Liveness,
    placements: Option<&BTreeMap<TensorId, Placement>>,
    cfg: &AccelConfig,
    dram: &BTreeSet<TensorId>,
    evictions: &BTreeMap<TensorId, BTreeSet<usize>>,
    staged: &BTreeMap<TensorId, i64>,
) -> Result<AllocOutcome, Conflict> {
    let windows = residency_windows(prog, lv, evictions);
    let mut tensors: BTreeMap<TensorId, TensorPlan> = BTreeMap::new();
    let mut placed: Vec<Placed> = Vec::new();
    let mut peak = BTreeMap::from([(group_key(Align::Row), 0i64), (group_key(Align::Col), 0i64)]);
    let mut cross_group = 0usize;

    for (t, start, end) in windows {
        let info = prog.graph.tensor(t);
        let staged_pb = if dram.contains(&t) { None } else { staged.get(&t).copied() };
        let per_bank = staged_pb.unwrap_or_else(|| per_bank_bytes(info.size_bytes(), cfg.banks));
        let too_big = per_bank > cfg.bank_bytes;
        if dram.contains(&t) || too_big {
            tensors
                .entry(t)
                .or_default()
                .windows
                .push(PlanWindow { start, end, home: Home::Dram });
            continue;
        }
        let pref = placements
            .and_then(|p| p.get(&t))
            .map(|p| p.align)
            .unwrap_or(Align::Row);
        let other = match pref {
            Align::Row => Align::Col,
            Align::Col => Align::Row,
        };
        let fit = first_fit(lv, prog, &placed, cfg, pref, (t, start, end), per_bank)
            .map(|off| (pref, off))
            .or_else(|| {
                first_fit(lv, prog, &placed, cfg, other, (t, start, end), per_bank)
                    .map(|off| (other, off))
            });
        match fit {
            Some((group, offset)) => {
                if group != pref && placements.and_then(|p| p.get(&t)).is_some() {
                    cross_group += 1;
                }
                let region = Region { group, offset, per_bank_bytes: per_bank };
                let home = if staged_pb.is_some() {
                    Home::Staged(region)
                } else {
                    Home::Scratch(region)
                };
                tensors
                    .entry(t)
                    .or_default()
                    .windows
                    .push(PlanWindow { start, end, home });
                let p = peak.get_mut(&group_key(group)).unwrap();
                *p = (*p).max(region.end());
                placed.push(Placed { tensor: t, start, end, offset, per_bank, group });
            }
            None => {
                let overlapping = placed
                    .iter()
                    .filter(|p| {
                        windows_conflict(lv, prog, (p.tensor, p.start, p.end), (t, start, end))
                    })
                    .map(|p| (p.tensor, p.start, p.end))
                    .collect();
                return Err(Conflict {
                    tensor: t,
                    start,
                    end,
                    per_bank_bytes: per_bank,
                    overlapping,
                });
            }
        }
    }

    Ok(AllocOutcome {
        tensors,
        peak_row_offset: peak[&group_key(Align::Row)],
        peak_col_offset: peak[&group_key(Align::Col)],
        cross_group,
    })
}

fn group_key(g: Align) -> u8 {
    match g {
        Align::Row => 0,
        Align::Col => 1,
    }
}

/// Lowest offset in `group` where `[off, off+need)` is free for the
/// whole window, or `None` if the group cannot hold it.
fn first_fit(
    lv: &Liveness,
    prog: &Program,
    placed: &[Placed],
    cfg: &AccelConfig,
    group: Align,
    win: (TensorId, usize, usize),
    need: i64,
) -> Option<i64> {
    let mut occupied: Vec<(i64, i64)> = placed
        .iter()
        .filter(|p| {
            p.group == group && windows_conflict(lv, prog, (p.tensor, p.start, p.end), win)
        })
        .map(|p| (p.offset, p.per_bank))
        .collect();
    occupied.sort_unstable();
    let mut cur = 0i64;
    for (off, sz) in occupied {
        if off - cur >= need {
            return Some(cur);
        }
        cur = cur.max(off + sz);
    }
    if cfg.bank_bytes - cur >= need {
        Some(cur)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;

    fn chain_prog() -> Program {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]); // 4 KiB
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let y = b.identity("y", t2);
        b.mark_output(y);
        Program::lower(b.finish())
    }

    #[test]
    fn per_bank_rounding() {
        assert_eq!(per_bank_bytes(1, 4), ALLOC_ALIGN);
        assert_eq!(per_bank_bytes(4 * ALLOC_ALIGN, 4), ALLOC_ALIGN);
        assert_eq!(per_bank_bytes(4 * ALLOC_ALIGN + 1, 4), 2 * ALLOC_ALIGN);
    }

    #[test]
    fn chain_reuses_addresses() {
        let prog = chain_prog();
        let lv = Liveness::analyze(&prog);
        let cfg = AccelConfig::inferentia_like();
        let out = allocate(
            &prog,
            &lv,
            None,
            &cfg,
            &BTreeSet::new(),
            &BTreeMap::new(),
            &BTreeMap::new(),
        ).unwrap();
        // t1 dies as t2 is defined (handoff): their regions may alias,
        // so the Row high-water stays well under the sum of all tensors.
        let total: i64 = prog.graph.tensors().map(|t| t.size_bytes()).sum();
        let used = out.peak_row_offset * cfg.banks as i64
            + out.peak_col_offset * cfg.banks as i64;
        assert!(used < total, "no address reuse: {used} >= {total}");
        assert_eq!(out.cross_group, 0);
    }

    #[test]
    fn simultaneous_windows_disjoint() {
        let prog = chain_prog();
        let lv = Liveness::analyze(&prog);
        let cfg = AccelConfig::inferentia_like();
        let out = allocate(
            &prog,
            &lv,
            None,
            &cfg,
            &BTreeSet::new(),
            &BTreeMap::new(),
            &BTreeMap::new(),
        ).unwrap();
        let flat: Vec<(TensorId, PlanWindow)> = out
            .tensors
            .iter()
            .flat_map(|(t, tp)| tp.windows.iter().map(|w| (*t, *w)))
            .collect();
        for (i, (ta, wa)) in flat.iter().enumerate() {
            for (tb, wb) in flat.iter().skip(i + 1) {
                let (Home::Scratch(ra), Home::Scratch(rb)) = (wa.home, wb.home) else {
                    continue;
                };
                if ra.group != rb.group {
                    continue;
                }
                if windows_conflict(&lv, &prog, (*ta, wa.start, wa.end), (*tb, wb.start, wb.end))
                {
                    assert!(
                        ra.end() <= rb.offset || rb.end() <= ra.offset,
                        "{ta:?} and {tb:?} overlap: {ra:?} vs {rb:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn oversized_tensor_streams() {
        let prog = chain_prog();
        let lv = Liveness::analyze(&prog);
        let cfg = AccelConfig::tiny(1024); // 4 KiB tensors >> 128 B banks
        let out = allocate(
            &prog,
            &lv,
            None,
            &cfg,
            &BTreeSet::new(),
            &BTreeMap::new(),
            &BTreeMap::new(),
        ).unwrap();
        for tp in out.tensors.values() {
            for w in &tp.windows {
                assert_eq!(w.home, Home::Dram);
            }
        }
    }

    #[test]
    fn conflict_reported_when_full() {
        // Each bank holds exactly one tensor slice, one slice per
        // group. x, t1, t2 overlap strictly in time (no handoff): the
        // third window fits in neither group and must be reported.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", x, &[1, 0]);
        let t3 = b.transpose("t3", x, &[1, 0]);
        let c = b.concat("c", &[t1, t2, t3], 0);
        b.mark_output(c);
        let prog = Program::lower(b.finish());
        let lv = Liveness::analyze(&prog);
        let mut cfg = AccelConfig::tiny(8 * 1024);
        cfg.bank_bytes = per_bank_bytes(32 * 32 * 4, cfg.banks);
        let r = allocate(
            &prog,
            &lv,
            None,
            &cfg,
            &BTreeSet::new(),
            &BTreeMap::new(),
            &BTreeMap::new(),
        );
        let err = r.unwrap_err();
        assert_eq!(err.tensor, t2);
        assert!(!err.overlapping.is_empty());
    }
}
