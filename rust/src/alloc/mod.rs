//! Static scratchpad planning — compile-time scheduling, offset
//! allocation and spill planning.
//!
//! The paper's premise is that a software-managed scratchpad is staged
//! *by the compiler*; until this subsystem existed, residency decisions
//! lived inside the simulator (`accel/scratchpad.rs` made Belady-style
//! eviction choices at replay time), so no memory plan was ever
//! actually produced. `alloc` closes that gap with three cooperating
//! components, following the combined scheduling / allocation /
//! tensor-replacement formulation of Li et al. (arXiv 2311.18246) and
//! the full-stack search framing of Zhang et al. (arXiv 2105.12842):
//!
//! * [`schedule`] — searches topological orders of the operator graph
//!   for minimum peak live footprint (greedy with bounded lookahead,
//!   measured by [`crate::passes::liveness::Liveness`]);
//! * [`offsets`] — assigns every staged tensor a concrete
//!   `(bank group, offset, size)` region by interval-overlap first-fit,
//!   honoring `BankAssignment` placements and reusing addresses across
//!   non-overlapping live ranges;
//! * [`spill`] — when demand exceeds the configured SRAM, makes
//!   evictions explicit: window splits for clean inputs/weights,
//!   `spill.*`/`reload.*` copy nests (real IR) for intermediates, with
//!   the same furthest-next-use victim flavor the simulator used
//!   dynamically.
//!
//! The product is a [`MemoryPlan`]: per-tensor residency windows, each
//! with a concrete region (or DRAM streaming). The simulator's planned
//! mode ([`crate::accel::sim::simulate_planned`]) replays a plan
//! verbatim and *verifies* it — capacity, region overlap and residency
//! assertions — instead of improvising; [`verify_plan`] is the
//! checker. The dynamic path remains as the baseline so benches can
//! report planned-vs-dynamic traffic (`bench_alloc_plan`).
//!
//! Plan-format invariants (checked by [`verify_plan`], documented in
//! DESIGN.md):
//! 1. every tensor a nest touches has a window covering that position;
//! 2. scratch regions sit inside a bank: `0 <= offset` and
//!    `offset + per_bank_bytes <= bank_bytes`, with `per_bank_bytes`
//!    covering the tensor spread over the group's `banks` banks;
//! 3. no two time-overlapping scratch windows of the same group
//!    overlap in `[offset, offset + per_bank_bytes)` — except the
//!    single-position operand→result handoff the dynamic simulator
//!    also permits;
//! 4. windows are sorted, disjoint, and within the schedule.

pub mod offsets;
pub mod schedule;
pub mod spill;

pub use offsets::{Home, PlanWindow, Region, TensorPlan, ALLOC_ALIGN};
pub use schedule::{
    schedule_groups_min_footprint, schedule_min_footprint, ScheduleOpts, ScheduleStats,
};
pub use spill::{SpillAction, SpillFlavor};

use crate::accel::config::AccelConfig;
use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::passes::bank::{Align, BankAssignment};
use crate::passes::liveness::Liveness;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// Planner configuration.
#[derive(Clone, Copy, Debug)]
pub struct AllocOpts {
    /// Scheduler lookahead (see [`ScheduleOpts`]).
    pub lookahead: usize,
    /// Hard cap on spill-resolution rounds; beyond it the failing
    /// tensors are streamed from DRAM (guaranteed termination).
    pub max_rounds: usize,
    /// Strict capacity mode: refuse (with [`PlanError::Oversized`]) any
    /// workload containing a tensor larger than the *total* scratchpad,
    /// instead of silently demoting it to DRAM streaming. Deployments
    /// that require guaranteed residency turn this on; the default
    /// keeps the documented streaming fallback.
    pub require_fit: bool,
    /// Spill victim ranking rule (see [`SpillFlavor`]); a joint-search
    /// axis, defaulting to the historical furthest-gap policy.
    pub spill: SpillFlavor,
}

impl Default for AllocOpts {
    fn default() -> Self {
        AllocOpts {
            lookahead: 4,
            max_rounds: 512,
            require_fit: false,
            spill: SpillFlavor::FurthestGap,
        }
    }
}

/// A planning failure — returned, never panicked, so a caller with a
/// degenerate chip description or an unservable workload gets a
/// diagnosable error instead of an invalid plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// The chip description cannot host any plan (zero banks or
    /// non-positive bank size).
    BadConfig(String),
    /// Strict capacity mode: a tensor exceeds the total scratchpad.
    Oversized { tensor: TensorId, name: String, bytes: i64, capacity: i64 },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::BadConfig(d) => write!(f, "plan: bad accelerator config: {d}"),
            PlanError::Oversized { tensor, name, bytes, capacity } => write!(
                f,
                "plan: tensor {tensor:?} ('{name}', {bytes} bytes) exceeds the \
                 total scratchpad capacity of {capacity} bytes"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Aggregate statistics of one planning run.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    /// Peak live bytes before/after scheduling.
    pub peak_live_before: i64,
    pub peak_live_after: i64,
    /// Nodes the scheduler moved.
    pub moved_nodes: usize,
    /// Allocation rounds (1 = no spilling needed).
    pub rounds: usize,
    /// Explicit spill/reload copy-nest pairs inserted.
    pub spill_pairs: usize,
    /// Bytes written to DRAM by those spills.
    pub spilled_bytes: i64,
    /// Input/weight residency windows split (plan-only evictions).
    pub window_splits: usize,
    /// Tensors demoted to DRAM streaming.
    pub streamed: usize,
    /// Tile-staged tensors (double-buffered [`Home::Staged`] regions).
    pub tile_staged: usize,
    /// Windows placed outside their preferred bank group.
    pub cross_group: usize,
    /// Per-bank offset high-water marks.
    pub peak_row_offset: i64,
    pub peak_col_offset: i64,
}

/// The compile-time memory plan for one scheduled program.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    /// Residency windows + regions per tensor.
    pub tensors: BTreeMap<TensorId, TensorPlan>,
    /// Schedule length (nest count) the plan was built for.
    pub n_positions: usize,
    /// Banks per group and bytes per bank the plan assumed.
    pub banks: usize,
    pub bank_bytes: i64,
    pub stats: PlanStats,
}

impl MemoryPlan {
    /// The window (if any) covering `pos` for tensor `t`.
    pub fn window_at(&self, t: TensorId, pos: usize) -> Option<&PlanWindow> {
        self.tensors.get(&t).and_then(|tp| tp.window_at(pos))
    }

    /// The scratch region `t` occupies at `pos` (None when absent or
    /// DRAM-streamed). Tile-staged windows report their staging region.
    pub fn region_at(&self, t: TensorId, pos: usize) -> Option<Region> {
        self.window_at(t, pos)?.home.region()
    }

    /// Planned scratchpad high-water mark in bytes: the measure of the
    /// *union* of occupied per-bank address ranges, maximized over
    /// schedule positions. (A union, not a sum: at a handoff position
    /// the dying operand and the newborn result alias one range and
    /// must be counted once — which also keeps this bounded by the
    /// configured capacity whenever the plan verifies.)
    pub fn peak_scratchpad_bytes(&self) -> i64 {
        let windows: Vec<(&PlanWindow, Region)> = self
            .tensors
            .values()
            .flat_map(|tp| {
                tp.windows.iter().filter_map(|w| w.home.region().map(|r| (w, r)))
            })
            .collect();
        let mut peak = 0i64;
        for pos in 0..self.n_positions {
            let mut per_bank = 0i64;
            for group in [Align::Row, Align::Col] {
                let mut ranges: Vec<(i64, i64)> = windows
                    .iter()
                    .filter(|(w, r)| w.start <= pos && pos <= w.end && r.group == group)
                    .map(|(_, r)| (r.offset, r.end()))
                    .collect();
                ranges.sort_unstable();
                let mut cur_end = 0i64;
                for (s, e) in ranges {
                    if s >= cur_end {
                        per_bank += e - s;
                        cur_end = e;
                    } else if e > cur_end {
                        per_bank += e - cur_end;
                        cur_end = e;
                    }
                }
            }
            peak = peak.max(per_bank);
        }
        peak * self.banks as i64
    }

    /// Planned scratchpad occupancy at one schedule position: the same
    /// per-position union measure [`Self::peak_scratchpad_bytes`]
    /// maximizes, exposed for occupancy timelines.
    pub fn occupied_bytes_at(&self, pos: usize) -> i64 {
        let mut per_bank = 0i64;
        for group in [Align::Row, Align::Col] {
            let mut ranges: Vec<(i64, i64)> = self
                .tensors
                .values()
                .flat_map(|tp| tp.windows.iter())
                .filter(|w| w.start <= pos && pos <= w.end)
                .filter_map(|w| w.home.region())
                .filter(|r| r.group == group)
                .map(|r| (r.offset, r.end()))
                .collect();
            ranges.sort_unstable();
            let mut cur_end = 0i64;
            for (s, e) in ranges {
                if s >= cur_end {
                    per_bank += e - s;
                    cur_end = e;
                } else if e > cur_end {
                    per_bank += e - cur_end;
                    cur_end = e;
                }
            }
        }
        per_bank * self.banks as i64
    }

    /// Summary for reports/benches.
    pub fn to_json(&self) -> Json {
        let s = &self.stats;
        Json::obj(vec![
            ("n_positions", Json::Int(self.n_positions as i64)),
            ("banks", Json::Int(self.banks as i64)),
            ("bank_bytes", Json::Int(self.bank_bytes)),
            ("planned_tensors", Json::Int(self.tensors.len() as i64)),
            ("peak_scratchpad", Json::Int(self.peak_scratchpad_bytes())),
            ("peak_live_before", Json::Int(s.peak_live_before)),
            ("peak_live_after", Json::Int(s.peak_live_after)),
            ("moved_nodes", Json::Int(s.moved_nodes as i64)),
            ("rounds", Json::Int(s.rounds as i64)),
            ("spill_pairs", Json::Int(s.spill_pairs as i64)),
            ("spilled_bytes", Json::Int(s.spilled_bytes)),
            ("window_splits", Json::Int(s.window_splits as i64)),
            ("streamed", Json::Int(s.streamed as i64)),
            ("tile_staged", Json::Int(s.tile_staged as i64)),
            ("cross_group", Json::Int(s.cross_group as i64)),
        ])
    }
}

/// A plan-invariant violation (planned-mode simulation refuses to run).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanViolation {
    /// A nest touches a tensor with no covering window.
    NotResident { tensor: TensorId, pos: usize },
    /// A region escapes its bank or under-covers its tensor.
    BadRegion { tensor: TensorId, detail: String },
    /// Two live windows overlap in the same bank group.
    Overlap { a: TensorId, b: TensorId },
    /// A window is outside the schedule or malformed.
    BadWindow { tensor: TensorId },
}

impl fmt::Display for PlanViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanViolation::NotResident { tensor, pos } => {
                write!(f, "plan: {tensor:?} not resident at position {pos}")
            }
            PlanViolation::BadRegion { tensor, detail } => {
                write!(f, "plan: bad region for {tensor:?}: {detail}")
            }
            PlanViolation::Overlap { a, b } => {
                write!(f, "plan: regions of {a:?} and {b:?} overlap while both live")
            }
            PlanViolation::BadWindow { tensor } => {
                write!(f, "plan: malformed window for {tensor:?}")
            }
        }
    }
}

impl std::error::Error for PlanViolation {}

/// Verify every plan-format invariant against a program and chip
/// configuration. The planned-mode simulator runs this before replay.
pub fn verify_plan(
    prog: &Program,
    plan: &MemoryPlan,
    cfg: &AccelConfig,
) -> Result<(), PlanViolation> {
    let lv = Liveness::analyze(prog);
    if plan.n_positions != prog.nests.len() {
        return Err(PlanViolation::BadWindow { tensor: TensorId(u32::MAX) });
    }

    // windows well-formed
    for (t, tp) in &plan.tensors {
        let mut prev_end: Option<usize> = None;
        for w in &tp.windows {
            if w.start > w.end || w.end >= plan.n_positions {
                return Err(PlanViolation::BadWindow { tensor: *t });
            }
            if let Some(pe) = prev_end {
                if w.start <= pe {
                    return Err(PlanViolation::BadWindow { tensor: *t });
                }
            }
            prev_end = Some(w.end);
            if let Some(r) = w.home.region() {
                if r.offset < 0 || r.offset + r.per_bank_bytes > plan.bank_bytes {
                    return Err(PlanViolation::BadRegion {
                        tensor: *t,
                        detail: format!(
                            "offset {}..{} outside bank of {} bytes",
                            r.offset,
                            r.end(),
                            plan.bank_bytes
                        ),
                    });
                }
                match w.home {
                    Home::Scratch(_) => {
                        let need = prog.graph.tensor(*t).size_bytes();
                        if r.total_bytes(plan.banks) < need {
                            return Err(PlanViolation::BadRegion {
                                tensor: *t,
                                detail: format!(
                                    "{} bytes across {} banks < tensor size {}",
                                    r.total_bytes(plan.banks),
                                    plan.banks,
                                    need
                                ),
                            });
                        }
                    }
                    Home::Staged(_) => {
                        // a staging region is deliberately smaller than
                        // the tensor; it must cover the largest single
                        // tile, and only tile nests may touch it
                        for (pos, nest) in prog.nests.iter().enumerate() {
                            if pos < w.start || pos > w.end {
                                continue;
                            }
                            let touches = nest.store.tensor == *t
                                || nest.body.loads().iter().any(|l| {
                                    l.pieces.iter().any(|p| p.tensor == Some(*t))
                                });
                            if !touches {
                                continue;
                            }
                            if nest.tile.is_none() {
                                return Err(PlanViolation::BadRegion {
                                    tensor: *t,
                                    detail: format!(
                                        "staged tensor touched by untiled nest '{}'",
                                        nest.name
                                    ),
                                });
                            }
                            let need =
                                crate::tile::footprint::nest_tensor_bytes(&prog.graph, nest, *t);
                            if r.total_bytes(plan.banks) < need {
                                return Err(PlanViolation::BadRegion {
                                    tensor: *t,
                                    detail: format!(
                                        "staging region {} bytes < tile working set {} at '{}'",
                                        r.total_bytes(plan.banks),
                                        need,
                                        nest.name
                                    ),
                                });
                            }
                        }
                    }
                    Home::Dram => unreachable!("region() returned Some"),
                }
            }
        }
    }

    // residency: every touched tensor has a covering window
    for (pos, nest) in prog.nests.iter().enumerate() {
        for load in nest.body.loads() {
            for piece in &load.pieces {
                if let Some(t) = piece.tensor {
                    if plan.window_at(t, pos).is_none() {
                        return Err(PlanViolation::NotResident { tensor: t, pos });
                    }
                }
            }
        }
        if plan.window_at(nest.store.tensor, pos).is_none() {
            return Err(PlanViolation::NotResident { tensor: nest.store.tensor, pos });
        }
    }

    // overlap: pairwise over scratch windows of the same group
    let flat: Vec<(TensorId, &PlanWindow, Region)> = plan
        .tensors
        .iter()
        .flat_map(|(t, tp)| {
            tp.windows
                .iter()
                .filter_map(move |w| w.home.region().map(|r| (*t, w, r)))
        })
        .collect();
    for (i, (ta, wa, ra)) in flat.iter().enumerate() {
        for (tb, wb, rb) in flat.iter().skip(i + 1) {
            if ra.group != rb.group {
                continue;
            }
            let addr_overlap = ra.offset < rb.end() && rb.offset < ra.end();
            if !addr_overlap {
                continue;
            }
            if offsets::windows_conflict(&lv, prog, (*ta, wa.start, wa.end), (*tb, wb.start, wb.end))
            {
                return Err(PlanViolation::Overlap { a: *ta, b: *tb });
            }
        }
    }
    Ok(())
}

/// Tile-staged tensor detection.
///
/// An intermediate qualifies when every nest writing or reading it is a
/// tile nest of **one** group and, per tile index, the tile's writes
/// complete before its reads begin (with at most the adjacent tile in
/// flight — the double-buffer window). Such a tensor never needs
/// whole-tensor residency: tile `t` is produced into a staging region
/// and consumed a few positions later while tile `t+1` is produced into
/// the buddy half. Returns the per-bank staging-region size (2× the
/// largest tile slice, 1× for single-tile groups); tensors whose
/// staging region cannot fit a bank are left out (they fall back to
/// whole-tensor planning or streaming).
fn detect_staged(program: &Program, cfg: &AccelConfig) -> BTreeMap<TensorId, i64> {
    let mut out = BTreeMap::new();
    for info in program.graph.tensors() {
        if info.kind != TensorKind::Intermediate {
            continue;
        }
        let writers = program.writers(info.id);
        let readers = program.readers(info.id);
        if writers.is_empty() || readers.is_empty() {
            continue;
        }
        let tag_of = |p: usize| program.nests[p].tile;
        let Some(t0) = tag_of(writers[0]) else { continue };
        if !writers
            .iter()
            .chain(&readers)
            .all(|&p| tag_of(p).map(|t| t.group == t0.group).unwrap_or(false))
        {
            continue;
        }
        // per tile index: (min, max) writer and reader positions
        let by_index = |positions: &[usize]| {
            let mut m: BTreeMap<u32, (usize, usize)> = BTreeMap::new();
            for &p in positions {
                let idx = tag_of(p).unwrap().index;
                let e = m.entry(idx).or_insert((p, p));
                e.0 = e.0.min(p);
                e.1 = e.1.max(p);
            }
            m
        };
        let w_by = by_index(&writers);
        let r_by = by_index(&readers);
        if w_by.keys().ne(r_by.keys()) {
            continue; // a tile written but never read (or vice versa)
        }
        // write-before-read inside each tile, and no tile's reads
        // outlive the write of tile index+2 (double-buffer window)
        let ordered = w_by.iter().all(|(idx, &(_, wmax))| r_by[idx].0 > wmax);
        if !ordered {
            continue;
        }
        let idxs: Vec<u32> = w_by.keys().copied().collect();
        let windowed = idxs
            .windows(3)
            .all(|w| r_by[&w[0]].1 < w_by[&w[2]].0);
        if !windowed {
            continue;
        }
        let max_touched = writers
            .iter()
            .chain(&readers)
            .map(|&p| {
                crate::tile::footprint::nest_tensor_bytes(
                    &program.graph,
                    &program.nests[p],
                    info.id,
                )
            })
            .max()
            .unwrap_or(0);
        if max_touched == 0 {
            continue;
        }
        let buf = if w_by.len() > 1 { 2 * max_touched } else { max_touched };
        let pb = offsets::per_bank_bytes(buf, cfg.banks);
        if pb > cfg.bank_bytes {
            continue;
        }
        out.insert(info.id, pb);
    }
    out
}

/// Planner result: the (possibly rescheduled, possibly spill-extended)
/// program plus its memory plan.
#[derive(Clone, Debug)]
pub struct AllocResult {
    pub program: Program,
    pub plan: MemoryPlan,
}

/// Run the full static planner: schedule, then iterate offset
/// allocation + spill resolution to a clean plan. Fails (never panics)
/// on a degenerate chip config, and — in strict capacity mode
/// ([`AllocOpts::require_fit`]) — on any tensor larger than the total
/// scratchpad.
pub fn plan_memory(
    program: Program,
    bank: Option<&BankAssignment>,
    cfg: &AccelConfig,
    opts: &AllocOpts,
) -> Result<AllocResult, PlanError> {
    if cfg.banks == 0 || cfg.bank_bytes <= 0 {
        return Err(PlanError::BadConfig(format!(
            "banks={} bank_bytes={}",
            cfg.banks, cfg.bank_bytes
        )));
    }
    if opts.require_fit {
        let capacity = cfg.scratchpad_bytes();
        for t in program.graph.tensors() {
            if t.size_bytes() > capacity {
                return Err(PlanError::Oversized {
                    tensor: t.id,
                    name: t.name.clone(),
                    bytes: t.size_bytes(),
                    capacity,
                });
            }
        }
    }
    // Tiled programs reschedule at tile-*group* granularity: the tile
    // transform interleaved each fused chain for minimal footprint and
    // the node-granular scheduler would unweave it, so whole groups
    // move as units instead (each group's interleave kept verbatim).
    let tiled = program.nests.iter().any(|n| n.tile.is_some());
    let sched_opts = ScheduleOpts { lookahead: opts.lookahead, ..Default::default() };
    let (mut program, sched) = if tiled {
        schedule_groups_min_footprint(program, &sched_opts)
    } else {
        schedule_min_footprint(program, &sched_opts)
    };

    // Chain intermediates produced and consumed tile-by-tile get
    // double-buffered staging regions instead of whole-tensor windows.
    let mut staged = detect_staged(&program, cfg);

    let placements = bank.map(|b| &b.placements);
    let mut dram: BTreeSet<TensorId> = BTreeSet::new();
    let mut evictions: BTreeMap<TensorId, BTreeSet<usize>> = BTreeMap::new();

    // Single-use inputs/weights are streamed, never planned into
    // residency: staging and streaming cost identical DRAM bytes in
    // this traffic model, and keeping one-shot operands out of the
    // scratchpad frees whole banks (what double-buffered weight
    // streaming achieves on real hardware). Multi-use operands keep
    // residency windows so their reuse stays on-chip.
    {
        let lv = Liveness::analyze(&program);
        for t in program.graph.tensors() {
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight)
                && lv.use_positions(t.id).len() == 1
            {
                dram.insert(t.id);
            }
        }
    }
    let mut stats = PlanStats {
        peak_live_before: sched.peak_before,
        peak_live_after: sched.peak_after,
        moved_nodes: sched.moved_nodes,
        ..Default::default()
    };

    loop {
        stats.rounds += 1;
        let lv = Liveness::analyze(&program);
        match offsets::allocate(&program, &lv, placements, cfg, &dram, &evictions, &staged) {
            Ok(out) => {
                stats.cross_group = out.cross_group;
                stats.peak_row_offset = out.peak_row_offset;
                stats.peak_col_offset = out.peak_col_offset;
                stats.tile_staged = staged.len();
                let plan = MemoryPlan {
                    tensors: out.tensors,
                    n_positions: program.nests.len(),
                    banks: cfg.banks,
                    bank_bytes: cfg.bank_bytes,
                    stats,
                };
                return Ok(AllocResult { program, plan });
            }
            Err(mut conflict) => {
                if staged.contains_key(&conflict.tensor) {
                    // a staging region the crowded plan cannot place:
                    // demote the tensor to tile-wise DRAM streaming
                    staged.remove(&conflict.tensor);
                    dram.insert(conflict.tensor);
                    stats.streamed += 1;
                    continue;
                }
                // staged regions are never spill victims — they are
                // already minimal, and spilling one would corrupt the
                // tile handoff the staging depends on
                conflict.overlapping.retain(|(t, _, _)| !staged.contains_key(t));
                let action = if stats.rounds >= opts.max_rounds {
                    // termination backstop: stream the failing tensor
                    dram.insert(conflict.tensor);
                    SpillAction::Stream { tensor: conflict.tensor }
                } else {
                    spill::resolve(
                        &mut program,
                        &lv,
                        &conflict,
                        &mut dram,
                        &mut evictions,
                        opts.spill.policy(),
                    )
                };
                match action {
                    SpillAction::SplitWindow { .. } => stats.window_splits += 1,
                    SpillAction::SpillPair { bytes, .. } => {
                        stats.spill_pairs += 1;
                        stats.spilled_bytes += bytes;
                    }
                    SpillAction::Stream { .. } => stats.streamed += 1,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::{verify_graph, verify_program};

    fn plan_for(g: crate::ir::Graph, cfg: &AccelConfig) -> AllocResult {
        plan_memory(Program::lower(g), None, cfg, &AllocOpts::default()).unwrap()
    }

    #[test]
    fn roomy_plan_needs_no_spills() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[16, 16]);
        let t = b.transpose("t", x, &[1, 0]);
        let y = b.relu("y", t);
        b.mark_output(y);
        let r = plan_for(b.finish(), &AccelConfig::inferentia_like());
        assert_eq!(r.plan.stats.rounds, 1);
        assert_eq!(r.plan.stats.spill_pairs, 0);
        verify_plan(&r.program, &r.plan, &AccelConfig::inferentia_like()).unwrap();
    }

    #[test]
    fn tight_plan_spills_and_verifies() {
        // Three parallel transposes of x feed a concat: four windows
        // overlap strictly while each bank holds exactly one tensor
        // slice, so the planner must insert spill/reload pairs.
        let mut cfg = AccelConfig::tiny(8 * 1024);
        cfg.bank_bytes = crate::alloc::offsets::per_bank_bytes(32 * 32 * 4, cfg.banks);
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", x, &[1, 0]);
        let t3 = b.transpose("t3", x, &[1, 0]);
        let c = b.concat("c", &[t1, t2, t3], 0);
        b.mark_output(c);
        let r = plan_for(b.finish(), &cfg);
        verify_graph(&r.program.graph).unwrap();
        verify_program(&r.program).unwrap();
        verify_plan(&r.program, &r.plan, &cfg).unwrap();
        assert!(r.plan.stats.rounds > 1, "{:?}", r.plan.stats);
        assert!(r.plan.stats.spill_pairs >= 1, "{:?}", r.plan.stats);
        let spills = r
            .program
            .graph
            .count_nodes(|n| n.name.starts_with("spill."));
        assert_eq!(spills, r.plan.stats.spill_pairs);
        // the plan fits the configured capacity by construction
        assert!(r.plan.peak_scratchpad_bytes() <= cfg.scratchpad_bytes());
    }

    #[test]
    fn peak_accounting_matches_regions() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let cfg = AccelConfig::inferentia_like();
        let r = plan_for(b.finish(), &cfg);
        let peak = r.plan.peak_scratchpad_bytes();
        assert!(peak > 0);
        assert!(peak <= cfg.scratchpad_bytes());
        // per-position occupancy is the same measure, maximized
        let max_at = (0..r.plan.n_positions)
            .map(|p| r.plan.occupied_bytes_at(p))
            .max()
            .unwrap_or(0);
        assert_eq!(max_at, peak);
    }

    #[test]
    fn tensor_exactly_filling_a_bank_group_plans_clean() {
        // 32×32 f32 = 4096 B = 4 banks × 1024 B: the tensor fills one
        // bank group to the last byte. The region must land at offset 0
        // with per_bank_bytes == bank_bytes (no off-by-one), and the
        // plan must verify with no spill activity.
        let cfg = AccelConfig::tiny(8 * 1024); // banks=4, bank_bytes=1024
        assert_eq!(
            offsets::per_bank_bytes(32 * 32 * 4, cfg.banks),
            cfg.bank_bytes
        );
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        // two readers: single-use inputs are streamed by policy, and the
        // point here is a *resident* group-filling region
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", x, &[1, 0]);
        b.mark_output(t1);
        b.mark_output(t2);
        let r = plan_for(b.finish(), &cfg);
        verify_plan(&r.program, &r.plan, &cfg).unwrap();
        assert_eq!(r.plan.stats.rounds, 1, "{:?}", r.plan.stats);
        assert_eq!(r.plan.stats.spill_pairs, 0);
        assert_eq!(r.plan.stats.streamed, 0);
        let region = r.plan.region_at(x, 0).expect("x planned resident");
        assert_eq!(region.offset, 0);
        assert_eq!(region.per_bank_bytes, cfg.bank_bytes);
    }

    #[test]
    fn oversized_tensor_is_planner_err_in_strict_mode() {
        // 64×64 f32 = 16 KiB > the whole 8 KiB scratchpad: strict mode
        // must report it, not emit a silently-streaming plan.
        let cfg = AccelConfig::tiny(8 * 1024);
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let opts = AllocOpts { require_fit: true, ..Default::default() };
        let err = plan_memory(Program::lower(b.finish()), None, &cfg, &opts).unwrap_err();
        assert!(
            matches!(err, PlanError::Oversized { bytes: 16384, capacity: 8192, .. }),
            "{err}"
        );
    }

    #[test]
    fn oversized_tensor_streams_to_valid_plan_by_default() {
        // same workload without strict mode: the documented fallback is
        // DRAM streaming, and the emitted plan must still verify
        let cfg = AccelConfig::tiny(8 * 1024);
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let r = plan_for(b.finish(), &cfg);
        verify_plan(&r.program, &r.plan, &cfg).unwrap();
        for tp in r.plan.tensors.values() {
            for w in &tp.windows {
                assert_eq!(w.home, Home::Dram);
            }
        }
    }

    #[test]
    fn degenerate_config_is_err_not_panic() {
        let mut cfg = AccelConfig::tiny(8 * 1024);
        cfg.banks = 0; // would divide by zero in per-bank sizing
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let err = plan_memory(
            Program::lower(b.finish()),
            None,
            &cfg,
            &AllocOpts::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PlanError::BadConfig(_)), "{err}");
    }

    #[test]
    fn tiled_chain_intermediates_get_staged_regions() {
        // conv → bn → relu with 4 KiB feature maps on a 4 KiB chip:
        // after tiling, the chain intermediates must be planned into
        // Staged regions smaller than the tensors they stage
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 16, 16]);
        let w = b.weight("w", &[4, 4, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let n = b.batchnorm("bn", c);
        let r = b.relu("r", n);
        b.mark_output(r);
        let cfg = AccelConfig::tiny(4 * 1024);
        let mut prog = Program::lower(b.finish());
        let tstats =
            crate::tile::run_tiling(&mut prog, &cfg, &crate::tile::TileOpts::default());
        assert!(tstats.fused_chains >= 1, "{tstats:?}");
        let res = plan_memory(prog, None, &cfg, &AllocOpts::default()).unwrap();
        verify_plan(&res.program, &res.plan, &cfg).unwrap();
        assert!(res.plan.stats.tile_staged >= 1, "{:?}", res.plan.stats);
        let staged: Vec<_> = res
            .plan
            .tensors
            .iter()
            .flat_map(|(t, tp)| {
                tp.windows
                    .iter()
                    .filter(|w| matches!(w.home, Home::Staged(_)))
                    .map(move |w| (*t, *w))
            })
            .collect();
        assert!(!staged.is_empty());
        for (t, w) in staged {
            let region = w.home.region().unwrap();
            assert!(
                region.total_bytes(res.plan.banks)
                    < res.program.graph.tensor(t).size_bytes(),
                "staging region should be smaller than the staged tensor"
            );
        }
        let _ = (x, r);
    }

    #[test]
    fn untiled_programs_detect_no_staging() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[16, 16]);
        let t = b.transpose("t", x, &[1, 0]);
        let y = b.relu("y", t);
        b.mark_output(y);
        let prog = Program::lower(b.finish());
        let staged = detect_staged(&prog, &AccelConfig::inferentia_like());
        assert!(staged.is_empty());
    }

    #[test]
    fn verify_plan_catches_missing_window() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let cfg = AccelConfig::inferentia_like();
        let mut r = plan_for(b.finish(), &cfg);
        r.plan.tensors.remove(&x);
        let err = verify_plan(&r.program, &r.plan, &cfg).unwrap_err();
        assert!(matches!(err, PlanViolation::NotResident { tensor, .. } if tensor == x));
    }

    #[test]
    fn verify_plan_catches_overlap() {
        // x is read twice, so it keeps a scratch region live across
        // both adds — as does the first sum s.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let y = b.input("y", &[8, 8]);
        let s = b.add("s", x, y);
        let u = b.add("u", s, x);
        b.mark_output(u);
        let cfg = AccelConfig::inferentia_like();
        let mut r = plan_for(b.finish(), &cfg);
        // force s onto x's region while both are live
        let rx = r.plan.region_at(x, 0).expect("x is multi-use, planned");
        let tp = r.plan.tensors.get_mut(&s).unwrap();
        tp.windows[0].home = Home::Scratch(rx);
        let err = verify_plan(&r.program, &r.plan, &cfg).unwrap_err();
        assert!(matches!(err, PlanViolation::Overlap { .. }), "{err}");
    }
}
