//! Graph scheduling for minimum live footprint.
//!
//! The nest order a [`Program`] executes is a compiler degree of
//! freedom: any topological order of the operator graph is legal, and
//! orders differ — sometimes dramatically, on branchy graphs like
//! Inception blocks or attention heads — in how many intermediate bytes
//! are live at the peak. Because the scratchpad is software-managed,
//! shrinking that peak directly shrinks spill traffic (the
//! scheduling/allocation coupling of Li et al., arXiv 2311.18246).
//!
//! The search is greedy min-footprint with a bounded lookahead: at each
//! step every ready node is evaluated by simulating `lookahead` further
//! greedy steps and the candidate whose horizon peak is lowest wins.
//! Liveness is measured with the same byte accounting as
//! [`crate::passes::liveness::Liveness::peak_live_bytes`] (intermediate
//! and output tensors only — inputs and weights are staged on demand).
//! The result is guaranteed never worse than the input order: if the
//! greedy order raises the measured peak, the input order is kept.

use crate::ir::graph::{Node, NodeId};
use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::passes::liveness::Liveness;
use std::collections::{BTreeMap, HashMap};

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleOpts {
    /// Greedy steps simulated beyond each candidate before choosing it.
    pub lookahead: usize,
    /// Cap on candidates evaluated per step (ready sets are small in
    /// practice; the cap bounds worst-case cost on very wide graphs).
    pub max_candidates: usize,
}

impl Default for ScheduleOpts {
    fn default() -> Self {
        ScheduleOpts { lookahead: 4, max_candidates: 32 }
    }
}

/// What scheduling did.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScheduleStats {
    /// Peak live intermediate/output bytes of the input order.
    pub peak_before: i64,
    /// Peak of the chosen order (== `peak_before` when unchanged).
    pub peak_after: i64,
    /// Nodes whose schedule position changed.
    pub moved_nodes: usize,
    /// True when the greedy order was worse and the input order kept.
    pub kept_input_order: bool,
}

/// Footprint simulation state shared by the greedy search and its
/// lookahead rollouts.
#[derive(Clone)]
struct SimState {
    /// Remaining consumer-node count per live tensor (`usize::MAX` for
    /// graph outputs, which stay live to the end).
    consumers_left: BTreeMap<TensorId, usize>,
    /// Unscheduled-predecessor count per node index.
    indegree: Vec<usize>,
    scheduled: Vec<bool>,
    live_bytes: i64,
}

struct SchedGraph {
    nodes: Vec<Node>,
    /// Bytes a tensor contributes to the footprint (0 for inputs and
    /// weights, which are not part of the planned live set).
    bytes: BTreeMap<TensorId, i64>,
    /// Predecessor node indexes per node.
    preds: Vec<Vec<usize>>,
    /// Successor node indexes per node.
    succs: Vec<Vec<usize>>,
    /// Total consumer-node count per tensor (MAX-pinned for outputs).
    consumers: BTreeMap<TensorId, usize>,
}

impl SchedGraph {
    fn build(prog: &Program) -> SchedGraph {
        let nodes: Vec<Node> = prog.graph.nodes().to_vec();
        let mut bytes = BTreeMap::new();
        let mut consumers: BTreeMap<TensorId, usize> = BTreeMap::new();
        for t in prog.graph.tensors() {
            let b = match t.kind {
                TensorKind::Intermediate | TensorKind::Output => t.size_bytes(),
                _ => 0,
            };
            bytes.insert(t.id, b);
            if t.kind == TensorKind::Output {
                consumers.insert(t.id, usize::MAX);
            }
        }
        let producer_of: HashMap<TensorId, usize> = nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.output, i))
            .collect();
        let mut preds = vec![Vec::new(); nodes.len()];
        let mut succs = vec![Vec::new(); nodes.len()];
        for (i, n) in nodes.iter().enumerate() {
            let mut seen = Vec::new();
            for inp in &n.inputs {
                let c = consumers.entry(*inp).or_insert(0);
                if *c != usize::MAX && !seen.contains(inp) {
                    *c += 1;
                    seen.push(*inp);
                }
                if let Some(&p) = producer_of.get(inp) {
                    if !preds[i].contains(&p) {
                        preds[i].push(p);
                        succs[p].push(i);
                    }
                }
            }
        }
        SchedGraph { nodes, bytes, preds, succs, consumers }
    }

    fn initial_state(&self) -> SimState {
        SimState {
            consumers_left: self.consumers.clone(),
            indegree: self.preds.iter().map(|p| p.len()).collect(),
            scheduled: vec![false; self.nodes.len()],
            live_bytes: 0,
        }
    }

    /// Schedule node `i` in `st`, returning the live footprint after it
    /// (output becomes live; inputs whose last consumer this was die).
    fn step(&self, st: &mut SimState, i: usize) -> i64 {
        st.scheduled[i] = true;
        for &s in &self.succs[i] {
            st.indegree[s] -= 1;
        }
        let n = &self.nodes[i];
        st.live_bytes += self.bytes[&n.output];
        let mut seen = Vec::new();
        for inp in &n.inputs {
            if seen.contains(inp) {
                continue;
            }
            seen.push(*inp);
            if let Some(c) = st.consumers_left.get_mut(inp) {
                if *c != usize::MAX {
                    *c -= 1;
                    if *c == 0 {
                        st.live_bytes -= self.bytes[inp];
                        st.consumers_left.remove(inp);
                    }
                }
            }
        }
        st.live_bytes
    }

    fn ready(&self, st: &SimState) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&i| !st.scheduled[i] && st.indegree[i] == 0)
            .collect()
    }

    /// Footprint after scheduling node `i`, computed in O(degree)
    /// without mutating or cloning the state.
    fn footprint_after(&self, st: &SimState, i: usize) -> i64 {
        let n = &self.nodes[i];
        let mut live = st.live_bytes + self.bytes[&n.output];
        let mut seen = Vec::new();
        for inp in &n.inputs {
            if seen.contains(inp) {
                continue;
            }
            seen.push(*inp);
            if let Some(&c) = st.consumers_left.get(inp) {
                if c == 1 {
                    live -= self.bytes[inp];
                }
            }
        }
        live
    }

    /// One purely-greedy step: schedule the ready node minimizing the
    /// resulting footprint (ties broken by original position). Returns
    /// the footprint after the step, or `None` when nothing is ready.
    fn greedy_step(&self, st: &mut SimState) -> Option<(usize, i64)> {
        let ready = self.ready(st);
        let mut best: Option<(i64, usize)> = None;
        for &i in &ready {
            let after = self.footprint_after(st, i);
            if best.map(|(b, _)| after < b).unwrap_or(true) {
                best = Some((after, i));
            }
        }
        let (_, i) = best?;
        let after = self.step(st, i);
        Some((i, after))
    }
}

/// Search a topological order minimizing peak live footprint, then
/// reorder the program (graph nodes and nests consistently) to it.
pub fn schedule_min_footprint(prog: Program, opts: &ScheduleOpts) -> (Program, ScheduleStats) {
    let peak_before = Liveness::analyze(&prog).peak_live_bytes(&prog);
    let g = SchedGraph::build(&prog);
    let n = g.nodes.len();
    if n <= 1 {
        let stats = ScheduleStats {
            peak_before,
            peak_after: peak_before,
            ..Default::default()
        };
        return (prog, stats);
    }

    let mut st = g.initial_state();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    while order.len() < n {
        let ready = g.ready(&st);
        assert!(!ready.is_empty(), "scheduler: graph has a cycle?");
        let candidates: Vec<usize> =
            ready.iter().copied().take(opts.max_candidates.max(1)).collect();
        let mut best: Option<(i64, i64, usize)> = None; // (horizon peak, after, idx)
        for &c in &candidates {
            let mut probe = st.clone();
            let after = g.step(&mut probe, c);
            let mut horizon_peak = after;
            for _ in 0..opts.lookahead {
                match g.greedy_step(&mut probe) {
                    Some((_, f)) => horizon_peak = horizon_peak.max(f),
                    None => break,
                }
            }
            let key = (horizon_peak, after, c);
            if best
                .map(|(hp, af, i)| (key.0, key.1, key.2) < (hp, af, i))
                .unwrap_or(true)
            {
                best = Some(key);
            }
        }
        let (_, _, chosen) = best.expect("non-empty candidate set");
        g.step(&mut st, chosen);
        order.push(chosen);
    }

    // Reorder graph nodes and nests to the chosen order; keep the input
    // order if the greedy result measured worse.
    let reordered = reorder_program(&prog, &g.nodes, &order);
    let peak_after = Liveness::analyze(&reordered).peak_live_bytes(&reordered);
    let moved = order.iter().enumerate().filter(|&(k, &i)| k != i).count();
    if peak_after > peak_before {
        let stats = ScheduleStats {
            peak_before,
            peak_after: peak_before,
            moved_nodes: 0,
            kept_input_order: true,
        };
        (prog, stats)
    } else {
        let stats = ScheduleStats {
            peak_before,
            peak_after,
            moved_nodes: moved,
            kept_input_order: false,
        };
        (reordered, stats)
    }
}

/// Apply a node permutation to a program: graph node list and nest list
/// are both reordered (nests of one node stay contiguous, preserving
/// their relative order, e.g. `concat`'s per-input nests).
fn reorder_program(prog: &Program, nodes: &[Node], order: &[usize]) -> Program {
    let mut out = prog.clone();
    let rank: HashMap<NodeId, usize> = order
        .iter()
        .enumerate()
        .map(|(k, &i)| (nodes[i].id, k))
        .collect();
    out.graph.nodes.sort_by_key(|n| rank[&n.id]);
    out.nests.sort_by_key(|n| rank[&n.node]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::{verify_graph, verify_program};

    /// Two independent branches: a fat one (big tensors) and a thin
    /// one. Scheduling the thin branch fully before the fat one (or
    /// vice versa) beats interleaving them.
    fn branchy() -> Program {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]); // 16 KiB
        // fat branch: 3 chained transposes of the full tensor
        let f1 = b.transpose("f1", x, &[1, 0]);
        // thin branch built from a slice: 1/8 the bytes
        let s = b.slice("s", x, &[0, 0], &[8, 64], &[1, 1]);
        let f2 = b.transpose("f2", f1, &[1, 0]);
        let t1 = b.transpose("t1", s, &[1, 0]);
        let f3 = b.transpose("f3", f2, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let fr = b.reshape("fr", f3, &[8, 512]);
        let tr = b.reshape("tr", t2, &[8, 64]);
        let cat = b.concat("cat", &[tr, fr], 1);
        b.mark_output(cat);
        Program::lower(b.finish())
    }

    #[test]
    fn schedule_preserves_validity() {
        let prog = branchy();
        let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
        verify_graph(&out.graph).unwrap();
        verify_program(&out).unwrap();
        assert!(stats.peak_after <= stats.peak_before);
    }

    #[test]
    fn schedule_reduces_branch_peak() {
        let prog = branchy();
        let before = Liveness::analyze(&prog).peak_live_bytes(&prog);
        let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
        let after = Liveness::analyze(&out).peak_live_bytes(&out);
        assert_eq!(stats.peak_before, before);
        assert_eq!(stats.peak_after, after);
        assert!(after <= before, "schedule made the peak worse");
    }

    #[test]
    fn chain_is_stable() {
        // A pure chain has exactly one topological order: nothing moves.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let y = b.identity("y", t2);
        b.mark_output(y);
        let prog = Program::lower(b.finish());
        let names: Vec<String> = prog.nests.iter().map(|n| n.name.clone()).collect();
        let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
        let names2: Vec<String> = out.nests.iter().map(|n| n.name.clone()).collect();
        assert_eq!(names, names2);
        assert_eq!(stats.moved_nodes, 0);
    }

    #[test]
    fn zoo_orders_stay_valid() {
        for g in [
            crate::models::mlp(2, 32, 16, 4, 2),
            crate::models::transformer_block(16, 32, 2, 64),
            crate::models::inception_stack(1, 2),
        ] {
            let prog = Program::lower(g);
            let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
            verify_program(&out).unwrap();
            assert!(stats.peak_after <= stats.peak_before);
        }
    }
}
