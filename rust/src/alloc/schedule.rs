//! Graph scheduling for minimum live footprint.
//!
//! The nest order a [`Program`] executes is a compiler degree of
//! freedom: any topological order of the operator graph is legal, and
//! orders differ — sometimes dramatically, on branchy graphs like
//! Inception blocks or attention heads — in how many intermediate bytes
//! are live at the peak. Because the scratchpad is software-managed,
//! shrinking that peak directly shrinks spill traffic (the
//! scheduling/allocation coupling of Li et al., arXiv 2311.18246).
//!
//! The search is greedy min-footprint with a bounded lookahead: at each
//! step every ready node is evaluated by simulating `lookahead` further
//! greedy steps and the candidate whose horizon peak is lowest wins.
//! Liveness is measured with the same byte accounting as
//! [`crate::passes::liveness::Liveness::peak_live_bytes`] (intermediate
//! and output tensors only — inputs and weights are staged on demand).
//! The result is guaranteed never worse than the input order: if the
//! greedy order raises the measured peak, the input order is kept.

use crate::cost::policy::{DecisionPolicy, GreedyPolicy};
use crate::ir::graph::{Node, NodeId};
use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::passes::liveness::Liveness;
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleOpts {
    /// Greedy steps simulated beyond each candidate before choosing it.
    pub lookahead: usize,
    /// Cap on candidates evaluated per step (ready sets are small in
    /// practice; the cap bounds worst-case cost on very wide graphs).
    pub max_candidates: usize,
}

impl Default for ScheduleOpts {
    fn default() -> Self {
        ScheduleOpts { lookahead: 4, max_candidates: 32 }
    }
}

/// What scheduling did.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScheduleStats {
    /// Peak live intermediate/output bytes of the input order.
    pub peak_before: i64,
    /// Peak of the chosen order (== `peak_before` when unchanged).
    pub peak_after: i64,
    /// Schedule items whose position changed: graph nodes under the
    /// node-granular scheduler, tile-group *units* under
    /// [`schedule_groups_min_footprint`] (one unit may hold many
    /// nests, so the two counts are not comparable across modes).
    pub moved_nodes: usize,
    /// True when the greedy order was worse and the input order kept.
    pub kept_input_order: bool,
}

/// Footprint simulation state shared by the greedy search and its
/// lookahead rollouts.
#[derive(Clone)]
struct SimState {
    /// Remaining consumer-node count per live tensor (`usize::MAX` for
    /// graph outputs, which stay live to the end).
    consumers_left: BTreeMap<TensorId, usize>,
    /// Unscheduled-predecessor count per node index.
    indegree: Vec<usize>,
    scheduled: Vec<bool>,
    live_bytes: i64,
}

struct SchedGraph {
    nodes: Vec<Node>,
    /// Bytes a tensor contributes to the footprint (0 for inputs and
    /// weights, which are not part of the planned live set).
    bytes: BTreeMap<TensorId, i64>,
    /// Predecessor node indexes per node.
    preds: Vec<Vec<usize>>,
    /// Successor node indexes per node.
    succs: Vec<Vec<usize>>,
    /// Total consumer-node count per tensor (MAX-pinned for outputs).
    consumers: BTreeMap<TensorId, usize>,
}

impl SchedGraph {
    fn build(prog: &Program) -> SchedGraph {
        let nodes: Vec<Node> = prog.graph.nodes().to_vec();
        let mut bytes = BTreeMap::new();
        let mut consumers: BTreeMap<TensorId, usize> = BTreeMap::new();
        for t in prog.graph.tensors() {
            let b = match t.kind {
                TensorKind::Intermediate | TensorKind::Output => t.size_bytes(),
                _ => 0,
            };
            bytes.insert(t.id, b);
            if t.kind == TensorKind::Output {
                consumers.insert(t.id, usize::MAX);
            }
        }
        let producer_of: HashMap<TensorId, usize> = nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.output, i))
            .collect();
        let mut preds = vec![Vec::new(); nodes.len()];
        let mut succs = vec![Vec::new(); nodes.len()];
        for (i, n) in nodes.iter().enumerate() {
            let mut seen = Vec::new();
            for inp in &n.inputs {
                let c = consumers.entry(*inp).or_insert(0);
                if *c != usize::MAX && !seen.contains(inp) {
                    *c += 1;
                    seen.push(*inp);
                }
                if let Some(&p) = producer_of.get(inp) {
                    if !preds[i].contains(&p) {
                        preds[i].push(p);
                        succs[p].push(i);
                    }
                }
            }
        }
        SchedGraph { nodes, bytes, preds, succs, consumers }
    }

    fn initial_state(&self) -> SimState {
        SimState {
            consumers_left: self.consumers.clone(),
            indegree: self.preds.iter().map(|p| p.len()).collect(),
            scheduled: vec![false; self.nodes.len()],
            live_bytes: 0,
        }
    }

    /// Schedule node `i` in `st`, returning the live footprint after it
    /// (output becomes live; inputs whose last consumer this was die).
    fn step(&self, st: &mut SimState, i: usize) -> i64 {
        st.scheduled[i] = true;
        for &s in &self.succs[i] {
            st.indegree[s] -= 1;
        }
        let n = &self.nodes[i];
        st.live_bytes += self.bytes[&n.output];
        let mut seen = Vec::new();
        for inp in &n.inputs {
            if seen.contains(inp) {
                continue;
            }
            seen.push(*inp);
            if let Some(c) = st.consumers_left.get_mut(inp) {
                if *c != usize::MAX {
                    *c -= 1;
                    if *c == 0 {
                        st.live_bytes -= self.bytes[inp];
                        st.consumers_left.remove(inp);
                    }
                }
            }
        }
        st.live_bytes
    }

    fn ready(&self, st: &SimState) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&i| !st.scheduled[i] && st.indegree[i] == 0)
            .collect()
    }

    /// Footprint after scheduling node `i`, computed in O(degree)
    /// without mutating or cloning the state.
    fn footprint_after(&self, st: &SimState, i: usize) -> i64 {
        let n = &self.nodes[i];
        let mut live = st.live_bytes + self.bytes[&n.output];
        let mut seen = Vec::new();
        for inp in &n.inputs {
            if seen.contains(inp) {
                continue;
            }
            seen.push(*inp);
            if let Some(&c) = st.consumers_left.get(inp) {
                if c == 1 {
                    live -= self.bytes[inp];
                }
            }
        }
        live
    }

    /// One purely-greedy step: schedule the ready node minimizing the
    /// resulting footprint (ties broken by original position). Returns
    /// the footprint after the step, or `None` when nothing is ready.
    fn greedy_step(&self, st: &mut SimState) -> Option<(usize, i64)> {
        let ready = self.ready(st);
        let mut best: Option<(i64, usize)> = None;
        for &i in &ready {
            let after = self.footprint_after(st, i);
            if best.map(|(b, _)| after < b).unwrap_or(true) {
                best = Some((after, i));
            }
        }
        let (_, i) = best?;
        let after = self.step(st, i);
        Some((i, after))
    }
}

/// Search a topological order minimizing peak live footprint, then
/// reorder the program (graph nodes and nests consistently) to it.
pub fn schedule_min_footprint(prog: Program, opts: &ScheduleOpts) -> (Program, ScheduleStats) {
    schedule_min_footprint_with(prog, opts, &GreedyPolicy)
}

/// [`schedule_min_footprint`] with an explicit candidate-scoring
/// policy ([`DecisionPolicy::schedule_key`]).
pub fn schedule_min_footprint_with(
    prog: Program,
    opts: &ScheduleOpts,
    policy: &dyn DecisionPolicy,
) -> (Program, ScheduleStats) {
    let peak_before = Liveness::analyze(&prog).peak_live_bytes(&prog);
    let g = SchedGraph::build(&prog);
    let n = g.nodes.len();
    if n <= 1 {
        let stats = ScheduleStats {
            peak_before,
            peak_after: peak_before,
            ..Default::default()
        };
        return (prog, stats);
    }

    let mut st = g.initial_state();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    while order.len() < n {
        let ready = g.ready(&st);
        assert!(!ready.is_empty(), "scheduler: graph has a cycle?");
        let candidates: Vec<usize> =
            ready.iter().copied().take(opts.max_candidates.max(1)).collect();
        let mut best: Option<((i64, i64), usize)> = None; // (policy key, idx)
        for &c in &candidates {
            let mut probe = st.clone();
            let after = g.step(&mut probe, c);
            let mut horizon_peak = after;
            for _ in 0..opts.lookahead {
                match g.greedy_step(&mut probe) {
                    Some((_, f)) => horizon_peak = horizon_peak.max(f),
                    None => break,
                }
            }
            let key = policy.schedule_key(horizon_peak, after);
            if best.map(|(bk, bi)| (key, c) < (bk, bi)).unwrap_or(true) {
                best = Some((key, c));
            }
        }
        let (_, chosen) = best.expect("non-empty candidate set");
        g.step(&mut st, chosen);
        order.push(chosen);
    }

    // Reorder graph nodes and nests to the chosen order; keep the input
    // order if the greedy result measured worse.
    let reordered = reorder_program(&prog, &g.nodes, &order);
    let peak_after = Liveness::analyze(&reordered).peak_live_bytes(&reordered);
    let moved = order.iter().enumerate().filter(|&(k, &i)| k != i).count();
    if peak_after > peak_before {
        let stats = ScheduleStats {
            peak_before,
            peak_after: peak_before,
            moved_nodes: 0,
            kept_input_order: true,
        };
        (prog, stats)
    } else {
        let stats = ScheduleStats {
            peak_before,
            peak_after,
            moved_nodes: moved,
            kept_input_order: false,
        };
        (reordered, stats)
    }
}

/// Tile-group-granular rescheduling.
///
/// Tiled programs used to skip the min-footprint search entirely: the
/// node-granular reorder sorts nests by node and would unweave the
/// chain interleaving (`A@0 B@0 A@1 B@1 …`) the staging detection
/// depends on. Here the schedule units are the maximal tile-group
/// runs ([`crate::tile::pipeline::tile_runs`]; untagged nests are
/// singleton units): units are reordered greedily for minimum peak
/// live footprint with the same bounded lookahead, and each unit's
/// internal interleave is preserved verbatim. Unit dependencies are
/// taken at *node* granularity (every unit of a producer node precedes
/// every unit of its consumer nodes, and one node's units keep their
/// relative order), which keeps both the nest schedule and the graph
/// node order valid. Like the node scheduler, the result is never
/// worse than the input: if the greedy unit order measures a higher
/// peak, the input order is kept.
pub fn schedule_groups_min_footprint(
    prog: Program,
    opts: &ScheduleOpts,
) -> (Program, ScheduleStats) {
    schedule_groups_min_footprint_with(prog, opts, &GreedyPolicy)
}

/// [`schedule_groups_min_footprint`] with an explicit scoring policy.
pub fn schedule_groups_min_footprint_with(
    prog: Program,
    opts: &ScheduleOpts,
    policy: &dyn DecisionPolicy,
) -> (Program, ScheduleStats) {
    let peak_before = Liveness::analyze(&prog).peak_live_bytes(&prog);
    let unchanged = |prog: Program| {
        let stats = ScheduleStats {
            peak_before,
            peak_after: peak_before,
            ..Default::default()
        };
        (prog, stats)
    };
    let runs = crate::tile::pipeline::tile_runs(&prog);
    let n = runs.len();
    if n <= 1 {
        return unchanged(prog);
    }

    // unit metadata: nodes per unit (first-occurrence order), tensor
    // reads/writes per unit, footprint bytes per tensor
    let mut units_of_node: BTreeMap<NodeId, Vec<usize>> = BTreeMap::new();
    let mut reads: Vec<BTreeSet<TensorId>> = vec![BTreeSet::new(); n];
    let mut writes: Vec<BTreeSet<TensorId>> = vec![BTreeSet::new(); n];
    for (u, &(a, b)) in runs.iter().enumerate() {
        for nest in &prog.nests[a..=b] {
            let e = units_of_node.entry(nest.node).or_default();
            if e.last() != Some(&u) {
                e.push(u);
            }
            writes[u].insert(nest.store.tensor);
            for load in nest.body.loads() {
                for piece in &load.pieces {
                    if let Some(t) = piece.tensor {
                        reads[u].insert(t);
                    }
                }
            }
        }
    }
    let bytes: BTreeMap<TensorId, i64> = prog
        .graph
        .tensors()
        .map(|t| {
            let b = match t.kind {
                TensorKind::Intermediate | TensorKind::Output => t.size_bytes(),
                _ => 0,
            };
            (t.id, b)
        })
        .collect();
    let first_writer: BTreeMap<TensorId, usize> = {
        let mut m = BTreeMap::new();
        for (u, w) in writes.iter().enumerate() {
            for &t in w {
                m.entry(t).or_insert(u);
            }
        }
        m
    };
    // consumer-unit counts (usize::MAX pins graph outputs live)
    let outputs: BTreeSet<TensorId> = prog.graph.outputs().into_iter().collect();
    let mut consumers: BTreeMap<TensorId, usize> = BTreeMap::new();
    for &t in &outputs {
        consumers.insert(t, usize::MAX);
    }
    for r in &reads {
        for &t in r {
            let c = consumers.entry(t).or_insert(0);
            if *c != usize::MAX {
                *c += 1;
            }
        }
    }

    // node-granular dependency edges between units
    let producer_of: HashMap<TensorId, NodeId> =
        prog.graph.nodes().iter().map(|nd| (nd.output, nd.id)).collect();
    let mut preds: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    for node in prog.graph.nodes() {
        let Some(cu) = units_of_node.get(&node.id) else { continue };
        for inp in &node.inputs {
            if let Some(pn) = producer_of.get(inp) {
                if let Some(pu) = units_of_node.get(pn) {
                    for &a in pu {
                        for &b in cu {
                            if a != b {
                                preds[b].insert(a);
                            }
                        }
                    }
                }
            }
        }
        for w in cu.windows(2) {
            preds[w[1]].insert(w[0]);
        }
    }

    // greedy min-footprint over units with bounded lookahead
    #[derive(Clone)]
    struct UnitState {
        consumers_left: BTreeMap<TensorId, usize>,
        indegree: Vec<usize>,
        scheduled: Vec<bool>,
        live: i64,
    }
    let succs: Vec<Vec<usize>> = {
        let mut s = vec![Vec::new(); n];
        for (b, ps) in preds.iter().enumerate() {
            for &a in ps {
                s[a].push(b);
            }
        }
        s
    };
    let step = |st: &mut UnitState, u: usize| -> i64 {
        st.scheduled[u] = true;
        for &s in &succs[u] {
            st.indegree[s] -= 1;
        }
        for &t in &writes[u] {
            if first_writer.get(&t) == Some(&u) {
                st.live += bytes[&t];
            }
        }
        for &t in &reads[u] {
            if let Some(c) = st.consumers_left.get_mut(&t) {
                if *c != usize::MAX {
                    *c -= 1;
                    if *c == 0 {
                        st.live -= bytes[&t];
                        st.consumers_left.remove(&t);
                    }
                }
            }
        }
        st.live
    };
    let ready = |st: &UnitState| -> Vec<usize> {
        (0..n).filter(|&u| !st.scheduled[u] && st.indegree[u] == 0).collect()
    };
    let greedy_step = |st: &mut UnitState| -> Option<i64> {
        let r = ready(st);
        let mut best: Option<(i64, usize)> = None;
        for &u in &r {
            let mut probe = st.clone();
            let after = step(&mut probe, u);
            if best.map(|(b, _)| after < b).unwrap_or(true) {
                best = Some((after, u));
            }
        }
        let (_, u) = best?;
        Some(step(st, u))
    };

    let init = UnitState {
        consumers_left: consumers.clone(),
        indegree: preds.iter().map(|p| p.len()).collect(),
        scheduled: vec![false; n],
        live: 0,
    };
    let mut st = init.clone();
    let mut order: Vec<usize> = Vec::with_capacity(n);
    while order.len() < n {
        let r = ready(&st);
        assert!(!r.is_empty(), "group scheduler: unit graph has a cycle?");
        let candidates: Vec<usize> =
            r.iter().copied().take(opts.max_candidates.max(1)).collect();
        let mut best: Option<((i64, i64), usize)> = None;
        for &c in &candidates {
            let mut probe = st.clone();
            let after = step(&mut probe, c);
            let mut horizon_peak = after;
            for _ in 0..opts.lookahead {
                match greedy_step(&mut probe) {
                    Some(f) => horizon_peak = horizon_peak.max(f),
                    None => break,
                }
            }
            let key = policy.schedule_key(horizon_peak, after);
            if best.map(|(bk, bi)| (key, c) < (bk, bi)).unwrap_or(true) {
                best = Some((key, c));
            }
        }
        let (_, chosen) = best.expect("non-empty candidate set");
        step(&mut st, chosen);
        order.push(chosen);
    }

    // materialize: nests by unit order (internal order verbatim),
    // graph nodes by first occurrence in the new nest order
    let mut new_nests = Vec::with_capacity(prog.nests.len());
    for &u in &order {
        let (a, b) = runs[u];
        new_nests.extend(prog.nests[a..=b].iter().cloned());
    }
    let mut node_rank: HashMap<NodeId, usize> = HashMap::new();
    for (k, nest) in new_nests.iter().enumerate() {
        node_rank.entry(nest.node).or_insert(k);
    }
    let mut out = prog.clone();
    out.nests = new_nests;
    out.graph
        .nodes
        .sort_by_key(|nd| node_rank.get(&nd.id).copied().unwrap_or(usize::MAX));

    // only adopt a *strictly* better order: an equal-peak reorder would
    // churn tiled schedules (and their byte-exact expectations) for
    // nothing
    let peak_after = Liveness::analyze(&out).peak_live_bytes(&out);
    if peak_after >= peak_before {
        let stats = ScheduleStats {
            peak_before,
            peak_after: peak_before,
            moved_nodes: 0,
            kept_input_order: true,
        };
        return (prog, stats);
    }
    let moved = order.iter().enumerate().filter(|&(k, &u)| k != u).count();
    let stats = ScheduleStats {
        peak_before,
        peak_after,
        moved_nodes: moved,
        kept_input_order: false,
    };
    (out, stats)
}

/// Apply a node permutation to a program: graph node list and nest list
/// are both reordered (nests of one node stay contiguous, preserving
/// their relative order, e.g. `concat`'s per-input nests).
fn reorder_program(prog: &Program, nodes: &[Node], order: &[usize]) -> Program {
    let mut out = prog.clone();
    let rank: HashMap<NodeId, usize> = order
        .iter()
        .enumerate()
        .map(|(k, &i)| (nodes[i].id, k))
        .collect();
    out.graph.nodes.sort_by_key(|n| rank[&n.id]);
    out.nests.sort_by_key(|n| rank[&n.node]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::verify::{verify_graph, verify_program};

    /// Two independent branches: a fat one (big tensors) and a thin
    /// one. Scheduling the thin branch fully before the fat one (or
    /// vice versa) beats interleaving them.
    fn branchy() -> Program {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]); // 16 KiB
        // fat branch: 3 chained transposes of the full tensor
        let f1 = b.transpose("f1", x, &[1, 0]);
        // thin branch built from a slice: 1/8 the bytes
        let s = b.slice("s", x, &[0, 0], &[8, 64], &[1, 1]);
        let f2 = b.transpose("f2", f1, &[1, 0]);
        let t1 = b.transpose("t1", s, &[1, 0]);
        let f3 = b.transpose("f3", f2, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let fr = b.reshape("fr", f3, &[8, 512]);
        let tr = b.reshape("tr", t2, &[8, 64]);
        let cat = b.concat("cat", &[tr, fr], 1);
        b.mark_output(cat);
        Program::lower(b.finish())
    }

    #[test]
    fn schedule_preserves_validity() {
        let prog = branchy();
        let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
        verify_graph(&out.graph).unwrap();
        verify_program(&out).unwrap();
        assert!(stats.peak_after <= stats.peak_before);
    }

    #[test]
    fn schedule_reduces_branch_peak() {
        let prog = branchy();
        let before = Liveness::analyze(&prog).peak_live_bytes(&prog);
        let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
        let after = Liveness::analyze(&out).peak_live_bytes(&out);
        assert_eq!(stats.peak_before, before);
        assert_eq!(stats.peak_after, after);
        assert!(after <= before, "schedule made the peak worse");
    }

    #[test]
    fn chain_is_stable() {
        // A pure chain has exactly one topological order: nothing moves.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let y = b.identity("y", t2);
        b.mark_output(y);
        let prog = Program::lower(b.finish());
        let names: Vec<String> = prog.nests.iter().map(|n| n.name.clone()).collect();
        let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
        let names2: Vec<String> = out.nests.iter().map(|n| n.name.clone()).collect();
        assert_eq!(names, names2);
        assert_eq!(stats.moved_nodes, 0);
    }

    #[test]
    fn group_schedule_keeps_interleave_contiguous_and_never_worse() {
        use crate::ir::loopnest::TileTag;
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]);
        let f1 = b.relu("f1", x);
        let f2 = b.sigmoid("f2", f1);
        let s = b.slice("s", x, &[0, 0], &[8, 64], &[1, 1]);
        let t1 = b.relu("t1", s);
        let c = b.concat("c", &[t1, t1], 0);
        b.mark_output(f2);
        b.mark_output(c);
        let mut prog = Program::lower(b.finish());
        // tag the f1/f2 pair as one interleaved tile group
        prog.nests[0].tile = Some(TileTag { group: 0, index: 0, count: 2 });
        prog.nests[1].tile = Some(TileTag { group: 0, index: 1, count: 2 });
        let before = Liveness::analyze(&prog).peak_live_bytes(&prog);
        let (out, stats) = schedule_groups_min_footprint(prog, &ScheduleOpts::default());
        verify_graph(&out.graph).unwrap();
        verify_program(&out).unwrap();
        assert_eq!(stats.peak_before, before);
        assert!(stats.peak_after <= stats.peak_before);
        // the tagged group's nests stay contiguous, internal order intact
        let tagged: Vec<usize> = out
            .nests
            .iter()
            .enumerate()
            .filter(|(_, n)| n.tile.is_some())
            .map(|(i, _)| i)
            .collect();
        assert_eq!(tagged.len(), 2);
        assert_eq!(tagged[1], tagged[0] + 1, "group interleave unwoven");
        let names: Vec<&str> = out
            .nests
            .iter()
            .filter(|n| n.tile.is_some())
            .map(|n| n.name.as_str())
            .collect();
        assert_eq!(names, vec!["f1", "f2"]);
    }

    #[test]
    fn group_schedule_moves_units_when_strictly_better() {
        use crate::ir::loopnest::TileTag;
        // two fat branches, each immediately reducible to a sliver:
        // the builder order materializes both 16 KiB tensors at once
        // (32 KiB peak); finishing one branch before starting the
        // other caps the peak near one fat tensor
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]); // 16 KiB
        let fat_a = b.relu("fat_a", x);
        let fat_b = b.sigmoid("fat_b", x);
        let sm_a = b.slice("sm_a", fat_a, &[0, 0], &[4, 64], &[1, 1]);
        let sm_b = b.slice("sm_b", fat_b, &[0, 0], &[4, 64], &[1, 1]);
        let cat = b.concat("cat", &[sm_a, sm_b], 0);
        b.mark_output(cat);
        let mut prog = Program::lower(b.finish());
        prog.nests[0].tile = Some(TileTag { group: 0, index: 0, count: 1 });
        let (out, stats) = schedule_groups_min_footprint(prog, &ScheduleOpts::default());
        verify_graph(&out.graph).unwrap();
        verify_program(&out).unwrap();
        assert!(
            stats.peak_after < stats.peak_before,
            "expected a strict improvement: {stats:?}"
        );
        assert!(stats.moved_nodes > 0);
        assert!(!stats.kept_input_order);
    }

    #[test]
    fn zoo_orders_stay_valid() {
        for g in [
            crate::models::mlp(2, 32, 16, 4, 2),
            crate::models::transformer_block(16, 32, 2, 64),
            crate::models::inception_stack(1, 2),
        ] {
            let prog = Program::lower(g);
            let (out, stats) = schedule_min_footprint(prog, &ScheduleOpts::default());
            verify_program(&out).unwrap();
            assert!(stats.peak_after <= stats.peak_before);
        }
    }
}
