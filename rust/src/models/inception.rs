//! Inception-style block stack — exercises `concat` (multi-writer
//! tensors) through DME and bank mapping, the hardest memory-bound
//! shape: a concatenated tensor's definition is piecewise and its
//! placement must unify across all branch producers.

use crate::ir::builder::GraphBuilder;
use crate::ir::tensor::TensorId;
use crate::ir::Graph;

fn conv_relu(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    cin: i64,
    cout: i64,
    k: i64,
) -> TensorId {
    let w = b.weight(&format!("{name}_w"), &[cout, cin, k, k]);
    let c = b.conv2d(name, x, w, 1, (k - 1) / 2);
    b.relu(&format!("{name}_r"), c)
}

/// One inception block: 1×1 / 3×3 / 5×5 / pool-proj branches, channel
/// concat. Branch widths are the canonical ones divided by `wd`.
fn inception_block(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    cin: i64,
    wd: i64,
) -> (TensorId, i64) {
    let b1 = conv_relu(b, &format!("{name}_b1"), x, cin, 32 / wd, 1);
    let b3a = conv_relu(b, &format!("{name}_b3a"), x, cin, 48 / wd, 1);
    let b3 = conv_relu(b, &format!("{name}_b3"), b3a, 48 / wd, 64 / wd, 3);
    let b5a = conv_relu(b, &format!("{name}_b5a"), x, cin, 16 / wd, 1);
    let b5 = conv_relu(b, &format!("{name}_b5"), b5a, 16 / wd, 32 / wd, 5);
    let pool = b.maxpool(&format!("{name}_pool"), x, 1, 1);
    let pp = conv_relu(b, &format!("{name}_pp"), pool, cin, 32 / wd, 1);
    let cat = b.concat(&format!("{name}_cat"), &[b1, b3, b5, pp], 1);
    (cat, (32 + 64 + 32 + 32) / wd)
}

/// A small inception stack on 32×32 features.
pub fn inception_stack(batch: i64, blocks: usize) -> Graph {
    inception_stack_scaled(batch, blocks, 32, 1)
}

/// Inception stack with a `res`×`res` input and branch widths divided
/// by `width_div` (must divide 16). Same multi-writer concat topology;
/// tiny settings keep exhaustive execution on the reference
/// interpreter cheap for the differential equivalence suite.
pub fn inception_stack_scaled(batch: i64, blocks: usize, res: i64, width_div: i64) -> Graph {
    let wd = width_div;
    let mut b = GraphBuilder::new();
    let x = b.input("image", &[batch, 3, res, res]);
    let stem = conv_relu(&mut b, "stem", x, 3, 64 / wd, 3);
    let mut cur = stem;
    let mut c = 64 / wd;
    for k in 0..blocks {
        let (out, cout) = inception_block(&mut b, &format!("inc{k}"), cur, c, wd);
        cur = out;
        c = cout;
    }
    let gap = b.gap("gap", cur);
    let flat = b.reshape("flatten", gap, &[batch, c]);
    let w = b.weight("fc_w", &[c, 10]);
    let logits = b.matmul("fc", flat, w);
    b.mark_output(logits);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::ir::{OpKind, Program};
    use crate::passes::dme::run_dme;
    use crate::passes::manager::{BankMode, PassManager};

    #[test]
    fn builds_and_verifies() {
        let g = inception_stack(1, 3);
        verify_graph(&g).unwrap();
        assert_eq!(
            g.count_nodes(|n| matches!(n.kind, OpKind::Concat { .. })),
            3
        );
        verify_program(&Program::lower(g)).unwrap();
    }

    #[test]
    fn concat_feeding_convs_not_eliminable_but_flatten_is() {
        // concats feed padded convs (oob_zero reads with multi-piece
        // defs) → conservatively kept; the flatten reshape dies.
        let mut prog = Program::lower(inception_stack(1, 2));
        let stats = run_dme(&mut prog);
        verify_program(&prog).unwrap();
        assert!(stats.pairs_eliminated >= 1); // flatten at least
    }

    #[test]
    fn concat_branches_unify_placement() {
        let report = PassManager::default().run(inception_stack(1, 2)).unwrap();
        let bank = report.bank.as_ref().unwrap();
        // all four branch outputs of each concat share the concat's
        // placement (transfer through concat along a non-banked axis is
        // identity) — no remap copies needed anywhere in this topology
        assert_eq!(bank.stats.copies_inserted, 0, "{:?}", bank.stats);
    }

    #[test]
    fn local_pays_concat_branch_remaps() {
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        let report = pm.run(inception_stack(1, 2)).unwrap();
        let bank = report.bank.as_ref().unwrap();
        assert!(bank.stats.copies_inserted > 0);
    }
}
