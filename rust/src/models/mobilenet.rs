//! MobileNetV1-style network — exercises the depthwise-separable conv
//! path (the `DepthwiseConv2d` operator) through the full pipeline.
//!
//! Depthwise convs stress bank mapping differently from dense convs:
//! the channel dim is both the "contraction" and the output dim, so
//! input and output requirements coincide and global propagation rides
//! straight through.

use crate::ir::builder::GraphBuilder;
use crate::ir::op::OpKind;
use crate::ir::tensor::TensorId;
use crate::ir::Graph;

fn dw_separable(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    cin: i64,
    cout: i64,
    stride: i64,
) -> TensorId {
    let dw_w = b.weight(&format!("{name}_dww"), &[cin, 1, 3, 3]);
    let dw = b.apply(
        &format!("{name}_dw"),
        OpKind::DepthwiseConv2d { stride, pad: 1 },
        &[x, dw_w],
    );
    let bn1 = b.batchnorm(&format!("{name}_bn1"), dw);
    let r1 = b.relu(&format!("{name}_r1"), bn1);
    let pw_w = b.weight(&format!("{name}_pww"), &[cout, cin, 1, 1]);
    let pw = b.conv2d(&format!("{name}_pw"), r1, pw_w, 1, 0);
    let bn2 = b.batchnorm(&format!("{name}_bn2"), pw);
    b.relu(&format!("{name}_r2"), bn2)
}

/// MobileNetV1 (width 1.0) on 224×224 input.
pub fn mobilenet_v1(batch: i64) -> Graph {
    mobilenet_v1_scaled(batch, 224, 1, 1000)
}

/// MobileNetV1 with a `res`×`res` input and every channel width divided
/// by `width_div` (must divide 32). Same depthwise-separable topology
/// as the full model; tiny settings keep exhaustive execution on the
/// reference interpreter cheap for the differential equivalence suite.
pub fn mobilenet_v1_scaled(batch: i64, res: i64, width_div: i64, classes: i64) -> Graph {
    let wd = width_div;
    let mut b = GraphBuilder::new();
    let x = b.input("image", &[batch, 3, res, res]);
    let w0 = b.weight("conv0_w", &[32 / wd, 3, 3, 3]);
    let c0 = b.conv2d("conv0", x, w0, 2, 1);
    let bn0 = b.batchnorm("bn0", c0);
    let mut cur = b.relu("r0", bn0);
    // (cin, cout, stride)
    let blocks: [(i64, i64, i64); 13] = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    for (k, (cin, cout, stride)) in blocks.iter().enumerate() {
        cur = dw_separable(&mut b, &format!("b{k}"), cur, cin / wd, cout / wd, *stride);
    }
    let gap = b.gap("gap", cur);
    let flat = b.reshape("flatten", gap, &[batch, 1024 / wd]);
    let fcw = b.weight("fc_w", &[1024 / wd, classes]);
    let logits = b.matmul("fc", flat, fcw);
    b.mark_output(logits);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{simulate, AccelConfig};
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::passes::manager::{BankMode, PassManager};

    #[test]
    fn structure_and_verify() {
        let g = mobilenet_v1(1);
        verify_graph(&g).unwrap();
        let dw = g.count_nodes(|n| matches!(n.kind, OpKind::DepthwiseConv2d { .. }));
        assert_eq!(dw, 13);
        let pw = g.count_nodes(|n| matches!(n.kind, OpKind::Conv2d { .. }));
        assert_eq!(pw, 14); // stem + 13 pointwise
        verify_program(&crate::ir::Program::lower(g)).unwrap();
    }

    #[test]
    fn pipeline_and_bank_mapping() {
        let report = PassManager::default().run(mobilenet_v1(1)).unwrap();
        verify_program(&report.program).unwrap();
        // global vs local: global must win here too
        let local = PassManager { bank_mode: BankMode::Local, ..Default::default() }
            .run(mobilenet_v1(1))
            .unwrap();
        let cfg = AccelConfig::inferentia_like();
        let g_sim = simulate(&report.program, &cfg, None);
        let l_sim = simulate(&local.program, &cfg, None);
        assert!(g_sim.onchip_copy_total() < l_sim.onchip_copy_total());
    }

    #[test]
    fn depthwise_requirements_respected() {
        let report = PassManager::default().run(mobilenet_v1(1)).unwrap();
        let bank = report.bank.as_ref().unwrap();
        // every depthwise conv's activation input must be Row@1
        for node in bank.graph.nodes() {
            if matches!(node.kind, OpKind::DepthwiseConv2d { .. }) {
                assert_eq!(
                    bank.placements.get(&node.inputs[0]),
                    Some(&crate::passes::bank::Placement::row(1)),
                    "dwconv {} input misplaced",
                    node.name
                );
            }
        }
    }
}
