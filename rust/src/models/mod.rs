//! Workload builders for the paper's evaluation and the extra examples.
//!
//! * [`resnet`] — ResNet-50 (and -18), the §3 global-bank-mapping
//!   workload (E2).
//! * [`wavenet`] — a Parallel-WaveNet-shaped flow stack, the §3
//!   data-movement-elimination workload (E1): 124 load-store pairs of
//!   which exactly one (the externally visible output layout copy)
//!   is not eliminable.
//! * [`mlp`] — small dense network (quickstart / smoke tests).
//! * [`transformer`] — a transformer encoder block with the
//!   transpose-heavy attention plumbing (extra DME workload).

pub mod inception;
pub mod mlp;
pub mod mobilenet;
pub mod resnet;
pub mod transformer;
pub mod wavenet;

pub use inception::{inception_stack, inception_stack_scaled};
pub use mlp::mlp;
pub use mobilenet::{mobilenet_v1, mobilenet_v1_scaled};
pub use resnet::{resnet18, resnet18_scaled, resnet50, resnet50_scaled};
pub use transformer::transformer_block;
pub use wavenet::{parallel_wavenet, parallel_wavenet_with, WaveNetConfig};

/// The zoo by CLI name, with the same default dimensions the `polymem`
/// binary uses (`--model ...`). This is also the model registry the
/// serving plan cache compiles from, so CLI and serving agree on what
/// a name means. `batch` is ignored by the workloads that have no
/// batch dimension (wavenet, transformer). Returns `None` for unknown
/// names.
pub fn by_name(name: &str, batch: i64) -> Option<crate::ir::Graph> {
    match name {
        "resnet50" => Some(resnet50(batch)),
        "resnet18" => Some(resnet18(batch)),
        "wavenet" => Some(parallel_wavenet()),
        "mlp" => Some(mlp(batch, 784, 512, 10, 4)),
        "transformer" => Some(transformer_block(128, 256, 8, 1024)),
        "mobilenet" => Some(mobilenet_v1(batch)),
        "inception" => Some(inception_stack(batch, 4)),
        _ => None,
    }
}
