//! Workload builders for the paper's evaluation and the extra examples.
//!
//! * [`resnet`] — ResNet-50 (and -18), the §3 global-bank-mapping
//!   workload (E2).
//! * [`wavenet`] — a Parallel-WaveNet-shaped flow stack, the §3
//!   data-movement-elimination workload (E1): 124 load-store pairs of
//!   which exactly one (the externally visible output layout copy)
//!   is not eliminable.
//! * [`mlp`] — small dense network (quickstart / smoke tests).
//! * [`transformer`] — a transformer encoder block with the
//!   transpose-heavy attention plumbing (extra DME workload).

pub mod inception;
pub mod mlp;
pub mod mobilenet;
pub mod resnet;
pub mod transformer;
pub mod wavenet;

pub use inception::{inception_stack, inception_stack_scaled};
pub use mlp::mlp;
pub use mobilenet::{mobilenet_v1, mobilenet_v1_scaled};
pub use resnet::{resnet18, resnet18_scaled, resnet50, resnet50_scaled};
pub use transformer::transformer_block;
pub use wavenet::{parallel_wavenet, parallel_wavenet_with, WaveNetConfig};
