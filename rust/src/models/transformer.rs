//! A transformer encoder block — an extra DME workload: multi-head
//! attention's reshape/transpose plumbing is exactly the memory-bound
//! glue §2.1 targets.

use crate::ir::builder::GraphBuilder;
use crate::ir::tensor::TensorId;
use crate::ir::Graph;

/// One encoder block over `[seq, d_model]` (batch folded into seq).
/// `heads` must divide `d_model`.
pub fn transformer_block(seq: i64, d_model: i64, heads: i64, d_ff: i64) -> Graph {
    let mut b = GraphBuilder::new();
    let x = b.input("x", &[seq, d_model]);
    let d_head = d_model / heads;
    assert_eq!(d_head * heads, d_model, "heads must divide d_model");

    // Q, K, V projections
    let mut qkv: Vec<TensorId> = Vec::new();
    for name in ["q", "k", "v"] {
        let w = b.weight(&format!("w_{name}"), &[d_model, d_model]);
        let proj = b.matmul(&format!("proj_{name}"), x, w);
        // [seq, d_model] -> [seq, heads, d_head] -> [heads, seq, d_head]
        let split = b.reshape(&format!("{name}_split"), proj, &[seq, heads, d_head]);
        let perm = b.transpose(&format!("{name}_perm"), split, &[1, 0, 2]);
        qkv.push(perm);
    }
    let (q, k, v) = (qkv[0], qkv[1], qkv[2]);

    // attention per head (heads unrolled: the IR has no batched matmul)
    let mut head_outs = Vec::new();
    for h in 0..heads {
        let qh3 = b.slice(
            &format!("q{h}"),
            q,
            &[h, 0, 0],
            &[h + 1, seq, d_head],
            &[1, 1, 1],
        );
        let qh = b.reshape(&format!("q{h}m"), qh3, &[seq, d_head]);
        let kh3 = b.slice(
            &format!("k{h}"),
            k,
            &[h, 0, 0],
            &[h + 1, seq, d_head],
            &[1, 1, 1],
        );
        let kh = b.reshape(&format!("k{h}m"), kh3, &[seq, d_head]);
        let kt = b.transpose(&format!("k{h}t"), kh, &[1, 0]);
        let scores = b.matmul(&format!("scores{h}"), qh, kt); // [seq, seq]
        let probs = b.apply(&format!("probs{h}"), crate::ir::OpKind::Softmax, &[scores]);
        let vh3 = b.slice(
            &format!("v{h}"),
            v,
            &[h, 0, 0],
            &[h + 1, seq, d_head],
            &[1, 1, 1],
        );
        let vh = b.reshape(&format!("v{h}m"), vh3, &[seq, d_head]);
        let out = b.matmul(&format!("attn{h}"), probs, vh); // [seq, d_head]
        head_outs.push(out);
    }
    let cat = b.concat("heads_cat", &head_outs, 1); // [seq, d_model]
    let wo = b.weight("w_o", &[d_model, d_model]);
    let attn_out = b.matmul("proj_o", cat, wo);
    let res1 = b.add("res1", attn_out, x);

    // feed-forward
    let w1 = b.weight("ff_w1", &[d_model, d_ff]);
    let ff1 = b.matmul("ff1", res1, w1);
    let act = b.relu("ff_act", ff1);
    let w2 = b.weight("ff_w2", &[d_ff, d_model]);
    let ff2 = b.matmul("ff2", act, w2);
    let res2 = b.add("res2", ff2, res1);
    b.mark_output(res2);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::ir::Program;
    use crate::passes::dme::run_dme;

    #[test]
    fn builds_and_verifies() {
        let g = transformer_block(64, 128, 4, 256);
        verify_graph(&g).unwrap();
        let prog = Program::lower(g);
        verify_program(&prog).unwrap();
        // plumbing: 3×(reshape+transpose) + 4 heads ×(2 slices+2 reshapes
        // + 1 v-slice+1 v-reshape + kt) + concat nests …
        assert!(prog.load_store_pairs() >= 20);
    }

    #[test]
    fn dme_removes_most_plumbing() {
        let g = transformer_block(32, 64, 4, 128);
        let mut prog = Program::lower(g);
        let before = prog.load_store_pairs();
        let stats = run_dme(&mut prog);
        verify_program(&prog).unwrap();
        assert!(
            stats.pairs_eliminated as f64 >= before as f64 * 0.8,
            "only {}/{} eliminated",
            stats.pairs_eliminated,
            before
        );
    }

    #[test]
    fn output_shape() {
        let g = transformer_block(16, 32, 2, 64);
        let out = g.outputs()[0];
        assert_eq!(g.tensor(out).shape, vec![16, 32]);
    }
}
