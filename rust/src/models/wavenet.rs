//! A Parallel-WaveNet-shaped graph (van den Oord et al., 2017) — the
//! paper's data-movement-elimination workload (E1).
//!
//! Parallel WaveNet's student is a stack of inverse-autoregressive
//! flows, each a WaveNet of dilated 1-D convolutions with gated
//! activations. Memory-bound glue dominates the op count: every layer
//! *splits* its gate convolution into filter/gate halves and
//! *strided-slices* the residual input to align time axes ("valid"
//! dilated convolutions shrink the time dimension — the padding-free
//! formulation), and flows exchange data through layout *transposes*.
//!
//! The builder is sized to reproduce the paper's E1 population:
//!
//! * **124 load-store pairs**: 3 inter-flow transposes + 4 flows × 10
//!   layers × 3 slices + 1 output transpose;
//! * ≈ **146 MB** of copy-defined intermediate tensors;
//! * exactly **one** pair not eliminable: the final output-layout
//!   transpose writes an externally visible tensor (the model output),
//!   which DME must preserve — the same 123/124 shape the paper
//!   reports.
//!
//! Simplifications vs the real system (see DESIGN.md): no mel
//! conditioning input and no skip-sum head (the student flows don't
//! use skip aggregation); weight values are irrelevant to the
//! analysis, only shapes and dependences matter.

use crate::ir::builder::GraphBuilder;
use crate::ir::tensor::TensorId;
use crate::ir::Graph;

/// Configuration for the WaveNet-shaped builder.
#[derive(Clone, Copy, Debug)]
pub struct WaveNetConfig {
    pub flows: usize,
    pub layers_per_flow: usize,
    pub channels: i64,
    /// Input time steps (channel-major [1, C, T] after the first
    /// transpose).
    pub time: i64,
    pub kernel: i64,
    /// Dilations cycle through `1 << (layer % dilation_cycle)`.
    pub dilation_cycle: u32,
}

impl Default for WaveNetConfig {
    fn default() -> Self {
        // Sized so copy-defined intermediates total ≈146 MB (fp32).
        WaveNetConfig { flows: 4, layers_per_flow: 10, channels: 64, time: 6350, kernel: 2, dilation_cycle: 10 }
    }
}

/// One gated dilated-conv layer on `[1, C, T]` (valid convolution:
/// `T → T - (K-1)·dilation`).
fn layer(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    c: i64,
    dilation: i64,
    kernel: i64,
) -> TensorId {
    let t_in = b.graph().tensor(x).shape[2];
    let shrink = (kernel - 1) * dilation;
    let t_out = t_in - shrink;

    // gate conv to 2C channels
    let wg = b.weight(&format!("{name}_wg"), &[2 * c, c, kernel]);
    let gate = b.conv1d(&format!("{name}_gate"), x, wg, dilation); // [1, 2C, T']

    // split into filter / gate halves (two strided_slice copy nests)
    let filt = b.slice(
        &format!("{name}_filt"),
        gate,
        &[0, 0, 0],
        &[1, c, t_out],
        &[1, 1, 1],
    );
    let gt = b.slice(
        &format!("{name}_gt"),
        gate,
        &[0, c, 0],
        &[1, 2 * c, t_out],
        &[1, 1, 1],
    );
    let th = b.tanh(&format!("{name}_tanh"), filt);
    let sg = b.sigmoid(&format!("{name}_sig"), gt);
    let gated = b.mul(&format!("{name}_mul"), th, sg); // [1, C, T']

    // 1×1 residual conv
    let wr = b.weight(&format!("{name}_wr"), &[c, c, 1]);
    let res = b.conv1d(&format!("{name}_res"), gated, wr, 1); // [1, C, T']

    // align the residual input in time (third copy nest)
    let x_aligned = b.slice(
        &format!("{name}_align"),
        x,
        &[0, 0, shrink],
        &[1, c, t_in],
        &[1, 1, 1],
    );
    b.add(&format!("{name}_add"), res, x_aligned)
}

/// Build the Parallel-WaveNet-shaped graph.
pub fn parallel_wavenet_with(cfg: WaveNetConfig) -> Graph {
    let mut b = GraphBuilder::new();
    // audio/noise input arrives time-major [1, T, C]
    let input = b.input("z", &[1, cfg.time, cfg.channels]);
    let mut x = input;
    for f in 0..cfg.flows {
        if f == 0 {
            // the model input arrives time-major: transpose to [1, C, T]
            x = b.transpose(&format!("flow{f}_in"), x, &[0, 2, 1]);
        } else if f == 1 {
            // the boundary between the first two flow programs exchanges
            // time-major audio (layout glue the production pipeline
            // inserts between separately compiled flow programs); later
            // flows chain channel-major directly
            let tm = b.transpose(&format!("flow{f}_tm"), x, &[0, 2, 1]);
            x = b.transpose(&format!("flow{f}_in"), tm, &[0, 2, 1]);
        }
        for l in 0..cfg.layers_per_flow {
            let dil = 1i64 << (l as u32 % cfg.dilation_cycle);
            x = layer(&mut b, &format!("f{f}l{l}"), x, cfg.channels, dil, cfg.kernel);
        }
    }
    // project to 1 audio channel and emit time-major — the output
    // transpose is externally visible and therefore NOT eliminable.
    let wout = b.weight("proj_w", &[1, cfg.channels, 1]);
    let audio = b.conv1d("proj", x, wout, 1); // [1, 1, T_final]
    let out = b.transpose("audio_out", audio, &[0, 2, 1]); // [1, T_final, 1]
    b.mark_output(out);
    b.finish()
}

/// The default E1 workload.
pub fn parallel_wavenet() -> Graph {
    parallel_wavenet_with(WaveNetConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::ir::Program;
    use crate::passes::dme::run_dme;

    #[test]
    fn has_exactly_124_pairs() {
        let g = parallel_wavenet();
        verify_graph(&g).unwrap();
        let prog = Program::lower(g);
        verify_program(&prog).unwrap();
        // 1 (flow0 in) + 2 (flow1 round trip) + 120 slices + 1 out = 124,
        // the paper's E1 population
        assert_eq!(prog.load_store_pairs(), 124);
    }

    #[test]
    fn dme_eliminates_all_but_output() {
        let g = parallel_wavenet();
        let mut prog = Program::lower(g);
        let before = prog.load_store_pairs();
        let stats = run_dme(&mut prog);
        verify_program(&prog).unwrap();
        assert_eq!(stats.pairs_before, before);
        assert_eq!(
            prog.load_store_pairs(),
            1,
            "only the output transpose survives"
        );
        assert_eq!(stats.pairs_eliminated, before - 1);
    }

    #[test]
    fn copy_bytes_near_146mb() {
        let g = parallel_wavenet();
        let mut prog = Program::lower(g);
        let stats = run_dme(&mut prog);
        let mb = stats.bytes_before as f64 / 1e6;
        assert!(
            (140.0..152.0).contains(&mb),
            "copy-defined intermediates = {mb:.1} MB, want ≈146"
        );
        // nearly everything eliminated
        assert!(stats.bytes_eliminated as f64 / stats.bytes_before as f64 > 0.97);
    }

    #[test]
    fn receptive_field_shrinks_time() {
        let cfg = WaveNetConfig::default();
        let g = parallel_wavenet_with(cfg);
        let out = g.outputs()[0];
        // per flow: sum_{l=0..9} (K-1)·2^l = 1023; 4 flows → 4092
        assert_eq!(g.tensor(out).shape, vec![1, cfg.time - 4092, 1]);
    }

    #[test]
    fn small_config_still_valid() {
        let cfg = WaveNetConfig { flows: 2, layers_per_flow: 3, channels: 8, time: 64, kernel: 2, dilation_cycle: 10 };
        let g = parallel_wavenet_with(cfg);
        verify_graph(&g).unwrap();
        let mut prog = Program::lower(g);
        let stats = run_dme(&mut prog);
        verify_program(&prog).unwrap();
        assert!(stats.pairs_eliminated > 0);
        assert_eq!(prog.load_store_pairs(), 1);
    }
}
