//! Small dense network — quickstart / smoke-test workload.

use crate::ir::builder::GraphBuilder;
use crate::ir::Graph;

/// `depth` hidden layers of width `hidden` on a `[batch, input]` input.
pub fn mlp(batch: i64, input: i64, hidden: i64, classes: i64, depth: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let mut x = b.input("x", &[batch, input]);
    let mut cur = input;
    for k in 0..depth {
        let w = b.weight(&format!("w{k}"), &[cur, hidden]);
        let h = b.matmul(&format!("fc{k}"), x, w);
        let bias = b.weight(&format!("b{k}"), &[hidden]);
        let hb = b.apply(&format!("bias{k}"), crate::ir::OpKind::BiasAdd, &[h, bias]);
        x = b.relu(&format!("act{k}"), hb);
        cur = hidden;
    }
    let w = b.weight("w_out", &[cur, classes]);
    let logits = b.matmul("fc_out", x, w);
    let sm = b.apply("probs", crate::ir::OpKind::Softmax, &[logits]);
    b.mark_output(sm);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::ir::Program;

    #[test]
    fn builds_and_verifies() {
        let g = mlp(8, 784, 256, 10, 3);
        verify_graph(&g).unwrap();
        let out = g.outputs()[0];
        assert_eq!(g.tensor(out).shape, vec![8, 10]);
        verify_program(&Program::lower(g)).unwrap();
    }

    #[test]
    fn no_copy_nests() {
        let prog = Program::lower(mlp(4, 32, 16, 4, 2));
        assert_eq!(prog.load_store_pairs(), 0);
    }
}
