//! ResNet-50 / ResNet-18 (He et al., CVPR 2016) inference graphs,
//! NCHW, batch-norm folded to per-channel scale/shift, v1.5 strides
//! (downsample on the 3×3 conv).
//!
//! The bank-mapping experiment (paper §3, E2) runs on `resnet50()`.

use crate::ir::builder::GraphBuilder;
use crate::ir::tensor::TensorId;
use crate::ir::Graph;

/// Conv + folded-BN + optional ReLU.
fn conv_bn(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    cin: i64,
    cout: i64,
    k: i64,
    stride: i64,
    relu: bool,
) -> TensorId {
    let w = b.weight(&format!("{name}_w"), &[cout, cin, k, k]);
    let c = b.conv2d(name, x, w, stride, (k - 1) / 2);
    let bn = b.batchnorm(&format!("{name}_bn"), c);
    if relu {
        b.relu(&format!("{name}_relu"), bn)
    } else {
        bn
    }
}

/// Bottleneck block (1×1 reduce → 3×3 → 1×1 expand) + shortcut.
fn bottleneck(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    cin: i64,
    mid: i64,
    cout: i64,
    stride: i64,
) -> TensorId {
    let c1 = conv_bn(b, &format!("{name}_c1"), x, cin, mid, 1, 1, true);
    let c2 = conv_bn(b, &format!("{name}_c2"), c1, mid, mid, 3, stride, true);
    let c3 = conv_bn(b, &format!("{name}_c3"), c2, mid, cout, 1, 1, false);
    let shortcut = if cin != cout || stride != 1 {
        conv_bn(b, &format!("{name}_proj"), x, cin, cout, 1, stride, false)
    } else {
        x
    };
    let sum = b.add(&format!("{name}_add"), c3, shortcut);
    b.relu(&format!("{name}_out"), sum)
}

/// Basic block (3×3 → 3×3) + shortcut, for ResNet-18.
fn basic_block(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    cin: i64,
    cout: i64,
    stride: i64,
) -> TensorId {
    let c1 = conv_bn(b, &format!("{name}_c1"), x, cin, cout, 3, stride, true);
    let c2 = conv_bn(b, &format!("{name}_c2"), c1, cout, cout, 3, 1, false);
    let shortcut = if cin != cout || stride != 1 {
        conv_bn(b, &format!("{name}_proj"), x, cin, cout, 1, stride, false)
    } else {
        x
    };
    let sum = b.add(&format!("{name}_add"), c2, shortcut);
    b.relu(&format!("{name}_out"), sum)
}

fn stem(b: &mut GraphBuilder, batch: i64, res: i64, wd: i64) -> TensorId {
    let x = b.input("image", &[batch, 3, res, res]);
    let c1 = conv_bn(b, "conv1", x, 3, 64 / wd, 7, 2, true);
    b.maxpool("pool1", c1, 3, 2)
}

fn head(b: &mut GraphBuilder, x: TensorId, c: i64, batch: i64, classes: i64) -> TensorId {
    let gap = b.gap("gap", x);
    let flat = b.reshape("flatten", gap, &[batch, c]);
    let wfc = b.weight("fc_w", &[c, classes]);
    let logits = b.matmul("fc", flat, wfc);
    let bias = b.weight("fc_b", &[classes]);
    b.apply("fc_bias", crate::ir::OpKind::BiasAdd, &[logits, bias])
}

/// Full ResNet-50 v1.5 inference graph.
pub fn resnet50(batch: i64) -> Graph {
    resnet50_scaled(batch, 224, 1, 1000)
}

/// ResNet-50 with a `res`×`res` input and every channel width divided
/// by `width_div` (which must divide 64). Identical topology and
/// operator mix to the full model — tiny settings (e.g. `res = 16`,
/// `width_div = 8`) keep exhaustive execution on the reference
/// interpreter cheap enough for the differential equivalence suite.
/// `res` must keep every stage's spatial extent ≥ 1 (res ≥ 16).
pub fn resnet50_scaled(batch: i64, res: i64, width_div: i64, classes: i64) -> Graph {
    let wd = width_div;
    let mut b = GraphBuilder::new();
    let mut x = stem(&mut b, batch, res, wd);
    // (blocks, mid, out, stride of first block)
    let stages: [(usize, i64, i64, i64); 4] = [
        (3, 64 / wd, 256 / wd, 1),
        (4, 128 / wd, 512 / wd, 2),
        (6, 256 / wd, 1024 / wd, 2),
        (3, 512 / wd, 2048 / wd, 2),
    ];
    let mut cin = 64 / wd;
    for (si, (blocks, mid, cout, stride)) in stages.iter().enumerate() {
        for bi in 0..*blocks {
            let s = if bi == 0 { *stride } else { 1 };
            x = bottleneck(
                &mut b,
                &format!("s{}b{}", si + 1, bi),
                x,
                cin,
                *mid,
                *cout,
                s,
            );
            cin = *cout;
        }
    }
    let out = head(&mut b, x, 2048 / wd, batch, classes);
    b.mark_output(out);
    b.finish()
}

/// ResNet-18 (basic blocks) — smaller bank-mapping workload.
pub fn resnet18(batch: i64) -> Graph {
    resnet18_scaled(batch, 224, 1, 1000)
}

/// ResNet-18 with configurable resolution / width (see
/// [`resnet50_scaled`]).
pub fn resnet18_scaled(batch: i64, res: i64, width_div: i64, classes: i64) -> Graph {
    let wd = width_div;
    let mut b = GraphBuilder::new();
    let mut x = stem(&mut b, batch, res, wd);
    let stages: [(usize, i64, i64); 4] = [
        (2, 64 / wd, 1),
        (2, 128 / wd, 2),
        (2, 256 / wd, 2),
        (2, 512 / wd, 2),
    ];
    let mut cin = 64 / wd;
    for (si, (blocks, cout, stride)) in stages.iter().enumerate() {
        for bi in 0..*blocks {
            let s = if bi == 0 { *stride } else { 1 };
            x = basic_block(&mut b, &format!("s{}b{}", si + 1, bi), x, cin, *cout, s);
            cin = *cout;
        }
    }
    let out = head(&mut b, x, 512 / wd, batch, classes);
    b.mark_output(out);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tensor::TensorKind;
    use crate::ir::verify::{verify_graph, verify_program};
    use crate::ir::{OpKind, Program};

    #[test]
    fn resnet50_structure() {
        let g = resnet50(1);
        verify_graph(&g).unwrap();
        let convs = g.count_nodes(|n| matches!(n.kind, OpKind::Conv2d { .. }));
        // 1 stem + 3×(3+1) + 4×3+1 + 6×3+1 + 3×3+1 = 53
        assert_eq!(convs, 53);
        // ~25.5M params → ~102 MB fp32
        let wb = g.bytes_of_kind(TensorKind::Weight);
        assert!((90_000_000..115_000_000).contains(&wb), "weights {wb}B");
        // output is [1, 1000]
        let out = g.outputs()[0];
        assert_eq!(g.tensor(out).shape, vec![1, 1000]);
    }

    #[test]
    fn resnet50_lowers_and_verifies() {
        let prog = Program::lower(resnet50(1));
        verify_program(&prog).unwrap();
        // only the flatten reshape is a copy nest
        assert_eq!(prog.load_store_pairs(), 1);
    }

    #[test]
    fn resnet18_structure() {
        let g = resnet18(1);
        verify_graph(&g).unwrap();
        let convs = g.count_nodes(|n| matches!(n.kind, OpKind::Conv2d { .. }));
        // 1 stem + 2×2×4 + 3 projections (stages 2-4) = 20
        assert_eq!(convs, 20);
        verify_program(&Program::lower(g)).unwrap();
    }

    #[test]
    fn scaled_variants_build_and_verify() {
        let g = resnet50_scaled(1, 16, 8, 10);
        verify_graph(&g).unwrap();
        // same conv count as the full model: the topology is unchanged
        assert_eq!(
            g.count_nodes(|n| matches!(n.kind, OpKind::Conv2d { .. })),
            53
        );
        assert_eq!(g.tensor(g.outputs()[0]).shape, vec![1, 10]);
        verify_program(&Program::lower(g)).unwrap();

        let g18 = resnet18_scaled(1, 16, 8, 10);
        verify_graph(&g18).unwrap();
        assert_eq!(
            g18.count_nodes(|n| matches!(n.kind, OpKind::Conv2d { .. })),
            20
        );
        verify_program(&Program::lower(g18)).unwrap();
    }

    #[test]
    fn batch_dim_respected() {
        let g = resnet50(4);
        let out = g.outputs()[0];
        assert_eq!(g.tensor(out).shape, vec![4, 1000]);
        verify_graph(&g).unwrap();
    }
}
