//! Differential equivalence testing across the pass pipeline.
//!
//! [`diff_pipeline`] snapshots the program after every
//! [`PassManager`] stage (lower → DME → bank map + copy splice →
//! static plan), executes each snapshot on the reference interpreter
//! with identically seeded inputs, and asserts **bit-identical** graph
//! outputs against the freshly lowered (stage-0) program. Any
//! divergence is reported with the stage, tensor, flat element index
//! and both values — enough to replay and bisect.
//!
//! The comparison is on raw `f64` bits ([`f64::to_bits`]): the
//! interpreter's determinism contract (lexicographic reduction order,
//! pass-invariant compute domains) makes exact equality the correct
//! bar — an epsilon would only mask real routing bugs.

use super::{interpret, Buffers, InterpError};
use crate::ir::loopnest::Program;
use crate::ir::tensor::TensorId;
use crate::ir::Graph;
use crate::passes::manager::PassManager;
use std::collections::BTreeMap;
use std::fmt;

/// Flat per-output-tensor values of one executed stage.
pub type StageOutputs = BTreeMap<TensorId, Vec<f64>>;

/// How one stage's outputs depart from the baseline's.
#[derive(Clone, Debug)]
pub enum OutputDiff {
    /// The tensor is absent from the later stage's outputs.
    Missing { tensor: TensorId },
    /// The tensor changed element count (a shape-corrupting pass).
    Resized { tensor: TensorId, want: usize, got: usize },
    /// A genuine per-element bitwise divergence.
    Element { tensor: TensorId, index: usize, want: f64, got: f64 },
}

impl fmt::Display for OutputDiff {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OutputDiff::Missing { tensor } => write!(f, "output {tensor:?} missing"),
            OutputDiff::Resized { tensor, want, got } => {
                write!(f, "output {tensor:?} resized: {got} elements != {want} (baseline)")
            }
            OutputDiff::Element { tensor, index, want, got } => {
                write!(f, "{tensor:?}[{index}]: {got} != {want} (baseline)")
            }
        }
    }
}

/// A differential-testing failure.
#[derive(Clone, Debug)]
pub enum DiffError {
    /// The pass pipeline itself failed (verification error etc.).
    Pipeline(String),
    /// A stage snapshot faulted under interpretation.
    Interp { stage: String, err: InterpError },
    /// An output tensor disappeared from a stage's graph.
    MissingOutput { stage: String, tensor: TensorId },
    /// Output divergence against the lowered baseline.
    Mismatch { stage: String, diff: OutputDiff },
}

impl fmt::Display for DiffError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DiffError::Pipeline(e) => write!(f, "diff: pipeline failed: {e}"),
            DiffError::Interp { stage, err } => {
                write!(f, "diff: stage '{stage}' faulted: {err}")
            }
            DiffError::MissingOutput { stage, tensor } => {
                write!(f, "diff: stage '{stage}' lost output tensor {tensor:?}")
            }
            DiffError::Mismatch { stage, diff } => {
                write!(f, "diff: stage '{stage}' diverges: {diff}")
            }
        }
    }
}

impl std::error::Error for DiffError {}

/// Summary of one successful differential run.
#[derive(Clone, Debug)]
pub struct DiffReport {
    /// Stage names compared, in pipeline order (first is the baseline).
    pub stages: Vec<String>,
    /// Output tensors compared per stage.
    pub outputs: usize,
    /// Total f64 elements compared per stage.
    pub elements: usize,
}

/// Execute one stage snapshot and collect its graph-output buffers.
pub fn stage_outputs(
    prog: &Program,
    outputs: &[TensorId],
    seed: u64,
    stage: &str,
) -> Result<StageOutputs, DiffError> {
    let mut bufs = Buffers::seeded(&prog.graph, seed);
    interpret(prog, &mut bufs)
        .map_err(|err| DiffError::Interp { stage: stage.to_string(), err })?;
    let mut outs = StageOutputs::new();
    for &t in outputs {
        let vals = bufs
            .try_tensor(t)
            .ok_or(DiffError::MissingOutput { stage: stage.to_string(), tensor: t })?;
        outs.insert(t, vals.to_vec());
    }
    Ok(outs)
}

/// First divergence between two stages' outputs, if any: a missing or
/// resized tensor, or the first bitwise element mismatch.
pub fn first_mismatch(want: &StageOutputs, got: &StageOutputs) -> Option<OutputDiff> {
    for (t, w) in want {
        let Some(gv) = got.get(t) else {
            return Some(OutputDiff::Missing { tensor: *t });
        };
        if w.len() != gv.len() {
            return Some(OutputDiff::Resized { tensor: *t, want: w.len(), got: gv.len() });
        }
        for (i, (a, b)) in w.iter().zip(gv).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Some(OutputDiff::Element {
                    tensor: *t,
                    index: i,
                    want: *a,
                    got: *b,
                });
            }
        }
    }
    None
}

/// Panic unless `after` computes bit-identical graph outputs to
/// `before` under seed `seed` (outputs taken from `before`'s graph).
/// The shared before/after harness for single-pass tests (DME unit,
/// integration and property tests all call this).
pub fn assert_equivalent(before: &Program, after: &Program, seed: u64) {
    let outputs = before.graph.outputs();
    let b = stage_outputs(before, &outputs, seed, "before")
        .unwrap_or_else(|e| panic!("baseline program faulted: {e}"));
    let a = stage_outputs(after, &outputs, seed, "after")
        .unwrap_or_else(|e| panic!("transformed program faulted: {e}"));
    if let Some(diff) = first_mismatch(&b, &a) {
        panic!("transformed program changed semantics: {diff}");
    }
}

/// Run `pm` on `graph`, snapshotting after every stage, and assert all
/// stages compute bit-identical outputs under seed `seed`.
pub fn diff_pipeline(
    graph: Graph,
    pm: &PassManager,
    seed: u64,
) -> Result<DiffReport, DiffError> {
    let outputs: Vec<TensorId> = graph.outputs();
    let mut snaps: Vec<(String, Program)> = Vec::new();
    pm.run_observed(graph, |stage, prog| {
        snaps.push((stage.to_string(), prog.clone()));
    })
    .map_err(|e| DiffError::Pipeline(e.to_string()))?;

    let mut base: Option<StageOutputs> = None;
    let mut elements = 0usize;
    for (stage, prog) in &snaps {
        let outs = stage_outputs(prog, &outputs, seed, stage)?;
        match &base {
            None => {
                elements = outs.values().map(|v| v.len()).sum();
                base = Some(outs);
            }
            Some(b) => {
                if let Some(diff) = first_mismatch(b, &outs) {
                    return Err(DiffError::Mismatch { stage: stage.clone(), diff });
                }
            }
        }
    }
    Ok(DiffReport {
        stages: snaps.iter().map(|(s, _)| s.clone()).collect(),
        outputs: outputs.len(),
        elements,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::AccelConfig;
    use crate::ir::builder::GraphBuilder;
    use crate::passes::manager::{AllocStage, BankMode, PassManager};

    fn sample() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 6, 6]);
        let t1 = b.transpose("t1", x, &[0, 2, 3, 1]);
        let t2 = b.transpose("t2", t1, &[0, 3, 1, 2]);
        let w = b.weight("w", &[4, 4, 3, 3]);
        let c = b.conv2d("c", t2, w, 1, 1);
        let r = b.relu("r", c);
        b.mark_output(r);
        b.finish()
    }

    #[test]
    fn default_pipeline_is_equivalent() {
        let rep = diff_pipeline(sample(), &PassManager::default(), 11).unwrap();
        assert!(rep.stages.len() >= 3, "{:?}", rep.stages);
        assert_eq!(rep.stages[0], "lower");
        assert!(rep.elements > 0);
    }

    #[test]
    fn planned_pipeline_is_equivalent() {
        // a cramped scratchpad forces window splits / spill nests, which
        // must replay to the same outputs
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(AccelConfig::tiny(16 * 1024))),
            ..Default::default()
        };
        let rep = diff_pipeline(sample(), &pm, 11).unwrap();
        assert_eq!(rep.stages.last().map(|s| s.as_str()), Some("plan"));
    }

    #[test]
    fn local_bank_mode_is_equivalent() {
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        diff_pipeline(sample(), &pm, 11).unwrap();
    }

    #[test]
    fn mismatch_reporting_names_tensor_and_index() {
        // fabricate diverging outputs directly
        let t = TensorId(3);
        let mut a = StageOutputs::new();
        let mut b = StageOutputs::new();
        a.insert(t, vec![1.0, 2.0, 3.0]);
        b.insert(t, vec![1.0, 2.5, 3.0]);
        match first_mismatch(&a, &b).unwrap() {
            OutputDiff::Element { tensor, index, want, got } => {
                assert_eq!((tensor, index), (t, 1));
                assert_eq!((want, got), (2.0, 2.5));
            }
            other => panic!("wrong diff kind: {other:?}"),
        }
        assert!(first_mismatch(&a, &a).is_none());
    }

    #[test]
    fn resized_and_missing_outputs_reported_as_such() {
        let t = TensorId(4);
        let mut a = StageOutputs::new();
        a.insert(t, vec![1.0, 2.0]);
        let mut shorter = StageOutputs::new();
        shorter.insert(t, vec![1.0]);
        assert!(matches!(
            first_mismatch(&a, &shorter),
            Some(OutputDiff::Resized { want: 2, got: 1, .. })
        ));
        let empty = StageOutputs::new();
        assert!(matches!(
            first_mismatch(&a, &empty),
            Some(OutputDiff::Missing { .. })
        ));
    }
}
