//! Reference scalar interpreter — the semantic oracle for the pass
//! pipeline.
//!
//! Every transformation this repo performs (DME, bank mapping, copy
//! splicing, static planning with spill/reload nests) claims to reduce
//! memory traffic *without changing what the program computes*. This
//! module makes that claim checkable: it executes any normalized
//! loop-nest [`Program`] on concrete `f64` buffers, one domain point at
//! a time, with
//!
//! * full **copy-nest** semantics (piecewise loads, synthesized-zero
//!   pad borders, `oob_zero` implicit-padding reads),
//! * full **compute-nest** semantics per [`OpKind`] (matmul/conv
//!   sum-of-products, max/avg pooling, global average pool, softmax,
//!   elementwise unary/binary, batch-norm, bias-add), and
//! * replay of planner-inserted `spill.*`/`reload.*` and bank-mapping
//!   `MemCopy` nests (plain copies), so post-planning programs are
//!   executable too.
//!
//! Determinism contract: reduction nests accumulate in lexicographic
//! domain order, and passes never alter a compute nest's domain — so a
//! correct transformation produces **bit-identical** `f64` outputs, and
//! the differential harness ([`diff`]) compares raw bits, not epsilons.
//! Inputs and weights are seeded with *integers* of per-element
//! distinct magnitude (exact in f64 at these sizes), which keeps copy
//! plumbing exact and makes element misroutes collision-proof;
//! transcendental ops (softmax/sigmoid/tanh) and very deep product
//! chains are merely deterministic, which is all bit-comparison needs.
//!
//! Strictness: reads of never-written elements, loads outside a tensor
//! box (without `oob_zero`), stores outside the output box and domain
//! points no load piece covers are all hard [`InterpError`]s — each one
//! is a class of miscompile the structural verifier cannot see.

pub mod diff;

use crate::ir::graph::Graph;
use crate::ir::loopnest::{Body, LoadStmt, LoopNest, Program};
use crate::ir::op::{BinaryFn, OpKind, PoolKind, UnaryFn};
use crate::ir::tensor::{TensorId, TensorKind};
use crate::poly::IterDomain;
use crate::util::rng::SplitMix64;
use std::collections::BTreeMap;
use std::fmt;

/// An execution fault: the program is not a well-defined function of
/// its inputs. Every variant is a miscompile signature.
#[derive(Clone, Debug, PartialEq)]
pub enum InterpError {
    /// No load piece covers a domain point.
    UncoveredLoad { nest: String, point: Vec<i64> },
    /// A load (without `oob_zero`) indexed outside the tensor box.
    OobLoad { nest: String, tensor: TensorId, index: Vec<i64> },
    /// A store indexed outside the output tensor box.
    OobStore { nest: String, tensor: TensorId, index: Vec<i64> },
    /// A read of an element no earlier nest wrote.
    UnwrittenRead { nest: String, tensor: TensorId, index: Vec<i64> },
    /// A compute nest whose node kind has no interpretable semantics
    /// (or whose store shape departs from the lowering contract).
    Opaque { nest: String, detail: String },
}

impl fmt::Display for InterpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InterpError::UncoveredLoad { nest, point } => {
                write!(f, "interp: nest '{nest}': no load piece covers {point:?}")
            }
            InterpError::OobLoad { nest, tensor, index } => {
                write!(f, "interp: nest '{nest}': load of {tensor:?} at {index:?} out of bounds")
            }
            InterpError::OobStore { nest, tensor, index } => {
                write!(f, "interp: nest '{nest}': store to {tensor:?} at {index:?} out of bounds")
            }
            InterpError::UnwrittenRead { nest, tensor, index } => {
                write!(f, "interp: nest '{nest}': read of unwritten {tensor:?}[{index:?}]")
            }
            InterpError::Opaque { nest, detail } => {
                write!(f, "interp: nest '{nest}': uninterpretable: {detail}")
            }
        }
    }
}

impl std::error::Error for InterpError {}

/// Concrete memory state: one flat `f64` buffer per tensor plus a
/// per-element initialization mask (reads of unwritten elements fault).
#[derive(Clone, Debug)]
pub struct Buffers {
    data: BTreeMap<TensorId, Vec<f64>>,
    written: BTreeMap<TensorId, Vec<bool>>,
}

impl Buffers {
    /// Deterministically seed every `Input`/`Weight` tensor from
    /// `(seed, tensor id)`. Each element gets a **distinct magnitude**
    /// (`base + index`, random sign, `base ≥ 1`), so any intra-tensor
    /// misroute — two elements swapped or aliased by a wrong access
    /// map — changes some output bit even under a single fixed seed
    /// (the sensitivity the deleted unique-fingerprint walkers had);
    /// per-tensor random bases keep cross-tensor values mostly
    /// distinct too. The per-tensor streams are independent of which
    /// *other* tensors exist, so pre- and post-pass programs (whose
    /// intermediate tensor sets differ) see identical external data.
    pub fn seeded(g: &Graph, seed: u64) -> Buffers {
        let mut data = BTreeMap::new();
        let mut written = BTreeMap::new();
        for t in g.tensors() {
            let n = t.numel() as usize;
            match t.kind {
                TensorKind::Input | TensorKind::Weight => {
                    let mut rng = SplitMix64::new(
                        seed ^ (t.id.0 as u64).wrapping_mul(0x9e3779b97f4a7c15),
                    );
                    let base = 1 + rng.range_i64(0, 512);
                    data.insert(
                        t.id,
                        (0..n)
                            .map(|k| {
                                let v = (base + k as i64) as f64;
                                if rng.next_u64() & 1 == 0 {
                                    v
                                } else {
                                    -v
                                }
                            })
                            .collect(),
                    );
                    written.insert(t.id, vec![true; n]);
                }
                TensorKind::Intermediate | TensorKind::Output => {
                    data.insert(t.id, vec![0.0; n]);
                    written.insert(t.id, vec![false; n]);
                }
            }
        }
        Buffers { data, written }
    }

    /// The flat (row-major) contents of a tensor.
    pub fn tensor(&self, t: TensorId) -> &[f64] {
        &self.data[&t]
    }

    pub fn try_tensor(&self, t: TensorId) -> Option<&[f64]> {
        self.data.get(&t).map(|v| v.as_slice())
    }

    /// Replace a tensor's contents wholesale (marks every element
    /// written). Tests use this to pin exact input values instead of
    /// seeding.
    pub fn set_tensor(&mut self, t: TensorId, vals: Vec<f64>) {
        let n = self.data[&t].len();
        assert_eq!(vals.len(), n, "set_tensor: length {} != {n}", vals.len());
        self.written.insert(t, vec![true; n]);
        self.data.insert(t, vals);
    }

    /// True when every element of `t` has been written.
    pub fn fully_written(&self, t: TensorId) -> bool {
        self.written.get(&t).map(|m| m.iter().all(|&w| w)).unwrap_or(false)
    }

    fn write(&mut self, t: TensorId, lin: usize, v: f64) {
        self.data.get_mut(&t).unwrap()[lin] = v;
        self.written.get_mut(&t).unwrap()[lin] = true;
    }
}

/// Execute the whole program in nest order against `bufs`.
pub fn interpret(prog: &Program, bufs: &mut Buffers) -> Result<(), InterpError> {
    // index-space boxes for every tensor, built once
    let doms: BTreeMap<TensorId, IterDomain> = prog
        .graph
        .tensors()
        .map(|t| (t.id, IterDomain::new(&t.shape)))
        .collect();
    for nest in &prog.nests {
        exec_nest(prog, nest, &doms, bufs)?;
    }
    Ok(())
}

/// Seed fresh buffers from the program's graph, execute, and return the
/// final memory state.
pub fn interpret_seeded(prog: &Program, seed: u64) -> Result<Buffers, InterpError> {
    let mut bufs = Buffers::seeded(&prog.graph, seed);
    interpret(prog, &mut bufs)?;
    Ok(bufs)
}

/// Resolve one (piecewise) load at a domain point.
fn load_value(
    doms: &BTreeMap<TensorId, IterDomain>,
    bufs: &Buffers,
    nest: &LoopNest,
    load: &LoadStmt,
    p: &[i64],
) -> Result<f64, InterpError> {
    let piece = load.pieces.iter().find(|a| a.holds(p)).ok_or_else(|| {
        InterpError::UncoveredLoad { nest: nest.name.clone(), point: p.to_vec() }
    })?;
    let Some(t) = piece.tensor else {
        return Ok(0.0); // synthesized zero (pad border)
    };
    let idx = piece.map.apply(p);
    let dom = &doms[&t];
    if !dom.contains(&idx) {
        if piece.oob_zero {
            return Ok(0.0); // hardware-padded read
        }
        return Err(InterpError::OobLoad { nest: nest.name.clone(), tensor: t, index: idx });
    }
    let lin = dom.linearize(&idx) as usize;
    if !bufs.written[&t][lin] {
        return Err(InterpError::UnwrittenRead { nest: nest.name.clone(), tensor: t, index: idx });
    }
    Ok(bufs.data[&t][lin])
}

/// Map a domain point through the store map, bounds-checked.
fn store_index(
    nest: &LoopNest,
    out_dom: &IterDomain,
    p: &[i64],
) -> Result<usize, InterpError> {
    let oidx = nest.store.map.apply(p);
    if !out_dom.contains(&oidx) {
        return Err(InterpError::OobStore {
            nest: nest.name.clone(),
            tensor: nest.store.tensor,
            index: oidx,
        });
    }
    Ok(out_dom.linearize(&oidx) as usize)
}

fn exec_nest(
    prog: &Program,
    nest: &LoopNest,
    doms: &BTreeMap<TensorId, IterDomain>,
    bufs: &mut Buffers,
) -> Result<(), InterpError> {
    let g = &prog.graph;
    let out = nest.store.tensor;
    let out_dom = doms[&out].clone();
    match &nest.body {
        Body::Copy { load } => {
            for p in nest.domain.points() {
                let v = load_value(doms, bufs, nest, load, &p)?;
                let lin = store_index(nest, &out_dom, &p)?;
                bufs.write(out, lin, v);
            }
            Ok(())
        }
        Body::Compute { loads, .. } => {
            let kind = g.node(nest.node).kind.clone();
            exec_compute(nest, &kind, loads, doms, &out_dom, bufs)
        }
    }
}

/// Per-[`OpKind`] compute semantics over one nest. Reductions
/// accumulate in lexicographic domain order (the determinism contract).
fn exec_compute(
    nest: &LoopNest,
    kind: &OpKind,
    loads: &[LoadStmt],
    doms: &BTreeMap<TensorId, IterDomain>,
    out_dom: &IterDomain,
    bufs: &mut Buffers,
) -> Result<(), InterpError> {
    let out = nest.store.tensor;
    let ext = nest.domain.extents().to_vec();
    match kind {
        // ---- sum-of-products reductions (systolic array ops) ----
        OpKind::MatMul
        | OpKind::Conv2d { .. }
        | OpKind::DepthwiseConv2d { .. }
        | OpKind::Conv1d { .. } => {
            reduce(nest, loads, doms, out_dom, bufs, 0.0, |acc, vals| {
                acc + vals.iter().product::<f64>()
            })?;
            Ok(())
        }

        // ---- pooling reductions (vector engine) ----
        OpKind::Pool { kind: PoolKind::Max, .. } => {
            reduce(nest, loads, doms, out_dom, bufs, f64::NEG_INFINITY, |acc, vals| {
                acc.max(vals[0])
            })?;
            Ok(())
        }
        OpKind::Pool { kind: PoolKind::Avg, .. } => {
            // window size from the domain, not the op attributes: the
            // domain is the one thing no pass rewrites
            let count = (ext[4] * ext[5]) as f64;
            let acc = reduce(nest, loads, doms, out_dom, bufs, 0.0, |acc, vals| {
                acc + vals[0]
            })?;
            finalize_scaled(bufs, out, &acc, 1.0 / count);
            Ok(())
        }
        OpKind::GlobalAvgPool => {
            let count = (ext[2] * ext[3]) as f64;
            let acc = reduce(nest, loads, doms, out_dom, bufs, 0.0, |acc, vals| {
                acc + vals[0]
            })?;
            finalize_scaled(bufs, out, &acc, 1.0 / count);
            Ok(())
        }

        // ---- pointwise ops ----
        OpKind::Unary(f) => {
            let func = *f;
            pointwise(nest, loads, doms, out_dom, bufs, move |vals| match func {
                UnaryFn::Relu => vals[0].max(0.0),
                UnaryFn::Sigmoid => 1.0 / (1.0 + (-vals[0]).exp()),
                UnaryFn::Tanh => vals[0].tanh(),
                UnaryFn::Exp => vals[0].exp(),
                UnaryFn::Neg => -vals[0],
            })
        }
        OpKind::Binary(f) => {
            let func = *f;
            pointwise(nest, loads, doms, out_dom, bufs, move |vals| match func {
                BinaryFn::Add => vals[0] + vals[1],
                BinaryFn::Sub => vals[0] - vals[1],
                BinaryFn::Mul => vals[0] * vals[1],
                BinaryFn::Max => vals[0].max(vals[1]),
            })
        }
        OpKind::BatchNorm => {
            // loads: x, per-channel scale, per-channel shift
            pointwise(nest, loads, doms, out_dom, bufs, |vals| {
                vals[0] * vals[1] + vals[2]
            })
        }
        OpKind::BiasAdd => pointwise(nest, loads, doms, out_dom, bufs, |vals| {
            vals[0] + vals[1]
        }),

        // ---- softmax: a row reduction over the last output dim ----
        OpKind::Softmax => {
            if !nest.store.map.is_identity() || out_dom.extents() != nest.domain.extents() {
                return Err(InterpError::Opaque {
                    nest: nest.name.clone(),
                    detail: "softmax store departs from identity lowering".into(),
                });
            }
            let numel = out_dom.cardinality() as usize;
            let mut vals = vec![0.0f64; numel];
            for p in nest.domain.points() {
                let v = load_value(doms, bufs, nest, &loads[0], &p)?;
                vals[out_dom.linearize(&p) as usize] = v;
            }
            let row = *out_dom.extents().last().unwrap() as usize;
            for chunk_start in (0..numel).step_by(row) {
                let chunk = &mut vals[chunk_start..chunk_start + row];
                let m = chunk.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
                let mut sum = 0.0;
                for v in chunk.iter_mut() {
                    *v = (*v - m).exp();
                    sum += *v;
                }
                for v in chunk.iter_mut() {
                    *v /= sum;
                }
            }
            for (lin, v) in vals.into_iter().enumerate() {
                bufs.write(out, lin, v);
            }
            Ok(())
        }

        // memory-bound kinds always lower to Body::Copy; a Compute body
        // carrying one is a lowering bug
        OpKind::Transpose { .. }
        | OpKind::Reshape { .. }
        | OpKind::Tile { .. }
        | OpKind::Repeat { .. }
        | OpKind::StridedSlice { .. }
        | OpKind::Concat { .. }
        | OpKind::Pad { .. }
        | OpKind::Identity
        | OpKind::MemCopy => Err(InterpError::Opaque {
            nest: nest.name.clone(),
            detail: format!("memory-bound op '{}' with a compute body", kind.mnemonic()),
        }),
    }
}

/// Run a reduction: initialize each touched output element to `init` on
/// first touch, fold `combine` over the domain in lexicographic order,
/// then write the results back. Returns the accumulator (indexed by
/// flat output offset; untouched elements are `None`) so avg-style ops
/// can rescale before the write-back overwrites it.
fn reduce(
    nest: &LoopNest,
    loads: &[LoadStmt],
    doms: &BTreeMap<TensorId, IterDomain>,
    out_dom: &IterDomain,
    bufs: &mut Buffers,
    init: f64,
    combine: impl Fn(f64, &[f64]) -> f64,
) -> Result<Vec<Option<f64>>, InterpError> {
    let out = nest.store.tensor;
    let mut acc: Vec<Option<f64>> = vec![None; out_dom.cardinality() as usize];
    let mut vals = vec![0.0f64; loads.len()];
    for p in nest.domain.points() {
        for (k, load) in loads.iter().enumerate() {
            vals[k] = load_value(doms, bufs, nest, load, &p)?;
        }
        let lin = store_index(nest, out_dom, &p)?;
        let cur = acc[lin].unwrap_or(init);
        acc[lin] = Some(combine(cur, &vals));
    }
    for (lin, v) in acc.iter().enumerate() {
        if let Some(v) = v {
            bufs.write(out, lin, *v);
        }
    }
    Ok(acc)
}

/// Overwrite the just-reduced elements with `acc * scale` (avg pools).
fn finalize_scaled(bufs: &mut Buffers, out: TensorId, acc: &[Option<f64>], scale: f64) {
    for (lin, v) in acc.iter().enumerate() {
        if let Some(v) = v {
            bufs.write(out, lin, *v * scale);
        }
    }
}

/// Evaluate an injective-store pointwise nest.
fn pointwise(
    nest: &LoopNest,
    loads: &[LoadStmt],
    doms: &BTreeMap<TensorId, IterDomain>,
    out_dom: &IterDomain,
    bufs: &mut Buffers,
    f: impl Fn(&[f64]) -> f64,
) -> Result<(), InterpError> {
    let out = nest.store.tensor;
    let mut vals = vec![0.0f64; loads.len()];
    for p in nest.domain.points() {
        for (k, load) in loads.iter().enumerate() {
            vals[k] = load_value(doms, bufs, nest, load, &p)?;
        }
        let lin = store_index(nest, out_dom, &p)?;
        bufs.write(out, lin, f(&vals));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;

    fn run(g: crate::ir::Graph) -> Buffers {
        let prog = Program::lower(g);
        interpret_seeded(&prog, 7).unwrap()
    }

    #[test]
    fn transpose_moves_elements() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[2, 3]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let bufs = run(b.finish());
        let xs = bufs.tensor(x).to_vec();
        let ts = bufs.tensor(t);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(ts[(i * 2 + j) as usize], xs[(j * 3 + i) as usize]);
            }
        }
    }

    #[test]
    fn pad_border_is_zero_interior_preserved() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[2]);
        let p = b.pad("p", x, &[1], &[1]);
        b.mark_output(p);
        let bufs = run(b.finish());
        let xs = bufs.tensor(x).to_vec();
        let ps = bufs.tensor(p);
        assert_eq!(ps[0], 0.0);
        assert_eq!(ps[1], xs[0]);
        assert_eq!(ps[2], xs[1]);
        assert_eq!(ps[3], 0.0);
    }

    #[test]
    fn matmul_matches_direct_computation() {
        let mut b = GraphBuilder::new();
        let a = b.input("a", &[2, 3]);
        let w = b.weight("w", &[3, 2]);
        let m = b.matmul("m", a, w);
        b.mark_output(m);
        let bufs = run(b.finish());
        let av = bufs.tensor(a).to_vec();
        let wv = bufs.tensor(w).to_vec();
        let mv = bufs.tensor(m);
        for i in 0..2usize {
            for j in 0..2usize {
                let want: f64 = (0..3usize).map(|k| av[i * 3 + k] * wv[k * 2 + j]).sum();
                assert_eq!(mv[i * 2 + j], want);
            }
        }
    }

    #[test]
    fn conv2d_padded_matches_direct_computation() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 1, 3, 3]);
        let w = b.weight("w", &[1, 1, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        b.mark_output(c);
        let bufs = run(b.finish());
        let xv = bufs.tensor(x).to_vec();
        let wv = bufs.tensor(w).to_vec();
        let cv = bufs.tensor(c);
        for oh in 0i64..3 {
            for ow in 0i64..3 {
                let mut want = 0.0;
                for kh in 0i64..3 {
                    for kw in 0i64..3 {
                        let (ih, iw) = (oh + kh - 1, ow + kw - 1);
                        if (0..3).contains(&ih) && (0..3).contains(&iw) {
                            want += xv[(ih * 3 + iw) as usize] * wv[(kh * 3 + kw) as usize];
                        }
                    }
                }
                assert_eq!(cv[(oh * 3 + ow) as usize], want);
            }
        }
    }

    #[test]
    fn avg_pool_and_gap_divide_by_window() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 1, 2, 2]);
        let p = b.apply(
            "avg",
            OpKind::Pool { kind: PoolKind::Avg, window: 2, stride: 2 },
            &[x],
        );
        let gp = b.gap("gap", x);
        b.mark_output(p);
        b.mark_output(gp);
        let bufs = run(b.finish());
        let xv = bufs.tensor(x).to_vec();
        let mean = xv.iter().sum::<f64>() / 4.0;
        assert_eq!(bufs.tensor(p)[0], mean);
        assert_eq!(bufs.tensor(gp)[0], mean);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        // pinned small inputs: strict positivity below only holds while
        // the row spread stays under exp's underflow range (~745)
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 4]);
        let s = b.apply("sm", OpKind::Softmax, &[x]);
        b.mark_output(s);
        let prog = Program::lower(b.finish());
        let mut bufs = Buffers::seeded(&prog.graph, 7);
        bufs.set_tensor(x, (0..12).map(|k| (k % 5) as f64 - 2.0).collect());
        interpret(&prog, &mut bufs).unwrap();
        let sv = bufs.tensor(s);
        for r in 0..3 {
            let sum: f64 = sv[r * 4..(r + 1) * 4].iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {r} sums to {sum}");
            assert!(sv[r * 4..(r + 1) * 4].iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn concat_then_slice_routes_correctly() {
        let mut b = GraphBuilder::new();
        let a = b.input("a", &[2, 2]);
        let c = b.input("c", &[2, 3]);
        let cat = b.concat("cat", &[a, c], 1);
        let s = b.slice("s", cat, &[0, 1], &[2, 4], &[1, 1]);
        b.mark_output(s);
        let bufs = run(b.finish());
        let av = bufs.tensor(a).to_vec();
        let cv = bufs.tensor(c).to_vec();
        let sv = bufs.tensor(s);
        // cat row r = [a[r,0], a[r,1], c[r,0], c[r,1], c[r,2]]; slice cols 1..4
        for r in 0..2usize {
            assert_eq!(sv[r * 3], av[r * 2 + 1]);
            assert_eq!(sv[r * 3 + 1], cv[r * 3]);
            assert_eq!(sv[r * 3 + 2], cv[r * 3 + 1]);
        }
    }

    #[test]
    fn unwritten_read_faults() {
        // hand-build a program that reads an intermediate nobody wrote
        use crate::ir::loopnest::{Body, LoadStmt, LoopNest, StoreStmt};
        use crate::ir::tensor::{DType, TensorKind};
        use crate::poly::AccessMap;
        let mut g = crate::ir::Graph::new();
        let x = g.add_tensor("x", &[4], DType::F32, TensorKind::Input);
        let t = g.add_tensor("t", &[4], DType::F32, TensorKind::Intermediate);
        let y = g.add_tensor("y", &[4], DType::F32, TensorKind::Output);
        let n = g.add_node("bad", OpKind::Identity, vec![t], y);
        let _ = x;
        let prog = Program {
            graph: g,
            nests: vec![LoopNest {
                node: n,
                tile: None,
                name: "bad".into(),
                domain: IterDomain::new(&[4]),
                store: StoreStmt { tensor: y, map: AccessMap::identity(1) },
                body: Body::Copy { load: LoadStmt::total(t, AccessMap::identity(1)) },
            }],
        };
        let err = interpret_seeded(&prog, 1).unwrap_err();
        assert!(matches!(err, InterpError::UnwrittenRead { .. }), "{err}");
    }

    #[test]
    fn seeding_is_stable_across_tensor_set_changes() {
        // the same input tensor id must get the same values even when
        // the graph carries different intermediates around it
        let mut b1 = GraphBuilder::new();
        let x1 = b1.input("x", &[8]);
        let y1 = b1.identity("y", x1);
        b1.mark_output(y1);
        let g1 = b1.finish();

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input("x", &[8]);
        let t = b2.transpose("t", x2, &[0]);
        let y2 = b2.identity("y", t);
        b2.mark_output(y2);
        let g2 = b2.finish();

        let s1 = Buffers::seeded(&g1, 99);
        let s2 = Buffers::seeded(&g2, 99);
        assert_eq!(s1.tensor(x1), s2.tensor(x2));
    }

    #[test]
    fn full_model_executes_and_fills_outputs() {
        let g = crate::models::mlp(2, 6, 5, 3, 2);
        let prog = Program::lower(g);
        let bufs = interpret_seeded(&prog, 3).unwrap();
        for out in prog.graph.outputs() {
            assert!(bufs.fully_written(out), "output {out:?} not fully written");
        }
    }
}
