//! Software-managed scratchpad residency — the **dynamic baseline**.
//!
//! This tracker improvises residency at replay time: eviction picks
//! the resident victim with the furthest next use (Belady-style,
//! computable because the schedule is static). It stands in for what a
//! compiler-managed scratchpad achieves *at best* without an explicit
//! plan. The real compile-time answer lives in [`crate::alloc`], which
//! bakes the same furthest-next-use policy into a static
//! [`crate::alloc::MemoryPlan`] with concrete `(bank, offset, size)`
//! regions and explicit spill IR; the simulator's planned mode
//! ([`crate::accel::sim::simulate_planned`]) replays that plan
//! verbatim and verifies it, while this module remains the baseline
//! benches compare against (`bench_alloc_plan`).

use crate::ir::tensor::TensorId;
use std::collections::BTreeMap;

/// What happened when making room.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EvictEvent {
    /// Victim was dead (no future use): dropped silently.
    Dropped { tensor: TensorId, bytes: i64 },
    /// Victim still live: must be spilled to DRAM.
    Spilled { tensor: TensorId, bytes: i64 },
}

/// Residency tracker.
#[derive(Clone, Debug)]
pub struct Scratchpad {
    capacity: i64,
    used: i64,
    resident: BTreeMap<TensorId, i64>,
    /// High-water mark.
    peak: i64,
}

impl Scratchpad {
    pub fn new(capacity: i64) -> Self {
        assert!(capacity > 0);
        Scratchpad { capacity, used: 0, resident: BTreeMap::new(), peak: 0 }
    }

    pub fn capacity(&self) -> i64 {
        self.capacity
    }

    pub fn used(&self) -> i64 {
        self.used
    }

    pub fn peak(&self) -> i64 {
        self.peak
    }

    pub fn is_resident(&self, t: TensorId) -> bool {
        self.resident.contains_key(&t)
    }

    pub fn resident_bytes(&self, t: TensorId) -> Option<i64> {
        self.resident.get(&t).copied()
    }

    /// Tensors currently resident.
    pub fn residents(&self) -> impl Iterator<Item = (&TensorId, &i64)> {
        self.resident.iter()
    }

    /// Drop a tensor without spilling (it is dead).
    pub fn release(&mut self, t: TensorId) {
        if let Some(b) = self.resident.remove(&t) {
            self.used -= b;
        }
    }

    /// Ensure `t` (of `bytes`) is resident, evicting by furthest next
    /// use as needed. `next_use` gives each *other* resident tensor's
    /// next use position (`None` = dead, `usize::MAX` = model output /
    /// far future). Returns eviction events. A tensor larger than the
    /// whole scratchpad is not admitted (callers stream it from DRAM)
    /// and `false` is returned as the second tuple element.
    pub fn admit(
        &mut self,
        t: TensorId,
        bytes: i64,
        next_use: &dyn Fn(TensorId) -> Option<usize>,
    ) -> (Vec<EvictEvent>, bool) {
        if self.is_resident(t) {
            return (vec![], true);
        }
        if bytes > self.capacity {
            return (vec![], false);
        }
        let mut events = Vec::new();
        while self.used + bytes > self.capacity {
            // victim: dead tensors first, else furthest next use
            let victim = self
                .resident
                .keys()
                .copied()
                .map(|r| (r, next_use(r)))
                .max_by_key(|(_, nu)| match nu {
                    None => (2, usize::MAX), // dead: best victim
                    Some(p) => (1, *p),      // live: furthest next use
                })
                .map(|(r, nu)| (r, nu));
            let Some((victim, nu)) = victim else { break };
            let vbytes = self.resident.remove(&victim).unwrap();
            self.used -= vbytes;
            events.push(match nu {
                None => EvictEvent::Dropped { tensor: victim, bytes: vbytes },
                Some(_) => EvictEvent::Spilled { tensor: victim, bytes: vbytes },
            });
        }
        self.resident.insert(t, bytes);
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        (events, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: u32) -> TensorId {
        TensorId(n)
    }

    #[test]
    fn admit_and_release() {
        let mut sp = Scratchpad::new(100);
        let (ev, ok) = sp.admit(t(1), 60, &|_| None);
        assert!(ok && ev.is_empty());
        assert!(sp.is_resident(t(1)));
        assert_eq!(sp.used(), 60);
        sp.release(t(1));
        assert_eq!(sp.used(), 0);
    }

    #[test]
    fn rejects_oversized() {
        let mut sp = Scratchpad::new(100);
        let (ev, ok) = sp.admit(t(1), 150, &|_| None);
        assert!(!ok && ev.is_empty());
        assert!(!sp.is_resident(t(1)));
    }

    #[test]
    fn evicts_dead_before_live() {
        let mut sp = Scratchpad::new(100);
        sp.admit(t(1), 50, &|_| None).1.then_some(()).unwrap();
        sp.admit(t(2), 40, &|_| None).1.then_some(()).unwrap();
        // t1 dead, t2 live at 5
        let nu = |r: TensorId| -> Option<usize> {
            if r == t(2) {
                Some(5)
            } else {
                None
            }
        };
        let (ev, ok) = sp.admit(t(3), 30, &nu);
        assert!(ok);
        assert_eq!(ev, vec![EvictEvent::Dropped { tensor: t(1), bytes: 50 }]);
        assert!(sp.is_resident(t(2)));
    }

    #[test]
    fn evicts_furthest_live() {
        let mut sp = Scratchpad::new(100);
        sp.admit(t(1), 50, &|_| Some(10)).1.then_some(()).unwrap();
        sp.admit(t(2), 40, &|_| Some(10)).1.then_some(()).unwrap();
        let nu = |r: TensorId| -> Option<usize> {
            match r.0 {
                1 => Some(3),  // near use
                2 => Some(99), // far use
                _ => None,
            }
        };
        let (ev, ok) = sp.admit(t(3), 30, &nu);
        assert!(ok);
        assert_eq!(ev, vec![EvictEvent::Spilled { tensor: t(2), bytes: 40 }]);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut sp = Scratchpad::new(100);
        sp.admit(t(1), 70, &|_| None);
        sp.release(t(1));
        sp.admit(t(2), 30, &|_| None);
        assert_eq!(sp.peak(), 70);
    }

    #[test]
    fn double_admit_idempotent() {
        let mut sp = Scratchpad::new(100);
        sp.admit(t(1), 60, &|_| None);
        let (ev, ok) = sp.admit(t(1), 60, &|_| None);
        assert!(ok && ev.is_empty());
        assert_eq!(sp.used(), 60);
    }
}
