//! Accelerator configuration.
//!
//! Parameters are modeled after what is publicly known about a
//! NeuronCore-class inference chip: a 128×128 systolic array, a
//! software-managed multi-bank scratchpad of a few MiB, and DRAM
//! reachable over a DMA fabric. Absolute numbers are *model* constants
//! (the real chip's are not public); every experiment reports ratios,
//! which are robust to the absolute scale.

use crate::util::json::Json;

/// Chip parameters for the traffic/cycle model.
#[derive(Clone, Debug)]
pub struct AccelConfig {
    pub name: String,
    /// Scratchpad banks per group (Row group and Col group each).
    pub banks: usize,
    /// Bytes per bank.
    pub bank_bytes: i64,
    /// Systolic array height (rows = contraction lanes).
    pub pe_rows: usize,
    /// Systolic array width (columns = output lanes).
    pub pe_cols: usize,
    /// Vector engine lanes.
    pub vector_lanes: usize,
    /// Core clock, Hz.
    pub clock_hz: f64,
    /// DRAM bandwidth, bytes/second.
    pub dram_bps: f64,
    /// On-chip bank-to-bank copy bandwidth, bytes/second (the slow
    /// shared path the paper refers to).
    pub onchip_copy_bps: f64,
    /// Cores on the chip available for pipeline-parallel sharding.
    /// 1 (the default everywhere) keeps every existing single-engine
    /// path — and every committed benchmark baseline — bit-identical;
    /// multi-core runs opt in via [`AccelConfig::with_cores`] or
    /// `simulate --cores N`.
    pub num_cores: usize,
    /// Core-to-core fabric bandwidth, bytes/second (NeuronLink-class:
    /// faster than DRAM, slower than the in-core scratchpad paths).
    /// Charged once per stage boundary a cut tensor crosses.
    pub intercore_bps: f64,
}

impl AccelConfig {
    /// Inferentia-like default used by all experiments.
    pub fn inferentia_like() -> Self {
        AccelConfig {
            name: "inferentia-like".into(),
            banks: 16,
            bank_bytes: 256 * 1024, // 2 groups × 16 × 256 KiB = 8 MiB scratchpad
            pe_rows: 128,
            pe_cols: 128,
            vector_lanes: 256,
            clock_hz: 1.4e9,
            dram_bps: 50e9,
            onchip_copy_bps: 200e9,
            num_cores: 1,
            intercore_bps: 100e9,
        }
    }

    /// Tiny configuration for unit tests (forces spills on small data).
    /// `scratchpad_bytes` is the TOTAL capacity across both bank groups.
    pub fn tiny(scratchpad_bytes: i64) -> Self {
        AccelConfig {
            name: "tiny-test".into(),
            banks: 4,
            bank_bytes: scratchpad_bytes / 8, // 2 groups × 4 banks
            pe_rows: 8,
            pe_cols: 8,
            vector_lanes: 16,
            clock_hz: 1e9,
            dram_bps: 1e9,
            onchip_copy_bps: 4e9,
            num_cores: 1,
            intercore_bps: 2e9,
        }
    }

    /// The same chip with `n` cores enabled for sharding.
    pub fn with_cores(mut self, n: usize) -> Self {
        self.num_cores = n.max(1);
        self
    }

    /// Total scratchpad capacity in bytes (both groups).
    pub fn scratchpad_bytes(&self) -> i64 {
        2 * self.banks as i64 * self.bank_bytes
    }

    /// Serialize for reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("banks", Json::Int(self.banks as i64)),
            ("bank_bytes", Json::Int(self.bank_bytes)),
            ("pe_rows", Json::Int(self.pe_rows as i64)),
            ("pe_cols", Json::Int(self.pe_cols as i64)),
            ("vector_lanes", Json::Int(self.vector_lanes as i64)),
            ("clock_hz", Json::Num(self.clock_hz)),
            ("dram_bps", Json::Num(self.dram_bps)),
            ("onchip_copy_bps", Json::Num(self.onchip_copy_bps)),
            ("num_cores", Json::Int(self.num_cores as i64)),
            ("intercore_bps", Json::Num(self.intercore_bps)),
        ])
    }

    /// Parse from a JSON config (the `polymem --accel-config` file).
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut cfg = AccelConfig::inferentia_like();
        if let Some(v) = j.get("name").and_then(|v| v.as_str()) {
            cfg.name = v.to_string();
        }
        if let Some(v) = j.get("banks").and_then(|v| v.as_i64()) {
            cfg.banks = v as usize;
        }
        if let Some(v) = j.get("bank_bytes").and_then(|v| v.as_i64()) {
            cfg.bank_bytes = v;
        }
        if let Some(v) = j.get("pe_rows").and_then(|v| v.as_i64()) {
            cfg.pe_rows = v as usize;
        }
        if let Some(v) = j.get("pe_cols").and_then(|v| v.as_i64()) {
            cfg.pe_cols = v as usize;
        }
        if let Some(v) = j.get("vector_lanes").and_then(|v| v.as_i64()) {
            cfg.vector_lanes = v as usize;
        }
        if let Some(v) = j.get("clock_hz").and_then(|v| v.as_f64()) {
            cfg.clock_hz = v;
        }
        if let Some(v) = j.get("dram_bps").and_then(|v| v.as_f64()) {
            cfg.dram_bps = v;
        }
        if let Some(v) = j.get("onchip_copy_bps").and_then(|v| v.as_f64()) {
            cfg.onchip_copy_bps = v;
        }
        if let Some(v) = j.get("num_cores").and_then(|v| v.as_i64()) {
            cfg.num_cores = v as usize;
        }
        if let Some(v) = j.get("intercore_bps").and_then(|v| v.as_f64()) {
            cfg.intercore_bps = v;
        }
        if cfg.banks == 0 || cfg.bank_bytes <= 0 {
            return Err("accel config: banks/bank_bytes must be positive".into());
        }
        if cfg.num_cores == 0 || !(cfg.intercore_bps > 0.0) {
            return Err("accel config: num_cores/intercore_bps must be positive".into());
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = AccelConfig::inferentia_like();
        assert_eq!(c.scratchpad_bytes(), 8 * 1024 * 1024);
        assert!(c.dram_bps < c.onchip_copy_bps);
    }

    #[test]
    fn json_roundtrip() {
        let c = AccelConfig::inferentia_like();
        let j = c.to_json();
        let c2 = AccelConfig::from_json(&j).unwrap();
        assert_eq!(c2.banks, c.banks);
        assert_eq!(c2.bank_bytes, c.bank_bytes);
        assert_eq!(c2.name, c.name);
    }

    #[test]
    fn json_partial_overrides() {
        let j = crate::util::json::parse(r#"{"banks": 8}"#).unwrap();
        let c = AccelConfig::from_json(&j).unwrap();
        assert_eq!(c.banks, 8);
        assert_eq!(c.pe_rows, 128); // default kept
    }

    #[test]
    fn json_rejects_zero_banks() {
        let j = crate::util::json::parse(r#"{"banks": 0}"#).unwrap();
        assert!(AccelConfig::from_json(&j).is_err());
    }

    #[test]
    fn cores_default_single_and_roundtrip() {
        // the single-core default keeps every pre-sharding path intact
        assert_eq!(AccelConfig::inferentia_like().num_cores, 1);
        assert_eq!(AccelConfig::tiny(8 * 1024).num_cores, 1);
        let c = AccelConfig::inferentia_like().with_cores(4);
        let c2 = AccelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.num_cores, 4);
        assert_eq!(c2.intercore_bps.to_bits(), c.intercore_bps.to_bits());
        // fabric sits between DRAM and the on-chip copy path
        assert!(c.dram_bps < c.intercore_bps && c.intercore_bps <= c.onchip_copy_bps);
        let bad = crate::util::json::parse(r#"{"num_cores": 0}"#).unwrap();
        assert!(AccelConfig::from_json(&bad).is_err());
    }
}
