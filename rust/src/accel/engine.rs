//! Coarse cycle model.
//!
//! Latency per nest = max(compute time, DMA time) — the double-buffered
//! overlap a production schedule achieves — summed over the schedule.
//! MXU nests run on the systolic array at `pe_rows × pe_cols` MACs per
//! cycle; vector/copy nests run on the vector engine lanes; DMA runs at
//! the configured bandwidths. This is deliberately coarse: the paper's
//! claims are about traffic, and cycles are only used for end-to-end
//! throughput estimates in the serving example.

use super::config::AccelConfig;
use crate::ir::loopnest::{Body, LoopNest};
use crate::ir::op::OpKind;

/// Compute time (seconds) for one nest.
pub fn compute_seconds(cfg: &AccelConfig, nest: &LoopNest, kind: &OpKind) -> f64 {
    let points = nest.domain.cardinality() as f64;
    match &nest.body {
        Body::Compute { flops_per_point, .. } => {
            let flops = points * *flops_per_point as f64;
            let per_cycle = if is_mxu_kind(kind) {
                2.0 * cfg.pe_rows as f64 * cfg.pe_cols as f64 // MAC = 2 flops
            } else {
                cfg.vector_lanes as f64
            };
            flops / per_cycle / cfg.clock_hz
        }
        Body::Copy { .. } => {
            // copy engine moves one element per lane per cycle
            points / cfg.vector_lanes as f64 / cfg.clock_hz
        }
    }
}

/// DMA time (seconds) for moving `bytes` over the given path.
pub fn dma_seconds(cfg: &AccelConfig, bytes: i64, offchip: bool) -> f64 {
    let bps = if offchip { cfg.dram_bps } else { cfg.onchip_copy_bps };
    bytes as f64 / bps
}

/// Overlapped latency for one schedule step.
pub fn step_seconds(compute: f64, dma: f64) -> f64 {
    compute.max(dma)
}

/// One stage of a double-buffered tile schedule: DMA the tile's
/// operands in, compute, DMA the tile's results out.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipeStep {
    pub dma_in: f64,
    pub compute: f64,
    pub dma_out: f64,
}

/// Latency of a software-pipelined tile schedule on two engines: one
/// DMA queue (prefetch + write-back, in order, prefetch of tile `t+1`
/// issued ahead of tile `t`'s write-back — the double-buffer priority)
/// and one compute engine. Tile `t` computes only after its prefetch
/// lands; its write-back queues after its compute.
///
/// A single-step schedule degenerates to the *serial* `in + compute +
/// out` — the honest cost of a nest whose working set cannot be
/// double-buffered, replacing the optimistic per-nest `max(compute,
/// dma)` the coarse model assumes. For any tiling of the same work the
/// pipelined makespan is at most that serial time, and on
/// bandwidth-bound nests it approaches `max(Σdma, Σcompute)`.
pub fn pipeline_seconds(steps: &[PipeStep]) -> f64 {
    let n = steps.len();
    if n == 0 {
        return 0.0;
    }
    let mut in_done = vec![0.0f64; n];
    let mut dma_free = steps[0].dma_in;
    in_done[0] = dma_free;
    let mut comp_free = 0.0f64;
    for t in 0..n {
        // prefetch the next tile while this one computes
        if t + 1 < n {
            dma_free += steps[t + 1].dma_in;
            in_done[t + 1] = dma_free;
        }
        let comp_done = in_done[t].max(comp_free) + steps[t].compute;
        comp_free = comp_done;
        // write-back rides the DMA queue after the compute finishes
        dma_free = dma_free.max(comp_done) + steps[t].dma_out;
    }
    dma_free.max(comp_free)
}

/// Per-step engine intervals of a pipelined tile schedule: when each
/// tile's prefetch, compute and write-back occupy their engine.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PipeInterval {
    pub in_start: f64,
    pub in_done: f64,
    pub comp_start: f64,
    pub comp_done: f64,
    pub out_start: f64,
    pub out_done: f64,
}

/// The full engine timeline behind [`pipeline_seconds`]: the same
/// recurrence, unrolled into per-step intervals for trace export. The
/// makespan equals `last.out_done.max(last.comp_done)` with the exact
/// floating-point operation order of [`pipeline_seconds`] — the
/// bit-equivalence test below pins it, because the cost model's
/// calibration suite compares pipelined seconds via `to_bits()`.
pub fn pipeline_intervals(steps: &[PipeStep]) -> Vec<PipeInterval> {
    let n = steps.len();
    let mut out = vec![PipeInterval::default(); n];
    if n == 0 {
        return out;
    }
    out[0].in_start = 0.0;
    let mut dma_free = steps[0].dma_in;
    out[0].in_done = dma_free;
    let mut comp_free = 0.0f64;
    for t in 0..n {
        if t + 1 < n {
            out[t + 1].in_start = dma_free;
            dma_free += steps[t + 1].dma_in;
            out[t + 1].in_done = dma_free;
        }
        let comp_start = out[t].in_done.max(comp_free);
        let comp_done = comp_start + steps[t].compute;
        out[t].comp_start = comp_start;
        out[t].comp_done = comp_done;
        comp_free = comp_done;
        let out_start = dma_free.max(comp_done);
        out[t].out_start = out_start;
        dma_free = out_start + steps[t].dma_out;
        out[t].out_done = dma_free;
    }
    out
}

/// Seconds to ship `bytes` between adjacent cores over the fabric.
pub fn intercore_seconds(cfg: &AccelConfig, bytes: i64) -> f64 {
    bytes as f64 / cfg.intercore_bps
}

/// Steady-state initiation interval of a multi-core pipeline: once the
/// pipe is full, a new batch completes every `max_i(stage_i +
/// transfer_i)` seconds (each core must finish its stage *and* hand the
/// result to its successor before accepting the next batch).
/// `transfer_seconds` has one entry per stage; the last stage's entry
/// covers its write-back hand-off and is normally 0.
pub fn multicore_interval(stage_seconds: &[f64], transfer_seconds: &[f64]) -> f64 {
    assert_eq!(stage_seconds.len(), transfer_seconds.len());
    let mut iv = 0.0f64;
    for (s, t) in stage_seconds.iter().zip(transfer_seconds) {
        iv = iv.max(s + t);
    }
    iv
}

/// Makespan (seconds) of `batches` back-to-back batches through a
/// multi-core pipeline with one stage per core. Stage `s` of batch `b`
/// starts when both the core is free (it holds a batch until its
/// inter-core send completes) and batch `b` has arrived from stage
/// `s-1`; fill and drain are accounted naturally by the recurrence.
/// One batch degenerates to `Σ stage + Σ transfer[..k-1]`; for large
/// `batches` the marginal batch costs [`multicore_interval`].
pub fn multicore_pipeline_seconds(
    stage_seconds: &[f64],
    transfer_seconds: &[f64],
    batches: usize,
) -> f64 {
    assert_eq!(stage_seconds.len(), transfer_seconds.len());
    let k = stage_seconds.len();
    if k == 0 || batches == 0 {
        return 0.0;
    }
    let mut core_free = vec![0.0f64; k];
    let mut makespan = 0.0f64;
    for _b in 0..batches {
        let mut arrive = 0.0f64; // host feeds stage 0 back-to-back
        for s in 0..k {
            let start = arrive.max(core_free[s]);
            let done = start + stage_seconds[s];
            let sent = done + transfer_seconds[s];
            core_free[s] = sent;
            arrive = sent;
            if s + 1 == k {
                makespan = makespan.max(done);
            }
        }
    }
    makespan
}

/// One core's busy interval for one batch in the multi-core pipeline.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoreSpan {
    pub core: usize,
    pub batch: usize,
    /// Stage compute+DMA work occupies the core over `[start, done)`.
    pub start: f64,
    pub done: f64,
    /// Inter-core send occupies the fabric over `[done, sent)`.
    pub sent: f64,
}

/// The full per-core timeline behind [`multicore_pipeline_seconds`]:
/// the same recurrence, unrolled into one span per `(batch, core)` for
/// Chrome-trace export (one lane per core). The makespan equals the
/// last batch's `done` on the last core with the exact floating-point
/// operation order of the scalar recurrence — pinned bit-exactly by
/// the test below, because sharded calibration compares seconds via
/// `to_bits()`.
pub fn multicore_pipeline_intervals(
    stage_seconds: &[f64],
    transfer_seconds: &[f64],
    batches: usize,
) -> Vec<CoreSpan> {
    assert_eq!(stage_seconds.len(), transfer_seconds.len());
    let k = stage_seconds.len();
    let mut out = Vec::with_capacity(k * batches);
    if k == 0 || batches == 0 {
        return out;
    }
    let mut core_free = vec![0.0f64; k];
    for b in 0..batches {
        let mut arrive = 0.0f64;
        for s in 0..k {
            let start = arrive.max(core_free[s]);
            let done = start + stage_seconds[s];
            let sent = done + transfer_seconds[s];
            core_free[s] = sent;
            arrive = sent;
            out.push(CoreSpan { core: s, batch: b, start, done, sent });
        }
    }
    out
}

fn is_mxu_kind(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Conv2d { .. }
            | OpKind::DepthwiseConv2d { .. }
            | OpKind::Conv1d { .. }
            | OpKind::MatMul
    )
}

/// Roofline helper: ideal MXU seconds for `flops` at full utilization.
pub fn mxu_roofline_seconds(cfg: &AccelConfig, flops: f64) -> f64 {
    flops / (2.0 * cfg.pe_rows as f64 * cfg.pe_cols as f64) / cfg.clock_hz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::lower_node;

    #[test]
    fn mxu_faster_than_vector_for_matmul() {
        let cfg = AccelConfig::inferentia_like();
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[128, 128]);
        let w = b.weight("w", &[128, 128]);
        let m = b.matmul("mm", x, w);
        b.mark_output(m);
        let g = b.finish();
        let node = g.nodes().last().unwrap();
        let nest = &lower_node(&g, node)[0];
        let t_mxu = compute_seconds(&cfg, nest, &node.kind);
        // same nest treated as a vector op would be much slower
        let t_vec = {
            let points = nest.domain.cardinality() as f64 * 2.0;
            points / cfg.vector_lanes as f64 / cfg.clock_hz
        };
        assert!(t_mxu < t_vec / 10.0);
        // 128³ matmul on a 128×128 array ≈ 128 cycles
        let expect = 128.0 / cfg.clock_hz;
        assert!((t_mxu - expect).abs() < expect * 0.01);
    }

    #[test]
    fn dma_scales_with_bytes_and_path() {
        let cfg = AccelConfig::inferentia_like();
        assert!(dma_seconds(&cfg, 1 << 20, true) > dma_seconds(&cfg, 1 << 20, false));
        assert_eq!(dma_seconds(&cfg, 0, true), 0.0);
        let a = dma_seconds(&cfg, 1000, true);
        let b = dma_seconds(&cfg, 2000, true);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn step_overlap_takes_max() {
        assert_eq!(step_seconds(2.0, 3.0), 3.0);
        assert_eq!(step_seconds(5.0, 3.0), 5.0);
    }

    #[test]
    fn untiled_serial_never_beats_pipelined_tiles() {
        // a bandwidth-bound nest: DMA dominates compute 4:1. Untiled it
        // must serialize (nothing fits on chip to overlap with); split
        // into 8 double-buffered tiles the DMA hides almost all compute.
        let untiled = PipeStep { dma_in: 8.0, compute: 2.0, dma_out: 8.0 };
        let serial = pipeline_seconds(&[untiled]);
        assert_eq!(serial, 18.0);
        let tiles: Vec<PipeStep> = (0..8)
            .map(|_| PipeStep { dma_in: 1.0, compute: 0.25, dma_out: 1.0 })
            .collect();
        let pipelined = pipeline_seconds(&tiles);
        assert!(
            pipelined < serial,
            "pipelined {pipelined} not better than serial {serial}"
        );
        // bandwidth-bound: the DMA engine is the critical path, so the
        // makespan is within one tile of the total DMA time
        assert!(pipelined >= 16.0);
        assert!(pipelined <= 16.0 + 1.0 + 0.25 + 1e-9, "{pipelined}");
    }

    #[test]
    fn compute_bound_pipeline_hides_dma() {
        let tiles: Vec<PipeStep> = (0..4)
            .map(|_| PipeStep { dma_in: 1.0, compute: 5.0, dma_out: 1.0 })
            .collect();
        let t = pipeline_seconds(&tiles);
        // compute chain dominates: in_0 + 4*compute + out_3
        assert!((t - (1.0 + 20.0 + 1.0)).abs() < 1e-9, "{t}");
        assert_eq!(pipeline_seconds(&[]), 0.0);
    }

    #[test]
    fn intervals_bit_equal_to_pipeline_seconds() {
        // the cost model compares pipelined seconds via to_bits(), so
        // the interval unrolling must reproduce the recurrence exactly
        let cases: Vec<Vec<PipeStep>> = vec![
            vec![],
            vec![PipeStep { dma_in: 8.0, compute: 2.0, dma_out: 8.0 }],
            (0..8)
                .map(|_| PipeStep { dma_in: 1.0, compute: 0.25, dma_out: 1.0 })
                .collect(),
            (0..17)
                .map(|k| PipeStep {
                    dma_in: 0.3 + 0.071 * k as f64,
                    compute: 1.7 / (1.0 + k as f64),
                    dma_out: 0.013 * (k % 5) as f64,
                })
                .collect(),
        ];
        for steps in cases {
            let iv = pipeline_intervals(&steps);
            assert_eq!(iv.len(), steps.len());
            let makespan = iv
                .last()
                .map(|l| l.out_done.max(l.comp_done))
                .unwrap_or(0.0);
            assert_eq!(makespan.to_bits(), pipeline_seconds(&steps).to_bits());
        }
    }

    #[test]
    fn intervals_are_engine_consistent() {
        let steps: Vec<PipeStep> = (0..6)
            .map(|k| PipeStep {
                dma_in: 1.0 + k as f64 * 0.1,
                compute: 2.0,
                dma_out: 0.5,
            })
            .collect();
        let iv = pipeline_intervals(&steps);
        for (k, i) in iv.iter().enumerate() {
            // each engine's segments are well-formed
            assert!(i.in_start <= i.in_done);
            assert!(i.comp_start <= i.comp_done);
            assert!(i.out_start <= i.out_done);
            // compute waits for its prefetch; write-back for compute
            assert!(i.comp_start >= i.in_done);
            assert!(i.out_start >= i.comp_done);
            if k > 0 {
                // one DMA queue, one compute engine: no overlap
                assert!(iv[k - 1].comp_done <= i.comp_start);
                assert!(iv[k - 1].in_done <= i.in_start);
                // prefetch of tile k is issued before write-back of k-1
                assert!(i.in_done <= iv[k - 1].out_start);
            }
        }
    }

    #[test]
    fn multicore_single_batch_is_sum_of_stages_and_transfers() {
        let stages = [2.0, 3.0, 1.0];
        let transfers = [0.5, 0.25, 0.0];
        let t = multicore_pipeline_seconds(&stages, &transfers, 1);
        // one batch: all stage times plus the two interior hand-offs
        assert!((t - (2.0 + 0.5 + 3.0 + 0.25 + 1.0)).abs() < 1e-12, "{t}");
        assert_eq!(multicore_pipeline_seconds(&stages, &transfers, 0), 0.0);
        assert_eq!(multicore_pipeline_seconds(&[], &[], 4), 0.0);
    }

    #[test]
    fn multicore_steady_state_is_bottleneck_interval() {
        let stages = [2.0, 3.0, 1.0];
        let transfers = [0.5, 0.25, 0.0];
        let iv = multicore_interval(&stages, &transfers);
        assert_eq!(iv, 3.25);
        // marginal batch in the filled pipe costs exactly the interval
        let t9 = multicore_pipeline_seconds(&stages, &transfers, 9);
        let t10 = multicore_pipeline_seconds(&stages, &transfers, 10);
        assert!((t10 - t9 - iv).abs() < 1e-9, "{}", t10 - t9);
        // and a k-stage pipeline beats the serial single core on the
        // same work once the pipe is full
        let single = stages.iter().sum::<f64>();
        assert!(t10 < single * 10.0);
    }

    #[test]
    fn multicore_intervals_bit_equal_to_pipeline_seconds() {
        let cases: Vec<(Vec<f64>, Vec<f64>, usize)> = vec![
            (vec![2.0], vec![0.0], 7),
            (vec![2.0, 3.0, 1.0], vec![0.5, 0.25, 0.0], 1),
            (vec![2.0, 3.0, 1.0], vec![0.5, 0.25, 0.0], 10),
            (
                (0..5).map(|k| 0.3 + 0.071 * k as f64).collect(),
                (0..5).map(|k| 0.013 * (k % 3) as f64).collect(),
                13,
            ),
        ];
        for (stages, transfers, batches) in cases {
            let spans = multicore_pipeline_intervals(&stages, &transfers, batches);
            assert_eq!(spans.len(), stages.len() * batches);
            let makespan = spans
                .iter()
                .filter(|s| s.core + 1 == stages.len())
                .map(|s| s.done)
                .fold(0.0f64, f64::max);
            assert_eq!(
                makespan.to_bits(),
                multicore_pipeline_seconds(&stages, &transfers, batches).to_bits()
            );
            // per-core lanes never overlap: a core's next batch starts
            // at or after its previous send completed
            for core in 0..stages.len() {
                let lane: Vec<&CoreSpan> = spans.iter().filter(|s| s.core == core).collect();
                for w in lane.windows(2) {
                    assert!(w[0].sent <= w[1].start + 1e-15);
                }
            }
        }
    }

    #[test]
    fn intercore_seconds_uses_fabric_bandwidth() {
        let cfg = AccelConfig::inferentia_like();
        let t = intercore_seconds(&cfg, 1 << 20);
        assert!(t < dma_seconds(&cfg, 1 << 20, true)); // faster than DRAM
        assert_eq!(intercore_seconds(&cfg, 0), 0.0);
    }

    #[test]
    fn roofline_sanity() {
        let cfg = AccelConfig::inferentia_like();
        // one second of peak flops
        let peak = 2.0 * 128.0 * 128.0 * cfg.clock_hz;
        assert!((mxu_roofline_seconds(&cfg, peak) - 1.0).abs() < 1e-9);
    }
}
