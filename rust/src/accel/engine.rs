//! Coarse cycle model.
//!
//! Latency per nest = max(compute time, DMA time) — the double-buffered
//! overlap a production schedule achieves — summed over the schedule.
//! MXU nests run on the systolic array at `pe_rows × pe_cols` MACs per
//! cycle; vector/copy nests run on the vector engine lanes; DMA runs at
//! the configured bandwidths. This is deliberately coarse: the paper's
//! claims are about traffic, and cycles are only used for end-to-end
//! throughput estimates in the serving example.

use super::config::AccelConfig;
use crate::ir::loopnest::{Body, LoopNest};
use crate::ir::op::OpKind;

/// Compute time (seconds) for one nest.
pub fn compute_seconds(cfg: &AccelConfig, nest: &LoopNest, kind: &OpKind) -> f64 {
    let points = nest.domain.cardinality() as f64;
    match &nest.body {
        Body::Compute { flops_per_point, .. } => {
            let flops = points * *flops_per_point as f64;
            let per_cycle = if is_mxu_kind(kind) {
                2.0 * cfg.pe_rows as f64 * cfg.pe_cols as f64 // MAC = 2 flops
            } else {
                cfg.vector_lanes as f64
            };
            flops / per_cycle / cfg.clock_hz
        }
        Body::Copy { .. } => {
            // copy engine moves one element per lane per cycle
            points / cfg.vector_lanes as f64 / cfg.clock_hz
        }
    }
}

/// DMA time (seconds) for moving `bytes` over the given path.
pub fn dma_seconds(cfg: &AccelConfig, bytes: i64, offchip: bool) -> f64 {
    let bps = if offchip { cfg.dram_bps } else { cfg.onchip_copy_bps };
    bytes as f64 / bps
}

/// Overlapped latency for one schedule step.
pub fn step_seconds(compute: f64, dma: f64) -> f64 {
    compute.max(dma)
}

fn is_mxu_kind(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Conv2d { .. }
            | OpKind::DepthwiseConv2d { .. }
            | OpKind::Conv1d { .. }
            | OpKind::MatMul
    )
}

/// Roofline helper: ideal MXU seconds for `flops` at full utilization.
pub fn mxu_roofline_seconds(cfg: &AccelConfig, flops: f64) -> f64 {
    flops / (2.0 * cfg.pe_rows as f64 * cfg.pe_cols as f64) / cfg.clock_hz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::lower_node;

    #[test]
    fn mxu_faster_than_vector_for_matmul() {
        let cfg = AccelConfig::inferentia_like();
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[128, 128]);
        let w = b.weight("w", &[128, 128]);
        let m = b.matmul("mm", x, w);
        b.mark_output(m);
        let g = b.finish();
        let node = g.nodes().last().unwrap();
        let nest = &lower_node(&g, node)[0];
        let t_mxu = compute_seconds(&cfg, nest, &node.kind);
        // same nest treated as a vector op would be much slower
        let t_vec = {
            let points = nest.domain.cardinality() as f64 * 2.0;
            points / cfg.vector_lanes as f64 / cfg.clock_hz
        };
        assert!(t_mxu < t_vec / 10.0);
        // 128³ matmul on a 128×128 array ≈ 128 cycles
        let expect = 128.0 / cfg.clock_hz;
        assert!((t_mxu - expect).abs() < expect * 0.01);
    }

    #[test]
    fn dma_scales_with_bytes_and_path() {
        let cfg = AccelConfig::inferentia_like();
        assert!(dma_seconds(&cfg, 1 << 20, true) > dma_seconds(&cfg, 1 << 20, false));
        assert_eq!(dma_seconds(&cfg, 0, true), 0.0);
        let a = dma_seconds(&cfg, 1000, true);
        let b = dma_seconds(&cfg, 2000, true);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn step_overlap_takes_max() {
        assert_eq!(step_seconds(2.0, 3.0), 3.0);
        assert_eq!(step_seconds(5.0, 3.0), 5.0);
    }

    #[test]
    fn roofline_sanity() {
        let cfg = AccelConfig::inferentia_like();
        // one second of peak flops
        let peak = 2.0 * 128.0 * 128.0 * cfg.clock_hz;
        assert!((mxu_roofline_seconds(&cfg, peak) - 1.0).abs() < 1e-9);
    }
}
