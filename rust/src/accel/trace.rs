//! Event tracing for the simulator (tests, debugging, and the
//! `polymem simulate --trace` flag).

use super::dma::TrafficClass;
use crate::ir::tensor::TensorId;

/// One simulator event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// A tensor was staged into the scratchpad.
    Stage { pos: usize, tensor: TensorId, bytes: i64, class: TrafficClass },
    /// A dead tensor's space was released.
    Release { pos: usize, tensor: TensorId },
}

/// Bounded event log.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    events: Vec<TraceEvent>,
    limit: usize,
    dropped: usize,
}

impl Trace {
    pub fn new(limit: usize) -> Self {
        Trace { events: Vec::new(), limit, dropped: 0 }
    }

    pub fn push(&mut self, ev: TraceEvent) {
        if self.events.len() < self.limit {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Render a human-readable dump.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for ev in &self.events {
            match ev {
                TraceEvent::Stage { pos, tensor, bytes, class } => {
                    s.push_str(&format!(
                        "[{pos:>4}] stage   {tensor:?} {bytes}B ({})\n",
                        class.label()
                    ));
                }
                TraceEvent::Release { pos, tensor } => {
                    s.push_str(&format!("[{pos:>4}] release {tensor:?}\n"));
                }
            }
        }
        if self.dropped > 0 {
            s.push_str(&format!("... {} events dropped\n", self.dropped));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::AccelConfig;
    use crate::accel::sim::simulate;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;

    #[test]
    fn trace_records_staging() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        let mut tr = Trace::new(100);
        simulate(&prog, &AccelConfig::inferentia_like(), Some(&mut tr));
        assert!(tr
            .events()
            .iter()
            .any(|e| matches!(e, TraceEvent::Stage { class: TrafficClass::InputLoad, .. })));
        assert!(!tr.dump().is_empty());
    }

    #[test]
    fn trace_bounded() {
        let mut tr = Trace::new(2);
        for k in 0..5 {
            tr.push(TraceEvent::Release { pos: k, tensor: TensorId(0) });
        }
        assert_eq!(tr.events().len(), 2);
        assert_eq!(tr.dropped(), 3);
        assert!(tr.dump().contains("3 events dropped"));
    }
}
