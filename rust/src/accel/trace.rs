//! Event tracing + telemetry side-channels for the simulator (tests,
//! debugging, and the `polymem simulate --trace` / `--trace-out`
//! flags).
//!
//! A [`Trace`] collects four things during a replay:
//!
//! * **events** — the bounded log of discrete simulator actions
//!   ([`TraceEvent`]: staging, releases, spills, copy/remap nests);
//! * **attribution** — per-node × per-[`TrafficClass`] byte cells
//!   ([`Attribution`]). The simulator pairs *every* traffic charge
//!   with an attribution cell, so the cells sum bit-exactly to the
//!   replay's `TrafficCounters` (the conservation invariant pinned in
//!   `tests/obs_telemetry.rs`);
//! * **engine spans** — compute/DMA busy intervals ([`EngineSpan`])
//!   reconstructed from the latency model;
//! * **occupancy** — `(seconds, bytes)` scratchpad samples.
//!
//! The event log is bounded by the constructor limit; the attribution
//! table, spans and occupancy are proportional to the schedule, not to
//! the event volume, and are kept even when events overflow.

use super::dma::{TrafficClass, TrafficCounters};
use crate::ir::graph::NodeId;
use crate::ir::tensor::TensorId;
use crate::obs::ChromeTrace;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Attribution target for traffic nobody computes (a graph output
/// written back without a producer node, e.g. a passthrough input).
pub const EXTERNAL_NODE: NodeId = NodeId(u32::MAX);

/// One simulator event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// A tensor was staged into the scratchpad.
    Stage { pos: usize, tensor: TensorId, bytes: i64, class: TrafficClass },
    /// A dead tensor's space was released.
    Release { pos: usize, tensor: TensorId },
    /// A tensor (or tile) was written back to DRAM: an eviction under
    /// pressure, a non-resident result, or an explicit spill nest.
    Spill { pos: usize, tensor: TensorId, bytes: i64 },
    /// A copy nest / bank remap executed (`class` says which path the
    /// bytes took).
    MemCopy { pos: usize, node: NodeId, bytes: i64, class: TrafficClass },
}

/// Which engine a span occupies.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Engine {
    Compute,
    Dma,
}

/// One busy interval on one engine, in simulated seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineSpan {
    pub engine: Engine,
    pub label: String,
    pub start: f64,
    pub dur: f64,
}

/// Per-node × per-class DRAM/scratchpad byte cells.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Attribution {
    cells: BTreeMap<(NodeId, TrafficClass), i64>,
}

impl Attribution {
    /// Charge `bytes` to `(node, class)`. Zero-byte charges are
    /// dropped (they cannot change any total).
    pub fn add(&mut self, node: NodeId, class: TrafficClass, bytes: i64) {
        if bytes != 0 {
            *self.cells.entry((node, class)).or_insert(0) += bytes;
        }
    }

    pub fn get(&self, node: NodeId, class: TrafficClass) -> i64 {
        self.cells.get(&(node, class)).copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Iterate all non-zero cells.
    pub fn cells(&self) -> impl Iterator<Item = (NodeId, TrafficClass, i64)> + '_ {
        self.cells.iter().map(|(&(n, c), &b)| (n, c, b))
    }

    /// Collapse the cells back into per-class totals. Conservation:
    /// this equals the replay's `TrafficCounters` class-for-class.
    pub fn totals(&self) -> TrafficCounters {
        let mut t = TrafficCounters::new();
        for (&(_, c), &b) in &self.cells {
            t.add(c, b);
        }
        t
    }

    /// Per-node off-chip bytes, largest first (ties by node id).
    pub fn per_node_offchip(&self) -> Vec<(NodeId, i64)> {
        let mut by: BTreeMap<NodeId, i64> = BTreeMap::new();
        for (&(n, c), &b) in &self.cells {
            if c.is_offchip() {
                *by.entry(n).or_insert(0) += b;
            }
        }
        let mut v: Vec<(NodeId, i64)> = by.into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v
    }
}

/// Bounded event log + unbounded (schedule-proportional) telemetry.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    events: Vec<TraceEvent>,
    limit: usize,
    dropped: usize,
    attr: Attribution,
    spans: Vec<EngineSpan>,
    occupancy: Vec<(f64, i64)>,
}

/// Chrome-trace thread id of the compute engine.
pub const COMPUTE_TID: i64 = 0;
/// Chrome-trace thread id of the DMA queue.
pub const DMA_TID: i64 = 1;

impl Trace {
    pub fn new(limit: usize) -> Self {
        Trace { limit, ..Default::default() }
    }

    pub fn push(&mut self, ev: TraceEvent) {
        if self.events.len() < self.limit {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// The per-node × per-class byte attribution of the replay.
    pub fn attr(&self) -> &Attribution {
        &self.attr
    }

    pub(crate) fn attr_add(&mut self, node: NodeId, class: TrafficClass, bytes: i64) {
        self.attr.add(node, class, bytes);
    }

    /// Engine busy intervals (simulated seconds).
    pub fn spans(&self) -> &[EngineSpan] {
        &self.spans
    }

    pub(crate) fn push_span(&mut self, engine: Engine, label: String, start: f64, dur: f64) {
        if dur > 0.0 {
            self.spans.push(EngineSpan { engine, label, start, dur });
        }
    }

    /// `(seconds, scratchpad bytes)` occupancy samples.
    pub fn occupancy(&self) -> &[(f64, i64)] {
        &self.occupancy
    }

    pub(crate) fn push_occupancy(&mut self, ts: f64, bytes: i64) {
        self.occupancy.push((ts, bytes));
    }

    /// Export the engine timeline as Chrome trace-event JSON
    /// (`chrome://tracing` / Perfetto): thread 0 is the compute
    /// engine, thread 1 the DMA queue, plus a scratchpad-occupancy
    /// counter track.
    pub fn to_chrome_json(&self) -> Json {
        let mut ct = ChromeTrace::new();
        ct.thread_name(COMPUTE_TID, "compute");
        ct.thread_name(DMA_TID, "dma");
        for s in &self.spans {
            let tid = match s.engine {
                Engine::Compute => COMPUTE_TID,
                Engine::Dma => DMA_TID,
            };
            ct.span(tid, &s.label, s.start, s.dur);
        }
        for &(ts, bytes) in &self.occupancy {
            ct.counter("scratchpad_bytes", ts, bytes);
        }
        ct.to_json()
    }

    /// Render a human-readable dump.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for ev in &self.events {
            match ev {
                TraceEvent::Stage { pos, tensor, bytes, class } => {
                    s.push_str(&format!(
                        "[{pos:>4}] stage   {tensor:?} {bytes}B ({})\n",
                        class.label()
                    ));
                }
                TraceEvent::Release { pos, tensor } => {
                    s.push_str(&format!("[{pos:>4}] release {tensor:?}\n"));
                }
                TraceEvent::Spill { pos, tensor, bytes } => {
                    s.push_str(&format!("[{pos:>4}] spill   {tensor:?} {bytes}B\n"));
                }
                TraceEvent::MemCopy { pos, node, bytes, class } => {
                    s.push_str(&format!(
                        "[{pos:>4}] memcopy {node:?} {bytes}B ({})\n",
                        class.label()
                    ));
                }
            }
        }
        if self.dropped > 0 {
            s.push_str(&format!("... {} events dropped\n", self.dropped));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::AccelConfig;
    use crate::accel::sim::simulate;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;

    #[test]
    fn trace_records_staging() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        let mut tr = Trace::new(100);
        simulate(&prog, &AccelConfig::inferentia_like(), Some(&mut tr));
        assert!(tr
            .events()
            .iter()
            .any(|e| matches!(e, TraceEvent::Stage { class: TrafficClass::InputLoad, .. })));
        assert!(!tr.dump().is_empty());
    }

    #[test]
    fn trace_bounded() {
        let mut tr = Trace::new(2);
        for k in 0..5 {
            tr.push(TraceEvent::Release { pos: k, tensor: TensorId(0) });
        }
        assert_eq!(tr.events().len(), 2);
        assert_eq!(tr.dropped(), 3);
        assert!(tr.dump().contains("3 events dropped"));
    }

    #[test]
    fn attribution_totals_and_ranking() {
        let mut a = Attribution::default();
        a.add(NodeId(1), TrafficClass::WeightLoad, 100);
        a.add(NodeId(1), TrafficClass::Spill, 50);
        a.add(NodeId(2), TrafficClass::InputLoad, 400);
        a.add(NodeId(2), TrafficClass::OnchipCopy, 999); // on-chip: not ranked
        a.add(NodeId(3), TrafficClass::Reload, 0); // dropped
        assert_eq!(a.get(NodeId(1), TrafficClass::WeightLoad), 100);
        assert_eq!(a.get(NodeId(3), TrafficClass::Reload), 0);
        let t = a.totals();
        assert_eq!(t.get(TrafficClass::WeightLoad), 100);
        assert_eq!(t.offchip_total(), 550);
        assert_eq!(
            a.per_node_offchip(),
            vec![(NodeId(2), 400), (NodeId(1), 150)]
        );
    }

    #[test]
    fn simulate_fills_attribution_and_timeline() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[16, 16]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        let mut tr = Trace::new(100);
        let rep = simulate(&prog, &AccelConfig::inferentia_like(), Some(&mut tr));
        // conservation: attribution cells sum to the replay's counters
        for c in TrafficClass::ALL {
            assert_eq!(tr.attr().totals().get(c), rep.traffic.get(c), "{}", c.label());
        }
        assert!(!tr.spans().is_empty());
        assert!(!tr.occupancy().is_empty());
        let j = tr.to_chrome_json();
        assert!(j.get("traceEvents").and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0) > 0);
    }
}
