//! Schedule replay: byte-exact traffic accounting + coarse latency.
//!
//! Replays a lowered [`Program`] nest by nest against the scratchpad
//! residency model and produces a [`SimReport`]. All quantities are
//! deterministic functions of the schedule — this is the measurement
//! substrate standing in for Inferentia hardware counters.
//!
//! ## Metrics (see EXPERIMENTS.md for how they map to the paper)
//!
//! * **off-chip bytes** — every DRAM transfer: weight/input staging,
//!   output write-back, spills/reloads, copy nests and bank remaps that
//!   round-trip DRAM.
//! * **on-chip movement bytes** — every byte a DMA queue or copy engine
//!   writes into / reads out of the scratchpad: staging deposits,
//!   copy-nest moves, bank remaps. (Compute-engine operand reads are
//!   *not* movement — they are the useful work.)
//! * **copy-only subsets** — the same totals restricted to copy nests
//!   and remaps, i.e. the traffic the paper's passes attack.
//!
//! ## Telemetry (when a [`Trace`] is passed)
//!
//! Every `traffic.add` site pairs with an [`super::trace::Attribution`]
//! cell charged to the nest's node (evictions to the nest that forced
//! them; final output write-backs to the producer), so the per-node ×
//! per-class cells sum **bit-exactly** to `SimReport::traffic` — the
//! conservation invariant `tests/obs_telemetry.rs` checks against
//! `cost::evaluate` as well. The replays also emit discrete events
//! (stage / release / spill / memcopy), compute + DMA engine spans
//! reconstructed from the latency model (per-tile prefetch / compute /
//! write-back intervals in pipelined mode), and scratchpad-occupancy
//! samples. None of this changes any accounted quantity: the byte and
//! seconds arithmetic is identical with tracing on or off.

use super::config::AccelConfig;
use super::dma::{TrafficClass, TrafficCounters};
use super::engine;
use super::scratchpad::{EvictEvent, Scratchpad};
use super::trace::{Engine, Trace, TraceEvent, EXTERNAL_NODE};
use crate::ir::graph::NodeId;
use crate::ir::loopnest::{Body, Program};
use crate::ir::op::OpKind;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::passes::liveness::Liveness;
use std::collections::HashSet;

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub traffic: TrafficCounters,
    /// End-to-end latency estimate (seconds) with compute/DMA overlap.
    pub seconds: f64,
    /// Scratchpad high-water mark (bytes).
    pub peak_scratchpad: i64,
    pub nests_executed: usize,
    pub copy_nests_executed: usize,
    /// Scratchpad deposit bytes from staging DMA (weights/inputs/reloads).
    pub staging_deposit_bytes: i64,
}

impl SimReport {
    /// All DRAM bytes.
    pub fn offchip_total(&self) -> i64 {
        self.traffic.offchip_total()
    }

    /// DRAM bytes attributable to copies (paper E2 off-chip metric).
    pub fn offchip_copy_total(&self) -> i64 {
        self.traffic.offchip_copy_total()
    }

    /// All data-movement bytes touching the scratchpad (paper E1
    /// on-chip metric): staging deposits + on-chip copies/remaps.
    pub fn onchip_movement_total(&self) -> i64 {
        self.staging_deposit_bytes + self.traffic.onchip_total()
    }

    /// On-chip copy/remap bytes only (paper E2 on-chip metric).
    pub fn onchip_copy_total(&self) -> i64 {
        self.traffic.onchip_total()
    }
}

/// Replay a program. `trace` may be `None` for speed.
pub fn simulate(prog: &Program, cfg: &AccelConfig, mut trace: Option<&mut Trace>) -> SimReport {
    let liveness = Liveness::analyze(prog);
    let mut sp = Scratchpad::new(cfg.scratchpad_bytes());
    let mut traffic = TrafficCounters::new();
    let mut seconds = 0.0f64;
    let mut staging_deposit_bytes = 0i64;
    let mut copy_nests = 0usize;
    // intermediates currently only in DRAM (spilled or streamed)
    let mut in_dram: HashSet<TensorId> = HashSet::new();
    // node lookup index (§Perf: Graph::node is a linear scan)
    let node_by_id: std::collections::HashMap<_, _> =
        prog.graph.nodes().iter().map(|n| (n.id, n)).collect();

    for (pos, nest) in prog.nests.iter().enumerate() {
        let node = node_by_id[&nest.node];
        let mut off_bytes = 0i64;
        let mut on_bytes = 0i64;

        // ---- stage operands ----
        let mut operand_resident = true;
        let mut operands: Vec<TensorId> = nest
            .body
            .loads()
            .iter()
            .flat_map(|l| l.pieces.iter().filter_map(|p| p.tensor))
            .collect();
        operands.sort();
        operands.dedup();
        for &t in &operands {
            if sp.is_resident(t) {
                continue;
            }
            let info = prog.graph.tensor(t);
            let bytes = info.size_bytes();
            let class = match info.kind {
                TensorKind::Weight => TrafficClass::WeightLoad,
                TensorKind::Input => TrafficClass::InputLoad,
                _ => TrafficClass::Reload,
            };
            let next_use = |r: TensorId| liveness.next_use_after(prog, r, pos);
            let (events, admitted) = sp.admit(t, bytes, &next_use);
            record_evictions(
                &mut traffic,
                &mut in_dram,
                &events,
                &mut off_bytes,
                &mut trace,
                pos,
                node.id,
            );
            traffic.add(class, bytes);
            off_bytes += bytes;
            staging_deposit_bytes += bytes; // DMA writes the scratchpad
            if admitted {
                in_dram.remove(&t);
            } else {
                operand_resident = false; // streamed
            }
            if let Some(tr) = trace.as_deref_mut() {
                tr.attr_add(node.id, class, bytes);
                tr.push(TraceEvent::Stage { pos, tensor: t, bytes, class });
            }
        }

        // ---- allocate output ----
        let out = nest.store.tensor;
        let out_info = prog.graph.tensor(out);
        let out_bytes = out_info.size_bytes();
        let next_use = |r: TensorId| liveness.next_use_after(prog, r, pos);
        let (events, out_resident) = sp.admit(out, out_bytes, &next_use);
        record_evictions(
            &mut traffic,
            &mut in_dram,
            &events,
            &mut off_bytes,
            &mut trace,
            pos,
            node.id,
        );

        // ---- execute ----
        let elem = out_info.dtype.size_bytes();
        match &nest.body {
            Body::Copy { .. } => {
                copy_nests += 1;
                let moved = nest.domain.cardinality() * elem;
                let is_remap = matches!(node.kind, OpKind::MemCopy);
                let onchip = operand_resident && out_resident;
                let class = match (onchip, is_remap) {
                    (true, true) => TrafficClass::OnchipRemap,
                    (true, false) => TrafficClass::OnchipCopy,
                    (false, true) => TrafficClass::OffchipRemap,
                    (false, false) => TrafficClass::OffchipCopy,
                };
                // an off-chip copy round-trips DRAM (read + write)
                let bytes = if onchip { moved } else { 2 * moved };
                traffic.add(class, bytes);
                if onchip {
                    on_bytes += bytes;
                } else {
                    off_bytes += bytes;
                }
                if let Some(tr) = trace.as_deref_mut() {
                    tr.attr_add(node.id, class, bytes);
                    tr.push(TraceEvent::MemCopy { pos, node: node.id, bytes, class });
                }
            }
            Body::Compute { .. } => {
                if !out_resident {
                    // result streamed straight to DRAM
                    traffic.add(TrafficClass::Spill, out_bytes);
                    off_bytes += out_bytes;
                    in_dram.insert(out);
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.attr_add(node.id, TrafficClass::Spill, out_bytes);
                        tr.push(TraceEvent::Spill { pos, tensor: out, bytes: out_bytes });
                    }
                }
            }
        }

        // ---- latency ----
        let comp_s = engine::compute_seconds(cfg, nest, &node.kind);
        let dma_s = engine::dma_seconds(cfg, off_bytes, true)
            + engine::dma_seconds(cfg, on_bytes, false);
        if let Some(tr) = trace.as_deref_mut() {
            tr.push_span(Engine::Compute, nest.name.clone(), seconds, comp_s);
            tr.push_span(Engine::Dma, format!("dma:{}", nest.name), seconds, dma_s);
        }
        seconds += engine::step_seconds(comp_s, dma_s);

        // ---- release tensors dead after this step ----
        let dead: Vec<TensorId> = sp
            .residents()
            .map(|(t, _)| *t)
            .filter(|t| liveness.next_use_after(prog, *t, pos).is_none())
            .filter(|t| prog.graph.tensor(*t).kind != TensorKind::Output)
            .collect();
        for t in dead {
            sp.release(t);
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(TraceEvent::Release { pos, tensor: t });
            }
        }
        if let Some(tr) = trace.as_deref_mut() {
            tr.push_occupancy(seconds, sp.used());
        }
    }

    // ---- write model outputs back ----
    for out in prog.graph.outputs() {
        let bytes = prog.graph.tensor(out).size_bytes();
        traffic.add(TrafficClass::OutputStore, bytes);
        let dma = engine::dma_seconds(cfg, bytes, true);
        if let Some(tr) = trace.as_deref_mut() {
            let who = prog.graph.producer(out).map(|n| n.id).unwrap_or(EXTERNAL_NODE);
            tr.attr_add(who, TrafficClass::OutputStore, bytes);
            tr.push_span(Engine::Dma, format!("writeback:{out:?}"), seconds, dma);
        }
        seconds += dma;
    }

    SimReport {
        traffic,
        seconds,
        peak_scratchpad: sp.peak(),
        nests_executed: prog.nests.len(),
        copy_nests_executed: copy_nests,
        staging_deposit_bytes,
    }
}

/// Replay a program against a compile-time [`MemoryPlan`] ("planned
/// mode"). Residency is taken from the plan **verbatim** — the plan is
/// verified first (capacity, region overlap, residency coverage; see
/// [`crate::alloc::verify_plan`]) and replay refuses to start on any
/// violation, instead of improvising residency the way the dynamic
/// replay does.
///
/// Traffic uses the same classes as [`simulate`], charged from the
/// plan:
/// * input/weight scratch windows charge their staging bytes at the
///   window start (re-staged windows charge again, like the dynamic
///   path's reload of an evicted weight — but with **no** spill
///   write-back, since the planner knows those bytes are clean);
/// * DRAM-homed ("streamed") tensors charge a full read per use and a
///   `Spill` write when produced, matching the dynamic path's
///   never-admitted tensors — except that **tile nests charge only the
///   bytes their tile actually touches** (the access-map image of the
///   tile box), the transfer sizing the tiling stage computed, and a
///   slice whose box is identical to the one the same group's previous
///   tile fetched is charged once (it is still in the staging buffer);
/// * tile-staged tensors ([`crate::alloc::Home::Staged`]) never touch
///   DRAM: their tiles are deposited on chip by the producer and read
///   back by the consumer inside the staging region;
/// * copy nests move on-chip when both endpoints are resident; a
///   DRAM-homed destination makes the nest an explicit `Spill` write
///   (that is exactly what the spill planner's `spill.*` nests are).
pub fn simulate_planned(
    prog: &Program,
    plan: &crate::alloc::MemoryPlan,
    cfg: &AccelConfig,
    trace: Option<&mut Trace>,
) -> Result<SimReport, crate::alloc::PlanViolation> {
    replay_planned(prog, plan, cfg, trace, false)
}

/// Planned replay with the **double-buffered pipeline** latency model:
/// identical byte accounting to [`simulate_planned`], but runs of tile
/// nests from one group are scheduled as a software pipeline (prefetch
/// tile *t+1* while computing tile *t*, write back *t−1*) on a DMA
/// queue + compute engine pair ([`engine::pipeline_seconds`]), instead
/// of the per-nest `max(compute, dma)` estimate. Untiled nests keep the
/// coarse overlap model.
pub fn simulate_pipelined(
    prog: &Program,
    plan: &crate::alloc::MemoryPlan,
    cfg: &AccelConfig,
    trace: Option<&mut Trace>,
) -> Result<SimReport, crate::alloc::PlanViolation> {
    replay_planned(prog, plan, cfg, trace, true)
}

fn replay_planned(
    prog: &Program,
    plan: &crate::alloc::MemoryPlan,
    cfg: &AccelConfig,
    mut trace: Option<&mut Trace>,
    pipelined: bool,
) -> Result<SimReport, crate::alloc::PlanViolation> {
    use crate::alloc::Home;
    use crate::tile::footprint::{nest_tensor_box, nest_tensor_bytes};
    use crate::tile::pipeline::{run_steps, tile_runs, NestCost};

    crate::alloc::verify_plan(prog, plan, cfg)?;
    let mut traffic = TrafficCounters::new();
    let mut staging_deposit_bytes = 0i64;
    let mut copy_nests = 0usize;
    let mut costs: Vec<NestCost> = Vec::with_capacity(prog.nests.len());
    // per (tile group, tensor): the slice box the last touching tile
    // fetched — an identical box on the same or the next tile index is
    // still sitting in its staging buffer and is not fetched again
    // (weight-slice reuse across the spatial tiles of one channel
    // block). The plan reserves no named region for such slices; the
    // space is the tile budget's headroom — the sizing search counted
    // every tile-invariant slice at 1× inside `budget_fraction` of the
    // scratchpad, so the retained slice fits by construction even
    // though `peak_scratchpad` (planned regions only) doesn't show it.
    let mut last_box: std::collections::HashMap<(u32, TensorId), (u32, Vec<(i64, i64)>)> =
        std::collections::HashMap::new();
    let node_by_id: std::collections::HashMap<_, _> =
        prog.graph.nodes().iter().map(|n| (n.id, n)).collect();
    // release points for tracing: window end -> tensors
    let mut ends: std::collections::BTreeMap<usize, Vec<TensorId>> = Default::default();
    if trace.is_some() {
        for (t, tp) in &plan.tensors {
            for w in &tp.windows {
                if w.home.region().is_some() {
                    ends.entry(w.end).or_default().push(*t);
                }
            }
        }
    }

    for (pos, nest) in prog.nests.iter().enumerate() {
        let node = node_by_id[&nest.node];
        let mut off_in_bytes = 0i64;
        let mut off_out_bytes = 0i64;
        let mut on_bytes = 0i64;

        // ---- operands: staged at window start, streamed when DRAM ----
        let mut operands: Vec<TensorId> = nest
            .body
            .loads()
            .iter()
            .flat_map(|l| l.pieces.iter().filter_map(|p| p.tensor))
            .collect();
        operands.sort();
        operands.dedup();
        for &t in &operands {
            let info = prog.graph.tensor(t);
            let w = plan.window_at(t, pos).expect("verified residency");
            let staged_class = match info.kind {
                TensorKind::Weight => TrafficClass::WeightLoad,
                TensorKind::Input => TrafficClass::InputLoad,
                _ => TrafficClass::Reload,
            };
            match w.home {
                Home::Scratch(_) => {
                    // intermediates are produced on chip; inputs and
                    // weights pay a staging DMA when the window opens
                    let bytes = info.size_bytes();
                    let staged_here = w.start == pos
                        && matches!(info.kind, TensorKind::Input | TensorKind::Weight);
                    if staged_here {
                        traffic.add(staged_class, bytes);
                        off_in_bytes += bytes;
                        staging_deposit_bytes += bytes;
                        if let Some(tr) = trace.as_deref_mut() {
                            tr.attr_add(node.id, staged_class, bytes);
                            tr.push(TraceEvent::Stage {
                                pos,
                                tensor: t,
                                bytes,
                                class: staged_class,
                            });
                        }
                    }
                }
                Home::Staged(_) => {
                    // tile handoff inside the staging region: the
                    // producer deposited this tile on chip, no DMA
                }
                Home::Dram => {
                    // streamed: a full read per consuming nest — or,
                    // for a tile nest, just the tile's touched bytes,
                    // skipping slices already fetched by the previous
                    // tile of the same group (identical box)
                    let mut bytes = info.size_bytes();
                    let mut reuse = false;
                    if let Some(tag) = nest.tile {
                        match nest_tensor_box(&prog.graph, nest, t) {
                            None => {
                                bytes = 0;
                                reuse = true;
                            }
                            Some((bbox, by)) => {
                                bytes = by;
                                let key = (tag.group, t);
                                if let Some((pidx, pbox)) = last_box.get(&key) {
                                    if *pbox == bbox
                                        && (tag.index == *pidx || tag.index == *pidx + 1)
                                    {
                                        reuse = true;
                                    }
                                }
                                last_box.insert(key, (tag.index, bbox));
                            }
                        }
                    }
                    if !reuse {
                        traffic.add(staged_class, bytes);
                        off_in_bytes += bytes;
                        staging_deposit_bytes += bytes;
                        if let Some(tr) = trace.as_deref_mut() {
                            tr.attr_add(node.id, staged_class, bytes);
                            tr.push(TraceEvent::Stage {
                                pos,
                                tensor: t,
                                bytes,
                                class: staged_class,
                            });
                        }
                    }
                }
            }
        }
        // ---- output ----
        let out = nest.store.tensor;
        let out_info = prog.graph.tensor(out);
        let out_resident = plan
            .window_at(out, pos)
            .expect("verified")
            .home
            .on_chip();

        // ---- execute ----
        let elem = out_info.dtype.size_bytes();
        match &nest.body {
            Body::Copy { .. } => {
                copy_nests += 1;
                let moved = nest.domain.cardinality() * elem;
                let is_remap = matches!(node.kind, OpKind::MemCopy);
                if out_resident {
                    // on-chip deposit (streamed sources were charged above)
                    let class = if is_remap {
                        TrafficClass::OnchipRemap
                    } else {
                        TrafficClass::OnchipCopy
                    };
                    traffic.add(class, moved);
                    on_bytes += moved;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.attr_add(node.id, class, moved);
                        tr.push(TraceEvent::MemCopy { pos, node: node.id, bytes: moved, class });
                    }
                } else {
                    // explicit spill write (or streamed copy result)
                    traffic.add(TrafficClass::Spill, moved);
                    off_out_bytes += moved;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.attr_add(node.id, TrafficClass::Spill, moved);
                        tr.push(TraceEvent::Spill { pos, tensor: out, bytes: moved });
                    }
                }
            }
            Body::Compute { .. } => {
                if !out_resident {
                    let bytes = if nest.tile.is_some() {
                        nest_tensor_bytes(&prog.graph, nest, out)
                    } else {
                        out_info.size_bytes()
                    };
                    traffic.add(TrafficClass::Spill, bytes);
                    off_out_bytes += bytes;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.attr_add(node.id, TrafficClass::Spill, bytes);
                        tr.push(TraceEvent::Spill { pos, tensor: out, bytes });
                    }
                }
            }
        }

        costs.push(NestCost {
            compute: engine::compute_seconds(cfg, nest, &node.kind),
            dma_in: engine::dma_seconds(cfg, off_in_bytes, true)
                + engine::dma_seconds(cfg, on_bytes, false),
            dma_out: engine::dma_seconds(cfg, off_out_bytes, true),
        });

        if let Some(tr) = trace.as_deref_mut() {
            for t in ends.get(&pos).into_iter().flatten() {
                tr.push(TraceEvent::Release { pos, tensor: *t });
            }
        }
    }

    // ---- latency (+ engine timeline when traced) ----
    let mut seconds = 0.0f64;
    if pipelined {
        for run in tile_runs(prog) {
            if prog.nests[run.0].tile.is_some() {
                let steps = run_steps(prog, run, &costs);
                push_run_timeline(prog, plan, run, &steps, seconds, &mut trace);
                seconds += engine::pipeline_seconds(&steps);
            } else {
                let c = costs[run.0];
                if let Some(tr) = trace.as_deref_mut() {
                    let name = &prog.nests[run.0].name;
                    tr.push_span(Engine::Compute, name.clone(), seconds, c.compute);
                    tr.push_span(Engine::Dma, format!("dma:{name}"), seconds, c.dma_in + c.dma_out);
                    tr.push_occupancy(seconds, plan.occupied_bytes_at(run.0));
                }
                seconds += engine::step_seconds(c.compute, c.dma_in + c.dma_out);
            }
        }
    } else {
        for (pos, c) in costs.iter().enumerate() {
            if let Some(tr) = trace.as_deref_mut() {
                let name = &prog.nests[pos].name;
                tr.push_span(Engine::Compute, name.clone(), seconds, c.compute);
                tr.push_span(Engine::Dma, format!("dma:{name}"), seconds, c.dma_in + c.dma_out);
                tr.push_occupancy(seconds, plan.occupied_bytes_at(pos));
            }
            seconds += engine::step_seconds(c.compute, c.dma_in + c.dma_out);
        }
    }

    // ---- write model outputs back (same as the dynamic replay) ----
    for out in prog.graph.outputs() {
        let bytes = prog.graph.tensor(out).size_bytes();
        traffic.add(TrafficClass::OutputStore, bytes);
        let dma = engine::dma_seconds(cfg, bytes, true);
        if let Some(tr) = trace.as_deref_mut() {
            let who = prog.graph.producer(out).map(|n| n.id).unwrap_or(EXTERNAL_NODE);
            tr.attr_add(who, TrafficClass::OutputStore, bytes);
            tr.push_span(Engine::Dma, format!("writeback:{out:?}"), seconds, dma);
        }
        seconds += dma;
    }

    Ok(SimReport {
        traffic,
        seconds,
        peak_scratchpad: plan.peak_scratchpad_bytes(),
        nests_executed: prog.nests.len(),
        copy_nests_executed: copy_nests,
        staging_deposit_bytes,
    })
}

/// Eviction write-backs, attributed to the node whose staging forced
/// them (`node`).
fn record_evictions(
    traffic: &mut TrafficCounters,
    in_dram: &mut HashSet<TensorId>,
    events: &[EvictEvent],
    off_bytes: &mut i64,
    trace: &mut Option<&mut Trace>,
    pos: usize,
    node: NodeId,
) {
    for ev in events {
        if let EvictEvent::Spilled { tensor, bytes } = ev {
            traffic.add(TrafficClass::Spill, *bytes);
            *off_bytes += bytes;
            in_dram.insert(*tensor);
            if let Some(tr) = trace.as_deref_mut() {
                tr.attr_add(node, TrafficClass::Spill, *bytes);
                tr.push(TraceEvent::Spill { pos, tensor: *tensor, bytes: *bytes });
            }
        }
    }
}

/// Engine timeline of one double-buffered tile run: per-step prefetch
/// / compute / write-back intervals from
/// [`engine::pipeline_intervals`], offset by the run's start time,
/// plus one occupancy sample per nest at its step's compute start.
/// Step labels mirror [`crate::tile::pipeline::run_steps`]' folding:
/// one label per tile index (`g<group>.t<index>`), fused chain members
/// sharing it.
fn push_run_timeline(
    prog: &Program,
    plan: &crate::alloc::MemoryPlan,
    run: (usize, usize),
    steps: &[engine::PipeStep],
    base: f64,
    trace: &mut Option<&mut Trace>,
) {
    let Some(tr) = trace.as_deref_mut() else { return };
    let intervals = engine::pipeline_intervals(steps);
    // map each nest position of the run to its merged pipeline step
    let mut step_of_pos: Vec<usize> = Vec::with_capacity(run.1 - run.0 + 1);
    let mut labels: Vec<String> = Vec::new();
    let mut last_index: Option<u32> = None;
    for pos in run.0..=run.1 {
        let tag = prog.nests[pos].tile.expect("tile run");
        if last_index != Some(tag.index) {
            labels.push(format!("g{}.t{}", tag.group, tag.index));
            last_index = Some(tag.index);
        }
        step_of_pos.push(labels.len() - 1);
    }
    debug_assert_eq!(labels.len(), intervals.len());
    for (k, iv) in intervals.iter().enumerate() {
        let label = &labels[k];
        tr.push_span(
            Engine::Dma,
            format!("prefetch:{label}"),
            base + iv.in_start,
            iv.in_done - iv.in_start,
        );
        tr.push_span(
            Engine::Compute,
            label.clone(),
            base + iv.comp_start,
            iv.comp_done - iv.comp_start,
        );
        tr.push_span(
            Engine::Dma,
            format!("writeback:{label}"),
            base + iv.out_start,
            iv.out_done - iv.out_start,
        );
    }
    for (off, &k) in step_of_pos.iter().enumerate() {
        let pos = run.0 + off;
        tr.push_occupancy(base + intervals[k].comp_start, plan.occupied_bytes_at(pos));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::loopnest::Program;

    fn run(g: crate::ir::Graph, cfg: &AccelConfig) -> SimReport {
        simulate(&Program::lower(g), cfg, None)
    }

    #[test]
    fn compulsory_traffic_only() {
        // relu(x): input staged in, output written back — no copies.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 8, 8, 8]);
        let r = b.relu("r", x);
        b.mark_output(r);
        let rep = run(b.finish(), &AccelConfig::inferentia_like());
        let bytes = 8 * 8 * 8 * 4;
        assert_eq!(rep.traffic.get(TrafficClass::InputLoad), bytes);
        assert_eq!(rep.traffic.get(TrafficClass::OutputStore), bytes);
        assert_eq!(rep.onchip_copy_total(), 0);
        assert_eq!(rep.offchip_copy_total(), 0);
        assert!(rep.seconds > 0.0);
    }

    #[test]
    fn copy_nest_counts_onchip_when_resident() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t = b.transpose("t", x, &[1, 0]);
        let r = b.relu("r", t);
        b.mark_output(r);
        let rep = run(b.finish(), &AccelConfig::inferentia_like());
        assert_eq!(rep.traffic.get(TrafficClass::OnchipCopy), 32 * 32 * 4);
        assert_eq!(rep.traffic.get(TrafficClass::OffchipCopy), 0);
        assert_eq!(rep.copy_nests_executed, 1);
    }

    #[test]
    fn copy_nest_spills_when_too_big() {
        // scratchpad of 1 KiB, tensors of 4 KiB: copies round-trip DRAM
        let cfg = AccelConfig::tiny(1024);
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t = b.transpose("t", x, &[1, 0]);
        let r = b.relu("r", t);
        b.mark_output(r);
        let rep = run(b.finish(), &cfg);
        assert_eq!(rep.traffic.get(TrafficClass::OnchipCopy), 0);
        assert_eq!(rep.traffic.get(TrafficClass::OffchipCopy), 2 * 32 * 32 * 4);
    }

    #[test]
    fn memcopy_classified_as_remap() {
        use crate::passes::manager::{BankMode, PassManager};
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 16, 8, 8]);
        let w1 = b.weight("w1", &[16, 16, 3, 3]);
        let c1 = b.conv2d("c1", x, w1, 1, 1);
        let r = b.relu("r", c1);
        let w2 = b.weight("w2", &[16, 16, 3, 3]);
        let c2 = b.conv2d("c2", r, w2, 1, 1);
        b.mark_output(c2);
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        let report = pm.run(b.finish()).unwrap();
        let rep = simulate(&report.program, &AccelConfig::inferentia_like(), None);
        assert_eq!(rep.traffic.get(TrafficClass::OnchipRemap), 16 * 8 * 8 * 4);
    }

    #[test]
    fn dme_reduces_traffic() {
        use crate::passes::dme::run_dme;
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]);
        let mut cur = x;
        for k in 0..4 {
            cur = b.transpose(&format!("t{k}"), cur, &[1, 0]);
        }
        let y = b.relu("y", cur);
        b.mark_output(y);
        let g = b.finish();
        let cfg = AccelConfig::inferentia_like();
        let before = simulate(&Program::lower(g.clone()), &cfg, None);
        let mut prog = Program::lower(g);
        run_dme(&mut prog);
        let after = simulate(&prog, &cfg, None);
        assert!(after.onchip_movement_total() < before.onchip_movement_total());
        assert_eq!(after.onchip_copy_total(), 0);
        assert_eq!(before.onchip_copy_total(), 4 * 64 * 64 * 4);
        // compulsory traffic unchanged
        assert_eq!(
            after.traffic.get(TrafficClass::InputLoad),
            before.traffic.get(TrafficClass::InputLoad)
        );
    }

    #[test]
    fn spill_and_reload_under_pressure() {
        // capacity holds only one 6.4 KB tensor at a time, but x is
        // needed again at the end: it must spill and reload.
        let cfg = AccelConfig::tiny(8 * 1024); // 8 KiB
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[40, 40]); // 6.4 KB
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", t1, &[1, 0]);
        let a = b.add("a", t2, x); // x live across the whole chain
        b.mark_output(a);
        let rep = run(b.finish(), &cfg);
        assert!(rep.traffic.get(TrafficClass::Spill) > 0, "{:?}", rep.traffic);
        assert!(rep.traffic.get(TrafficClass::Reload) > 0, "{:?}", rep.traffic);
    }

    #[test]
    fn planned_matches_dynamic_when_roomy() {
        use crate::alloc::{plan_memory, AllocOpts};
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t = b.transpose("t", x, &[1, 0]);
        let r = b.relu("r", t);
        b.mark_output(r);
        let cfg = AccelConfig::inferentia_like();
        let res = plan_memory(Program::lower(b.finish()), None, &cfg, &AllocOpts::default())
            .unwrap();
        let dynamic = simulate(&res.program, &cfg, None);
        let planned = simulate_planned(&res.program, &res.plan, &cfg, None).unwrap();
        // with no capacity pressure the two accountings agree exactly
        assert_eq!(planned.offchip_total(), dynamic.offchip_total());
        assert_eq!(planned.onchip_copy_total(), dynamic.onchip_copy_total());
        assert_eq!(
            planned.onchip_movement_total(),
            dynamic.onchip_movement_total()
        );
        assert_eq!(planned.nests_executed, dynamic.nests_executed);
    }

    #[test]
    fn planned_spills_are_explicit_and_bounded() {
        use crate::alloc::{plan_memory, AllocOpts};
        // fan-out graph under a one-slice-per-bank configuration: the
        // planner must spill, and the planned replay must verify
        let mut cfg = AccelConfig::tiny(8 * 1024);
        cfg.bank_bytes = crate::alloc::offsets::per_bank_bytes(32 * 32 * 4, cfg.banks);
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[32, 32]);
        let t1 = b.transpose("t1", x, &[1, 0]);
        let t2 = b.transpose("t2", x, &[1, 0]);
        let t3 = b.transpose("t3", x, &[1, 0]);
        let c = b.concat("c", &[t1, t2, t3], 0);
        b.mark_output(c);
        let res = plan_memory(Program::lower(b.finish()), None, &cfg, &AllocOpts::default())
            .unwrap();
        let planned = simulate_planned(&res.program, &res.plan, &cfg, None).unwrap();
        assert!(res.plan.stats.spill_pairs >= 1);
        assert!(planned.traffic.get(TrafficClass::Spill) > 0);
        assert!(planned.peak_scratchpad <= cfg.scratchpad_bytes());
    }

    #[test]
    fn planned_rejects_corrupt_plan() {
        use crate::alloc::{plan_memory, AllocOpts};
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let cfg = AccelConfig::inferentia_like();
        let mut res =
            plan_memory(Program::lower(b.finish()), None, &cfg, &AllocOpts::default()).unwrap();
        res.plan.tensors.remove(&x);
        assert!(simulate_planned(&res.program, &res.plan, &cfg, None).is_err());
    }

    #[test]
    fn tiled_staging_cuts_offchip_vs_untiled_plan() {
        use crate::passes::manager::{AllocStage, PassManager, TileStage};
        // an elementwise chain whose tensors each fill the whole
        // scratchpad: untiled planning must stream both intermediates
        // through DRAM (a spill write plus a re-read each); tiling
        // fuses the chain and stages them on chip tile by tile, so only
        // the compulsory input reads and output writes remain
        let build = || {
            let mut b = GraphBuilder::new();
            let x = b.input("x", &[32, 32]);
            let y = b.input("y", &[32, 32]);
            let a = b.add("a", x, y);
            let r = b.relu("r", a);
            let s = b.sigmoid("s", r);
            b.mark_output(s);
            b.finish()
        };
        let cfg = AccelConfig::tiny(4 * 1024);
        let untiled = PassManager {
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let urep = untiled.run(build()).unwrap();
        let usim =
            simulate_planned(&urep.program, urep.plan.as_ref().unwrap(), &cfg, None).unwrap();

        let tiled = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let trep = tiled.run(build()).unwrap();
        let plan = trep.plan.as_ref().unwrap();
        assert!(plan.stats.tile_staged >= 1, "{:?}", plan.stats);
        let tsim = simulate_pipelined(&trep.program, plan, &cfg, None).unwrap();
        assert!(
            tsim.offchip_total() < usim.offchip_total(),
            "tiled off-chip {} not below untiled {}",
            tsim.offchip_total(),
            usim.offchip_total()
        );
        // byte accounting is latency-model independent
        let tplanned = simulate_planned(&trep.program, plan, &cfg, None).unwrap();
        assert_eq!(tplanned.traffic, tsim.traffic);
        assert!(tsim.seconds > 0.0);
        assert!(tsim.peak_scratchpad <= cfg.scratchpad_bytes());
    }

    #[test]
    fn peak_scratchpad_bounded() {
        let cfg = AccelConfig::inferentia_like();
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 64]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let rep = run(b.finish(), &cfg);
        assert!(rep.peak_scratchpad <= cfg.scratchpad_bytes());
        assert_eq!(rep.peak_scratchpad, 2 * 64 * 64 * 4);
    }
}
