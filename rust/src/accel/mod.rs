//! Simulated Inferentia-class accelerator.
//!
//! The paper measures its two optimizations in **bytes of on-chip and
//! off-chip memory copies** on Inferentia silicon. That metric is a
//! property of the compiled schedule, not of the silicon, so this
//! module replays a lowered [`crate::ir::Program`] against a byte-exact
//! traffic model:
//!
//! * [`config`] — chip parameters (banked scratchpad geometry, PE
//!   array, DRAM bandwidth, clock);
//! * [`scratchpad`] — software-managed residency with
//!   furthest-next-use eviction (what the real chip's compiler-managed
//!   scratchpad allocator approximates);
//! * [`dma`] — traffic counters by cause (weights, inputs, outputs,
//!   spills, reloads, copy nests, bank remaps);
//! * [`engine`] — a coarse cycle model (systolic array compute vs DMA
//!   overlap) for end-to-end latency estimates;
//! * [`sim`] — the schedule replayer producing a [`sim::SimReport`];
//! * [`trace`] — optional telemetry side-channels: the bounded event
//!   log, per-node × per-class byte attribution (conserved against the
//!   traffic counters), engine timelines and scratchpad occupancy,
//!   exportable as Chrome trace-event JSON.

pub mod config;
pub mod dma;
pub mod engine;
pub mod scratchpad;
pub mod sim;
pub mod trace;

pub use config::AccelConfig;
pub use dma::{TrafficClass, TrafficCounters};
pub use sim::{simulate, simulate_pipelined, simulate_planned, SimReport};
pub use trace::{Attribution, Trace, TraceEvent};
