//! DMA traffic accounting — the measurement the paper's evaluation is
//! built on ("measured in bytes").

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Why bytes moved. Off-chip classes transit DRAM; on-chip classes stay
/// inside the scratchpad.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum TrafficClass {
    // ---- off-chip (DRAM) ----
    /// Weights staged from DRAM.
    WeightLoad,
    /// Model inputs staged from DRAM/host.
    InputLoad,
    /// Model outputs written back.
    OutputStore,
    /// Live intermediate evicted under pressure.
    Spill,
    /// Previously spilled intermediate staged back.
    Reload,
    /// Copy nest executed through DRAM (operands not resident).
    OffchipCopy,
    /// Inter-bank remap that had to round-trip DRAM.
    OffchipRemap,
    // ---- on-chip (scratchpad) ----
    /// Copy nest executed bank-local (memory-bound operator).
    OnchipCopy,
    /// Inter-bank remap inside the scratchpad (`MemCopy` node).
    OnchipRemap,
    // ---- core-to-core fabric ----
    /// Cut-edge tensor shipped between pipeline stages over the
    /// inter-core fabric (charged once per stage boundary crossed).
    /// Neither DRAM nor scratchpad traffic: it rides its own
    /// `intercore_bps` link, so it joins neither the off-chip nor the
    /// on-chip total.
    InterCore,
}

impl TrafficClass {
    pub fn is_offchip(self) -> bool {
        !matches!(
            self,
            TrafficClass::OnchipCopy | TrafficClass::OnchipRemap | TrafficClass::InterCore
        )
    }

    pub fn label(self) -> &'static str {
        match self {
            TrafficClass::WeightLoad => "weight_load",
            TrafficClass::InputLoad => "input_load",
            TrafficClass::OutputStore => "output_store",
            TrafficClass::Spill => "spill",
            TrafficClass::Reload => "reload",
            TrafficClass::OffchipCopy => "offchip_copy",
            TrafficClass::OffchipRemap => "offchip_remap",
            TrafficClass::OnchipCopy => "onchip_copy",
            TrafficClass::OnchipRemap => "onchip_remap",
            TrafficClass::InterCore => "intercore",
        }
    }

    pub const ALL: [TrafficClass; 10] = [
        TrafficClass::WeightLoad,
        TrafficClass::InputLoad,
        TrafficClass::OutputStore,
        TrafficClass::Spill,
        TrafficClass::Reload,
        TrafficClass::OffchipCopy,
        TrafficClass::OffchipRemap,
        TrafficClass::OnchipCopy,
        TrafficClass::OnchipRemap,
        TrafficClass::InterCore,
    ];
}

/// Byte counters by class.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TrafficCounters {
    counts: BTreeMap<TrafficClass, i64>,
}

impl TrafficCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, class: TrafficClass, bytes: i64) {
        debug_assert!(bytes >= 0);
        *self.counts.entry(class).or_insert(0) += bytes;
    }

    pub fn get(&self, class: TrafficClass) -> i64 {
        self.counts.get(&class).copied().unwrap_or(0)
    }

    /// Total bytes over DRAM.
    pub fn offchip_total(&self) -> i64 {
        self.counts
            .iter()
            .filter(|(c, _)| c.is_offchip())
            .map(|(_, v)| v)
            .sum()
    }

    /// Total bytes moved inside the scratchpad by copies/remaps.
    /// Explicitly the two scratchpad classes — inter-core fabric bytes
    /// are a third bucket, not on-chip movement.
    pub fn onchip_total(&self) -> i64 {
        self.get(TrafficClass::OnchipCopy) + self.get(TrafficClass::OnchipRemap)
    }

    /// Total bytes over the core-to-core fabric (pipeline cut edges).
    pub fn intercore_total(&self) -> i64 {
        self.get(TrafficClass::InterCore)
    }

    /// Off-chip bytes attributable to *copies* (the paper's "off-chip
    /// memory copies"): copy nests, remaps, and the spill/reload churn
    /// they cause — as opposed to compulsory weight/input/output moves.
    pub fn offchip_copy_total(&self) -> i64 {
        self.get(TrafficClass::OffchipCopy)
            + self.get(TrafficClass::OffchipRemap)
            + self.get(TrafficClass::Spill)
            + self.get(TrafficClass::Reload)
    }

    pub fn merged(&self, other: &TrafficCounters) -> TrafficCounters {
        let mut out = self.clone();
        for (c, v) in &other.counts {
            out.add(*c, *v);
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = TrafficClass::ALL
            .iter()
            .map(|c| (c.label(), Json::Int(self.get(*c))))
            .collect();
        pairs.push(("offchip_total", Json::Int(self.offchip_total())));
        pairs.push(("onchip_total", Json::Int(self.onchip_total())));
        pairs.push(("intercore_total", Json::Int(self.intercore_total())));
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_partition() {
        let mut t = TrafficCounters::new();
        t.add(TrafficClass::WeightLoad, 100);
        t.add(TrafficClass::OnchipCopy, 40);
        t.add(TrafficClass::OnchipRemap, 2);
        t.add(TrafficClass::Spill, 10);
        t.add(TrafficClass::InterCore, 7);
        assert_eq!(t.offchip_total(), 110);
        assert_eq!(t.onchip_total(), 42);
        assert_eq!(t.intercore_total(), 7);
        assert_eq!(t.offchip_copy_total(), 10);
        assert_eq!(t.get(TrafficClass::Reload), 0);
        // the three totals partition every charged byte
        assert!(!TrafficClass::InterCore.is_offchip());
        assert_eq!(
            t.offchip_total() + t.onchip_total() + t.intercore_total(),
            110 + 42 + 7
        );
    }

    #[test]
    fn merge_adds() {
        let mut a = TrafficCounters::new();
        a.add(TrafficClass::InputLoad, 5);
        let mut b = TrafficCounters::new();
        b.add(TrafficClass::InputLoad, 7);
        b.add(TrafficClass::OnchipCopy, 1);
        let m = a.merged(&b);
        assert_eq!(m.get(TrafficClass::InputLoad), 12);
        assert_eq!(m.onchip_total(), 1);
    }

    #[test]
    fn json_has_all_classes() {
        let t = TrafficCounters::new();
        let j = t.to_json();
        for c in TrafficClass::ALL {
            assert!(j.get(c.label()).is_some(), "missing {}", c.label());
        }
    }
}
