//! Closed-loop / open-loop load simulation over planned service times.
//!
//! A deterministic discrete-event simulation of the serving pipeline:
//! arrivals (Poisson open loop, or a fixed client population closed
//! loop) enter a bounded queue; a single worker flushes batches under
//! the size/deadline policy, sizing each flush with the same
//! [`choose_bucket`] the live server uses; each flush occupies the
//! worker for its bucket's predicted pipelined service time and
//! charges the bucket's predicted off-chip bytes. Time is virtual
//! (u64 nanoseconds), so runs are exactly reproducible and complete in
//! microseconds of wall clock regardless of the simulated load.
//!
//! This is how `bench_serving` compares bucket sets at *equal offered
//! load*: the same seed produces the identical arrival sequence for
//! every policy under test.

use crate::coordinator::{choose_bucket, BucketCost};
use crate::obs::span::{FlightRecorder, SpanPhase};
use crate::obs::LogHistogram;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::time::Duration;

/// Arrival process.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// Open loop: Poisson arrivals at `rate_qps` until `requests` have
    /// arrived. Arrivals beyond `queue_cap` are rejected (backpressure).
    Poisson { rate_qps: f64, requests: usize, seed: u64 },
    /// Closed loop: `clients` concurrent callers, each resubmitting
    /// the instant its previous request completes, until `requests`
    /// total have been issued. Measures sustained saturation QPS.
    Closed { clients: usize, requests: usize },
}

/// Latency service-level objective for a load-sim run.
#[derive(Clone, Copy, Debug)]
pub struct SloSpec {
    /// End-to-end latency objective per request.
    pub latency: Duration,
    /// Target attainment fraction (e.g. 0.99 = 99% of requests within
    /// the objective).
    pub target: f64,
}

/// SLO outcome of one run: attainment against the objective and how
/// fast the run burned its error budget. Rejected requests count as
/// misses — shedding load is an SLO violation from the caller's view.
#[derive(Clone, Copy, Debug)]
pub struct SloReport {
    pub objective_us: u64,
    pub target: f64,
    /// Requests completed within the objective.
    pub met: u64,
    /// Late completions plus rejections.
    pub missed: u64,
    /// `met / (met + missed)`.
    pub attainment: f64,
    /// Error-budget burn rate: observed miss rate over the allowed
    /// miss rate `1 − target`. 1.0 = exactly on budget; above 1 the
    /// budget is burning faster than the objective allows.
    pub error_budget_burn: f64,
}

impl SloReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("objective_us", Json::Int(self.objective_us as i64)),
            ("target", Json::Num(self.target)),
            ("met", Json::Int(self.met as i64)),
            ("missed", Json::Int(self.missed as i64)),
            ("attainment", Json::Num(self.attainment)),
            ("error_budget_burn", Json::Num(self.error_budget_burn)),
        ])
    }
}

/// Load-simulation parameters (mirrors `ServerConfig`).
#[derive(Clone, Copy, Debug)]
pub struct LoadSimConfig {
    pub arrivals: Arrivals,
    /// Flush deadline for the oldest queued request.
    pub max_wait: Duration,
    /// Queue bound; arrivals beyond it are rejected.
    pub queue_cap: usize,
    /// Latency objective to score the run against (optional).
    pub slo: Option<SloSpec>,
}

/// What one simulated run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub label: String,
    /// The bucket set the flush policy chose from.
    pub buckets: Vec<usize>,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    /// Virtual time from first arrival to last completion.
    pub makespan_seconds: f64,
    /// Sustained throughput: completed / makespan.
    pub qps: f64,
    /// End-to-end request latency (queue wait + service), microseconds.
    pub latency_us: LogHistogram,
    /// Total predicted off-chip DRAM bytes charged by executed batches.
    pub offchip_bytes: i64,
    /// Amortized off-chip bytes per completed request.
    pub bytes_per_request: f64,
    pub mean_batch: f64,
    /// Flush count per chosen bucket batch size.
    pub flushes_by_bucket: BTreeMap<usize, u64>,
    /// SLO scoring, when the config set an objective.
    pub slo: Option<SloReport>,
}

impl LoadReport {
    pub fn p50(&self) -> Duration {
        Duration::from_micros(self.latency_us.percentile(0.50))
    }

    pub fn p99(&self) -> Duration {
        Duration::from_micros(self.latency_us.percentile(0.99))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            (
                "buckets",
                Json::Arr(self.buckets.iter().map(|&b| Json::Int(b as i64)).collect()),
            ),
            ("submitted", Json::Int(self.submitted as i64)),
            ("completed", Json::Int(self.completed as i64)),
            ("rejected", Json::Int(self.rejected as i64)),
            ("batches", Json::Int(self.batches as i64)),
            ("makespan_seconds", Json::Num(self.makespan_seconds)),
            ("qps", Json::Num(self.qps)),
            ("p50_latency_us", Json::Int(self.latency_us.percentile(0.50) as i64)),
            ("p99_latency_us", Json::Int(self.latency_us.percentile(0.99) as i64)),
            ("mean_latency_us", Json::Num(self.latency_us.mean())),
            ("offchip_bytes", Json::Int(self.offchip_bytes)),
            ("bytes_per_request", Json::Num(self.bytes_per_request)),
            ("mean_batch", Json::Num(self.mean_batch)),
            (
                "flushes_by_bucket",
                Json::Obj(
                    self.flushes_by_bucket
                        .iter()
                        .map(|(&b, &n)| (format!("b{b}"), Json::Int(n as i64)))
                        .collect(),
                ),
            ),
        ]);
        if let (Json::Obj(pairs), Some(slo)) = (&mut j, &self.slo) {
            pairs.insert("slo".to_string(), slo.to_json());
        }
        j
    }
}

const NS: f64 = 1e9;

/// Run one load simulation over a bucket cost table. A single-bucket
/// table reproduces the fixed `max_batch` baseline; a multi-bucket
/// table is cost-aware bucketized batching.
pub fn run_load(costs: &[BucketCost], cfg: &LoadSimConfig, label: &str) -> LoadReport {
    run_load_traced(costs, cfg, label, None)
}

/// [`run_load`] with an optional flight recorder: every admitted
/// request records the same six-phase span chain the live server does,
/// stamped with *virtual* nanoseconds, so a simulated run exports to
/// the identical Chrome trace format as a live `Server`.
pub fn run_load_traced(
    costs: &[BucketCost],
    cfg: &LoadSimConfig,
    label: &str,
    recorder: Option<&FlightRecorder>,
) -> LoadReport {
    assert!(!costs.is_empty(), "load sim needs at least one bucket");
    let rec = |span: u64, phase: SpanPhase, s: u64, e: u64, v: i64| {
        if let Some(r) = recorder {
            r.record_phase(span, phase, s, e, v);
        }
    };
    let max_bucket = costs.iter().map(|c| c.batch).max().unwrap_or(1).max(1);
    let max_wait_ns = cfg.max_wait.as_nanos() as u64;

    // future arrival times (ns); closed-loop refills on completion
    let mut arrivals: BinaryHeap<Reverse<u64>> = BinaryHeap::new();
    let (total_requests, mut issued) = match cfg.arrivals {
        Arrivals::Poisson { rate_qps, requests, seed } => {
            assert!(rate_qps > 0.0, "Poisson rate must be positive");
            let mut rng = SplitMix64::new(seed);
            let mut t = 0.0f64;
            for _ in 0..requests {
                // exponential inter-arrival via inverse transform
                let u = rng.next_f64().max(1e-12);
                t += -u.ln() / rate_qps;
                arrivals.push(Reverse((t * NS) as u64));
            }
            (requests, requests)
        }
        Arrivals::Closed { clients, requests } => {
            let initial = if clients < 1 { 1 } else { clients }.min(requests);
            for _ in 0..initial {
                arrivals.push(Reverse(0));
            }
            (requests, initial)
        }
    };
    let closed = matches!(cfg.arrivals, Arrivals::Closed { .. });

    // queued requests: (enqueue time ns, span id)
    let mut queue: VecDeque<(u64, u64)> = VecDeque::new();
    let mut now = 0u64;
    let mut submitted = 0u64;
    let mut completed = 0u64;
    let mut rejected = 0u64;
    let mut batches = 0u64;
    let mut offchip: i64 = 0;
    let mut batch_size_sum = 0u64;
    let mut last_completion = 0u64;
    let mut latency_us = LogHistogram::new();
    let mut flushes_by_bucket: BTreeMap<usize, u64> = BTreeMap::new();
    let (mut slo_met, mut slo_missed) = (0u64, 0u64);
    let objective_ns = cfg.slo.map(|s| s.latency.as_nanos() as u64);

    loop {
        // admit every arrival due by `now`
        while let Some(&Reverse(t)) = arrivals.peek() {
            if t > now {
                break;
            }
            arrivals.pop();
            submitted += 1;
            if queue.len() < cfg.queue_cap {
                // rejected arrivals allocate no span — matches the
                // live server, where backpressure precedes span birth
                let span = recorder.map(|r| r.next_span_id()).unwrap_or(0);
                rec(span, SpanPhase::Submit, t, t, 0);
                queue.push_back((t, span));
            } else {
                rejected += 1;
                if objective_ns.is_some() {
                    slo_missed += 1; // shed load misses the SLO
                }
            }
        }
        let Some(&(oldest, _)) = queue.front() else {
            // idle: jump to the next arrival, or finish
            match arrivals.peek() {
                Some(&Reverse(t)) => {
                    now = t;
                    continue;
                }
                None => break,
            }
        };
        let deadline = oldest + max_wait_ns;
        if queue.len() < max_bucket && now < deadline {
            // wait for the batch to fill or the deadline to pass
            let next_arrival = arrivals.peek().map(|&Reverse(t)| t).unwrap_or(u64::MAX);
            now = deadline.min(next_arrival);
            continue;
        }
        // flush: cost-aware bucket choice, then the worker is busy for
        // the bucket's predicted pipelined service time
        let (take, bucket) =
            choose_bucket(queue.len(), costs).expect("non-empty queue and table");
        let done = now + (bucket.service_seconds * NS) as u64;
        for _ in 0..take {
            let (enq, span) = queue.pop_front().expect("take <= queue.len()");
            rec(span, SpanPhase::Enqueue, enq, now, 0);
            rec(span, SpanPhase::BucketChoice, now, now, bucket.batch as i64);
            rec(span, SpanPhase::Flush, now, now, take as i64);
            rec(span, SpanPhase::Replay, now, done, take as i64);
            rec(span, SpanPhase::Respond, done, done, 0);
            let lat_ns = done - enq;
            latency_us.record(lat_ns / 1_000);
            if let Some(obj) = objective_ns {
                if lat_ns <= obj {
                    slo_met += 1;
                } else {
                    slo_missed += 1;
                }
            }
            completed += 1;
            if closed && issued < total_requests {
                // this client immediately submits its next request
                arrivals.push(Reverse(done));
                issued += 1;
            }
        }
        batches += 1;
        batch_size_sum += take as u64;
        *flushes_by_bucket.entry(bucket.batch).or_insert(0) += 1;
        offchip += bucket.offchip_bytes;
        last_completion = done;
        now = done;
    }

    let makespan = (last_completion as f64 / NS).max(1e-12);
    let mut buckets: Vec<usize> = costs.iter().map(|c| c.batch).collect();
    buckets.sort_unstable();
    LoadReport {
        label: label.to_string(),
        buckets,
        submitted,
        completed,
        rejected,
        batches,
        makespan_seconds: makespan,
        qps: completed as f64 / makespan,
        latency_us,
        offchip_bytes: offchip,
        bytes_per_request: if completed > 0 {
            offchip as f64 / completed as f64
        } else {
            0.0
        },
        mean_batch: if batches > 0 {
            batch_size_sum as f64 / batches as f64
        } else {
            0.0
        },
        flushes_by_bucket,
        slo: cfg.slo.map(|spec| {
            let eligible = slo_met + slo_missed;
            let attainment = if eligible > 0 {
                slo_met as f64 / eligible as f64
            } else {
                1.0
            };
            let miss_rate = 1.0 - attainment;
            SloReport {
                objective_us: spec.latency.as_micros() as u64,
                target: spec.target,
                met: slo_met,
                missed: slo_missed,
                attainment,
                error_budget_burn: miss_rate / (1.0 - spec.target).max(1e-9),
            }
        }),
    }
}

/// Per-bucket service model under pipelined (possibly sharded)
/// execution: one flush occupies the engine front for
/// `interval_seconds` (the steady-state admission period) while its
/// requests wait `cost.service_seconds` end to end (the pipeline
/// latency). An unsharded engine has the two equal; a sharded pipeline
/// has `interval <= service`, which is exactly where its extra
/// throughput comes from.
#[derive(Clone, Copy, Debug)]
pub struct PipelinedBucket {
    pub cost: BucketCost,
    pub interval_seconds: f64,
}

/// Per-core placement of one model on a multi-core chip.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Independent single-core replicas, each flushing its own
    /// batches (no fabric traffic).
    Replicas(usize),
    /// One pipeline sharded across all cores.
    Sharded,
}

/// The amortized-cost placement rule at saturation: `cores` replicas
/// of a single-core plan complete a batch every `service / cores`
/// seconds, while the sharded pipeline completes one every
/// `interval`. Shard iff strictly ahead — ties keep replicas, which
/// ship no inter-core bytes.
pub fn choose_placement(
    service_seconds: f64,
    sharded_interval_seconds: f64,
    cores: usize,
) -> Placement {
    let cores = cores.max(1);
    if sharded_interval_seconds < service_seconds / cores as f64 {
        Placement::Sharded
    } else {
        Placement::Replicas(cores)
    }
}

/// [`run_load`] generalized to `workers` engines and a pipelined
/// service model: a flush starts on the earliest-free engine, holds it
/// for the bucket's `interval_seconds`, and completes its requests
/// after the bucket's `service_seconds`. With `workers = 1` and
/// `interval == service` per bucket this reproduces [`run_load`]
/// exactly (asserted in the unit tests); `run_load`'s own event loop
/// is left untouched because committed baselines replay it bit-exactly.
pub fn run_load_pipelined(
    buckets: &[PipelinedBucket],
    workers: usize,
    cfg: &LoadSimConfig,
    label: &str,
) -> LoadReport {
    assert!(!buckets.is_empty(), "load sim needs at least one bucket");
    assert!(workers >= 1, "load sim needs at least one worker");
    let costs: Vec<BucketCost> = buckets.iter().map(|b| b.cost).collect();
    let interval_of = |batch: usize| -> f64 {
        buckets
            .iter()
            .find(|b| b.cost.batch == batch)
            .expect("bucket chosen from this table")
            .interval_seconds
    };
    let max_bucket = costs.iter().map(|c| c.batch).max().unwrap_or(1).max(1);
    let max_wait_ns = cfg.max_wait.as_nanos() as u64;

    let mut arrivals: BinaryHeap<Reverse<u64>> = BinaryHeap::new();
    let (total_requests, mut issued) = match cfg.arrivals {
        Arrivals::Poisson { rate_qps, requests, seed } => {
            assert!(rate_qps > 0.0, "Poisson rate must be positive");
            let mut rng = SplitMix64::new(seed);
            let mut t = 0.0f64;
            for _ in 0..requests {
                let u = rng.next_f64().max(1e-12);
                t += -u.ln() / rate_qps;
                arrivals.push(Reverse((t * NS) as u64));
            }
            (requests, requests)
        }
        Arrivals::Closed { clients, requests } => {
            let initial = if clients < 1 { 1 } else { clients }.min(requests);
            for _ in 0..initial {
                arrivals.push(Reverse(0));
            }
            (requests, initial)
        }
    };
    let closed = matches!(cfg.arrivals, Arrivals::Closed { .. });

    let mut queue: VecDeque<u64> = VecDeque::new();
    // when each engine can admit its next flush (ns)
    let mut free = vec![0u64; workers];
    let mut now = 0u64;
    let mut submitted = 0u64;
    let mut completed = 0u64;
    let mut rejected = 0u64;
    let mut batches = 0u64;
    let mut offchip: i64 = 0;
    let mut batch_size_sum = 0u64;
    let mut last_completion = 0u64;
    let mut latency_us = LogHistogram::new();
    let mut flushes_by_bucket: BTreeMap<usize, u64> = BTreeMap::new();
    let (mut slo_met, mut slo_missed) = (0u64, 0u64);
    let objective_ns = cfg.slo.map(|s| s.latency.as_nanos() as u64);

    loop {
        while let Some(&Reverse(t)) = arrivals.peek() {
            if t > now {
                break;
            }
            arrivals.pop();
            submitted += 1;
            if queue.len() < cfg.queue_cap {
                queue.push_back(t);
            } else {
                rejected += 1;
                if objective_ns.is_some() {
                    slo_missed += 1;
                }
            }
        }
        let Some(&oldest) = queue.front() else {
            match arrivals.peek() {
                Some(&Reverse(t)) => {
                    now = t;
                    continue;
                }
                None => break,
            }
        };
        let deadline = oldest + max_wait_ns;
        if queue.len() < max_bucket && now < deadline {
            let next_arrival = arrivals.peek().map(|&Reverse(t)| t).unwrap_or(u64::MAX);
            now = deadline.min(next_arrival);
            continue;
        }
        // the batch is due: wait for the earliest-free engine, then
        // admit the flush there
        let (worker, &free_at) =
            free.iter().enumerate().min_by_key(|&(i, &t)| (t, i)).expect("workers >= 1");
        if free_at > now {
            now = free_at;
            continue;
        }
        let (take, bucket) =
            choose_bucket(queue.len(), &costs).expect("non-empty queue and table");
        let done = now + (bucket.service_seconds * NS) as u64;
        free[worker] = now + (interval_of(bucket.batch) * NS) as u64;
        for _ in 0..take {
            let enq = queue.pop_front().expect("take <= queue.len()");
            let lat_ns = done - enq;
            latency_us.record(lat_ns / 1_000);
            if let Some(obj) = objective_ns {
                if lat_ns <= obj {
                    slo_met += 1;
                } else {
                    slo_missed += 1;
                }
            }
            completed += 1;
            if closed && issued < total_requests {
                arrivals.push(Reverse(done));
                issued += 1;
            }
        }
        batches += 1;
        batch_size_sum += take as u64;
        *flushes_by_bucket.entry(bucket.batch).or_insert(0) += 1;
        offchip += bucket.offchip_bytes;
        last_completion = last_completion.max(done);
    }

    let makespan = (last_completion as f64 / NS).max(1e-12);
    let mut bucket_sizes: Vec<usize> = costs.iter().map(|c| c.batch).collect();
    bucket_sizes.sort_unstable();
    LoadReport {
        label: label.to_string(),
        buckets: bucket_sizes,
        submitted,
        completed,
        rejected,
        batches,
        makespan_seconds: makespan,
        qps: completed as f64 / makespan,
        latency_us,
        offchip_bytes: offchip,
        bytes_per_request: if completed > 0 {
            offchip as f64 / completed as f64
        } else {
            0.0
        },
        mean_batch: if batches > 0 {
            batch_size_sum as f64 / batches as f64
        } else {
            0.0
        },
        flushes_by_bucket,
        slo: cfg.slo.map(|spec| {
            let eligible = slo_met + slo_missed;
            let attainment = if eligible > 0 {
                slo_met as f64 / eligible as f64
            } else {
                1.0
            };
            let miss_rate = 1.0 - attainment;
            SloReport {
                objective_us: spec.latency.as_micros() as u64,
                target: spec.target,
                met: slo_met,
                missed: slo_missed,
                attainment,
                error_budget_burn: miss_rate / (1.0 - spec.target).max(1e-9),
            }
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // synthetic model shaped like the real artifacts: off-chip bytes =
    // weights + batch × activations, service time ∝ bytes / bandwidth
    fn table(buckets: &[usize]) -> Vec<BucketCost> {
        const WEIGHTS: i64 = 8_000_000;
        const ACT: i64 = 500_000;
        buckets
            .iter()
            .map(|&b| {
                let bytes = WEIGHTS + ACT * b as i64;
                BucketCost {
                    batch: b,
                    offchip_bytes: bytes,
                    service_seconds: bytes as f64 / 50e9,
                }
            })
            .collect()
    }

    fn cfg(arrivals: Arrivals) -> LoadSimConfig {
        LoadSimConfig {
            arrivals,
            max_wait: Duration::from_micros(500),
            queue_cap: 64,
            slo: None,
        }
    }

    #[test]
    fn closed_loop_completes_everything() {
        let r = run_load(
            &table(&[1, 2, 4, 8]),
            &cfg(Arrivals::Closed { clients: 12, requests: 500 }),
            "closed",
        );
        assert_eq!(r.completed, 500);
        assert_eq!(r.submitted, 500);
        assert_eq!(r.rejected, 0);
        assert!(r.qps > 0.0);
        assert!(r.mean_batch >= 1.0);
        assert!(r.p50() <= r.p99());
    }

    #[test]
    fn poisson_conserves_requests() {
        let r = run_load(
            &table(&[1, 2, 4, 8]),
            &LoadSimConfig {
                // offered load above the bucket-8 service capacity
                // (~33k qps): the tight queue must shed requests
                arrivals: Arrivals::Poisson { rate_qps: 60_000.0, requests: 2_000, seed: 7 },
                max_wait: Duration::from_micros(500),
                queue_cap: 8, // tight: force rejects
                slo: None,
            },
            "poisson",
        );
        assert_eq!(r.submitted, 2_000);
        assert_eq!(r.completed + r.rejected, 2_000);
        assert!(r.rejected > 0, "tight queue never rejected");
    }

    #[test]
    fn bucketized_beats_fixed_at_low_load() {
        // low offered load: deadline flushes run partial batches, which
        // the bucketized policy serves on small-batch plans instead of
        // paying the full batch-8 traffic
        let all = table(&[1, 2, 4, 8]);
        let fixed = vec![all[3]];
        let arrivals = Arrivals::Poisson { rate_qps: 3_000.0, requests: 2_000, seed: 42 };
        let bucketized = run_load(&all, &cfg(arrivals), "bucketized");
        let baseline = run_load(&fixed, &cfg(arrivals), "fixed8");
        assert_eq!(bucketized.submitted, baseline.submitted, "unequal offered load");
        assert!(
            bucketized.bytes_per_request < baseline.bytes_per_request,
            "bucketized {} >= fixed {}",
            bucketized.bytes_per_request,
            baseline.bytes_per_request
        );
    }

    #[test]
    fn runs_are_deterministic() {
        let t = table(&[1, 4, 8]);
        let arrivals = Arrivals::Poisson { rate_qps: 10_000.0, requests: 1_000, seed: 3 };
        let a = run_load(&t, &cfg(arrivals), "a");
        let b = run_load(&t, &cfg(arrivals), "b");
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.offchip_bytes, b.offchip_bytes);
        assert_eq!(a.latency_us.percentile(0.99), b.latency_us.percentile(0.99));
        assert_eq!(a.qps, b.qps);
    }

    #[test]
    fn single_bucket_is_the_fixed_policy() {
        let r = run_load(
            &table(&[8]),
            &cfg(Arrivals::Closed { clients: 16, requests: 400 }),
            "fixed",
        );
        // saturated closed loop with one bucket: every flush is a full 8
        assert_eq!(r.completed, 400);
        assert!((r.mean_batch - 8.0).abs() < 1e-9, "mean batch {}", r.mean_batch);
        assert_eq!(r.flushes_by_bucket.get(&8), Some(&50));
        assert_eq!(r.flushes_by_bucket.len(), 1);
    }

    #[test]
    fn slo_report_counts_and_burn_are_consistent() {
        // generous objective: everything meets it, burn is zero
        let mut c = cfg(Arrivals::Closed { clients: 4, requests: 200 });
        c.slo = Some(SloSpec { latency: Duration::from_secs(60), target: 0.99 });
        let r = run_load(&table(&[1, 2, 4, 8]), &c, "slo-loose");
        let slo = r.slo.expect("slo configured");
        assert_eq!(slo.met + slo.missed, 200);
        assert_eq!(slo.missed, 0);
        assert!((slo.attainment - 1.0).abs() < 1e-12);
        assert_eq!(slo.error_budget_burn, 0.0);

        // impossible objective: every completion (and any reject)
        // misses; burn saturates at miss_rate / (1 - target)
        let mut c = cfg(Arrivals::Closed { clients: 4, requests: 200 });
        c.slo = Some(SloSpec { latency: Duration::from_nanos(1), target: 0.99 });
        let r = run_load(&table(&[1, 2, 4, 8]), &c, "slo-tight");
        let slo = r.slo.expect("slo configured");
        assert_eq!(slo.met, 0);
        assert_eq!(slo.missed, 200);
        assert_eq!(slo.attainment, 0.0);
        assert!((slo.error_budget_burn - 1.0 / 0.01).abs() < 1e-6);
        // and the report serializes the section
        let txt = r.to_json().to_string_compact();
        assert!(txt.contains("\"slo\""), "missing slo in {txt}");
        assert!(txt.contains("\"error_budget_burn\""));
    }

    fn as_pipelined(costs: &[BucketCost]) -> Vec<PipelinedBucket> {
        costs
            .iter()
            .map(|&cost| PipelinedBucket { cost, interval_seconds: cost.service_seconds })
            .collect()
    }

    #[test]
    fn pipelined_one_worker_equals_run_load() {
        // workers = 1 and interval == service is exactly the single
        // engine run_load models — the generalization must not drift
        let t = table(&[1, 2, 4, 8]);
        let pt = as_pipelined(&t);
        for arrivals in [
            Arrivals::Closed { clients: 12, requests: 500 },
            Arrivals::Poisson { rate_qps: 60_000.0, requests: 2_000, seed: 7 },
            Arrivals::Poisson { rate_qps: 3_000.0, requests: 1_000, seed: 42 },
        ] {
            let mut c = cfg(arrivals);
            c.queue_cap = 8; // tight enough to exercise rejection
            c.slo = Some(SloSpec { latency: Duration::from_millis(1), target: 0.99 });
            let a = run_load(&t, &c, "base");
            let b = run_load_pipelined(&pt, 1, &c, "pipe");
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.rejected, b.rejected);
            assert_eq!(a.batches, b.batches);
            assert_eq!(a.offchip_bytes, b.offchip_bytes);
            assert_eq!(a.qps, b.qps, "qps drifted");
            assert_eq!(a.latency_us.percentile(0.99), b.latency_us.percentile(0.99));
            assert_eq!(a.flushes_by_bucket, b.flushes_by_bucket);
            let (sa, sb) = (a.slo.unwrap(), b.slo.unwrap());
            assert_eq!((sa.met, sa.missed), (sb.met, sb.missed));
        }
    }

    #[test]
    fn sharded_interval_raises_saturated_qps() {
        // a sharded pipeline admits a new batch every interval while
        // requests still wait the full service latency: at saturation
        // the closed loop must complete strictly more per second
        let t = table(&[8]);
        let single = as_pipelined(&t);
        let sharded: Vec<PipelinedBucket> = t
            .iter()
            .map(|&cost| PipelinedBucket { cost, interval_seconds: cost.service_seconds / 3.0 })
            .collect();
        let c = cfg(Arrivals::Closed { clients: 32, requests: 600 });
        let base = run_load_pipelined(&single, 1, &c, "single");
        let pipe = run_load_pipelined(&sharded, 1, &c, "sharded");
        assert_eq!(base.completed, pipe.completed, "unequal offered load");
        assert!(
            pipe.qps > base.qps,
            "sharded {} <= single {}",
            pipe.qps,
            base.qps
        );
        // and two independent workers also beat one
        let two = run_load_pipelined(&single, 2, &c, "replicas");
        assert!(two.qps > base.qps, "replicas {} <= single {}", two.qps, base.qps);
    }

    #[test]
    fn placement_rule_picks_the_faster_side() {
        // interval under service/cores: sharding wins
        assert_eq!(choose_placement(1.0, 0.2, 4), Placement::Sharded);
        // interval at or above service/cores: replicas win (ties too —
        // replicas ship no fabric bytes)
        assert_eq!(choose_placement(1.0, 0.25, 4), Placement::Replicas(4));
        assert_eq!(choose_placement(1.0, 0.4, 4), Placement::Replicas(4));
        // one core: a pipeline can't beat itself
        assert_eq!(choose_placement(1.0, 0.9, 1), Placement::Replicas(1));
    }

    #[test]
    fn traced_run_records_one_complete_chain_per_completion() {
        use crate::obs::FlightRecorder;
        let r = FlightRecorder::new(64 * 1024);
        let rep = run_load_traced(
            &table(&[1, 2, 4, 8]),
            &cfg(Arrivals::Closed { clients: 6, requests: 300 }),
            "traced",
            Some(&r),
        );
        assert_eq!(rep.completed, 300);
        let chains = r.chains();
        assert_eq!(chains.len(), 300, "one chain per completed request");
        assert!(chains.values().all(|c| c.is_complete()), "incomplete span chain");
        // tracing must not perturb the simulation itself
        let untraced = run_load(
            &table(&[1, 2, 4, 8]),
            &cfg(Arrivals::Closed { clients: 6, requests: 300 }),
            "untraced",
        );
        assert_eq!(rep.qps, untraced.qps);
        assert_eq!(rep.offchip_bytes, untraced.offchip_bytes);
    }
}
