//! AOT plan cache: compile once, serve forever.
//!
//! Production serving runs a small set of precompiled batch-size
//! *buckets* per model (static-shape accelerators cannot batch
//! dynamically), so the cache key is everything that determines a
//! compiled artifact: `(model, batch, AccelConfig, decision)`. Each
//! entry memoizes the optimized `(Program, MemoryPlan)` from the pass
//! pipeline — joint beam search (`opt`) or staged-greedy tiling — plus
//! the unified cost model's prediction for it.
//!
//! **Service-time contract:** the artifact's `service_seconds` is
//! `cost::evaluate(..).pipelined_seconds`, and compilation re-replays
//! the plan through `accel::simulate_pipelined` and insists the two
//! agree bit-exactly (the repo-wide calibration invariant). The
//! serving layer can therefore treat the cost model's numbers as the
//! ground-truth service model without re-simulating per request.

use crate::accel::{simulate_pipelined, AccelConfig};
use crate::alloc::MemoryPlan;
use crate::cost::{evaluate, CostBreakdown, DecisionVector};
use crate::ir::Program;
use crate::passes::{AllocStage, OptStage, PassManager, TileStage};
use crate::util::error::Result;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Everything that determines a compiled serving artifact.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: String,
    pub batch: i64,
    /// Accelerator fingerprint: every geometry/bandwidth field that
    /// changes compilation (`AccelConfig` itself is not `Eq`/`Hash`).
    pub accel: String,
    /// Requested decision configuration: `"joint"` for the beam
    /// search (the winner is recorded per-artifact), otherwise the
    /// staged-greedy baseline decision vector.
    pub decision: String,
}

impl PlanKey {
    pub fn describe(&self) -> String {
        format!(
            "{}@b{} on {} [{}]",
            self.model, self.batch, self.accel, self.decision
        )
    }
}

fn accel_fingerprint(cfg: &AccelConfig) -> String {
    format!(
        "{}:{}x{}B:pe{}x{}:v{}:clk{:e}:dram{:e}:copy{:e}",
        cfg.name,
        cfg.banks,
        cfg.bank_bytes,
        cfg.pe_rows,
        cfg.pe_cols,
        cfg.vector_lanes,
        cfg.clock_hz,
        cfg.dram_bps,
        cfg.onchip_copy_bps
    )
}

/// One compiled serving artifact: the optimized program and plan for a
/// single `(model, batch)` point, with the cost model's prediction for
/// it and the pipelined service time the planned backend replays.
#[derive(Clone, Debug)]
pub struct PlannedArtifact {
    pub key: PlanKey,
    pub program: Program,
    pub plan: MemoryPlan,
    /// Unified cost-model prediction for `(program, plan)`.
    pub cost: CostBreakdown,
    /// Seconds of one batch execution under the double-buffered
    /// pipeline replay. Equal to `cost.pipelined_seconds` — verified
    /// against `simulate_pipelined` at compile time.
    pub service_seconds: f64,
    /// What `simulate_pipelined` actually measured at compile time:
    /// seconds of one execution. Stored separately from the
    /// prediction so the serving drift auditor compares two
    /// independently produced numbers (they are `ensure!`d equal here,
    /// but a future backend that stops replaying the plan would
    /// diverge — and the audit would show it).
    pub replayed_seconds: f64,
    /// What `simulate_pipelined` actually measured: off-chip bytes of
    /// one execution.
    pub replayed_offchip_bytes: i64,
    /// The decision vector the artifact was realized with (the joint
    /// search's winner, or the staged-greedy baseline).
    pub decision: String,
    pub batch: i64,
    /// Flattened per-request input length (batch dim divided out).
    pub in_len: usize,
    /// Flattened per-request output length.
    pub out_len: usize,
    pub compile_seconds: f64,
}

impl PlannedArtifact {
    /// Predicted off-chip DRAM bytes amortized per request at full
    /// occupancy of this bucket.
    pub fn bytes_per_request(&self) -> f64 {
        self.cost.offchip_total() as f64 / self.batch as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.key.model.clone())),
            ("batch", Json::Int(self.batch)),
            ("accel", Json::Str(self.key.accel.clone())),
            ("requested_decision", Json::Str(self.key.decision.clone())),
            ("decision", Json::Str(self.decision.clone())),
            ("offchip_bytes", Json::Int(self.cost.offchip_total())),
            ("bytes_per_request", Json::Num(self.bytes_per_request())),
            ("service_seconds", Json::Num(self.service_seconds)),
            ("peak_scratchpad", Json::Int(self.cost.peak_scratchpad)),
            ("in_len", Json::Int(self.in_len as i64)),
            ("out_len", Json::Int(self.out_len as i64)),
            ("compile_seconds", Json::Num(self.compile_seconds)),
        ])
    }
}

/// How the cache compiles: which chip, and joint search vs staged
/// greedy.
#[derive(Clone, Debug)]
pub struct PlanCacheConfig {
    pub accel: AccelConfig,
    /// `true`: whole-model joint beam search (`opt` stage); `false`:
    /// staged-greedy tiling (`tile` stage). Both end in the alloc
    /// stage so every artifact carries a `MemoryPlan`.
    pub joint: bool,
    /// Inter-pass IR verification while compiling (slower; on for
    /// tests, typically off for bulk bucket compilation).
    pub verify: bool,
}

/// Memoizing AOT compiler for one model's batch-size buckets.
pub struct PlanCache {
    model: String,
    cfg: PlanCacheConfig,
    entries: HashMap<i64, Arc<PlannedArtifact>>,
    hits: usize,
    misses: usize,
}

impl PlanCache {
    pub fn new(model: impl Into<String>, cfg: PlanCacheConfig) -> PlanCache {
        PlanCache { model: model.into(), cfg, entries: HashMap::new(), hits: 0, misses: 0 }
    }

    /// The cache key a given batch size resolves to.
    pub fn key(&self, batch: i64) -> PlanKey {
        PlanKey {
            model: self.model.clone(),
            batch,
            accel: accel_fingerprint(&self.cfg.accel),
            decision: if self.cfg.joint {
                "joint".to_string()
            } else {
                DecisionVector::baseline().describe()
            },
        }
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch the artifact for `batch`, compiling and memoizing it on
    /// first use.
    pub fn get_or_compile(&mut self, batch: i64) -> Result<Arc<PlannedArtifact>> {
        if let Some(a) = self.entries.get(&batch) {
            self.hits += 1;
            return Ok(a.clone());
        }
        let art = Arc::new(self.compile(batch)?);
        self.misses += 1;
        self.entries.insert(batch, art.clone());
        Ok(art)
    }

    /// Compile (or fetch) every bucket, returned in the given order —
    /// the artifact set a `PlannedBackend` serves.
    pub fn compile_buckets(&mut self, buckets: &[i64]) -> Result<Vec<Arc<PlannedArtifact>>> {
        buckets.iter().map(|&b| self.get_or_compile(b)).collect()
    }

    fn compile(&self, batch: i64) -> Result<PlannedArtifact> {
        crate::ensure!(batch >= 1, "bucket batch must be >= 1, got {batch}");
        let t0 = Instant::now();
        let key = self.key(batch);
        let g = crate::models::by_name(&self.model, batch).ok_or_else(|| {
            crate::format_err!("plan cache: unknown model '{}'", self.model)
        })?;
        let total_in: i64 = g.inputs().iter().map(|&id| g.tensor(id).numel()).sum();
        let total_out: i64 = g.outputs().iter().map(|&id| g.tensor(id).numel()).sum();
        crate::ensure!(
            total_in % batch == 0 && total_out % batch == 0,
            "model '{}' does not scale with batch {batch} (in {total_in}, out {total_out})",
            self.model
        );
        let accel = self.cfg.accel.clone();
        let pm = PassManager {
            opt: self.cfg.joint.then(|| OptStage::for_accel(accel.clone())),
            tile: (!self.cfg.joint).then(|| TileStage::for_accel(accel.clone())),
            alloc: Some(AllocStage::for_accel(accel.clone())),
            verify: self.cfg.verify,
            ..PassManager::default()
        };
        let rep = pm
            .run(g)
            .map_err(|e| crate::format_err!("compiling {}: {e}", key.describe()))?;
        let decision = rep
            .opt
            .as_ref()
            .map(|s| s.decision.clone())
            .unwrap_or_else(|| DecisionVector::baseline().describe());
        let program = rep.program;
        let plan = rep.plan.expect("alloc stage always configured");
        let cost = evaluate(&program, &plan, &accel);
        // the service-time contract: the pipelined replay must agree
        // with the prediction the serving layer hands out
        let sim = simulate_pipelined(&program, &plan, &accel, None)
            .map_err(|e| crate::format_err!("replaying {}: {e}", key.describe()))?;
        crate::ensure!(
            sim.seconds == cost.pipelined_seconds
                && sim.offchip_total() == cost.offchip_total(),
            "calibration broken for {}: simulated {}s/{}B vs predicted {}s/{}B",
            key.describe(),
            sim.seconds,
            sim.offchip_total(),
            cost.pipelined_seconds,
            cost.offchip_total()
        );
        Ok(PlannedArtifact {
            key,
            program,
            plan,
            service_seconds: cost.pipelined_seconds,
            replayed_seconds: sim.seconds,
            replayed_offchip_bytes: sim.offchip_total(),
            cost,
            decision,
            batch,
            in_len: (total_in / batch) as usize,
            out_len: (total_out / batch) as usize,
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_an_error() {
        let mut c = PlanCache::new(
            "no-such-model",
            PlanCacheConfig { accel: AccelConfig::tiny(64 * 1024), joint: false, verify: true },
        );
        assert!(c.get_or_compile(1).is_err());
        assert_eq!(c.misses(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn keys_distinguish_batch_accel_and_mode() {
        let mk = |joint, accel| {
            PlanCache::new("mlp", PlanCacheConfig { accel, joint, verify: true })
        };
        let a = mk(false, AccelConfig::tiny(64 * 1024));
        let b = mk(true, AccelConfig::tiny(64 * 1024));
        let c = mk(false, AccelConfig::tiny(128 * 1024));
        assert_ne!(a.key(1), a.key(2));
        assert_ne!(a.key(1), b.key(1));
        assert_ne!(a.key(1), c.key(1));
        assert_eq!(a.key(4), a.key(4));
    }
}
